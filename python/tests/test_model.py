"""L2 model checks: shapes, causality, loss behaviour, the Pallas-linear
path vs the jnp path, and weight-container round-trips."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.Config(vocab=32, d_model=16, n_layers=2, n_heads=2, d_ff=24, max_seq=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_forward_shapes(params):
    toks = jnp.zeros((2, 8), jnp.int32)
    logits = M.forward(params, toks, CFG)
    assert logits.shape == (2, 8, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    a = jnp.array([[1, 2, 3, 4, 5]], jnp.int32)
    b = a.at[0, 4].set(9)
    la = M.forward(params, a, CFG)
    lb = M.forward(params, b, CFG)
    np.testing.assert_allclose(la[0, :4], lb[0, :4], atol=1e-5)
    assert not np.allclose(la[0, 4], lb[0, 4])


def test_pallas_linear_path_matches_jnp(params):
    toks = jnp.array([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    a = M.forward(params, toks, CFG, use_pallas=False)
    b = M.forward(params, toks, CFG, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_loss_near_uniform_at_init(params):
    toks = jnp.zeros((2, 16), jnp.int32)
    loss = float(M.loss_fn(params, toks, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 1.5


def test_loss_decreases_with_sgd(params):
    # a couple of gradient steps on a fixed batch must reduce loss
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, CFG.vocab, jnp.int32)
    p = params
    losses = []
    for _ in range(5):
        loss, grads = jax.value_and_grad(M.loss_fn)(p, toks, CFG)
        losses.append(float(loss))
        p = jax.tree_util.tree_map(lambda a, g: a - 0.5 * g, p, grads)
    assert losses[-1] < losses[0]


def test_weights_round_trip(params):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        M.save_weights(params, CFG, path)
        loaded, cfg = M.load_weights(path)
        assert (cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.max_seq) == (
            CFG.vocab, CFG.d_model, CFG.n_layers, CFG.n_heads, CFG.d_ff, CFG.max_seq,
        )
        assert cfg.rope_theta == pytest.approx(CFG.rope_theta)
        assert cfg.eps == pytest.approx(CFG.eps, rel=1e-6)
        np.testing.assert_array_equal(loaded["tok_embed"], params["tok_embed"])
        np.testing.assert_array_equal(
            loaded["layers"][1]["w_down"], params["layers"][1]["w_down"]
        )
        toks = jnp.array([[1, 2, 3]], jnp.int32)
        np.testing.assert_allclose(
            M.forward(loaded, toks, cfg), M.forward(params, toks, CFG), atol=1e-6
        )


def test_rope_interleaved_convention():
    # position 0 is identity; rotating [1, 0] by angle t gives [cos, sin]
    cos, sin = M.rope_tables(M.Config(d_model=8, n_heads=1), 4)
    x = jnp.zeros((1, 4, 1, 8)).at[..., 0].set(1.0)
    r = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(r[0, 0, 0], x[0, 0, 0], atol=1e-6)
    np.testing.assert_allclose(float(r[0, 1, 0, 0]), float(cos[1, 0]), rtol=1e-6)
    np.testing.assert_allclose(float(r[0, 1, 0, 1]), float(sin[1, 0]), rtol=1e-6)


def test_token_loader_reads_rust_format():
    import struct

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        toks = np.array([0, 1, 255, 17], dtype="<u2")
        with open(path, "wb") as f:
            f.write(b"CLAQTK01")
            f.write(struct.pack("<I", 256))
            f.write(struct.pack("<Q", len(toks)))
            f.write(toks.tobytes())
        loaded, vocab = M.load_tokens(path)
        assert vocab == 256
        np.testing.assert_array_equal(loaded, toks)
