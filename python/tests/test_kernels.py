"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes/seeds; every kernel must match its `ref.py`
oracle to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gptq_update import gptq_update
from compile.kernels.kmeans import kmeans_step
from compile.kernels.matmul import linear, matmul_t
from compile.kernels.quant_matmul import quant_matmul

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------- matmul ----


@given(
    m=st.integers(1, 150),
    k=st.integers(1, 96),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**16),
)
def test_matmul_t_matches_ref(m, k, n, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, n, k)
    got = matmul_t(x, w)
    want = ref.matmul_t_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_linear_broadcasts_leading_dims():
    x = rand(0, 2, 7, 16)
    w = rand(1, 5, 16)
    got = linear(x, w)
    assert got.shape == (2, 7, 5)
    np.testing.assert_allclose(got, x @ w.T, rtol=1e-5, atol=1e-5)


def test_matmul_block_boundary_shapes():
    # shapes straddling the 64-tile boundary
    for m, n in [(64, 64), (65, 63), (128, 1), (1, 128)]:
        x = rand(2, m, 32)
        w = rand(3, n, 32)
        np.testing.assert_allclose(matmul_t(x, w), x @ w.T, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- quant_matmul ----


@given(
    m=st.integers(1, 80),
    k=st.integers(1, 64),
    n=st.integers(1, 80),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**16),
)
def test_quant_matmul_matches_ref(m, k, n, bits, seed):
    L = 1 << bits
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    cb = jax.random.normal(k2, (k, L), jnp.float32)
    idx = jax.random.randint(k3, (n, k), 0, L, jnp.int32)
    got = quant_matmul(x, cb, idx)
    want = ref.quant_matmul_ref(x, cb, idx)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dequant_ref_gathers_per_column():
    # hand-checkable case
    cb = jnp.array([[0.0, 1.0], [10.0, 20.0]], jnp.float32)  # k=2, L=2
    idx = jnp.array([[1, 0], [0, 1]], jnp.int32)  # n=2, k=2
    w = ref.dequant_ref(cb, idx)
    np.testing.assert_array_equal(w, jnp.array([[1.0, 10.0], [0.0, 20.0]]))


def test_quant_matmul_equals_dense_matmul_of_dequant():
    x = rand(5, 33, 20)
    cb = rand(6, 20, 8)
    idx = jax.random.randint(jax.random.PRNGKey(7), (41, 20), 0, 8, jnp.int32)
    w = ref.dequant_ref(cb, idx)
    np.testing.assert_allclose(quant_matmul(x, cb, idx), x @ w.T, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- kmeans ----


@given(
    c=st.integers(1, 20),
    n=st.integers(2, 64),
    K=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_kmeans_step_matches_ref(c, n, K, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    v = jax.random.normal(k1, (c, n), jnp.float32)
    cent = jax.random.normal(k2, (c, K), jnp.float32)
    got_c, got_i = kmeans_step(v, cent)
    want_c, want_i = ref.kmeans_step_ref(v, cent)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i.ravel(), want_i, rtol=1e-4, atol=1e-4)


def test_kmeans_step_reduces_inertia():
    v = jax.random.normal(jax.random.PRNGKey(1), (6, 128), jnp.float32)
    cent = jax.random.normal(jax.random.PRNGKey(2), (6, 8), jnp.float32)
    prev = None
    for _ in range(5):
        cent, inertia = kmeans_step(v, cent)
        total = float(jnp.sum(inertia))
        if prev is not None:
            assert total <= prev + 1e-4, f"inertia increased {prev} -> {total}"
        prev = total


def test_kmeans_empty_cluster_keeps_centroid():
    v = jnp.array([[0.0, 0.1, 0.2, 0.3]], jnp.float32)
    cent = jnp.array([[0.15, 100.0]], jnp.float32)  # second centroid empty
    new, _ = kmeans_step(v, cent)
    assert float(new[0, 1]) == 100.0


# --------------------------------------------------------- gptq_update ----


@given(
    rows=st.integers(1, 150),
    cols=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_gptq_update_matches_ref(rows, cols, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (rows, cols), jnp.float32)
    e = jax.random.normal(k2, (rows,), jnp.float32)
    u = jax.random.normal(k3, (cols,), jnp.float32)
    got = gptq_update(w, e, u)
    want = ref.gptq_update_ref(w, e, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gptq_update_masked_columns_untouched():
    w = rand(9, 16, 8)
    e = rand(10, 16)
    u = jnp.zeros((8,), jnp.float32).at[5:].set(1.0)  # columns 0..4 masked
    got = gptq_update(w, e, u)
    np.testing.assert_array_equal(got[:, :5], w[:, :5])
    assert not np.allclose(got[:, 5:], w[:, 5:])
