"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the Rust
PJRT runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (behind the
`xla` crate) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts written (to --artifacts, default ../artifacts):
  model_l.hlo.txt / model_xl.hlo.txt
      logits graph. Inputs: tokens (1, max_seq) i32, then the weight
      tensors in CLAQWT01 file order (tok_embed, per layer [attn_norm, wq,
      wk, wv, wo, mlp_norm, w_gate, w_up, w_down], final_norm, lm_head).
      Output: 1-tuple of logits (1, max_seq, vocab) f32.
  quant_matmul.hlo.txt
      fused dequant-matmul kernel, inputs x (128,128) f32, codebooks
      (128,16) f32, indices (128,128) i32 -> 1-tuple (128,128) f32.
  kmeans_step.hlo.txt
      one Lloyd step, inputs values (128,128) f32, centroids (128,16) f32
      -> 1-tuple of (new_centroids (128,16), inertia (128,1)).

Runs ONCE at `make artifacts`.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.kmeans import kmeans_step
from compile.kernels.quant_matmul import quant_matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params):
    """CLAQWT01 tensor order (must match rust/src/model/io.rs)."""
    flat = [params["tok_embed"]]
    for l in params["layers"]:
        for name in ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"):
            flat.append(l[name])
    flat.append(params["final_norm"])
    flat.append(params["lm_head"])
    return flat


def unflatten_params(flat, cfg: M.Config):
    it = iter(flat)
    params = dict(tok_embed=next(it), layers=[])
    for _ in range(cfg.n_layers):
        params["layers"].append(
            dict(
                attn_norm=next(it),
                wq=next(it),
                wk=next(it),
                wv=next(it),
                wo=next(it),
                mlp_norm=next(it),
                w_gate=next(it),
                w_up=next(it),
                w_down=next(it),
            )
        )
    params["final_norm"] = next(it)
    params["lm_head"] = next(it)
    return params


def param_specs(cfg: M.Config):
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    flat = [spec(v, d)]
    for _ in range(cfg.n_layers):
        flat += [
            spec(d), spec(d, d), spec(d, d), spec(d, d), spec(d, d),
            spec(d), spec(f, d), spec(f, d), spec(d, f),
        ]
    flat += [spec(d), spec(v, d)]
    return flat


def lower_model(cfg: M.Config, use_pallas: bool):
    def fn(tokens, *flat):
        params = unflatten_params(list(flat), cfg)
        return (M.forward(params, tokens, cfg, use_pallas=use_pallas),)

    tok_spec = jax.ShapeDtypeStruct((1, cfg.max_seq), jnp.int32)
    return jax.jit(fn).lower(tok_spec, *param_specs(cfg))


def lower_quant_matmul(m=128, k=128, n=128, L=16):
    def fn(x, cb, idx):
        return (quant_matmul(x, cb, idx),)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, L), jnp.float32),
        jax.ShapeDtypeStruct((n, k), jnp.int32),
    )


def lower_kmeans(c=128, n=128, K=16):
    def fn(v, cent):
        return kmeans_step(v, cent)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((c, n), jnp.float32),
        jax.ShapeDtypeStruct((c, K), jnp.float32),
    )


def write(text: str, path: str):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)", flush=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--artifacts", default="../artifacts")
    p.add_argument("--skip-models", action="store_true", help="only lower the kernels")
    args = p.parse_args()
    art = args.artifacts
    os.makedirs(art, exist_ok=True)

    write(to_hlo_text(lower_quant_matmul()), os.path.join(art, "quant_matmul.hlo.txt"))
    write(to_hlo_text(lower_kmeans()), os.path.join(art, "kmeans_step.hlo.txt"))
    if not args.skip_models:
        # Pallas-linear graphs: the L1 kernel lowered into the same HLO.
        write(to_hlo_text(lower_model(M.TINY_L, use_pallas=True)), os.path.join(art, "model_l.hlo.txt"))
        write(to_hlo_text(lower_model(M.TINY_XL, use_pallas=True)), os.path.join(art, "model_xl.hlo.txt"))


if __name__ == "__main__":
    main()
