"""Build-time trainer: fits the tiny-L / tiny-XL models on the synthetic
corpora produced by `claq datagen`, writing CLAQWT01 weight containers and
loss-curve CSVs into `artifacts/`. Hand-rolled AdamW (no optax offline).

Runs ONCE at `make artifacts`; never on the request path.

Env knobs: CLAQ_TRAIN_STEPS (default 400), CLAQ_TRAIN_BATCH (default 8).
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree_util.tree_map(jnp.zeros_like, params), t=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state, lr, wd=0.01, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))

    def upd(p, m_, v_):
        step = lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, dict(m=m, v=v, t=t)


def batches(tokens: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([tokens[s : s + seq] for s in starts]).astype(np.int32)


def cosine_lr(step, total, base=3e-3, warmup=20):
    if step < warmup:
        return base * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return base * 0.5 * (1 + np.cos(np.pi * frac))


def train_one(name: str, cfg: M.Config, corpus_paths, out_path: str, steps: int, batch: int, art_dir: str):
    # Train on the concatenation of both corpora so held-out perplexity is
    # meaningful on each (mirrors an LLM pretrained on both test domains).
    parts = []
    for cp in corpus_paths:
        toks, vocab = M.load_tokens(cp)
        assert vocab == cfg.vocab
        parts.append(toks)
    tokens = np.concatenate(parts)
    rng = np.random.default_rng(0xC1A9)
    key = jax.random.PRNGKey(7)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lr):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, toks, cfg)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    gen = batches(tokens, batch, cfg.max_seq, rng)
    curve = []
    t0 = time.time()
    for step in range(steps):
        toks = jnp.asarray(next(gen))
        lr = jnp.asarray(cosine_lr(step, steps), jnp.float32)
        params, opt, loss = step_fn(params, opt, toks, lr)
        if step % 10 == 0 or step == steps - 1:
            l = float(loss)
            curve.append((step, l))
            print(f"[{name}] step {step:4d} loss {l:.4f} ({time.time()-t0:.0f}s)", flush=True)

    M.save_weights(params, cfg, out_path)
    with open(os.path.join(art_dir, f"loss_curve_{name}.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in curve:
            f.write(f"{s},{l:.6f}\n")
    print(f"[{name}] wrote {out_path} (final loss {curve[-1][1]:.4f})", flush=True)
    return curve[-1][1]


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--artifacts", default="../artifacts")
    p.add_argument("--models", default="l,xl")
    args = p.parse_args()
    art = args.artifacts
    steps = int(os.environ.get("CLAQ_TRAIN_STEPS", "400"))
    batch = int(os.environ.get("CLAQ_TRAIN_BATCH", "8"))

    corpora = [
        os.path.join(art, "corpus_c4_train.bin"),
        os.path.join(art, "corpus_wiki_train.bin"),
    ]
    for corpus in corpora:
        if not os.path.exists(corpus):
            print(f"missing {corpus}; run `claq datagen` first", file=sys.stderr)
            sys.exit(1)

    wanted = args.models.split(",")
    if "l" in wanted:
        train_one("l", M.TINY_L, corpora, os.path.join(art, "weights_l.bin"), steps, batch, art)
    if "xl" in wanted:
        train_one("xl", M.TINY_XL, corpora, os.path.join(art, "weights_xl.bin"), max(steps * 2 // 3, 50), batch, art)


if __name__ == "__main__":
    main()
