"""Layer-1 Pallas kernel: fused codebook-dequantize + matmul — the CLAQ
deployment kernel (the paper defers this to "customized CUDA kernels";
DESIGN.md §4 describes the TPU re-think).

Inputs:
  x:         (m, k) f32 activations
  codebooks: (k, L) f32 — per-input-feature (column) codebook, L = 2^bits
  indices:   (n, k) i32 — quantized weight plane for W (n = out features)
Output:
  y: (m, n) = x @ dequant(W).T

The dequant inside each tile uses the **one-hot MXU trick**: instead of a
scalar gather (slow on TPU vector units), build onehot(idx) ∈ {0,1}^(bn·k·L)
and contract it with the codebook plane — a (bn·k, L)×(L,) matmul per input
feature batch that maps onto the systolic array. The codebook tile
(k × L ≤ 128·16 f32 = 8 KiB) comfortably stays resident in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, cb_ref, idx_ref, o_ref):
    x = x_ref[...]          # (bm, k)
    cb = cb_ref[...]        # (k, L)
    idx = idx_ref[...]      # (bn, k)
    L = cb.shape[-1]
    onehot = jax.nn.one_hot(idx, L, dtype=x.dtype)          # (bn, k, L)
    w = jnp.einsum("nkl,kl->nk", onehot, cb)                # dequant via MXU
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def quant_matmul(x, codebooks, indices, block_m: int = 64, block_n: int = 64):
    """Fused dequant-matmul; see module docstring for layout."""
    m, k = x.shape
    n, k2 = indices.shape
    assert k == k2, (x.shape, indices.shape)
    assert codebooks.shape[0] == k
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, codebooks.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, codebooks, indices)
