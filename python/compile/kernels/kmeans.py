"""Layer-1 Pallas kernel: one batched Lloyd step of 1-D K-Means — the
compute hot-spot of CLAQ's codebook construction (§3.1), batched over the
columns of a weight matrix.

Inputs:
  values:    (c, n) f32 — c independent columns of n samples each.
  centroids: (c, K) f32 — current centroids per column.
Outputs:
  new_centroids: (c, K), inertia: (c, 1)

Grid tiles the column axis; each program handles a (bc, n) tile with its
(bc, K) centroids resident in VMEM. The assignment is computed as a dense
(bc, n, K) distance tensor (vector units), and the centroid update is the
one-hot contraction (MXU) — no scatter needed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(v_ref, c_ref, newc_ref, inertia_ref):
    v = v_ref[...]  # (bc, n)
    c = c_ref[...]  # (bc, K)
    d = jnp.abs(v[:, :, None] - c[:, None, :])  # (bc, n, K)
    assign = jnp.argmin(d, axis=-1)
    onehot = jax.nn.one_hot(assign, c.shape[-1], dtype=v.dtype)  # (bc, n, K)
    counts = onehot.sum(axis=1)
    sums = jnp.einsum("cnk,cn->ck", onehot, v)
    newc_ref[...] = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c)
    best = jnp.min(d, axis=-1)
    inertia_ref[...] = jnp.sum(best * best, axis=-1, keepdims=True)


def kmeans_step(values, centroids, block_c: int = 8):
    """One Lloyd step for a batch of independent 1-D K-Means problems."""
    c, n = values.shape
    c2, k = centroids.shape
    assert c == c2
    bc = min(block_c, c)
    grid = (pl.cdiv(c, bc),)
    return pl.pallas_call(
        _kmeans_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((c, k), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, n), lambda i: (i, 0)),
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ),
        interpret=True,
    )(values, centroids)
