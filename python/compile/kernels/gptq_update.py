"""Layer-1 Pallas kernel: the GPTQ OBS rank-1 error-propagation update,
the inner-loop hot-spot of the quantization engine:

    W[:, j+1:] -= err ⊗ U[j, j+1:]

expressed as a full-width rank-1 update with `urow` pre-masked to zero on
already-quantized columns (branch-free, TPU-friendly). Grid tiles rows;
each program streams a (br, cols) tile of W through VMEM, reads the shared
`urow` tile, and writes the updated tile back — a pure VPU (elementwise)
kernel whose roofline is HBM bandwidth.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(w_ref, e_ref, u_ref, o_ref):
    w = w_ref[...]      # (br, cols)
    e = e_ref[...]      # (br, 1)
    u = u_ref[...]      # (1, cols)
    o_ref[...] = w - e * u


def gptq_update(w, err, urow, block_r: int = 64):
    """W - err[:, None] * urow[None, :] (rank-1), tiled over rows."""
    rows, cols = w.shape
    assert err.shape == (rows,)
    assert urow.shape == (cols,)
    br = min(block_r, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        _update_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(w, err.reshape(rows, 1), urow.reshape(1, cols))
