"""Pure-jnp oracles for the Pallas kernels. Every kernel in this package
is checked against these references by `python/tests/` (hypothesis sweeps
over shapes); the references themselves are validated by hand-computable
cases in `tests/test_ref.py`.
"""

import jax.numpy as jnp


def matmul_t_ref(x, w):
    """y = x @ w.T"""
    return x @ w.T


def dequant_ref(codebooks, indices):
    """Reconstruct W[n, k] with per-input-feature codebooks.

    codebooks: (k, L) — codebook of input feature i is codebooks[i].
    indices:   (n, k) int32 in [0, L).
    """
    k = codebooks.shape[0]
    return codebooks[jnp.arange(k)[None, :], indices]


def quant_matmul_ref(x, codebooks, indices):
    """y[m, n] = x[m, k] @ dequant(W)[n, k].T"""
    w = dequant_ref(codebooks, indices)
    return x @ w.T


def kmeans_step_ref(values, centroids):
    """One Lloyd step over a batch of independent 1-D problems.

    values:    (c, n) — c columns of n samples.
    centroids: (c, K)
    Returns (new_centroids (c, K), inertia (c,)).
    Empty clusters keep their previous centroid.
    """
    import jax

    d = jnp.abs(values[:, :, None] - centroids[:, None, :])  # (c, n, K)
    assign = jnp.argmin(d, axis=-1)  # (c, n)
    onehot = jax.nn.one_hot(assign, centroids.shape[1], dtype=values.dtype)  # (c, n, K)
    counts = onehot.sum(axis=1)  # (c, K)
    sums = jnp.einsum("cnk,cn->ck", onehot, values)
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
    best = jnp.min(d, axis=-1)
    inertia = jnp.sum(best * best, axis=-1)
    return new, inertia


def gptq_update_ref(w, err, urow):
    """OBS rank-1 error propagation: W -= err ⊗ urow.

    w:    (rows, cols) working weights.
    err:  (rows,) scaled quantization residual of the just-quantized column.
    urow: (cols,) the inverse-Hessian Cholesky row, pre-masked so entries
          for already-quantized columns are zero.
    """
    return w - err[:, None] * urow[None, :]
