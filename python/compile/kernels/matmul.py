"""Layer-1 Pallas kernel: blocked matmul `y = x @ W.T` for the linear
layers of the inference graph.

TPU mapping (DESIGN.md §4): the grid tiles (rows(x) × rows(W)); each
program loads an x-tile and a W-tile into VMEM and accumulates the
contraction on the MXU. interpret=True is mandatory in this sandbox —
real-TPU lowering emits a Mosaic custom-call the CPU PJRT client cannot
execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    # x tile: (bm, k); w tile: (bn, k); out tile: (bm, bn)
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x,
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul_t(x, w, block_m: int = 64, block_n: int = 64):
    """y[m, n] = x[m, k] @ w[n, k].T via a Pallas grid over (m, n) tiles."""
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w)


def linear(x, w):
    """Apply `x @ w.T` over arbitrary leading dims of x."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    y = matmul_t(x.reshape(-1, k), w)
    return y.reshape(*lead, w.shape[0])
