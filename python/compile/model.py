"""Layer-2: the LLaMA-style transformer in JAX.

Exactly mirrors the Rust reference forward (`rust/src/model/forward.rs`):
RMSNorm -> interleaved-RoPE causal MHA -> SiLU-gated MLP, pre-norm
residuals. Linear weights are stored (out_features, in_features); a
projection computes ``y = x @ W.T``.

Two execution paths share the math:
  * ``forward(params, tokens)``             — pure jnp (training speed).
  * ``forward(params, tokens, use_pallas=True)`` — linear layers routed
    through the L1 Pallas matmul kernel (the AOT/inference graph). With
    interpret=True the kernel lowers to plain HLO, so the PJRT CPU client
    can run the result.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.matmul import linear as pallas_linear


@dataclass(frozen=True)
class Config:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 352
    max_seq: int = 128
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


TINY_L = Config()
TINY_XL = Config(d_model=192, n_layers=6, n_heads=6, d_ff=512)


def init_params(cfg: Config, key) -> dict:
    """Random init at 1/sqrt(fan_in) scale, with **induced outlier
    channels**: ~5% of the input-feature columns of every projection are
    scaled up 3-6x. Large pretrained LLMs develop exactly this structure
    (rare high-magnitude channels concentrated in few columns — the
    phenomenon CLAQ's Outlier Order exploits); at our build-time training
    scale it does not emerge on its own, so it is planted at init and
    survives the short training run. Documented in DESIGN.md §1.
    """
    keys = iter(jax.random.split(key, 64 + 64 * cfg.n_layers))

    def mat(rows, cols):
        w = jax.random.normal(next(keys), (rows, cols), jnp.float32) / jnp.sqrt(cols)
        # outlier channels: ~5% of columns scaled by 3..6
        mask = jax.random.uniform(next(keys), (cols,)) < 0.05
        factor = 3.0 + 3.0 * jax.random.uniform(next(keys), (cols,))
        scale = jnp.where(mask, factor, 1.0)
        return w * scale[None, :]

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            dict(
                attn_norm=jnp.ones((cfg.d_model,), jnp.float32),
                wq=mat(cfg.d_model, cfg.d_model),
                wk=mat(cfg.d_model, cfg.d_model),
                wv=mat(cfg.d_model, cfg.d_model),
                wo=mat(cfg.d_model, cfg.d_model),
                mlp_norm=jnp.ones((cfg.d_model,), jnp.float32),
                w_gate=mat(cfg.d_ff, cfg.d_model),
                w_up=mat(cfg.d_ff, cfg.d_model),
                w_down=mat(cfg.d_model, cfg.d_ff),
            )
        )
    return dict(
        tok_embed=mat(cfg.vocab, cfg.d_model),
        layers=layers,
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
        lm_head=mat(cfg.vocab, cfg.d_model),
    )


def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_tables(cfg: Config, seq: int):
    """cos/sin tables, (seq, head_dim//2)."""
    half = cfg.head_dim // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = 1.0 / (cfg.rope_theta ** (2.0 * i / cfg.head_dim))
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    ang = pos * freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, n_heads, head_dim), interleaved pairs (2i, 2i+1)."""
    a = x[..., 0::2]
    b = x[..., 1::2]
    # cos/sin: (seq, half) -> broadcast over heads
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    ra = a * c - b * s
    rb = a * s + b * c
    out = jnp.stack([ra, rb], axis=-1)  # (..., seq, heads, half, 2)
    return out.reshape(x.shape)


def _linear(x, w, use_pallas):
    if use_pallas:
        return pallas_linear(x, w)
    return x @ w.T


def forward(params, tokens, cfg: Config, use_pallas: bool = False):
    """tokens: (batch, seq) int32 -> logits (batch, seq, vocab)."""
    b, seq = tokens.shape
    x = params["tok_embed"][tokens]  # (b, seq, d)
    cos, sin = rope_tables(cfg, seq)
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    scale = 1.0 / jnp.sqrt(jnp.array(cfg.head_dim, jnp.float32))

    for layer in params["layers"]:
        h = rmsnorm(x, layer["attn_norm"], cfg.eps)
        q = _linear(h, layer["wq"], use_pallas).reshape(b, seq, cfg.n_heads, cfg.head_dim)
        k = _linear(h, layer["wk"], use_pallas).reshape(b, seq, cfg.n_heads, cfg.head_dim)
        v = _linear(h, layer["wv"], use_pallas).reshape(b, seq, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        # (b, heads, seq, seq)
        att = jnp.einsum("bthd,bshd->bhts", q, k) * scale
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        p = jax.nn.softmax(att, axis=-1)
        mixed = jnp.einsum("bhts,bshd->bthd", p, v).reshape(b, seq, cfg.d_model)
        x = x + _linear(mixed, layer["wo"], use_pallas)

        h = rmsnorm(x, layer["mlp_norm"], cfg.eps)
        g = _linear(h, layer["w_gate"], use_pallas)
        u = _linear(h, layer["w_up"], use_pallas)
        act = jax.nn.silu(g) * u
        x = x + _linear(act, layer["w_down"], use_pallas)

    x = rmsnorm(x, params["final_norm"], cfg.eps)
    return _linear(x, params["lm_head"], use_pallas)


def loss_fn(params, tokens, cfg: Config):
    """Mean next-token cross-entropy over (batch, seq)."""
    logits = forward(params, tokens, cfg)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32), axis=-1)
    return -jnp.mean(ll)


# ---------------------------------------------------------------- IO ----

WEIGHTS_MAGIC = b"CLAQWT01"


def save_weights(params, cfg: Config, path: str) -> None:
    """Write the CLAQWT01 container (see rust/src/model/io.rs)."""
    import numpy as np
    import struct

    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(
            struct.pack(
                "<6I2f",
                cfg.vocab,
                cfg.d_model,
                cfg.n_layers,
                cfg.n_heads,
                cfg.d_ff,
                cfg.max_seq,
                cfg.rope_theta,
                cfg.eps,
            )
        )

        def dump(a):
            f.write(np.asarray(a, dtype="<f4").tobytes())

        dump(params["tok_embed"])
        for l in params["layers"]:
            for name in ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"):
                dump(l[name])
        dump(params["final_norm"])
        dump(params["lm_head"])


def load_weights(path: str):
    """Read a CLAQWT01 container -> (params, Config)."""
    import numpy as np
    import struct

    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == WEIGHTS_MAGIC, f"bad magic {magic!r}"
        vocab, d, n_layers, n_heads, d_ff, max_seq = struct.unpack("<6I", f.read(24))
        rope_theta, eps = struct.unpack("<2f", f.read(8))
        cfg = Config(vocab, d, n_layers, n_heads, d_ff, max_seq, rope_theta, eps)

        def take(*shape):
            n = 1
            for s in shape:
                n *= s
            a = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(shape)
            return jnp.asarray(a)

        params = dict(tok_embed=take(vocab, d), layers=[], final_norm=None, lm_head=None)
        for _ in range(n_layers):
            params["layers"].append(
                dict(
                    attn_norm=take(d),
                    wq=take(d, d),
                    wk=take(d, d),
                    wv=take(d, d),
                    wo=take(d, d),
                    mlp_norm=take(d),
                    w_gate=take(d_ff, d),
                    w_up=take(d_ff, d),
                    w_down=take(d, d_ff),
                )
            )
        params["final_norm"] = take(d)
        params["lm_head"] = take(vocab, d)
        rest = f.read(1)
        assert rest == b"", "trailing bytes in weights file"
    return params, cfg


def load_tokens(path: str):
    """Read a CLAQTK01 token file (see rust/src/data/corpus.rs)."""
    import numpy as np
    import struct

    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == b"CLAQTK01", f"bad magic {magic!r}"
        (vocab,) = struct.unpack("<I", f.read(4))
        (n,) = struct.unpack("<Q", f.read(8))
        toks = np.frombuffer(f.read(2 * n), dtype="<u2")
        assert len(toks) == n
    return toks, vocab
