//! Adaptive-precision walkthrough: sweep the equivalent bit budget from
//! 2.0 to 3.0 and show how AP (Outlier Order) allocates it, versus the
//! magnitude-based mixed-precision comparator (the Table 3 mechanism).
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example adaptive_precision

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::coordinator::registry::artifacts_dir;
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::load_tokens;
use claq::eval::perplexity::perplexity;
use claq::model::io::load_model;
use claq::quant::config::{Method, DEFAULT_S};
use claq::quant::outliers::ColumnMetric;
use claq::quant::precision::BitPair;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let model = load_model(&dir.join("weights_l.bin"))
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let train = load_tokens(&dir.join("corpus_c4_train.bin"))?;
    let heldout = load_tokens(&dir.join("corpus_c4_heldout.bin"))?;
    let calib = sample_segments(
        &train,
        &CalibConfig { n_segments: 24, seq_len: model.config.max_seq, seed: 1 },
    );

    println!("AP budget sweep (2&4 candidates, S = {DEFAULT_S}):\n");
    println!("{:>7} {:>14} {:>14} {:>16}", "bits", "ppl AP", "ppl MP(mag)", "4-bit cols (AP)");
    for target in [2.0, 2.1, 2.2, 2.5, 2.8, 3.0] {
        let mut row = vec![format!("{target:>7.1}")];
        let mut promoted = 0usize;
        for metric in [ColumnMetric::OutlierRatio, ColumnMetric::Magnitude] {
            let method = if target == 2.0 {
                Method::Claq { bits: 2 }
            } else {
                Method::ClaqAp { pair: BitPair::new(4, 2), target_bits: target, metric, s: DEFAULT_S }
            };
            let (qm, _) = quantize_model(&model, &method, &calib, &PipelineOpts::default());
            if metric == ColumnMetric::OutlierRatio {
                promoted = qm
                    .matrices
                    .values()
                    .map(|m| m.columns.iter().filter(|c| c.bits == 4).count())
                    .sum();
            }
            let ppl = perplexity(&qm.to_dense(), &heldout, 24).ppl;
            row.push(format!("{ppl:>14.2}"));
        }
        row.push(format!("{promoted:>16}"));
        println!("{}", row.join(""));
    }
    println!("\nLower budget → bigger AP advantage: precision goes exactly to the");
    println!("columns the Outlier Order metric flags as quantization-sensitive.");
    Ok(())
}
