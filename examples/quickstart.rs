//! Quickstart: quantize one weight matrix with CLAQ and compare against
//! the RTN / GPTQ baselines — the paper's §3.1 claim in 60 seconds.
//!
//! Run: `cargo run --release --example quickstart`

use claq::quant::config::Method;
use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
use claq::quant::outliers::OutlierStats;
use claq::tensor::linalg::gram;
use claq::tensor::Matrix;
use claq::util::rng::Rng;

fn main() {
    // A synthetic weight matrix with the structure CLAQ exploits: mostly
    // small Gaussian weights plus a few outlier-heavy columns.
    let (rows, cols) = (256, 64);
    let mut rng = Rng::new(42);
    let mut w = Matrix::zeros(rows, cols);
    rng.fill_normal(&mut w.data, 0.02);
    for &c in &[5usize, 17, 40] {
        for r in 0..rows {
            if rng.next_f64() < 0.3 {
                *w.at_mut(r, c) *= 8.0;
            }
        }
    }

    // Calibration "activations" → Hessian H = 2·E[x xᵀ].
    let mut x = Matrix::zeros(512, cols);
    rng.fill_normal(&mut x.data, 1.0);
    let mut h = gram(&x, 0.0);
    for v in h.iter_mut() {
        *v *= 2.0;
    }

    // The Outlier Order metric (§3.2) finds the planted columns.
    let stats = OutlierStats::compute(&w, 5.0);
    let mut top = stats.top_columns(0.05);
    top.sort_unstable();
    println!("Outlier Order top-5% columns: {top:?} (planted: [5, 17, 40])");
    println!(
        "top-10% of columns hold {:.0}% of all outliers\n",
        stats.concentration(0.10) * 100.0
    );

    // Quantize at 3 bits with each method and compare weight error.
    println!("{:<28} {:>12} {:>14}", "method", "rel. error", "proxy loss");
    for (name, rule, propagate) in [
        ("RTN (uniform, no OBS)", CentroidRule::UniformMinMax, false),
        ("GPTQ (uniform + OBS)", CentroidRule::UniformMinMax, true),
        ("CLAQ (K-Means + OBS)", CentroidRule::KMeans, true),
    ] {
        let plan = MatrixPlan::uniform(cols, 3, rule, propagate);
        let hess = propagate.then_some(h.as_slice());
        let q = quantize_matrix(&w, hess, &plan);
        println!(
            "{:<28} {:>12.5} {:>14.5}",
            name, q.metrics.rel_frobenius_err, q.metrics.proxy_loss
        );
    }

    // The fusion preset (AP + OR) at ~2.12 equivalent bits.
    let method = Method::fusion_2_12();
    let plan = method.plan_for(&w, None).unwrap();
    let q = quantize_matrix(&w, Some(&h), &plan);
    println!(
        "\nCLAQ*-2.12 fusion: rel. error {:.5} at {:.3} equivalent bits ({} FP16 outliers kept)",
        q.metrics.rel_frobenius_err,
        q.equivalent_bits_paper(),
        q.outliers.len()
    );
    let bits4 = plan.bits.iter().filter(|&&b| b == 4).count();
    println!("adaptive precision promoted {bits4}/{cols} columns to 4-bit");
}
