//! End-to-end driver: load the build-time-trained transformer, quantize it
//! with the paper's methods, and evaluate perplexity — through BOTH the
//! pure-Rust forward and the AOT JAX/Pallas graph on PJRT, proving all
//! three layers compose. Results are recorded in `artifacts/runs.csv`.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example quantize_model

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::coordinator::registry::artifacts_dir;
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::load_tokens;
use claq::eval::perplexity::perplexity;
use claq::model::io::load_model;
use claq::quant::config::Method;
use claq::runtime::executor::ModelExecutor;
use claq::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let model = load_model(&dir.join("weights_l.bin")).map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first to train the model")
    })?;
    println!(
        "loaded tiny-L: {} params ({} quantizable)",
        model.config.n_params(),
        model.quantizable_params()
    );

    let train = load_tokens(&dir.join("corpus_c4_train.bin"))?;
    let heldout = load_tokens(&dir.join("corpus_c4_heldout.bin"))?;
    let calib = sample_segments(
        &train,
        &CalibConfig { n_segments: 32, seq_len: model.config.max_seq, seed: 0xCA11B },
    );

    // PJRT runtime over the AOT-lowered JAX+Pallas graph.
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let windows = 40;

    println!(
        "\n{:<14} {:>8} {:>12} {:>12} {:>10} {:>9}",
        "method", "eq.bits", "ppl (rust)", "ppl (pjrt)", "quant s", "MB"
    );
    for method in [
        Method::Fp16,
        Method::Claq { bits: 4 },
        Method::Claq { bits: 3 },
        Method::Claq { bits: 2 },
        Method::fusion_2_12(),
    ] {
        let t0 = Instant::now();
        let (qm, _) = quantize_model(&model, &method, &calib, &PipelineOpts::default());
        let quant_s = t0.elapsed().as_secs_f64();
        let dense = qm.to_dense();
        let rep = qm.size_report();

        // L3 evaluation path (pure Rust)
        let ppl_rust = perplexity(&dense, &heldout, windows).ppl;

        // L2/L1 evaluation path (PJRT executing the lowered JAX+Pallas HLO)
        let mut exec = ModelExecutor::new(dir.join("model_l.hlo.txt"), &dense)?;
        let ppl_pjrt = exec.perplexity(&mut rt, &heldout, windows)?;

        let bits = if qm.matrices.is_empty() { 16.0 } else { rep.paper_equivalent_bits };
        let mb = if qm.matrices.is_empty() {
            model.quantizable_params() as f64 * 2.0 / 1e6 // fp16 deployment
        } else {
            rep.container_bytes as f64 / 1e6
        };
        println!(
            "{:<14} {:>8.2} {:>12.3} {:>12.3} {:>10.2} {:>9.3}",
            method.name(),
            bits,
            ppl_rust,
            ppl_pjrt,
            quant_s,
            mb
        );
        assert!(
            (ppl_rust / ppl_pjrt - 1.0).abs() < 0.02,
            "Rust and PJRT evaluation disagree"
        );
    }
    println!("\nRust-forward and PJRT(JAX/Pallas) perplexities agree — all layers compose.");
    Ok(())
}
