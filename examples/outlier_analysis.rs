//! Outlier-distribution analysis of the trained model — the Appendix A
//! evidence (Figures 3–5) as a runnable walkthrough: per-column ratios,
//! concentration, per-layer profile, and the S-threshold trade-off.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example outlier_analysis

use claq::coordinator::registry::artifacts_dir;
use claq::model::io::load_model;
use claq::model::{MatrixId, MatrixKind};
use claq::quant::outliers::OutlierStats;

fn spark(ratios: &[f64], buckets: usize) -> String {
    // coarse text sparkline of the sorted ratios
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
    let max = ratios.iter().cloned().fold(0.0, f64::max).max(1e-12);
    let mut out = String::new();
    for b in 0..buckets {
        let i = b * ratios.len() / buckets;
        let level = ((ratios[i] / max) * (glyphs.len() - 1) as f64).round() as usize;
        out.push(glyphs[level.min(glyphs.len() - 1)]);
    }
    out
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let model = load_model(&dir.join("weights_l.bin"))
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;

    // Figure 3: sorted column outlier ratios of layer-0 wo.
    let w = model.matrix(MatrixId { layer: 0, kind: MatrixKind::Wo });
    for s in [3.0, 5.0, 7.0] {
        let st = OutlierStats::compute(w, s);
        let mut sorted = st.ratios.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        println!(
            "layers.0.wo  S={s:<4} outliers={:<6} top10% hold {:>5.1}%   [{}]",
            st.total_outliers,
            st.concentration(0.10) * 100.0,
            spark(&sorted, 48),
        );
    }

    // Figure 5: per-layer overall ratio.
    println!("\nper-layer overall outlier ratio (S=5):");
    for layer in 0..model.config.n_layers {
        let mut total = 0.0;
        for kind in MatrixKind::ALL {
            total += OutlierStats::compute(model.matrix(MatrixId { layer, kind }), 5.0).overall_ratio();
        }
        let avg = total / MatrixKind::ALL.len() as f64;
        let bar = "#".repeat((avg * 4000.0).min(60.0) as usize);
        println!("  layer {layer}: {avg:.5} {bar}");
    }

    // Figure 4: where do the top columns sit?
    let st = OutlierStats::compute(w, 5.0);
    let mut top = st.top_columns(0.10);
    top.sort_unstable();
    println!("\nlayers.0.wo top-10% outlier columns (positions): {top:?}");
    println!("(spread across the matrix with no periodic pattern — Figure 4)");
    Ok(())
}
