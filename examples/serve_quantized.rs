//! Serving driver on the packed-execution backend: quantize once, then run
//! a batched, KV-cached generation loop **directly off the CLAQ planes** —
//! prefill each request once, decode token by token in batches — and
//! compare against the dense-dequantized backend. This is the deployment
//! story the paper defers to future CUDA kernels, exercised end to end on
//! this stack: the packed path never materializes a dense weight matrix.
//!
//! Run:
//!   cargo run --release --example serve_quantized [n_requests] [gen_tokens] [batch]
//!
//! Uses trained weights from `artifacts/` when present (`make artifacts`),
//! otherwise a random tiny-L model (throughput numbers are equally valid).

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::coordinator::registry::artifacts_dir;
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, load_tokens, CorpusKind};
use claq::model::exec::{argmax, decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::io::load_model;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::util::rng::Rng;
use std::time::Instant;

struct ServeReport {
    prefill_ms: Vec<f64>,
    step_ms: Vec<f64>,
    generated: usize,
    wall_s: f64,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

/// Serve `prompts`: prefill each request, then greedy-decode `gen_tokens`
/// continuation tokens, advancing requests in fixed batches of `batch`
/// through the shared `decode_step`. Returns latency/throughput stats and
/// the generated token streams.
fn serve(
    model: &ExecModel,
    prompts: &[Vec<u16>],
    gen_tokens: usize,
    batch: usize,
) -> (ServeReport, Vec<Vec<u16>>) {
    let cfg = &model.config;
    let n = prompts.len();
    let mut state = ExecState::new(*cfg);
    let mut caches: Vec<KvCache> = Vec::with_capacity(n);
    let mut generated: Vec<Vec<u16>> = vec![Vec::with_capacity(gen_tokens); n];
    let mut prefill_ms = Vec::with_capacity(n);
    let mut step_ms = Vec::new();
    let wall = Instant::now();

    // Prefill: one pass over each prompt, caching K/V.
    for (i, prompt) in prompts.iter().enumerate() {
        assert!(prompt.len() + gen_tokens <= cfg.max_seq, "request exceeds context");
        let mut cache = KvCache::new(cfg);
        let t = Instant::now();
        let logits = prefill(model, &mut cache, prompt, &mut state);
        prefill_ms.push(t.elapsed().as_secs_f64() * 1e3);
        generated[i].push(argmax(logits.row(prompt.len() - 1)));
        caches.push(cache);
    }

    // Decode: requests advance together in batches; each decode_step call
    // runs every projection once for the whole batch.
    for _ in 1..gen_tokens {
        let mut start = 0;
        while start < n {
            let end = (start + batch).min(n);
            let toks: Vec<u16> = (start..end).map(|i| *generated[i].last().unwrap()).collect();
            let t = Instant::now();
            let logits = decode_step(model, &mut caches[start..end], &toks, &mut state);
            step_ms.push(t.elapsed().as_secs_f64() * 1e3);
            for (b, i) in (start..end).enumerate() {
                generated[i].push(argmax(logits.row(b)));
            }
            start = end;
        }
    }

    let report = ServeReport {
        prefill_ms,
        step_ms,
        generated: n * gen_tokens,
        wall_s: wall.elapsed().as_secs_f64(),
    };
    (report, generated)
}

fn print_report(backend: &str, r: &ServeReport, batch: usize) {
    let mut steps = r.step_ms.clone();
    steps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut pre = r.prefill_ms.clone();
    pre.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\n[{backend}] {} tokens generated (decode batch {batch})", r.generated);
    println!("  prefill p50:     {:>9.3} ms", pct(&pre, 0.50));
    println!("  decode-step p50: {:>9.3} ms", pct(&steps, 0.50));
    println!("  decode-step p90: {:>9.3} ms", pct(&steps, 0.90));
    println!("  decode-step p99: {:>9.3} ms", pct(&steps, 0.99));
    println!("  decode tok/s:    {:>9.0}", r.generated as f64 / r.wall_s);
}

fn main() -> anyhow::Result<()> {
    let arg = |i: usize, default: usize| -> usize {
        std::env::args().nth(i).and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let n_requests = arg(1, 16).max(1);
    let gen_tokens = arg(2, 48).max(2); // ≥2 so the decode loop runs
    let batch = arg(3, 4).max(1);

    let dir = artifacts_dir();
    let model = match load_model(&dir.join("weights_l.bin")) {
        Ok(m) => m,
        Err(_) => {
            println!("(no trained artifacts — serving a random tiny-L model; run `make artifacts` for trained weights)");
            Model::random(TransformerConfig::tiny_l(), &mut Rng::new(17))
        }
    };
    let seq = model.config.max_seq;
    anyhow::ensure!(gen_tokens >= 1 && gen_tokens < seq, "gen_tokens must leave room for a prompt");
    let prompt_len = seq - gen_tokens;

    // Quantize once at CLAQ*-2.12 (the paper's headline config).
    let train = match load_tokens(&dir.join("corpus_c4_train.bin")) {
        Ok(t) => t,
        Err(_) => generate(CorpusKind::SynthC4, 16_384, 3),
    };
    let calib = sample_segments(&train, &CalibConfig { n_segments: 24, seq_len: seq, seed: 2 });
    let t0 = Instant::now();
    let (qm, _) = quantize_model(&model, &Method::fusion_2_12(), &calib, &PipelineOpts::default());
    let rep = qm.size_report();
    println!(
        "quantized to CLAQ*-2.12 in {:.1}s — container {:.2} MB ({:.2} bits/param, honest accounting)",
        t0.elapsed().as_secs_f64(),
        rep.container_bytes as f64 / 1e6,
        rep.container_bits_per_param
    );

    // Two execution backends over the same quantized model.
    let packed = qm.to_exec();
    let dense = ExecModel::dense(&qm.to_dense());
    println!(
        "projection weights resident: packed {:.2} MB vs dense {:.2} MB ({:.1}× smaller)",
        packed.projection_bytes() as f64 / 1e6,
        dense.projection_bytes() as f64 / 1e6,
        dense.projection_bytes() as f64 / packed.projection_bytes() as f64
    );

    // Request stream: random prompts; each request decodes gen_tokens.
    let prompts: Vec<Vec<u16>> = (0..n_requests)
        .map(|i| generate(CorpusKind::SynthC4, prompt_len, 1000 + i as u64))
        .collect();

    let (packed_rep, packed_out) = serve(&packed, &prompts, gen_tokens, batch);
    let (dense_rep, dense_out) = serve(&dense, &prompts, gen_tokens, batch);
    print_report(packed.backend, &packed_rep, batch);
    print_report(dense.backend, &dense_rep, batch);

    // The two backends decode the same quantized weights; greedy streams
    // should agree everywhere (up to float-tie rounding).
    let agree = packed_out
        .iter()
        .zip(&dense_out)
        .flat_map(|(a, b)| a.iter().zip(b))
        .filter(|(a, b)| a == b)
        .count();
    let total = n_requests * gen_tokens;
    println!(
        "\npacked/dense greedy agreement: {agree}/{total} tokens  |  packed speedup: {:.2}×",
        dense_rep.wall_s / packed_rep.wall_s
    );
    Ok(())
}
