//! Serving driver: load the quantized model and serve batched scoring
//! requests through the PJRT runtime, reporting latency percentiles and
//! throughput — the deployment story the paper defers to future CUDA
//! kernels, exercised end to end on this stack.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example serve_quantized [n_requests]

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::coordinator::registry::artifacts_dir;
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, load_tokens, CorpusKind};
use claq::model::io::load_model;
use claq::quant::config::Method;
use claq::runtime::executor::ModelExecutor;
use claq::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let dir = artifacts_dir();
    let model = load_model(&dir.join("weights_l.bin"))
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let seq = model.config.max_seq;

    // Quantize once at CLAQ*-2.12 (the paper's headline config).
    let train = load_tokens(&dir.join("corpus_c4_train.bin"))?;
    let calib = sample_segments(&train, &CalibConfig { n_segments: 24, seq_len: seq, seed: 2 });
    let t0 = Instant::now();
    let (qm, _) = quantize_model(&model, &Method::fusion_2_12(), &calib, &PipelineOpts::default());
    let dense = qm.to_dense();
    let rep = qm.size_report();
    println!(
        "quantized to CLAQ*-2.12 in {:.1}s — container {:.2} MB ({:.2} bits/param, honest accounting)",
        t0.elapsed().as_secs_f64(),
        rep.container_bytes as f64 / 1e6,
        rep.container_bits_per_param
    );

    // Request stream: random scoring jobs (seq tokens each).
    let requests: Vec<Vec<u16>> = (0..n_requests)
        .map(|i| generate(CorpusKind::SynthC4, seq, 1000 + i as u64))
        .collect();

    let mut rt = Runtime::cpu()?;
    let exec = ModelExecutor::new(dir.join("model_l.hlo.txt"), &dense)?;

    // Warm-up compile.
    let _ = exec.logits(&mut rt, &requests[0])?;

    let mut latencies_ms: Vec<f64> = Vec::with_capacity(n_requests);
    let serve_start = Instant::now();
    for req in &requests {
        let t = Instant::now();
        let logits = exec.logits(&mut rt, req)?;
        assert_eq!(logits.rows, seq);
        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let wall = serve_start.elapsed().as_secs_f64();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * p) as usize];
    println!("\nserved {n_requests} requests × {seq} tokens on PJRT ({})", rt.platform());
    println!("  p50 latency: {:>8.2} ms", pct(0.50));
    println!("  p90 latency: {:>8.2} ms", pct(0.90));
    println!("  p99 latency: {:>8.2} ms", pct(0.99));
    println!(
        "  throughput:  {:>8.0} tok/s",
        (n_requests * seq) as f64 / wall
    );
    Ok(())
}
