//! Open-loop load generator for the continuous-batching serving runtime:
//! quantize once, then fire Poisson-arrival requests at the
//! [`Scheduler`] running **directly off the CLAQ planes** and report
//! serving-grade metrics — time-to-first-token, per-token latency
//! percentiles, and aggregate tokens/s — for continuous batching vs. the
//! PR-1 lockstep (wave) baseline on the *same* engine and arrival trace.
//! Open-loop means arrivals do not wait for the server: queueing delay is
//! part of the measurement, as in real traffic.
//!
//! Run:
//!   cargo run --release --example serve_quantized \
//!       [n_requests] [arrival_rate_per_s] [max_slots] [seed] \
//!       [--checkpoint model.claq] [--save model.claq] \
//!       [--prefix-cache] [--prefix-cache-mb MB] [--shared-prefix N] \
//!       [--kv-page-tokens P] [--kv-quant-bits B] \
//!       [--kv-budget-mb M] [--max-queue Q] [--deadline-steps D]
//!
//! * `n_requests`        total requests in the trace        (default 32)
//! * `arrival_rate_per_s` mean Poisson arrival rate          (default 8.0)
//! * `max_slots`         live-batch bound of the scheduler  (default 8)
//! * `seed`              trace seed (prompts, lengths, gaps) (default 17)
//! * `--checkpoint PATH` cold-start from a CLAQMD01 checkpoint instead of
//!                       quantizing (quantize-once / serve-many; measures
//!                       load-to-ready latency). Make one with `--save` or
//!                       `claq pack`.
//! * `--save PATH`       after quantizing, write the checkpoint so later
//!                       runs can `--checkpoint` it.
//! * `--prefix-cache`    shared-system-prompt workload mode: every prompt
//!                       opens with the same system prefix, and the
//!                       continuous policy is replayed a second time with
//!                       the prefix-sharing KV cache enabled. The report
//!                       compares TTFT and prefill tokens per request and
//!                       checks both token streams agree exactly.
//! * `--prefix-cache-mb MB` byte budget for the prefix cache (default 64;
//!                       implies `--prefix-cache`).
//! * `--shared-prefix N` length of the shared system prefix (default 24
//!                       under `--prefix-cache`, else 0; `0` keeps fully
//!                       independent prompts).
//! * `--kv-page-tokens P` tokens per KV page (default 64). Purely a
//!                       memory-granularity knob: token streams are
//!                       bit-identical across page sizes.
//! * `--kv-quant-bits B` re-encode cold KV pages as B-bit k-means
//!                       codebooks (default 0 = off). **Lossy**: with the
//!                       prefix cache in play the cross-run agreement
//!                       check may drop below 100%, which the report
//!                       flags rather than asserts.
//! * `--kv-budget-mb M`  hard byte budget for f32 KV pages (default 0 =
//!                       unbounded). Under pressure the scheduler walks
//!                       its degradation ladder — prefix eviction, forced
//!                       cold-page quantization, preemption, rejection
//!                       (DESIGN.md §14) — and the report breaks requests
//!                       out per outcome.
//! * `--max-queue Q`     queue bound past which new submissions are shed
//!                       with `Rejected` (default 0 = unbounded).
//! * `--deadline-steps D` per-request step deadline; a request still
//!                       unfinished D engine steps after submission is
//!                       retired `DeadlineExceeded` (default 0 = none).
//!
//! Prompt lengths, generation budgets, and inter-arrival gaps are
//! randomized per request; every policy replays the identical trace, and
//! their token streams are checked to agree exactly (batch invariance;
//! with the prefix cache, bit-identical prefix reuse — DESIGN.md §10).
//! Uses trained weights from `artifacts/` when present (`make
//! artifacts`), otherwise a random tiny-L model (throughput numbers are
//! equally valid).

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::coordinator::registry::artifacts_dir;
use claq::data::calibration::default_calibration;
use claq::data::corpus::{generate, CorpusKind};
use claq::model::exec::{ExecModel, ExecState};
use claq::model::io::load_model;
use claq::model::linear::KernelKind;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::executor::ColdStart;
use claq::runtime::scheduler::{
    AdmissionPolicy, Completion, Request, Scheduler, SchedulerConfig,
};
use claq::util::rng::Rng;
use claq::util::threadpool::ThreadPool;
use std::path::PathBuf;
use std::time::Instant;

/// One request of the trace, with its arrival offset in seconds.
struct TracedRequest {
    at_s: f64,
    req: Request,
}

/// The three overload knobs, passed to every policy replay unchanged.
struct OverloadCfg {
    kv_budget_mb: usize,
    max_queue: usize,
    deadline_steps: u64,
}

/// Per-policy serving report over one trace replay.
struct ServeReport {
    policy: &'static str,
    wall_s: f64,
    generated: usize,
    /// TTFT of requests that finished `Length`/`Stop`.
    ttft_s: Vec<f64>,
    /// TTFT of admitted requests later shed (deadline/cancel) — rejected
    /// requests never produce a token, so they have no TTFT at all.
    ttft_shed_s: Vec<f64>,
    /// Per-outcome request counts.
    completed: u64,
    rejected: u64,
    deadline_exceeded: u64,
    preempted: u64,
    resumed: u64,
    /// Mean seconds per generated token of each request (excluding the
    /// prefill token; requests generating a single token contribute only
    /// to TTFT).
    tok_latency_s: Vec<f64>,
    pool_hit_rate: f64,
    pool_resident_mb: f64,
    peak_live: usize,
    /// Prompt tokens actually prefilled / served by prefix-page sharing.
    prefill_in: u64,
    prefill_saved: u64,
    prefix_hits: u64,
    prefix_lookups: u64,
    /// Distinct-page KV residency high-water mark (each shared page once).
    peak_kv_mb: f64,
    /// What `peak_live` contiguous full-context caches would have held.
    contiguous_kv_mb: f64,
    /// KV bytes prefix hits shared instead of memcpying.
    shared_saved_mb: f64,
    /// Pages re-encoded by cold-page quantization over the run.
    kv_pages_quantized: u64,
    /// id → generated tokens of *successfully finished* requests, for the
    /// cross-policy agreement check (shed requests carry partial or empty
    /// streams and are compared by count, not content).
    outputs: Vec<(u64, Vec<u16>)>,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

fn percentiles(mut xs: Vec<f64>) -> (f64, f64, f64) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (pct(&xs, 0.50), pct(&xs, 0.95), pct(&xs, 0.99))
}

/// Replay `trace` against a fresh scheduler under `policy`. The driver
/// owns the clock: requests are submitted once their arrival offset has
/// passed, the engine steps whenever it has work, and it sleeps only when
/// idle before the next arrival.
fn serve_trace(
    model: &ExecModel,
    trace: &[TracedRequest],
    max_slots: usize,
    policy: AdmissionPolicy,
    prefix_cache_bytes: usize,
    kv_page_tokens: usize,
    kv_quant_bits: u8,
    overload: &OverloadCfg,
    label: &'static str,
) -> ServeReport {
    let mut st = ExecState::new(model.config);
    let sched_cfg = SchedulerConfig::builder()
        .max_slots(max_slots)
        .prefill_token_budget(2 * model.config.max_seq)
        .policy(policy)
        .prefix_cache_bytes(prefix_cache_bytes)
        .kv_page_tokens(kv_page_tokens)
        .kv_quant_bits(kv_quant_bits)
        .kv_budget_bytes(overload.kv_budget_mb * (1 << 20))
        .max_queue(overload.max_queue)
        .deadline_steps(overload.deadline_steps)
        .build()
        .unwrap_or_else(|e| panic!("incoherent scheduler config: {e}"));
    let mut sched = Scheduler::new(model.config, sched_cfg);
    let mut arrival_by_id = vec![0.0f64; trace.len()];
    let mut completions: Vec<Completion> = Vec::new();
    let mut step_wall: Vec<f64> = Vec::new(); // engine step -> wall seconds
    let mut next = 0usize;
    let t0 = Instant::now();

    while next < trace.len() || sched.has_work() {
        let now = t0.elapsed().as_secs_f64();
        while next < trace.len() && trace[next].at_s <= now {
            let id = sched.submit(trace[next].req.clone()).expect("trace request valid");
            arrival_by_id[id as usize] = trace[next].at_s;
            next += 1;
        }
        if sched.has_work() {
            completions.extend(sched.step(model, &mut st));
            step_wall.push(t0.elapsed().as_secs_f64());
        } else {
            // idle: open-loop arrivals are in the future; sleep up to them
            let wait = trace[next].at_s - now;
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(0.005)));
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let mut ttft_s = Vec::with_capacity(completions.len());
    let mut ttft_shed_s = Vec::new();
    let mut tok_latency_s = Vec::new();
    let mut generated = 0usize;
    let mut outputs = Vec::with_capacity(completions.len());
    for c in &completions {
        generated += c.tokens.len();
        // A request shed before its first prefill (rejected, or a queued
        // deadline expiry) has admitted_step == 0: no engine step ever
        // touched it, so it has no TTFT and nothing indexes step_wall.
        if c.admitted_step == 0 {
            continue;
        }
        // step numbers are 1-based; step_wall[s-1] is when step s ended
        let first = step_wall[c.admitted_step as usize - 1];
        let last = step_wall[c.finished_step as usize - 1];
        let ttft = first - arrival_by_id[c.id as usize];
        if c.reason.is_success() {
            ttft_s.push(ttft);
            outputs.push((c.id, c.tokens.clone()));
        } else {
            ttft_shed_s.push(ttft);
        }
        if c.tokens.len() > 1 {
            tok_latency_s.push((last - first) / (c.tokens.len() - 1) as f64);
        }
    }
    outputs.sort_by_key(|(id, _)| *id);
    let stats = sched.stats();
    ServeReport {
        policy: label,
        wall_s,
        generated,
        ttft_s,
        ttft_shed_s,
        completed: stats.completed,
        rejected: stats.rejected,
        deadline_exceeded: stats.deadline_exceeded,
        preempted: stats.preempted,
        resumed: stats.resumed,
        tok_latency_s,
        pool_hit_rate: stats.pool_hit_rate,
        pool_resident_mb: stats.pool_resident_bytes as f64 / 1e6,
        peak_live: stats.peak_live,
        prefill_in: stats.prefill_tokens_in,
        prefill_saved: stats.prefill_tokens_saved,
        prefix_hits: stats.prefix_hits,
        prefix_lookups: stats.prefix_lookups,
        peak_kv_mb: stats.peak_kv_resident_bytes as f64 / 1e6,
        contiguous_kv_mb: (stats.peak_live
            * claq::model::exec::KvCache::contiguous_bytes(&model.config))
            as f64
            / 1e6,
        shared_saved_mb: stats.shared_kv_bytes_saved as f64 / 1e6,
        kv_pages_quantized: stats.kv_pages_quantized_total,
        outputs,
    }
}

fn print_report(r: &ServeReport) {
    let (t50, t95, t99) = percentiles(r.ttft_s.clone());
    let (l50, l95, l99) = percentiles(r.tok_latency_s.clone());
    println!(
        "\n[{}] {} tokens in {:.2}s  ->  {:.0} tok/s aggregate",
        r.policy,
        r.generated,
        r.wall_s,
        r.generated as f64 / r.wall_s
    );
    println!(
        "  ttft      p50/p95/p99: {:>7.1} / {:>7.1} / {:>7.1} ms  ({} completed)",
        t50 * 1e3,
        t95 * 1e3,
        t99 * 1e3,
        r.completed
    );
    if r.rejected + r.deadline_exceeded + r.preempted > 0 {
        println!(
            "  overload: {} rejected, {} deadline-exceeded, {} preemptions / {} resumes",
            r.rejected, r.deadline_exceeded, r.preempted, r.resumed
        );
        if !r.ttft_shed_s.is_empty() {
            let (s50, s95, s99) = percentiles(r.ttft_shed_s.clone());
            println!(
                "  ttft (shed after admission) p50/p95/p99: {:>7.1} / {:>7.1} / {:>7.1} ms",
                s50 * 1e3,
                s95 * 1e3,
                s99 * 1e3
            );
        }
    }
    println!(
        "  per-token p50/p95/p99: {:>7.2} / {:>7.2} / {:>7.2} ms",
        l50 * 1e3,
        l95 * 1e3,
        l99 * 1e3
    );
    println!(
        "  peak live batch: {}   kv-page-pool hit rate: {:.0}%   pooled: {:.2} MB",
        r.peak_live,
        r.pool_hit_rate * 100.0,
        r.pool_resident_mb
    );
    println!(
        "  kv pages: peak {:.2} MB resident vs {:.2} MB contiguous equivalent, \
         {} quantized, {:.2} MB copy saved by sharing",
        r.peak_kv_mb, r.contiguous_kv_mb, r.kv_pages_quantized, r.shared_saved_mb
    );
    if r.prefix_lookups > 0 {
        let n = r.outputs.len().max(1) as f64;
        println!(
            "  prefix cache: {} hits / {} lookups, {} prompt tokens saved \
             ({:.1}/req prefilled vs {:.1}/req saved)",
            r.prefix_hits,
            r.prefix_lookups,
            r.prefill_saved,
            r.prefill_in as f64 / n,
            r.prefill_saved as f64 / n
        );
    }
}

fn main() -> anyhow::Result<()> {
    // Flags are filtered out; the remaining positionals keep their
    // historical order.
    let mut checkpoint: Option<PathBuf> = None;
    let mut save: Option<PathBuf> = None;
    let mut prefix_cache = false;
    let mut prefix_cache_mb: f64 = 64.0;
    let mut shared_prefix: Option<usize> = None;
    let mut kv_page_tokens: usize = claq::model::exec::DEFAULT_PAGE_TOKENS;
    let mut kv_quant_bits: u8 = 0;
    let mut overload = OverloadCfg { kv_budget_mb: 0, max_queue: 0, deadline_steps: 0 };
    let mut pos: Vec<String> = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--checkpoint" => {
                checkpoint =
                    Some(it.next().expect("--checkpoint expects a path").into())
            }
            "--save" => save = Some(it.next().expect("--save expects a path").into()),
            "--prefix-cache" => prefix_cache = true,
            "--prefix-cache-mb" => {
                prefix_cache = true;
                prefix_cache_mb = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--prefix-cache-mb expects a number");
            }
            "--shared-prefix" => {
                shared_prefix = Some(
                    it.next()
                        .and_then(|v| v.parse().ok())
                        .expect("--shared-prefix expects a token count"),
                )
            }
            "--kv-page-tokens" => {
                kv_page_tokens = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--kv-page-tokens expects a token count");
            }
            "--kv-quant-bits" => {
                kv_quant_bits = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--kv-quant-bits expects 0..=8");
            }
            "--kv-budget-mb" => {
                overload.kv_budget_mb = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--kv-budget-mb expects a megabyte count (0 = unbounded)");
            }
            "--max-queue" => {
                overload.max_queue = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--max-queue expects a queue bound (0 = unbounded)");
            }
            "--deadline-steps" => {
                overload.deadline_steps = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--deadline-steps expects a step count (0 = none)");
            }
            _ => pos.push(a),
        }
    }
    anyhow::ensure!(
        !(checkpoint.is_some() && save.is_some()),
        "--save writes the artifact of a fresh quantization; it cannot be combined with \
         --checkpoint, which skips quantization entirely"
    );
    let arg = |i: usize| pos.get(i);
    let n_requests: usize = arg(0).and_then(|s| s.parse().ok()).unwrap_or(32).max(1);
    let rate: f64 = arg(1).and_then(|s| s.parse().ok()).unwrap_or(8.0).max(0.01);
    let max_slots: usize = arg(2).and_then(|s| s.parse().ok()).unwrap_or(8).max(1);
    let seed: u64 = arg(3).and_then(|s| s.parse().ok()).unwrap_or(17);
    // shared-system-prompt workload: defaults to a 24-token prefix when
    // the prefix cache is exercised, else fully independent prompts
    let shared_prefix = shared_prefix.unwrap_or(if prefix_cache { 24 } else { 0 });

    let packed = if let Some(path) = &checkpoint {
        // Quantize-once / serve-many: cold-start straight off the packed
        // planes — no calibration, no quantization, no dense weights.
        let cold = ColdStart::from_path(path)?;
        println!(
            "cold start: {} ({:.2} MB, method {}) -> packed ExecModel in {:.1} ms",
            path.display(),
            cold.checkpoint_bytes as f64 / 1e6,
            cold.method_name,
            cold.load_seconds * 1e3
        );
        cold.exec
    } else {
        let dir = artifacts_dir();
        let model = match load_model(&dir.join("weights_l.bin")) {
            Ok(m) => m,
            Err(_) => {
                println!("(no trained artifacts — serving a random tiny-L model; run `make artifacts` for trained weights)");
                Model::random(TransformerConfig::tiny_l(), &mut Rng::new(17))
            }
        };
        let seq = model.config.max_seq;

        // Quantize once at CLAQ*-2.12 (the paper's headline config), on
        // the shared calibration recipe (`data::calibration`).
        let calib = default_calibration(&dir, seq, 24);
        let t0 = Instant::now();
        let opts = PipelineOpts { save_checkpoint: save.clone(), ..PipelineOpts::default() };
        let (qm, stats) = quantize_model(&model, &Method::fusion_2_12(), &calib, &opts);
        let rep = qm.size_report();
        println!(
            "quantized to CLAQ*-2.12 in {:.1}s — container {:.2} MB ({:.2} bits/param, honest accounting)",
            t0.elapsed().as_secs_f64(),
            rep.container_bytes as f64 / 1e6,
            rep.container_bits_per_param
        );
        if let Some(path) = &save {
            match (stats.checkpoint_bytes, stats.checkpoint_error) {
                (Some(bytes), _) => println!(
                    "checkpoint: {} ({:.2} MB) — next time: --checkpoint {}",
                    path.display(),
                    bytes as f64 / 1e6,
                    path.display()
                ),
                (None, err) => anyhow::bail!(
                    "checkpoint save failed: {}",
                    err.unwrap_or_else(|| "unknown".into())
                ),
            }
            // Serve the deployed engine (f16 container codebooks, exactly
            // what the written artifact holds) so a later --checkpoint run
            // of the same trace is bit-identical to this one.
            qm.to_exec_deployed()?
        } else {
            qm.to_exec()
        }
    };
    let seq = packed.config.max_seq;
    anyhow::ensure!(seq >= 64, "serve example sizes its trace for max_seq >= 64 (got {seq})");
    anyhow::ensure!(
        packed.config.vocab >= claq::data::corpus::VOCAB,
        "trace prompts use the synthetic corpus vocab ({}); the model covers only {}",
        claq::data::corpus::VOCAB,
        packed.config.vocab
    );
    // longest prompt is shared_prefix + 48 tail tokens, and every request
    // needs ≥ 8 generation tokens of headroom inside the context window
    anyhow::ensure!(
        shared_prefix + 48 + 9 <= seq,
        "--shared-prefix {shared_prefix} leaves no room for tails in a {seq}-token context \
         (needs shared_prefix + 57 <= max_seq)"
    );
    // ExecState::new has row capacity max_seq; more slots could never decode
    let max_slots = max_slots.min(seq);
    println!(
        "packed projections resident: {:.2} MB — {} gather kernel sharded over {} threads",
        packed.projection_bytes() as f64 / 1e6,
        KernelKind::from_env().name(),
        ThreadPool::global().workers()
    );

    // Build the trace: Poisson arrivals, randomized prompt/generation
    // lengths, optionally opening with a shared system prefix (every
    // policy replays exactly this).
    let system = generate(CorpusKind::SynthC4, shared_prefix, 999);
    let mut rng = Rng::new(seed);
    let mut trace = Vec::with_capacity(n_requests);
    let mut at_s = 0.0f64;
    for i in 0..n_requests {
        at_s += -rng.next_f64().max(1e-12).ln() / rate; // Exp(rate) gap
        let tail_len = 16 + rng.below_usize(33); // 16..=48
        let prompt_len = shared_prefix + tail_len;
        let max_new = 8 + rng.below_usize((seq - prompt_len - 8).min(41)); // 8..≤48
        let mut prompt = system.clone();
        prompt.extend(generate(CorpusKind::SynthC4, tail_len, 1000 + i as u64));
        trace.push(TracedRequest {
            at_s,
            req: Request { prompt, max_new_tokens: max_new, stop_token: None },
        });
    }
    println!(
        "trace: {} requests, Poisson rate {:.1}/s, {} shared-prefix + 16–48 tail tokens, {} decode slots",
        n_requests, rate, shared_prefix, max_slots
    );

    let cont = serve_trace(
        &packed,
        &trace,
        max_slots,
        AdmissionPolicy::Continuous,
        0,
        kv_page_tokens,
        kv_quant_bits,
        &overload,
        "continuous",
    );
    let wave = serve_trace(
        &packed,
        &trace,
        max_slots,
        AdmissionPolicy::Wave,
        0,
        kv_page_tokens,
        kv_quant_bits,
        &overload,
        "lockstep-wave",
    );
    print_report(&cont);
    print_report(&wave);

    let budget = (prefix_cache_mb * 1e6) as usize;
    let cached = prefix_cache.then(|| {
        serve_trace(
            &packed,
            &trace,
            max_slots,
            AdmissionPolicy::Continuous,
            budget.max(1),
            kv_page_tokens,
            kv_quant_bits,
            &overload,
            "continuous+prefix-cache",
        )
    });
    if let Some(c) = &cached {
        print_report(c);
        let (cold50, _, _) = percentiles(cont.ttft_s.clone());
        let (warm50, _, _) = percentiles(c.ttft_s.clone());
        println!(
            "\nprefix cache vs cold continuous: ttft p50 {:.1} -> {:.1} ms ({:+.1}%), \
             prefill tokens/request {:.1} -> {:.1} ({} total saved)",
            cold50 * 1e3,
            warm50 * 1e3,
            (warm50 / cold50 - 1.0) * 100.0,
            cont.prefill_in as f64 / n_requests as f64,
            c.prefill_in as f64 / n_requests as f64,
            c.prefill_saved
        );
    }

    // Batch invariance across policies — and bit-identical prefix reuse
    // when the cache ran: identical token streams everywhere. With
    // --kv-quant-bits, sharing changes *which* pages are cold-quantized
    // (shared pages are skipped), so the cached run is tolerance-level
    // only and its agreement count may legitimately dip.
    if kv_quant_bits > 0 {
        println!(
            "\n(kv quantization at {kv_quant_bits} bits is lossy: agreement below is \
             informational, not a bit-identity check)"
        );
    }
    let mut runs: Vec<&ServeReport> = vec![&cont, &wave];
    if let Some(c) = &cached {
        runs.push(c);
    }
    // Under overload different policies may shed different requests, so
    // agreement is over the ids both runs finished successfully — a shed
    // request has no complete stream to compare.
    let by_id: std::collections::HashMap<u64, &Vec<u16>> =
        cont.outputs.iter().map(|(id, t)| (*id, t)).collect();
    for other in &runs[1..] {
        let mut common = 0usize;
        let mut agree = 0usize;
        for (id, tokens) in &other.outputs {
            if let Some(t) = by_id.get(id) {
                common += 1;
                if *t == tokens {
                    agree += 1;
                }
            }
        }
        println!(
            "continuous/{} token-stream agreement: {agree}/{common} requests \
             finished by both",
            other.policy
        );
    }
    println!(
        "continuous speedup over lockstep: {:.2}×",
        (cont.generated as f64 / cont.wall_s) / (wave.generated as f64 / wave.wall_s)
    );
    Ok(())
}
