//! Micro-benchmark harness (the `criterion` crate is unavailable offline).
//!
//! `cargo bench` targets are declared with `harness = false` and call
//! [`Bench::run`] per case: adaptive warm-up, fixed-duration measurement,
//! and robust statistics (median + MAD) printed in a criterion-like format.
//! Results are also appended to `target/claq-bench.csv` for the §Perf log,
//! and each group writes a machine-readable `BENCH_<group>.json` at the
//! repo root (name, ns/elem, elems/s per cell) so CI can track the perf
//! trajectory run over run. Scenario benches that time whole traces
//! (e.g. `bench_scheduler`) build [`Sample`]s by hand and land in the same
//! JSON via [`write_bench_json`].
//!
//! The second half of this module is the **bench-regression gate**
//! (`claq bench-check`, DESIGN.md §11): [`parse_bench_json`] reads a
//! `BENCH_<group>.json` back (hand-rolled reader — no serde offline) and
//! [`compare_bench`] diffs a fresh document against a committed baseline
//! with a relative tolerance, so CI fails when a tracked hot path
//! regresses beyond noise.

use std::hint::black_box as bb;
use std::io::Write;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Minimum wall time spent measuring each case.
    pub measure: Duration,
    /// Minimum wall time spent warming up each case.
    pub warmup: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { measure: Duration::from_millis(600), warmup: Duration::from_millis(150) }
    }
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<u64>,
    /// Extra numeric keys rendered verbatim into the cell's JSON —
    /// scenario benches use these for counters that don't fit the
    /// time/elems schema (e.g. prefill tokens per request). The gate
    /// ignores extras it doesn't know, but the throughput keys in
    /// [`GATED_RATE_EXTRAS`] are gated as floors when the baseline arms
    /// them.
    pub extra: Vec<(String, f64)>,
}

impl Sample {
    pub fn throughput(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / (self.median_ns * 1e-9))
    }
}

pub struct Bench {
    cfg: BenchConfig,
    samples: Vec<Sample>,
    group: String,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let cfg = if std::env::var("CLAQ_BENCH_FAST").is_ok() {
            BenchConfig { measure: Duration::from_millis(120), warmup: Duration::from_millis(30) }
        } else {
            BenchConfig::default()
        };
        println!("== bench group: {group} ==");
        Self { cfg, samples: Vec::new(), group: group.to_string() }
    }

    /// Measure `f`, which performs one logical iteration per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) {
        self.run_with_elems(name, None, f)
    }

    /// Measure `f`, reporting `elems` processed per iteration as throughput.
    pub fn run_with_elems<F: FnMut()>(&mut self, name: &str, elems: Option<u64>, mut f: F) {
        // Warm-up and iteration-count calibration.
        let mut iters_per_batch = 1u64;
        let warm_start = Instant::now();
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                bb(&mut f)();
            }
            let dt = t.elapsed();
            if warm_start.elapsed() >= self.cfg.warmup {
                // aim batches at ~1/20th of the measurement budget
                let target = self.cfg.measure.as_secs_f64() / 20.0;
                let per_iter = (dt.as_secs_f64() / iters_per_batch as f64).max(1e-9);
                iters_per_batch = ((target / per_iter).ceil() as u64).clamp(1, 1 << 24);
                break;
            }
            iters_per_batch = (iters_per_batch * 2).min(1 << 24);
        }

        // Measurement: collect batch timings until the budget is exhausted.
        let mut batch_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.cfg.measure || batch_ns.len() < 5 {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                bb(&mut f)();
            }
            let dt = t.elapsed();
            batch_ns.push(dt.as_nanos() as f64 / iters_per_batch as f64);
            total_iters += iters_per_batch;
            if batch_ns.len() > 10_000 {
                break;
            }
        }
        batch_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = batch_ns[batch_ns.len() / 2];
        let mean = batch_ns.iter().sum::<f64>() / batch_ns.len() as f64;
        let mut devs: Vec<f64> = batch_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let s = Sample {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mad_ns: mad,
            mean_ns: mean,
            elems,
            extra: Vec::new(),
        };
        let tp = s
            .throughput()
            .map(|t| format!("  ({:.2} Melem/s)", t / 1e6))
            .unwrap_or_default();
        println!(
            "{:<44} time: [{} ± {}]  iters: {}{}",
            name,
            fmt_ns(s.median_ns),
            fmt_ns(s.mad_ns),
            s.iters,
            tp
        );
        self.samples.push(s);
    }

    /// Attach an extra numeric key to the most recent sample (rendered
    /// verbatim into its JSON cell). The gate ignores extras it doesn't
    /// know — except the [`GATED_RATE_EXTRAS`] throughput keys, which a
    /// baseline may arm as floors — so new extras never break an old
    /// baseline.
    pub fn annotate(&mut self, key: &str, value: f64) {
        let s = self.samples.last_mut().expect("annotate before any sample");
        s.extra.push((key.to_string(), value));
    }

    /// Attach a throughput extra derived from the most recent sample's
    /// measured median: `units_per_iter / median_seconds`. This is how the
    /// kernel benches emit `bytes_decoded_per_s` and `tok_s` so the CI
    /// gate can track kernel throughput directly, not just wall time.
    pub fn annotate_rate(&mut self, key: &str, units_per_iter: f64) {
        let s = self.samples.last_mut().expect("annotate_rate before any sample");
        let rate = units_per_iter / (s.median_ns * 1e-9);
        s.extra.push((key.to_string(), rate));
    }

    /// Write accumulated samples to the CSV log and the tracked
    /// `BENCH_<group>.json` at the repo root.
    pub fn finish(self) {
        let rows: Vec<String> = self
            .samples
            .iter()
            .map(|s| {
                format!(
                    "{},{},{:.1},{:.1},{:.1},{}",
                    self.group, s.name, s.median_ns, s.mad_ns, s.mean_ns, s.iters
                )
            })
            .collect();
        append_csv(&rows);
        if let Err(e) = write_bench_json(&self.group, &self.samples) {
            eprintln!("warning: could not write BENCH_{}.json: {e}", self.group);
        }
    }
}

/// Write `BENCH_<group>.json` at the repo root from pre-built samples;
/// returns the path written. Scenario benches that measure whole serving
/// traces (not per-iteration closures) call this directly.
pub fn write_bench_json(group: &str, samples: &[Sample]) -> std::io::Result<std::path::PathBuf> {
    let path = bench_json_path(group);
    std::fs::write(&path, render_json(group, samples))?;
    Ok(path)
}

/// `BENCH_<group>.json` lives at the repo root: benches run with CWD =
/// `rust/` (the crate), so the root is the manifest's parent. Outside
/// cargo, fall back to the current directory.
fn bench_json_path(group: &str) -> std::path::PathBuf {
    let root = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(|d| std::path::PathBuf::from(d).join(".."))
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    root.join(format!("BENCH_{group}.json"))
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render the per-cell JSON document: median ns, iteration count, and —
/// for throughput cells — ns/elem and elems/s. Hand-rolled (no serde in
/// the offline sandbox); keys are stable so downstream diffing works.
fn render_json(group: &str, samples: &[Sample]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"group\": \"{}\",\n", json_escape(group)));
    out.push_str("  \"cells\": [\n");
    for (i, s) in samples.iter().enumerate() {
        let (ns_per_elem, elems_per_s) = match (s.elems, s.throughput()) {
            (Some(e), Some(t)) if e > 0 => {
                (format!("{:.4}", s.median_ns / e as f64), format!("{t:.1}"))
            }
            _ => ("null".to_string(), "null".to_string()),
        };
        let extra: String = s
            .extra
            .iter()
            .map(|(k, v)| format!(", \"{}\": {v:.4}", json_escape(k)))
            .collect();
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"mad_ns\": {:.1}, \"iters\": {}, \
             \"elems\": {}, \"ns_per_elem\": {}, \"elems_per_s\": {}{}}}{}\n",
            json_escape(&s.name),
            s.median_ns,
            s.mad_ns,
            s.iters,
            s.elems.map_or("null".to_string(), |e| e.to_string()),
            ns_per_elem,
            elems_per_s,
            extra,
            if i + 1 < samples.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Append pre-formatted rows (`group,name,median_ns,mad_ns,mean_ns,iters`)
/// to the shared bench log `target/claq-bench.csv`, creating it with the
/// header if absent. Scenario benches that time whole serving traces
/// rather than per-iteration closures (e.g. `bench_scheduler`) use this to
/// land in the same log as [`Bench::finish`].
pub fn append_csv(rows: &[String]) {
    let path = std::path::Path::new("target").join("claq-bench.csv");
    let exists = path.exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        if !exists {
            let _ = writeln!(f, "group,name,median_ns,mad_ns,mean_ns,iters");
        }
        for row in rows {
            let _ = writeln!(f, "{row}");
        }
    }
}

// ---------------------------------------------------------------------------
// Bench-regression gate: read BENCH_<group>.json back and diff against a
// committed baseline (the `claq bench-check` machinery).
// ---------------------------------------------------------------------------

/// One cell of a parsed `BENCH_<group>.json`. Unknown keys are collected
/// into `extras` (numbers only) rather than dropped, so baselines survive
/// schema additions and can arm throughput floors.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchCell {
    pub name: String,
    pub median_ns: f64,
    pub elems: Option<u64>,
    pub ns_per_elem: Option<f64>,
    /// Numeric keys outside the fixed schema (`tok_s`, counters, …).
    pub extras: Vec<(String, f64)>,
}

/// Throughput extras the gate treats as **floors** when a baseline cell
/// carries them with a positive value: the fresh run must emit the key,
/// and `fresh ≥ baseline / (1 + tol)`. Higher-is-better, mirroring the
/// lower-is-better `ns_per_elem` ceiling.
pub const GATED_RATE_EXTRAS: [&str; 2] = ["tok_s", "bytes_decoded_per_s"];

/// Cell keys that are part of the fixed schema, not extras.
const KNOWN_CELL_KEYS: [&str; 7] =
    ["name", "median_ns", "mad_ns", "iters", "elems", "ns_per_elem", "elems_per_s"];

#[derive(Clone, Debug, PartialEq)]
pub struct BenchDoc {
    pub group: String,
    pub cells: Vec<BenchCell>,
}

/// Minimal JSON value for the bench documents (no serde offline).
enum Json {
    Null,
    // payload kept for parser completeness; bench documents carry no bools
    #[allow(dead_code)]
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.pos)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or_else(|| self.err("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bytes.get(self.pos).copied().ok_or_else(|| self.err("unterminated string"))?
            {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc =
                        self.bytes.get(self.pos).copied().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        // \uXXXX and the rare escapes: the bench names this
                        // reader targets never contain them; keep the raw
                        // escape so comparisons still work byte-for-byte.
                        other => {
                            out.push('\\');
                            out.push(other as char);
                        }
                    }
                }
                _ => {
                    // multi-byte UTF-8 sequences pass through untouched
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            kvs.push((key, self.value()?));
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse a `BENCH_<group>.json` document (as written by [`write_bench_json`]
/// or hand-maintained under `ci/bench_baseline/`). Numeric fields may be
/// `null` or absent; baselines use that to leave a cell present but
/// unarmed.
pub fn parse_bench_json(text: &str) -> Result<BenchDoc, String> {
    let mut p = JsonParser { bytes: text.as_bytes(), pos: 0 };
    let root = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after document"));
    }
    let group = match root.get("group") {
        Some(Json::Str(s)) => s.clone(),
        _ => return Err("document has no string \"group\"".into()),
    };
    let cells_json = match root.get("cells") {
        Some(Json::Arr(a)) => a,
        _ => return Err("document has no \"cells\" array".into()),
    };
    let mut cells = Vec::with_capacity(cells_json.len());
    for (i, c) in cells_json.iter().enumerate() {
        let name = match c.get("name") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(format!("cell {i} has no string \"name\"")),
        };
        let extras = match c {
            Json::Obj(kvs) => kvs
                .iter()
                .filter(|(k, _)| !KNOWN_CELL_KEYS.contains(&k.as_str()))
                .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                .collect(),
            _ => Vec::new(),
        };
        cells.push(BenchCell {
            name,
            median_ns: c.get("median_ns").and_then(Json::as_f64).unwrap_or(0.0),
            elems: c.get("elems").and_then(Json::as_f64).map(|e| e as u64),
            ns_per_elem: c.get("ns_per_elem").and_then(Json::as_f64),
            extras,
        });
    }
    Ok(BenchDoc { group, cells })
}

/// Diff a freshly produced bench document against a committed baseline.
/// Returns human-readable violations (empty = gate passes):
///
/// * group mismatch, or a baseline cell missing from the fresh run
///   (structure regressions);
/// * `ns_per_elem` (preferred) or `median_ns` exceeding
///   `baseline × (1 + tol)` — a baseline metric of `null`/`0` leaves that
///   cell unarmed, which is how bootstrap baselines gate structure only;
/// * `elems` growth beyond the same tolerance on cells where `elems` is a
///   tracked size (e.g. the cold-start cells carry the checkpoint byte
///   size);
/// * a [`GATED_RATE_EXTRAS`] throughput key (`tok_s`,
///   `bytes_decoded_per_s`) falling below `baseline / (1 + tol)` — or
///   missing from the fresh cell — when the baseline arms it with a
///   positive value. Other extras stay informational.
///
/// Fresh-only cells and improvements are never violations.
pub fn compare_bench(baseline: &BenchDoc, fresh: &BenchDoc, tol: f64) -> Vec<String> {
    let mut violations = Vec::new();
    if baseline.group != fresh.group {
        violations.push(format!(
            "group mismatch: baseline '{}' vs fresh '{}'",
            baseline.group, fresh.group
        ));
        return violations;
    }
    for base in &baseline.cells {
        let Some(new) = fresh.cells.iter().find(|c| c.name == base.name) else {
            violations
                .push(format!("[{}] cell '{}' missing from fresh run", baseline.group, base.name));
            continue;
        };
        let limit = 1.0 + tol;
        match base.ns_per_elem {
            Some(b) if b > 0.0 => match new.ns_per_elem {
                Some(f) if f <= b * limit => {}
                Some(f) => violations.push(format!(
                    "[{}] '{}': ns_per_elem {f:.4} exceeds baseline {b:.4} by {:.1}% (tol {:.0}%)",
                    baseline.group,
                    base.name,
                    (f / b - 1.0) * 100.0,
                    tol * 100.0
                )),
                None => violations.push(format!(
                    "[{}] '{}': baseline has ns_per_elem but fresh run does not",
                    baseline.group, base.name
                )),
            },
            // unarmed metric: fall back to median_ns when the baseline
            // carries one
            _ if base.median_ns > 0.0 => {
                let f = new.median_ns;
                if f > base.median_ns * limit {
                    violations.push(format!(
                        "[{}] '{}': median_ns {f:.1} exceeds baseline {:.1} by {:.1}% (tol {:.0}%)",
                        baseline.group,
                        base.name,
                        base.median_ns,
                        (f / base.median_ns - 1.0) * 100.0,
                        tol * 100.0
                    ));
                }
            }
            _ => {} // cell fully unarmed: presence is all that is gated
        }
        if let (Some(be), Some(fe)) = (base.elems, new.elems) {
            if be > 0 && fe as f64 > be as f64 * (1.0 + tol) {
                violations.push(format!(
                    "[{}] '{}': elems grew {be} -> {fe} (beyond {:.0}% tolerance)",
                    baseline.group,
                    base.name,
                    tol * 100.0
                ));
            }
        }
        for (key, b) in &base.extras {
            if !GATED_RATE_EXTRAS.contains(&key.as_str()) || *b <= 0.0 {
                continue; // unknown or unarmed extra: informational only
            }
            match new.extras.iter().find(|(k, _)| k == key) {
                Some((_, f)) if *f >= b / limit => {}
                Some((_, f)) => violations.push(format!(
                    "[{}] '{}': {key} {f:.1} fell below baseline {b:.1} by {:.1}% (tol {:.0}%)",
                    baseline.group,
                    base.name,
                    (1.0 - f / b) * 100.0,
                    tol * 100.0
                )),
                None => violations.push(format!(
                    "[{}] '{}': baseline arms {key} but the fresh run does not emit it",
                    baseline.group, base.name
                )),
            }
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("selftest");
        b.cfg = BenchConfig { measure: Duration::from_millis(30), warmup: Duration::from_millis(5) };
        let mut acc = 0u64;
        b.run("add", || {
            acc = acc.wrapping_add(black_box(3));
        });
        assert!(b.samples[0].median_ns > 0.0);
        assert!(b.samples[0].iters > 0);
    }

    #[test]
    fn annotate_attaches_extras_to_last_sample() {
        let mut b = Bench::new("selftest");
        b.cfg =
            BenchConfig { measure: Duration::from_millis(10), warmup: Duration::from_millis(2) };
        let mut acc = 0u64;
        b.run("work", || {
            acc = acc.wrapping_add(black_box(1));
        });
        b.annotate("tok_s", 123.0);
        b.annotate_rate("bytes_decoded_per_s", 1e6);
        let s = &b.samples[0];
        assert_eq!(s.extra[0], ("tok_s".to_string(), 123.0));
        let (ref k, rate) = s.extra[1];
        assert_eq!(k, "bytes_decoded_per_s");
        // 1e6 units per iteration over the measured median
        let want = 1e6 / (s.median_ns * 1e-9);
        assert!((rate - want).abs() <= 1e-6 * want, "{rate} vs {want}");
    }

    #[test]
    fn json_has_throughput_fields() {
        let samples = vec![
            Sample {
                name: "quantize 512x512 2b kmeans+OBS".into(),
                iters: 10,
                median_ns: 2.0e6,
                mad_ns: 1.0e3,
                mean_ns: 2.1e6,
                elems: Some(512 * 512),
                extra: Vec::new(),
            },
            Sample {
                name: "no-elems \"cell\"".into(),
                iters: 3,
                median_ns: 5.0,
                mad_ns: 0.5,
                mean_ns: 5.0,
                elems: None,
                extra: vec![("prefill_in_per_req".into(), 12.5)],
            },
        ];
        let json = render_json("gptq", &samples);
        assert!(json.contains("\"group\": \"gptq\""));
        // 2e6 ns over 262144 elems = 7.6294 ns/elem
        assert!(json.contains("\"ns_per_elem\": 7.6294"), "{json}");
        assert!(json.contains("\"elems\": 262144"), "{json}");
        // quotes in names must be escaped, elem-less cells go null
        assert!(json.contains("no-elems \\\"cell\\\""), "{json}");
        assert!(json.contains("\"ns_per_elem\": null"), "{json}");
        // extra keys render inline on their cell
        assert!(json.contains("\"prefill_in_per_req\": 12.5000"), "{json}");
        // comma between the two cells, none trailing before the close
        assert!(json.contains("},\n"), "{json}");
        assert!(json.contains("}\n  ]"), "{json}");
        assert!(!json.contains(",\n  ]"), "{json}");
    }

    #[test]
    fn parse_round_trips_render() {
        let samples = vec![
            Sample {
                name: "decode batch=4".into(),
                iters: 100,
                median_ns: 4.0e5,
                mad_ns: 100.0,
                mean_ns: 4.1e5,
                elems: Some(4),
                extra: vec![("prefix_hits".into(), 3.0)],
            },
            Sample {
                name: "with \"quotes\"".into(),
                iters: 1,
                median_ns: 9.0,
                mad_ns: 0.0,
                mean_ns: 9.0,
                elems: None,
                extra: Vec::new(),
            },
        ];
        let doc = parse_bench_json(&render_json("decode", &samples)).unwrap();
        assert_eq!(doc.group, "decode");
        assert_eq!(doc.cells.len(), 2);
        assert_eq!(doc.cells[0].name, "decode batch=4");
        assert_eq!(doc.cells[0].elems, Some(4));
        assert!((doc.cells[0].ns_per_elem.unwrap() - 1.0e5).abs() < 1.0);
        assert_eq!(doc.cells[1].name, "with \"quotes\"");
        assert_eq!(doc.cells[1].elems, None);
        assert_eq!(doc.cells[1].ns_per_elem, None);
        assert_eq!(doc.cells[1].median_ns, 9.0);
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        assert!(parse_bench_json("").is_err());
        assert!(parse_bench_json("{\"cells\": []}").is_err(), "missing group");
        assert!(parse_bench_json("{\"group\": \"g\"}").is_err(), "missing cells");
        assert!(parse_bench_json("{\"group\": \"g\", \"cells\": [{}]}").is_err(), "nameless cell");
        assert!(parse_bench_json("{\"group\": \"g\", \"cells\": []} trailing").is_err());
    }

    fn doc(group: &str, cells: &[(&str, Option<f64>, f64, Option<u64>)]) -> BenchDoc {
        BenchDoc {
            group: group.into(),
            cells: cells
                .iter()
                .map(|(n, npe, med, e)| BenchCell {
                    name: (*n).into(),
                    median_ns: *med,
                    elems: *e,
                    ns_per_elem: *npe,
                    extras: Vec::new(),
                })
                .collect(),
        }
    }

    fn with_extras(mut d: BenchDoc, cell: &str, extras: &[(&str, f64)]) -> BenchDoc {
        let c = d.cells.iter_mut().find(|c| c.name == cell).unwrap();
        c.extras = extras.iter().map(|(k, v)| ((*k).to_string(), *v)).collect();
        d
    }

    #[test]
    fn gate_passes_within_tolerance_and_fails_beyond() {
        let base = doc("gptq", &[("cell", Some(100.0), 1.0e6, Some(1000))]);
        // +20% under a 25% tolerance: fine; improvements: fine
        let ok = doc("gptq", &[("cell", Some(120.0), 2.0e6, Some(1000))]);
        assert!(compare_bench(&base, &ok, 0.25).is_empty());
        let faster = doc("gptq", &[("cell", Some(50.0), 5.0e5, Some(1000))]);
        assert!(compare_bench(&base, &faster, 0.25).is_empty());
        // +30% beyond it: violation naming the cell and the overshoot
        let slow = doc("gptq", &[("cell", Some(130.0), 1.0e6, Some(1000))]);
        let v = compare_bench(&base, &slow, 0.25);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("'cell'") && v[0].contains("30.0%"), "{v:?}");
    }

    #[test]
    fn gate_flags_structure_and_size_regressions() {
        let base =
            doc("decode", &[("kept", Some(10.0), 1.0, Some(100)), ("gone", None, 0.0, None)]);
        let fresh = doc("decode", &[("kept", Some(10.0), 1.0, Some(200))]);
        let v = compare_bench(&base, &fresh, 0.25);
        // 'gone' disappeared; 'kept' elems doubled (a tracked size)
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|m| m.contains("'gone'") && m.contains("missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("'kept'") && m.contains("elems grew")), "{v:?}");
        // group mismatch short-circuits
        let v = compare_bench(&base, &doc("gptq", &[]), 0.25);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("group mismatch"));
    }

    #[test]
    fn parse_collects_unknown_numeric_keys_as_extras() {
        let samples = vec![Sample {
            name: "packed b=1".into(),
            iters: 7,
            median_ns: 1.0e6,
            mad_ns: 10.0,
            mean_ns: 1.0e6,
            elems: Some(64),
            extra: vec![("tok_s".into(), 1234.5), ("prefix_hits".into(), 3.0)],
        }];
        let doc = parse_bench_json(&render_json("decode", &samples)).unwrap();
        assert_eq!(
            doc.cells[0].extras,
            vec![("tok_s".to_string(), 1234.5), ("prefix_hits".to_string(), 3.0)]
        );
    }

    #[test]
    fn gate_rate_extras_are_floors() {
        let mk = |tok_s: f64| {
            with_extras(
                doc("decode", &[("cell", Some(10.0), 1.0e6, None)]),
                "cell",
                &[("tok_s", tok_s), ("prefix_hits", 0.0)],
            )
        };
        let base = mk(100.0);
        // a 10% dip sits above the 25%-tolerance floor (80.0); fine
        assert!(compare_bench(&base, &mk(90.0), 0.25).is_empty());
        assert!(compare_bench(&base, &mk(500.0), 0.25).is_empty(), "improvement passes");
        // 70.0 < 100/1.25: throughput regression
        let v = compare_bench(&base, &mk(70.0), 0.25);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("tok_s") && v[0].contains("fell below"), "{v:?}");
        // armed key missing from the fresh cell
        let bare = doc("decode", &[("cell", Some(10.0), 1.0e6, None)]);
        let v = compare_bench(&base, &bare, 0.25);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("does not emit"), "{v:?}");
        // non-whitelisted extras never gate, and a 0-valued rate is unarmed
        let noisy = with_extras(
            doc("decode", &[("cell", Some(10.0), 1.0e6, None)]),
            "cell",
            &[("tok_s", 0.0), ("prefix_hits", 99.0)],
        );
        assert!(compare_bench(&noisy, &bare, 0.25).is_empty());
    }

    #[test]
    fn gate_unarmed_baselines_check_presence_only() {
        // ns_per_elem null + median 0 = fully unarmed: any speed passes
        let base = doc("sched", &[("cell", None, 0.0, None)]);
        let fresh = doc("sched", &[("cell", Some(9.9e9), 9.9e9, Some(5))]);
        assert!(compare_bench(&base, &fresh, 0.25).is_empty());
        // median-armed fallback when ns_per_elem is null
        let base = doc("sched", &[("cell", None, 100.0, None)]);
        let slow = doc("sched", &[("cell", None, 200.0, None)]);
        assert_eq!(compare_bench(&base, &slow, 0.25).len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12e9).contains(" s"));
    }
}
