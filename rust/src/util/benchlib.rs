//! Micro-benchmark harness (the `criterion` crate is unavailable offline).
//!
//! `cargo bench` targets are declared with `harness = false` and call
//! [`Bench::run`] per case: adaptive warm-up, fixed-duration measurement,
//! and robust statistics (median + MAD) printed in a criterion-like format.
//! Results are also appended to `target/claq-bench.csv` for the §Perf log,
//! and each group writes a machine-readable `BENCH_<group>.json` at the
//! repo root (name, ns/elem, elems/s per cell) so CI can track the perf
//! trajectory run over run.

use std::hint::black_box as bb;
use std::io::Write;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Minimum wall time spent measuring each case.
    pub measure: Duration,
    /// Minimum wall time spent warming up each case.
    pub warmup: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { measure: Duration::from_millis(600), warmup: Duration::from_millis(150) }
    }
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<u64>,
}

impl Sample {
    pub fn throughput(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / (self.median_ns * 1e-9))
    }
}

pub struct Bench {
    cfg: BenchConfig,
    samples: Vec<Sample>,
    group: String,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let cfg = if std::env::var("CLAQ_BENCH_FAST").is_ok() {
            BenchConfig { measure: Duration::from_millis(120), warmup: Duration::from_millis(30) }
        } else {
            BenchConfig::default()
        };
        println!("== bench group: {group} ==");
        Self { cfg, samples: Vec::new(), group: group.to_string() }
    }

    /// Measure `f`, which performs one logical iteration per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) {
        self.run_with_elems(name, None, f)
    }

    /// Measure `f`, reporting `elems` processed per iteration as throughput.
    pub fn run_with_elems<F: FnMut()>(&mut self, name: &str, elems: Option<u64>, mut f: F) {
        // Warm-up and iteration-count calibration.
        let mut iters_per_batch = 1u64;
        let warm_start = Instant::now();
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                bb(&mut f)();
            }
            let dt = t.elapsed();
            if warm_start.elapsed() >= self.cfg.warmup {
                // aim batches at ~1/20th of the measurement budget
                let target = self.cfg.measure.as_secs_f64() / 20.0;
                let per_iter = (dt.as_secs_f64() / iters_per_batch as f64).max(1e-9);
                iters_per_batch = ((target / per_iter).ceil() as u64).clamp(1, 1 << 24);
                break;
            }
            iters_per_batch = (iters_per_batch * 2).min(1 << 24);
        }

        // Measurement: collect batch timings until the budget is exhausted.
        let mut batch_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.cfg.measure || batch_ns.len() < 5 {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                bb(&mut f)();
            }
            let dt = t.elapsed();
            batch_ns.push(dt.as_nanos() as f64 / iters_per_batch as f64);
            total_iters += iters_per_batch;
            if batch_ns.len() > 10_000 {
                break;
            }
        }
        batch_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = batch_ns[batch_ns.len() / 2];
        let mean = batch_ns.iter().sum::<f64>() / batch_ns.len() as f64;
        let mut devs: Vec<f64> = batch_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let s = Sample {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mad_ns: mad,
            mean_ns: mean,
            elems,
        };
        let tp = s
            .throughput()
            .map(|t| format!("  ({:.2} Melem/s)", t / 1e6))
            .unwrap_or_default();
        println!(
            "{:<44} time: [{} ± {}]  iters: {}{}",
            name,
            fmt_ns(s.median_ns),
            fmt_ns(s.mad_ns),
            s.iters,
            tp
        );
        self.samples.push(s);
    }

    /// Write accumulated samples to the CSV log and the tracked
    /// `BENCH_<group>.json` at the repo root.
    pub fn finish(self) {
        let rows: Vec<String> = self
            .samples
            .iter()
            .map(|s| {
                format!(
                    "{},{},{:.1},{:.1},{:.1},{}",
                    self.group, s.name, s.median_ns, s.mad_ns, s.mean_ns, s.iters
                )
            })
            .collect();
        append_csv(&rows);
        let path = bench_json_path(&self.group);
        if let Err(e) = std::fs::write(&path, render_json(&self.group, &self.samples)) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
    }
}

/// `BENCH_<group>.json` lives at the repo root: benches run with CWD =
/// `rust/` (the crate), so the root is the manifest's parent. Outside
/// cargo, fall back to the current directory.
fn bench_json_path(group: &str) -> std::path::PathBuf {
    let root = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(|d| std::path::PathBuf::from(d).join(".."))
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    root.join(format!("BENCH_{group}.json"))
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render the per-cell JSON document: median ns, iteration count, and —
/// for throughput cells — ns/elem and elems/s. Hand-rolled (no serde in
/// the offline sandbox); keys are stable so downstream diffing works.
fn render_json(group: &str, samples: &[Sample]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"group\": \"{}\",\n", json_escape(group)));
    out.push_str("  \"cells\": [\n");
    for (i, s) in samples.iter().enumerate() {
        let (ns_per_elem, elems_per_s) = match (s.elems, s.throughput()) {
            (Some(e), Some(t)) if e > 0 => {
                (format!("{:.4}", s.median_ns / e as f64), format!("{t:.1}"))
            }
            _ => ("null".to_string(), "null".to_string()),
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"mad_ns\": {:.1}, \"iters\": {}, \
             \"elems\": {}, \"ns_per_elem\": {}, \"elems_per_s\": {}}}{}\n",
            json_escape(&s.name),
            s.median_ns,
            s.mad_ns,
            s.iters,
            s.elems.map_or("null".to_string(), |e| e.to_string()),
            ns_per_elem,
            elems_per_s,
            if i + 1 < samples.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Append pre-formatted rows (`group,name,median_ns,mad_ns,mean_ns,iters`)
/// to the shared bench log `target/claq-bench.csv`, creating it with the
/// header if absent. Scenario benches that time whole serving traces
/// rather than per-iteration closures (e.g. `bench_scheduler`) use this to
/// land in the same log as [`Bench::finish`].
pub fn append_csv(rows: &[String]) {
    let path = std::path::Path::new("target").join("claq-bench.csv");
    let exists = path.exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        if !exists {
            let _ = writeln!(f, "group,name,median_ns,mad_ns,mean_ns,iters");
        }
        for row in rows {
            let _ = writeln!(f, "{row}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("selftest");
        b.cfg = BenchConfig { measure: Duration::from_millis(30), warmup: Duration::from_millis(5) };
        let mut acc = 0u64;
        b.run("add", || {
            acc = acc.wrapping_add(black_box(3));
        });
        assert!(b.samples[0].median_ns > 0.0);
        assert!(b.samples[0].iters > 0);
    }

    #[test]
    fn json_has_throughput_fields() {
        let samples = vec![
            Sample {
                name: "quantize 512x512 2b kmeans+OBS".into(),
                iters: 10,
                median_ns: 2.0e6,
                mad_ns: 1.0e3,
                mean_ns: 2.1e6,
                elems: Some(512 * 512),
            },
            Sample {
                name: "no-elems \"cell\"".into(),
                iters: 3,
                median_ns: 5.0,
                mad_ns: 0.5,
                mean_ns: 5.0,
                elems: None,
            },
        ];
        let json = render_json("gptq", &samples);
        assert!(json.contains("\"group\": \"gptq\""));
        // 2e6 ns over 262144 elems = 7.6294 ns/elem
        assert!(json.contains("\"ns_per_elem\": 7.6294"), "{json}");
        assert!(json.contains("\"elems\": 262144"), "{json}");
        // quotes in names must be escaped, elem-less cells go null
        assert!(json.contains("no-elems \\\"cell\\\""), "{json}");
        assert!(json.contains("\"ns_per_elem\": null"), "{json}");
        // comma between the two cells, none trailing before the close
        assert!(json.contains("},\n"), "{json}");
        assert!(json.contains("}\n  ]"), "{json}");
        assert!(!json.contains(",\n  ]"), "{json}");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12e9).contains(" s"));
    }
}
