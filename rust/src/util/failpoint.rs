//! Deterministic, seeded fault injection — the trigger half of the chaos
//! harness (`tests/chaos.rs` is the property half; DESIGN.md §14).
//!
//! A *failpoint* is a named site in the runtime that can be told to fail
//! on purpose: the KV page pool's take path ([`POOL_TAKE`] — a take
//! returns `None` as if the budget were exhausted), checkpoint decode
//! ([`CKPT_DECODE`] — `Checkpoint::decode` bails), and thread-pool job
//! dispatch ([`POOL_DISPATCH`] — the job panics inside the pool's
//! `catch_unwind`, exercising panic isolation). Sites are armed either
//! process-wide via the `CLAQ_FAILPOINTS` environment variable or
//! per-instance/per-scope from tests; unset, a site costs one
//! thread-local read plus one lazily-initialized static read.
//!
//! Syntax (`;`-separated clauses, whitespace-tolerant):
//!
//! ```text
//! CLAQ_FAILPOINTS="pool_take@p0.1;seed=7"
//! ```
//!
//! `name@pP` arms `name` with firing probability `P` ∈ [0, 1];
//! `seed=N` fixes the decision stream. Decisions are **deterministic**:
//! the k-th evaluation of a given failpoint fires iff
//! `splitmix64(seed ⊕ fnv1a(name) ⊕ k·φ) < P·2⁶⁴`, a pure function of
//! `(seed, name, k)` with no global RNG state — so a fixed seed replays
//! the exact same fault schedule, which is what lets the chaos property
//! suite assert bit-identical survivors run after run. (At a site
//! evaluated concurrently from several threads, the *set* of firing call
//! numbers is still deterministic; which thread draws which call number
//! is not — the only such site is `pool_dispatch`.)

use crate::util::rng::SplitMix64;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// `KvPagePool::take_page`: a fired take returns `None`, indistinguishable
/// from budget exhaustion — the scheduler must walk its degradation ladder.
pub const POOL_TAKE: &str = "pool_take";
/// `Checkpoint::decode`: a fired decode bails with a tagged error.
pub const CKPT_DECODE: &str = "ckpt_decode";
/// `ThreadPool` job execution: a fired job panics inside the pool's
/// per-job `catch_unwind` (inline fallback paths bypass it).
pub const POOL_DISPATCH: &str = "pool_dispatch";

struct Point {
    name: String,
    /// Fire iff `hash < threshold` (u128 so `p = 1.0` means always).
    threshold: u128,
    /// Cap on total fires (`0` = unlimited) — lets a test inject exactly
    /// one fault and then prove the victim recovered.
    max_fires: u64,
    calls: AtomicU64,
    fires: AtomicU64,
}

/// An armed set of failpoints. Cheap to share (`Arc`), `Sync`, and fully
/// deterministic from its seed — see the module docs for the decision
/// function.
pub struct Failpoints {
    seed: u64,
    points: Vec<Point>,
}

impl std::fmt::Debug for Failpoints {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut d = f.debug_struct("Failpoints");
        d.field("seed", &self.seed);
        for p in &self.points {
            d.field(&p.name, &(p.calls.load(Ordering::Relaxed), p.fires.load(Ordering::Relaxed)));
        }
        d.finish()
    }
}

impl Failpoints {
    /// Empty set (nothing armed) with a decision seed.
    pub fn new(seed: u64) -> Self {
        Self { seed, points: Vec::new() }
    }

    /// Arm `name` with firing probability `p` (clamped to [0, 1]).
    pub fn with_point(self, name: &str, p: f64) -> Self {
        self.with_limited_point(name, p, 0)
    }

    /// [`with_point`](Self::with_point) capped at `max_fires` total fires
    /// (`0` = unlimited).
    pub fn with_limited_point(mut self, name: &str, p: f64, max_fires: u64) -> Self {
        self.points.push(Point {
            name: name.to_string(),
            threshold: (p.clamp(0.0, 1.0) * 2f64.powi(64)) as u128,
            max_fires,
            calls: AtomicU64::new(0),
            fires: AtomicU64::new(0),
        });
        self
    }

    /// Parse the `CLAQ_FAILPOINTS` syntax (see module docs). Malformed
    /// specs are errors, never silently ignored — a typo'd chaos lane
    /// that tests nothing is worse than a red one.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut out = Self::new(0);
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(seed) = clause.strip_prefix("seed=") {
                out.seed = seed.trim().parse().with_context(|| format!("bad seed {seed:?}"))?;
            } else if let Some((name, prob)) = clause.split_once("@p") {
                let p: f64 = prob
                    .trim()
                    .parse()
                    .with_context(|| format!("bad probability in clause {clause:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("probability {p} in clause {clause:?} outside [0, 1]");
                }
                out = out.with_point(name.trim(), p);
            } else {
                bail!("unrecognized failpoint clause {clause:?} (want name@pP or seed=N)");
            }
        }
        Ok(out)
    }

    /// Evaluate `name`: true = the caller must fail here. Unarmed names
    /// never fire. Each call advances the site's call counter, so the
    /// decision sequence is replayable from the seed alone.
    pub fn fire(&self, name: &str) -> bool {
        let Some(pt) = self.points.iter().find(|p| p.name == name) else {
            return false;
        };
        let k = pt.calls.fetch_add(1, Ordering::Relaxed);
        let h = SplitMix64::new(
            self.seed ^ fnv1a(&pt.name) ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
        .next_u64();
        if (h as u128) >= pt.threshold {
            return false;
        }
        let n = pt.fires.fetch_add(1, Ordering::Relaxed);
        pt.max_fires == 0 || n < pt.max_fires
    }

    /// Total fires of `name` so far (0 for unarmed names).
    pub fn fired(&self, name: &str) -> u64 {
        self.points.iter().find(|p| p.name == name).map_or(0, |p| {
            let n = p.fires.load(Ordering::Relaxed);
            if p.max_fires == 0 {
                n
            } else {
                n.min(p.max_fires)
            }
        })
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The process-wide set parsed from `CLAQ_FAILPOINTS` (once). `None` when
/// the variable is unset; a malformed value panics loudly at first use.
pub fn global() -> Option<&'static Arc<Failpoints>> {
    static GLOBAL: OnceLock<Option<Arc<Failpoints>>> = OnceLock::new();
    GLOBAL
        .get_or_init(|| {
            let spec = std::env::var("CLAQ_FAILPOINTS").ok()?;
            match Failpoints::parse(&spec) {
                Ok(fp) => Some(Arc::new(fp)),
                Err(e) => panic!("invalid CLAQ_FAILPOINTS ({spec:?}): {e:#}"),
            }
        })
        .as_ref()
}

thread_local! {
    /// Stack of scope-local overrides (tests). The top of the stack
    /// shadows the global set on this thread only — pool worker threads
    /// never see a submitter's scoped set, which is why thread-crossing
    /// sites take an explicit [`Failpoints`] handle instead.
    static SCOPED: RefCell<Vec<Arc<Failpoints>>> = const { RefCell::new(Vec::new()) };
}

/// RAII guard installing a thread-scoped override; see [`scoped`].
pub struct ScopedGuard;

impl Drop for ScopedGuard {
    fn drop(&mut self) {
        SCOPED.with(|s| s.borrow_mut().pop());
    }
}

/// Shadow the global set with `fp` on the current thread until the guard
/// drops. Intended for tests of same-thread sites (checkpoint decode);
/// sites owned by a long-lived object ([`crate::model::exec::KvPagePool`],
/// [`crate::util::threadpool::ThreadPool`]) take a handle directly.
pub fn scoped(fp: Arc<Failpoints>) -> ScopedGuard {
    SCOPED.with(|s| s.borrow_mut().push(fp));
    ScopedGuard
}

/// Evaluate `name` against the thread-scoped override if one is
/// installed, else the global env-armed set. This is the call wired into
/// the runtime sites; with nothing armed it reduces to a thread-local
/// read plus a `OnceLock` read.
pub fn fire(name: &str) -> bool {
    let scoped = SCOPED.with(|s| s.borrow().last().cloned());
    match scoped {
        Some(fp) => fp.fire(name),
        None => global().is_some_and(|fp| fp.fire(name)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_never_fires() {
        let fp = Failpoints::new(1);
        for _ in 0..100 {
            assert!(!fp.fire(POOL_TAKE));
        }
    }

    #[test]
    fn probability_one_always_fires_and_zero_never() {
        let always = Failpoints::new(3).with_point(POOL_TAKE, 1.0);
        let never = Failpoints::new(3).with_point(POOL_TAKE, 0.0);
        for _ in 0..64 {
            assert!(always.fire(POOL_TAKE));
            assert!(!never.fire(POOL_TAKE));
        }
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let a = Failpoints::new(7).with_point(POOL_TAKE, 0.3);
        let b = Failpoints::new(7).with_point(POOL_TAKE, 0.3);
        let sa: Vec<bool> = (0..200).map(|_| a.fire(POOL_TAKE)).collect();
        let sb: Vec<bool> = (0..200).map(|_| b.fire(POOL_TAKE)).collect();
        assert_eq!(sa, sb);
        assert!(sa.iter().any(|&x| x), "p=0.3 over 200 draws must fire");
        assert!(sa.iter().any(|&x| !x), "p=0.3 over 200 draws must also pass");
    }

    #[test]
    fn seeds_and_names_give_independent_streams() {
        let fp = Failpoints::new(11).with_point("a", 0.5).with_point("b", 0.5);
        let sa: Vec<bool> = (0..128).map(|_| fp.fire("a")).collect();
        let sb: Vec<bool> = (0..128).map(|_| fp.fire("b")).collect();
        assert_ne!(sa, sb, "distinct names must not share a decision stream");
        let other = Failpoints::new(12).with_point("a", 0.5);
        let so: Vec<bool> = (0..128).map(|_| other.fire("a")).collect();
        assert_ne!(sa, so, "distinct seeds must not share a decision stream");
    }

    #[test]
    fn fire_limit_caps_total_fires() {
        let fp = Failpoints::new(5).with_limited_point("x", 1.0, 2);
        let fired = (0..50).filter(|_| fp.fire("x")).count();
        assert_eq!(fired, 2);
    }

    #[test]
    fn parse_round_trips_the_documented_syntax() {
        let fp = Failpoints::parse("pool_take@p0.1; seed=7").unwrap();
        assert_eq!(fp.seed, 7);
        assert_eq!(fp.points.len(), 1);
        assert_eq!(fp.points[0].name, POOL_TAKE);
        // order-independent: seed first works too
        let fp2 = Failpoints::parse("seed=7;pool_take@p0.1").unwrap();
        let s1: Vec<bool> = (0..64).map(|_| fp.fire(POOL_TAKE)).collect();
        let s2: Vec<bool> = (0..64).map(|_| fp2.fire(POOL_TAKE)).collect();
        assert_eq!(s1, s2);

        assert!(Failpoints::parse("pool_take@p1.5").is_err());
        assert!(Failpoints::parse("pool_take=0.1").is_err());
        assert!(Failpoints::parse("seed=abc").is_err());
        assert!(Failpoints::parse("").unwrap().points.is_empty());
    }

    #[test]
    fn scoped_override_shadows_and_pops() {
        assert!(!fire("scoped_test_point"));
        {
            let _g = scoped(Arc::new(Failpoints::new(1).with_point("scoped_test_point", 1.0)));
            assert!(fire("scoped_test_point"));
        }
        assert!(!fire("scoped_test_point"));
    }
}
