//! Zero-dependency CLI argument parsing (the `clap` crate is unavailable
//! offline). Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, and typed lookups with defaults.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0] and the
    /// subcommand itself). Flags taking values must be listed in
    /// `value_flags` so booleans and values are disambiguated.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_flags: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_flags.contains(&stripped) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{stripped} expects a value"))?;
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("invalid value for --{key}: {e}")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(v(&["pos1", "--bits", "2.12", "--fast", "--out=path.bin", "pos2"]), &["bits"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get("bits"), Some("2.12"));
        assert_eq!(a.get("out"), Some("path.bin"));
        assert!(a.has("fast"));
    }

    #[test]
    fn typed_lookup() {
        let a = Args::parse(v(&["--n", "42"]), &["n"]).unwrap();
        assert_eq!(a.get_parse_or::<u32>("n", 0).unwrap(), 42);
        assert_eq!(a.get_parse_or::<u32>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(v(&["--bits"]), &["bits"]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(v(&["--n", "xyz"]), &["n"]).unwrap();
        assert!(a.get_parse::<u32>("n").is_err());
    }
}
