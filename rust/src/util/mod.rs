//! Substrate utilities: PRNG, statistics, threading, property testing,
//! benchmarking, and CLI parsing — all dependency-free because the build
//! environment is offline (only `xla` and `anyhow` are vendored).

pub mod benchlib;
pub mod cli;
pub mod failpoint;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod tmp;
