//! Deterministic, dependency-free pseudo-random number generation.
//!
//! The sandbox has no `rand` crate, so we implement a small, well-known
//! generator family ourselves: [`SplitMix64`] for seeding and [`Pcg64`]
//! (xsl-rr variant over a 128-bit LCG) as the workhorse stream. Both are
//! fully deterministic from a `u64` seed, which every experiment in this
//! repo relies on for reproducibility.

/// SplitMix64 — used to expand small seeds into well-mixed state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: a small, fast, statistically solid PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Rng {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id, so parallel workers
    /// can draw independent sequences from the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let lo = sm.next_u64() as u128;
        let hi = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream);
        let inc = (((sm2.next_u64() as u128) << 64) | sm2.next_u64() as u128) | 1;
        let mut rng = Self { state: (hi << 64) | lo, inc };
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Widening multiply; bias is negligible for our n << 2^64 uses,
        // but reject to be exact.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below_usize(weights.len().max(1));
        }
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [0.0, 0.0, 10.0, 0.1];
        let hits = (0..1000).filter(|_| r.weighted(&w) == 2).count();
        assert!(hits > 900);
    }

    #[test]
    fn choose_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.choose_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 8);
    }
}
