//! Small statistics helpers shared by the quantizers, the evaluation
//! harness, and the bench library.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Mean of |x|; 0.0 for empty input.
pub fn mean_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| (x as f64).abs()).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// p-th quantile (0.0..=1.0) with linear interpolation; input need not be
/// sorted (we sort a copy).
pub fn quantile(xs: &[f32], p: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&p));
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, p)
}

/// p-th quantile of an already-sorted slice.
pub fn quantile_sorted(sorted: &[f32], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0] as f64;
    }
    let pos = p * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

/// Streaming mean/variance (Welford). Used by the calibration Hessian
/// accumulator and the bench harness.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference between two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

/// Softmax over logits into `out` (both length n), numerically stable.
pub fn softmax_into(logits: &[f32], out: &mut [f32]) {
    assert_eq!(logits.len(), out.len());
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = ((l - max) as f64).exp();
        *o = e as f32;
        sum += e;
    }
    let inv = (1.0 / sum) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// log(sum(exp(logits))) — stable.
pub fn log_sum_exp(logits: &[f32]) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    if !max.is_finite() {
        return max;
    }
    let s: f64 = logits.iter().map(|&l| (l as f64 - max).exp()).sum();
    max + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((variance(&xs) - 1.25).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0f32, 1.0, 3.0, 2.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-9);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0f32, 5.0, 2.0, 8.0, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x as f64);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
    }

    #[test]
    fn softmax_sums_to_one() {
        let logits = [1.0f32, 2.0, 3.0, -100.0];
        let mut out = [0.0f32; 4];
        softmax_into(&logits, &mut out);
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn lse_stable() {
        let logits = [1000.0f32, 1000.0];
        let v = log_sum_exp(&logits);
        assert!((v - (1000.0 + (2.0f64).ln())).abs() < 1e-6);
    }
}
