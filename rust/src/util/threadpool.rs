//! A small scoped thread pool over `std::thread` (no rayon/tokio in the
//! offline sandbox). The coordinator uses it to quantize independent weight
//! matrices in parallel and the harness uses it for method-grid fan-out.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Fixed-size pool executing `FnOnce` jobs. Jobs submitted through
/// [`ThreadPool::scope`] may borrow from the enclosing stack frame.
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// Create a pool sized to the host (at least 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Pool sized from available parallelism.
    pub fn host() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `jobs` (indexed closures) across the pool and wait for all.
    /// Results are returned in job order.
    pub fn run<T, F>(&self, n_jobs: usize, job: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n_jobs == 0 {
            return Vec::new();
        }
        let next = AtomicUsize::new(0);
        let results: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();
        let workers = self.workers.min(n_jobs);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_jobs {
                        break;
                    }
                    let out = job(i);
                    *results[i].lock().unwrap() = Some(out);
                });
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("job did not produce a result"))
            .collect()
    }

    /// Parallel map over a slice.
    pub fn map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(&I) -> T + Sync,
    {
        self.run(items.len(), |i| f(&items[i]))
    }
}

/// A simple counting semaphore used for backpressure in the serving example.
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Arc<Self> {
        Arc::new(Self { permits: Mutex::new(permits), cv: Condvar::new() })
    }

    pub fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    pub fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        self.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_returns_in_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run(100, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_borrows_input() {
        let pool = ThreadPool::new(3);
        let items: Vec<String> = (0..10).map(|i| format!("x{i}")).collect();
        let out = pool.map(&items, |s| s.len());
        assert_eq!(out, vec![2; 10]);
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = ThreadPool::new(8);
        let counter = AtomicU64::new(0);
        pool.run(1000, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_jobs_ok() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.run(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn semaphore_counts() {
        let sem = Semaphore::new(2);
        sem.acquire();
        sem.acquire();
        sem.release();
        sem.acquire(); // would deadlock if release didn't restore a permit
        sem.release();
        sem.release();
    }
}
