//! A persistent-worker thread pool over `std::thread` (no rayon/tokio in
//! the offline sandbox).
//!
//! PR 1's pool spawned fresh scoped threads on every [`ThreadPool::run`]
//! call, which is fine for the coordinator's coarse per-matrix jobs but far
//! too expensive for the serving hot path, where the packed kernels shard
//! every projection of every decode step (`model/linear.rs`). This version
//! keeps workers parked on a condvar between tasks, so a dispatch costs a
//! mutex hand-off instead of `workers` thread spawns. The submitting thread
//! also claims job indices itself while it waits, so a pool of `n` workers
//! delivers `n`-way parallelism with `n - 1` spawned threads and no
//! oversubscription.
//!
//! Jobs may borrow from the submitting stack frame: `run` publishes a
//! lifetime-erased pointer to the closure and does not return (or unwind)
//! until every job index has finished, which is the invariant that makes
//! the erasure sound. Panics inside jobs are caught, the pool is drained to
//! quiescence, and the first payload is re-raised on the submitter.
//!
//! The serving path shares one process-wide pool ([`ThreadPool::global`],
//! sized by `CLAQ_THREADS` or the host); the coordinator keeps building
//! private pools for its own fan-out.

use crate::util::failpoint::{self, Failpoints};
use std::any::Any;
use std::cell::Cell;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Whether the current thread is executing a pool job. A nested `run`
    /// from inside a job executes inline instead of dispatching: the outer
    /// task holds the submit lock until it drains, so dispatching from a
    /// worker would deadlock.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Lifetime-erased job: called with a job index in `0..n_jobs`.
///
/// Safety contract: the pointee outlives the task because `run` blocks
/// until `outstanding == 0` before its closure goes out of scope.
struct Task {
    job: *const (dyn Fn(usize) + Sync),
    n_jobs: usize,
}

// SAFETY: `job` is only dereferenced while the submitting `run` call keeps
// the closure alive (it waits for all claimed indices to finish), and the
// pointee is `Sync`, so shared calls from worker threads are fine.
unsafe impl Send for Task {}

#[derive(Default)]
struct Shared {
    /// Current task; `None` between submissions.
    task: Option<Task>,
    /// Next unclaimed job index of the current task.
    next: usize,
    /// Claimed-or-unclaimed job indices not yet finished.
    outstanding: usize,
    /// First panic payload raised by a job of the current task.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Inner {
    shared: Mutex<Shared>,
    /// Workers park here between tasks.
    work: Condvar,
    /// The submitter parks here until `outstanding` hits zero.
    done: Condvar,
    /// Armed failpoints: [`failpoint::POOL_DISPATCH`] makes a dispatched
    /// job panic inside the per-job `catch_unwind`, exercising the panic
    /// isolation contract (the inline fallback paths bypass it). Wired
    /// from `CLAQ_FAILPOINTS` at construction; tests inject via
    /// [`ThreadPool::with_failpoints`].
    failpoints: Option<Arc<Failpoints>>,
}

impl Inner {
    /// Claim one job index of the current task, if any remain.
    fn claim(&self) -> Option<(*const (dyn Fn(usize) + Sync), usize)> {
        let mut s = self.shared.lock().unwrap();
        match &s.task {
            Some(t) if s.next < t.n_jobs => {
                let idx = s.next;
                let job = t.job;
                s.next += 1;
                Some((job, idx))
            }
            _ => None,
        }
    }

    /// Run one claimed job, catching panics, and retire it.
    fn execute(&self, job: *const (dyn Fn(usize) + Sync), idx: usize) {
        // SAFETY: see the Task contract — the closure is alive until the
        // submitter observes outstanding == 0, which cannot happen before
        // this job retires below.
        let f = unsafe { &*job };
        let was_in_job = IN_POOL_JOB.with(|flag| flag.replace(true));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if self.failpoints.as_ref().is_some_and(|fp| fp.fire(failpoint::POOL_DISPATCH)) {
                panic!("failpoint {} fired in pool job {idx}", failpoint::POOL_DISPATCH);
            }
            f(idx)
        }));
        IN_POOL_JOB.with(|flag| flag.set(was_in_job));
        let mut s = self.shared.lock().unwrap();
        if let Err(payload) = result {
            if s.panic.is_none() {
                s.panic = Some(payload);
            }
        }
        s.outstanding -= 1;
        if s.outstanding == 0 {
            self.done.notify_all();
        }
    }

    fn worker_loop(&self) {
        loop {
            let claimed = {
                let mut s = self.shared.lock().unwrap();
                loop {
                    if s.shutdown {
                        return;
                    }
                    match &s.task {
                        Some(t) if s.next < t.n_jobs => break,
                        _ => s = self.work.wait(s).unwrap(),
                    }
                }
                let job = s.task.as_ref().unwrap().job;
                let idx = s.next;
                s.next += 1;
                (job, idx)
            };
            self.execute(claimed.0, claimed.1);
        }
    }
}

/// Fixed-size pool with persistent workers. `workers` is the delivered
/// parallelism: `workers - 1` threads are spawned and the submitting thread
/// contributes the last lane during [`ThreadPool::run`].
pub struct ThreadPool {
    workers: usize,
    inner: Arc<Inner>,
    /// Serializes concurrent `run` calls (one task in flight at a time).
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool delivering `workers`-way parallelism (at least 1).
    /// `new(1)` spawns no threads and runs jobs inline.
    pub fn new(workers: usize) -> Self {
        Self::build(workers, failpoint::global().cloned())
    }

    /// [`new`](Self::new) with an explicit armed failpoint set (replacing
    /// any env-derived one) — the panic-isolation test's injection path.
    pub fn with_failpoints(workers: usize, fp: Arc<Failpoints>) -> Self {
        Self::build(workers, Some(fp))
    }

    fn build(workers: usize, failpoints: Option<Arc<Failpoints>>) -> Self {
        let workers = workers.max(1);
        let inner = Arc::new(Inner {
            shared: Mutex::new(Shared::default()),
            work: Condvar::new(),
            done: Condvar::new(),
            failpoints,
        });
        let handles = (1..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("claq-pool-{i}"))
                    .spawn(move || inner.worker_loop())
                    .expect("spawn pool worker")
            })
            .collect();
        Self { workers, inner, submit: Mutex::new(()), handles }
    }

    /// Pool sized from available parallelism.
    pub fn host() -> Self {
        Self::new(host_threads())
    }

    /// The process-wide pool the execution kernels shard onto. Sized by
    /// `CLAQ_THREADS` when set (use `CLAQ_THREADS=1` to force serial
    /// kernels), otherwise by the host; never torn down.
    pub fn global() -> &'static ThreadPool {
        static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let n = std::env::var("CLAQ_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or_else(host_threads);
            ThreadPool::new(n)
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Publish a lifetime-erased task, contribute the submitting thread as
    /// the last parallel lane, and block until every job index retires —
    /// the dispatch core shared by [`Self::run`] and [`Self::run_units`].
    fn dispatch(&self, erased: &(dyn Fn(usize) + Sync), n_jobs: usize) {
        // SAFETY: lifetime erasure to 'static; sound because this function
        // waits for outstanding == 0 before returning, so the pointee (and
        // everything it borrows) outlives every call — see the Task
        // contract.
        let job_ptr: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(erased)
        };

        let guard = self.submit.lock().unwrap();
        {
            let mut s = self.inner.shared.lock().unwrap();
            s.task = Some(Task { job: job_ptr, n_jobs });
            s.next = 0;
            s.outstanding = n_jobs;
            s.panic = None;
        }
        // Wake only as many workers as there are jobs beyond the one the
        // submitter will take itself — notify_all would stampede every
        // parked worker through the mutex on each decode-step dispatch.
        for _ in 0..(n_jobs - 1).min(self.handles.len()) {
            self.inner.work.notify_one();
        }

        // Contribute the submitting thread as the last parallel lane.
        while let Some((job, idx)) = self.inner.claim() {
            self.inner.execute(job, idx);
        }

        let panic = {
            let mut s = self.inner.shared.lock().unwrap();
            while s.outstanding > 0 {
                s = self.inner.done.wait(s).unwrap();
            }
            s.task = None;
            s.panic.take()
        };
        drop(guard);
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run `n_jobs` indexed closures across the pool and wait for all.
    /// Results are returned in job order. The submitting thread executes
    /// jobs too, and a `run` issued from *inside* a pool job executes
    /// inline (the nested-dispatch case that would otherwise deadlock on
    /// the submit lock), so the call cannot hang on a busy pool.
    pub fn run<T, F>(&self, n_jobs: usize, job: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n_jobs == 0 {
            return Vec::new();
        }
        if n_jobs == 1 || self.handles.is_empty() || IN_POOL_JOB.with(Cell::get) {
            return (0..n_jobs).map(&job).collect();
        }

        let results: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();
        let wrapper = |i: usize| {
            let out = job(i);
            *results[i].lock().unwrap() = Some(out);
        };
        self.dispatch(&wrapper, n_jobs);

        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("job did not produce a result"))
            .collect()
    }

    /// [`Self::run`] for jobs that produce no results: skips the per-call
    /// results vector, so a dispatch performs **no heap allocation** —
    /// what the serving hot path (`model/linear.rs::run_row_sharded`)
    /// needs to keep steady-state decode allocation-free. Same inline
    /// fallbacks and panic propagation as `run`.
    pub fn run_units<F>(&self, n_jobs: usize, job: F)
    where
        F: Fn(usize) + Sync,
    {
        if n_jobs == 0 {
            return;
        }
        if n_jobs == 1 || self.handles.is_empty() || IN_POOL_JOB.with(Cell::get) {
            for i in 0..n_jobs {
                job(i);
            }
            return;
        }
        self.dispatch(&job, n_jobs);
    }

    /// Parallel map over a slice.
    pub fn map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(&I) -> T + Sync,
    {
        self.run(items.len(), |i| f(&items[i]))
    }

    /// Run `kernel(first_row, chunk)` over disjoint contiguous row-chunks
    /// of `data` (rows of `row_len` elements each), at most `shards`
    /// chunks — the in-place flavour of the row-sharding idiom
    /// (`model/linear.rs::run_row_sharded` is the staging flavour). Rows
    /// are never split across chunks, so kernels that own whole rows need
    /// no synchronization; `shards <= 1` runs inline on the caller.
    /// Callers pick `shards` (and thereby the serial/parallel cutoff)
    /// because the profitable grain size is theirs to judge.
    pub fn run_row_chunks<K>(&self, data: &mut [f32], row_len: usize, shards: usize, kernel: K)
    where
        K: Fn(usize, &mut [f32]) + Sync,
    {
        debug_assert_eq!(data.len() % row_len.max(1), 0);
        let rows = data.len() / row_len.max(1);
        if shards <= 1 || rows <= 1 {
            kernel(0, data);
            return;
        }
        // Each job locks only its own part (uncontended); the Mutex is the
        // fence that hands the &mut chunk to whichever worker claims the
        // job index.
        let per_shard = rows.div_ceil(shards.min(rows));
        let mut parts: Vec<Mutex<(usize, &mut [f32])>> = Vec::with_capacity(shards);
        let mut rest = data;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + per_shard).min(rows);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_len);
            rest = tail;
            parts.push(Mutex::new((r0, chunk)));
            r0 = r1;
        }
        self.run(parts.len(), |i| {
            let mut part = parts[i].lock().unwrap();
            let (r0, ref mut chunk) = *part;
            kernel(r0, &mut **chunk);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut s = self.inner.shared.lock().unwrap();
            s.shutdown = true;
        }
        self.inner.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Host parallelism (at least 1) without building a pool.
pub fn host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn run_returns_in_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run(100, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_borrows_input() {
        let pool = ThreadPool::new(3);
        let items: Vec<String> = (0..10).map(|i| format!("x{i}")).collect();
        let out = pool.map(&items, |s| s.len());
        assert_eq!(out, vec![2; 10]);
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = ThreadPool::new(8);
        let counter = AtomicU64::new(0);
        pool.run(1000, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_jobs_ok() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.run(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.workers(), 1);
        let out = pool.run(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_tasks() {
        // The point of persistent workers: many dispatches on one pool.
        let pool = ThreadPool::new(4);
        for round in 0..50usize {
            let out = pool.run(16, |i| i * round);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * round);
            }
        }
    }

    #[test]
    fn jobs_borrow_submitter_stack() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let sums = pool.run(8, |i| data[i * 8..(i + 1) * 8].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), (0..64).sum());
    }

    #[test]
    fn panic_in_job_propagates_after_drain() {
        let pool = ThreadPool::new(4);
        let ran = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(32, |i| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 7 {
                    panic!("job 7 failed");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The pool must still be usable afterwards (drained to quiescence).
        let out = pool.run(4, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert!(ran.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn nested_run_executes_inline_instead_of_deadlocking() {
        let pool = ThreadPool::new(4);
        let out = pool.run(4, |i| {
            // dispatching from inside a job must fall back to inline
            pool.run(3, move |j| i * 10 + j).iter().sum::<usize>()
        });
        assert_eq!(out, vec![3, 33, 63, 93]);
    }

    #[test]
    fn run_units_runs_every_job_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run_units(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "job {i}");
        }
        // zero and single-job fast paths
        pool.run_units(0, |_| panic!("no jobs to run"));
        let once = AtomicUsize::new(0);
        pool.run_units(1, |_| {
            once.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(once.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn run_units_propagates_panics() {
        let pool = ThreadPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_units(16, |i| {
                if i == 3 {
                    panic!("unit job 3 failed");
                }
            });
        }));
        assert!(result.is_err());
        // pool still usable afterwards
        let out = pool.run(4, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn run_row_chunks_covers_every_row_once() {
        let pool = ThreadPool::new(4);
        let row_len = 8;
        let rows = 37; // not a multiple of the shard count
        let mut data = vec![0.0f32; rows * row_len];
        for shards in [1usize, 2, 4, 16, 64] {
            data.fill(0.0);
            pool.run_row_chunks(&mut data, row_len, shards, |r0, chunk| {
                for (lr, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + lr + 1) as f32; // row index, exactly once
                    }
                }
            });
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(data[r * row_len + c], (r + 1) as f32, "shards={shards} row {r}");
                }
            }
        }
    }

    #[test]
    fn dispatch_failpoint_panic_does_not_poison_the_pool() {
        // A panicking task must not poison the pool: arm the dispatch
        // failpoint for exactly one fire, check the payload surfaces on the
        // submitter, then check the surviving pool behaves bit-identically
        // to a fresh pool across every dispatch flavour.
        let fp = Arc::new(Failpoints::new(9).with_limited_point(failpoint::POOL_DISPATCH, 1.0, 1));
        let pool = ThreadPool::with_failpoints(4, Arc::clone(&fp));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_units(64, |_| {});
        }));
        let payload = result.expect_err("armed dispatch failpoint must surface its panic");
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| payload.downcast_ref::<&str>().copied())
            .expect("panic payload is a string");
        assert!(msg.contains(failpoint::POOL_DISPATCH), "payload surfaced verbatim: {msg}");
        assert_eq!(fp.fired(failpoint::POOL_DISPATCH), 1);

        let fresh = ThreadPool::new(4);

        let out = pool.run(33, |i| (i as u64).wrapping_mul(0x9E37_79B9));
        assert_eq!(out, fresh.run(33, |i| (i as u64).wrapping_mul(0x9E37_79B9)));

        let survivor_sum = AtomicU64::new(0);
        pool.run_units(65, |i| {
            survivor_sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        let fresh_sum = AtomicU64::new(0);
        fresh.run_units(65, |i| {
            fresh_sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(survivor_sum.load(Ordering::Relaxed), fresh_sum.load(Ordering::Relaxed));

        // Row sharding: every row written exactly once, identical to fresh.
        let row_len = 4;
        let rows = 19;
        let kernel = |r0: usize, chunk: &mut [f32]| {
            for (lr, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                for (c, v) in row.iter_mut().enumerate() {
                    *v = (r0 + lr) as f32 * 10.0 + c as f32;
                }
            }
        };
        let mut survivor = vec![0.0f32; rows * row_len];
        let mut baseline = vec![0.0f32; rows * row_len];
        pool.run_row_chunks(&mut survivor, row_len, 8, kernel);
        fresh.run_row_chunks(&mut baseline, row_len, 8, kernel);
        assert_eq!(survivor, baseline);
    }

    #[test]
    fn global_pool_exists() {
        let pool = ThreadPool::global();
        assert!(pool.workers() >= 1);
        let out = pool.run(8, |i| i);
        assert_eq!(out.len(), 8);
    }
}
