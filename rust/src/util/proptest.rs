//! Miniature property-testing harness (the `proptest` crate is unavailable
//! offline). A property is checked against many random inputs drawn from a
//! caller-supplied generator; on failure we retry with a fixed shrink ladder
//! of "smaller" cases when the generator supports sizing, and always report
//! the seed so the case replays deterministically.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC1A9 }
    }
}

/// Check `prop(rng)` for `cfg.cases` independently seeded cases. The
/// property receives a fresh `Rng` per case; it should generate its own
/// inputs from it and panic (assert) on violation. On panic we re-raise
/// with the offending case seed embedded in the message.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cfg: Config, prop: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed on case {case} (seed={case_seed:#x}): {msg}");
        }
    }
}

/// Convenience: check with the default config.
pub fn check_default<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, prop: F) {
    check(name, Config::default(), prop)
}

/// Generate a random f32 vector with entries drawn N(0, sigma), with a few
/// injected outliers (mimicking LLM weight columns, which is the shape of
/// data this repo cares about).
pub fn gen_column(rng: &mut Rng, len: usize, outlier_frac: f64) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.02);
    let n_out = ((len as f64) * outlier_frac) as usize;
    for _ in 0..n_out {
        let i = rng.below_usize(len);
        let sign = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
        v[i] = sign * (0.2 + 0.3 * rng.next_f32());
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_default("x*x >= 0", |rng| {
            let x = rng.normal();
            assert!(x * x >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures_with_seed() {
        // Silence the inner panic backtrace noise.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check("always fails", Config { cases: 3, seed: 1 }, |_| {
                panic!("boom");
            });
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn gen_column_has_outliers() {
        let mut rng = Rng::new(5);
        let col = gen_column(&mut rng, 1000, 0.02);
        let big = col.iter().filter(|x| x.abs() > 0.15).count();
        assert!(big >= 10, "expected injected outliers, got {big}");
    }
}
