//! Unique per-process temp paths for tests and benches. Parallel `cargo
//! test` processes (and threads within one process) must not collide on a
//! shared temp name, so every caller gets `claq_<tag>_<pid>_<counter>`;
//! one definition keeps the uniqueness discipline in one place.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// A temp-dir path unique to this process and call (never created).
pub fn unique_path(tag: &str) -> PathBuf {
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "claq_{tag}_{}_{}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_distinct_and_tagged() {
        let a = unique_path("x");
        let b = unique_path("x");
        assert_ne!(a, b);
        assert!(a.file_name().unwrap().to_string_lossy().starts_with("claq_x_"));
    }
}
