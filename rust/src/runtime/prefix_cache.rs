//! Prefix-sharing KV reuse: a token-sequence trie that pins retired
//! requests' KV prefixes so later requests with a shared prompt prefix
//! skip most of their prefill — and, since the cache went paged, skip the
//! copy too.
//!
//! Real serving fleets overwhelmingly share prompt prefixes (system
//! prompts, few-shot templates). Cold admission pays a full `prefill` for
//! every prompt token, yet the K/V rows of a position depend only on the
//! tokens at or before it (causal attention; RoPE is a function of the
//! absolute position). Two prompts that agree on their first `d` tokens
//! therefore produce **bit-identical** K/V rows for positions `0..d` —
//! the kernels are deterministic and batch/thread-invariant (DESIGN.md
//! §7) — so those rows can be *shared* out of a previously computed cache
//! instead of recomputed. Sharing is an `Arc` clone per page
//! ([`KvCache::share_prefix_from`], DESIGN.md §13): a hit costs O(pages)
//! pointer work and copies **zero KV bytes** — the per-layer memcpy the
//! pre-paging `copy_prefix_from` paid is gone, tracked by the
//! `saved_bytes` counter.
//!
//! **Structure.** A radix trie keyed on prompt tokens ([`Node`] per
//! token). When the scheduler retires a request it offers the prompt and
//! the request's [`KvCache`]; the cache is truncated back to the prompt
//! (decoded-token pages are released to the pool) and pinned at the trie
//! node at that depth. Each node's `subtree_entries` counts the pinned
//! caches at or below it — the ref-count that keeps interior nodes alive
//! and lets eviction prune paths that no longer lead to an entry.
//!
//! **Lookup.** [`probe`](PrefixCache::probe) walks a new prompt down the
//! trie and returns the deepest match, capped at `prompt.len() - 1`: the
//! last prompt position is always prefilled, because its logits produce
//! the request's first token. [`share_into`](PrefixCache::share_into)
//! then clones the matched prefix's page table out of *any* pinned entry
//! below the matched node (they all share those tokens, so their leading
//! rows are bit-identical) into a pool-provided destination cache, and
//! the scheduler prefills only the prompt tail on top of it — the tail
//! write forks a shared partial page copy-on-write, never the full ones.
//!
//! **Eviction.** The cache is byte-budgeted on the pages its entries
//! reference: inserts beyond `budget_bytes` evict the least-recently used
//! entry (clock ticks are unique, so the order is total) and release its
//! pages to the [`KvPagePool`] — pinning borrows pages from the pool's
//! working set, eviction pays them back (pages still shared with a live
//! request only drop a reference and come home when that request
//! retires). A duplicate insert refreshes the existing entry's LRU stamp
//! and releases the new cache to the pool.
//!
//! The trie uses `BTreeMap` children so every walk (including the
//! pick-any-entry descent in `share_into`) is deterministic: serving
//! output never depends on it (any entry yields identical bytes), but
//! stats and eviction order stay reproducible run over run.
//! `tests/prefix_cache.rs` pins the end-to-end property: prefix-hit
//! serving is token-identical to cold prefill for both backends and both
//! admission policies; `tests/paged_kv.rs` pins page-refcount hygiene
//! under eviction thrash.

use crate::model::exec::{KvCache, KvPagePool};
use std::collections::BTreeMap;

/// One pinned KV prefix. `cache.len()` equals the depth of the node that
/// owns the entry (the number of prompt tokens whose K/V rows it holds).
struct Entry {
    cache: KvCache,
    /// LRU clock tick of the last share or insert that touched this entry.
    last_used: u64,
}

#[derive(Default)]
struct Node {
    children: BTreeMap<u16, Node>,
    /// A cache pinned at exactly this node's depth, if any.
    entry: Option<Entry>,
    /// Pinned entries at or below this node. Every live node has ≥ 1
    /// (nodes are pruned when their last entry is evicted), which is what
    /// makes any `probe` depth shareable.
    subtree_entries: usize,
}

/// The prefix-sharing KV cache. See the module docs for the design.
pub struct PrefixCache {
    root: Node,
    budget_bytes: usize,
    resident_bytes: usize,
    entries: usize,
    clock: u64,
    lookups: u64,
    hits: u64,
    saved_tokens: u64,
    /// KV bytes a hit would have memcpy'd pre-paging (prefix length ×
    /// per-token f32 KV bytes) — now pure refcount work.
    saved_bytes: u64,
    evictions: u64,
}

impl PrefixCache {
    /// Byte budget covers the pages the pinned entries reference; a
    /// single cache larger than the budget is never pinned (the cache
    /// degrades to a no-op rather than thrash).
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            root: Node::default(),
            budget_bytes,
            resident_bytes: 0,
            entries: 0,
            clock: 0,
            lookups: 0,
            hits: 0,
            saved_tokens: 0,
            saved_bytes: 0,
            evictions: 0,
        }
    }

    /// Longest reusable prefix of `prompt`, capped at `prompt.len() - 1`
    /// so the final prompt position (whose logits yield the first output
    /// token) is always prefilled. Read-only: no LRU touch, no counters —
    /// the scheduler probes for budget accounting before committing to an
    /// admission, then shares.
    pub fn probe(&self, prompt: &[u16]) -> usize {
        let cap = prompt.len().saturating_sub(1);
        let mut node = &self.root;
        let mut depth = 0;
        while depth < cap {
            match node.children.get(&prompt[depth]) {
                Some(child) => {
                    node = child;
                    depth += 1;
                }
                None => break,
            }
        }
        depth
    }

    /// Share the longest cached prefix of `prompt` into `dst` (a shell or
    /// reset cache from the pool) and return its length; `dst` ends at
    /// exactly that depth, ready for a tail prefill, referencing the
    /// entry's pages — **no KV bytes are copied** (the tail page forks
    /// copy-on-write at the first append). Returns 0 on a miss (`dst`
    /// untouched). Counts the lookup, the hit, the saved prefill tokens
    /// and the saved copy bytes, and refreshes the source entry's LRU
    /// stamp.
    pub fn share_into(&mut self, prompt: &[u16], dst: &mut KvCache) -> usize {
        self.lookups += 1;
        let depth = self.probe(prompt);
        if depth == 0 {
            return 0;
        }
        let mut node = &mut self.root;
        for &t in &prompt[..depth] {
            node = node.children.get_mut(&t).expect("probed path exists");
        }
        // Any entry below the matched node shares the first `depth`
        // tokens, so its leading rows are bit-identical; take the
        // smallest-token descent for determinism.
        while node.entry.is_none() {
            node = node
                .children
                .values_mut()
                .next()
                .expect("interior trie node with no entry below it");
        }
        let e = node.entry.as_mut().unwrap();
        debug_assert!(e.cache.len() >= depth, "pinned entry shorter than its trie depth");
        dst.share_prefix_from(&e.cache, depth);
        e.last_used = self.clock;
        self.clock += 1;
        self.hits += 1;
        self.saved_tokens += depth as u64;
        self.saved_bytes += (depth * dst.token_bytes()) as u64;
        depth
    }

    /// Pin a retired request's cache under its prompt. The cache is
    /// truncated back to the prompt (generated-token pages released to
    /// `pool`); if an entry for this exact prompt already exists, or the
    /// truncated cache alone exceeds the budget, the cache goes straight
    /// back to `pool`. Inserting may evict least-recently-used entries
    /// into `pool` until the byte budget holds again.
    pub fn insert(&mut self, prompt: &[u16], mut cache: KvCache, pool: &mut KvPagePool) {
        if prompt.is_empty() {
            pool.put_cache(cache);
            return;
        }
        assert!(
            cache.len() >= prompt.len(),
            "pinned cache ({} positions) must cover the prompt ({})",
            cache.len(),
            prompt.len()
        );
        cache.truncate_into(prompt.len(), pool);
        let bytes = cache.bytes();
        if bytes > self.budget_bytes {
            pool.put_cache(cache);
            return;
        }
        let stamp = self.clock;
        self.clock += 1;
        match insert_rec(&mut self.root, prompt, cache, stamp) {
            Ok(()) => {
                self.entries += 1;
                self.resident_bytes += bytes;
                self.evict_to_budget(pool);
            }
            // Exact prompt already pinned: its LRU stamp was refreshed;
            // the offered cache is surplus.
            Err(dup) => pool.put_cache(dup),
        }
    }

    fn evict_to_budget(&mut self, pool: &mut KvPagePool) {
        while self.resident_bytes > self.budget_bytes {
            self.evict_lru(pool);
        }
    }

    fn evict_lru(&mut self, pool: &mut KvPagePool) {
        let mut path = Vec::new();
        let mut lru: Option<(u64, Vec<u16>)> = None;
        find_lru(&self.root, &mut path, &mut lru);
        let (_, key) = lru.expect("eviction requires at least one entry");
        let e = remove_rec(&mut self.root, &key).expect("LRU path resolves to an entry");
        self.resident_bytes -= e.cache.bytes();
        self.entries -= 1;
        self.evictions += 1;
        pool.put_cache(e.cache);
    }

    /// Evict the single least-recently-used entry back into `pool` —
    /// rung 1 of the scheduler's memory-pressure ladder. Returns `false`
    /// when the trie is empty (no memory to give back), so the caller can
    /// fall through to the next rung.
    pub fn evict_one(&mut self, pool: &mut KvPagePool) -> bool {
        if self.entries == 0 {
            return false;
        }
        self.evict_lru(pool);
        true
    }

    /// Evict every entry back into `pool` (shutdown / the page-hygiene
    /// property's final drain). Counts as evictions.
    pub fn drain(&mut self, pool: &mut KvPagePool) {
        while self.entries > 0 {
            self.evict_lru(pool);
        }
        debug_assert_eq!(self.resident_bytes, 0);
    }

    /// Visit every pinned cache (the scheduler's distinct-page residency
    /// walk; order is the deterministic trie order).
    pub fn visit_caches(&self, f: &mut dyn FnMut(&KvCache)) {
        visit_rec(&self.root, f);
    }

    /// Pinned caches currently held.
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Bytes of the pages the pinned entries reference (each entry
    /// counted in full; system-wide dedup of pages shared with live
    /// requests happens in the scheduler's stats walk).
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Shares attempted (one per admission when the cache is enabled).
    pub fn lookups(&self) -> u64 {
        self.lookups
    }

    /// Shares that reused a non-empty prefix.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Prompt tokens served by page sharing instead of prefill.
    pub fn saved_tokens(&self) -> u64 {
        self.saved_tokens
    }

    /// KV bytes the pre-paging copy path would have memcpy'd on hits.
    pub fn saved_bytes(&self) -> u64 {
        self.saved_bytes
    }

    /// Entries evicted back into the pool to hold the byte budget.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

/// Returns `Err(cache)` when an entry for this exact key already exists
/// (its LRU stamp is refreshed); `Ok` increments `subtree_entries` along
/// the inserted path on unwind.
fn insert_rec(node: &mut Node, key: &[u16], cache: KvCache, stamp: u64) -> Result<(), KvCache> {
    let inserted = if key.is_empty() {
        if let Some(e) = &mut node.entry {
            e.last_used = stamp;
            return Err(cache);
        }
        node.entry = Some(Entry { cache, last_used: stamp });
        Ok(())
    } else {
        let child = node.children.entry(key[0]).or_default();
        insert_rec(child, &key[1..], cache, stamp)
    };
    if inserted.is_ok() {
        node.subtree_entries += 1;
    }
    inserted
}

fn find_lru(node: &Node, path: &mut Vec<u16>, best: &mut Option<(u64, Vec<u16>)>) {
    if let Some(e) = &node.entry {
        if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
            *best = Some((e.last_used, path.clone()));
        }
    }
    for (&tok, child) in &node.children {
        path.push(tok);
        find_lru(child, path, best);
        path.pop();
    }
}

fn visit_rec(node: &Node, f: &mut dyn FnMut(&KvCache)) {
    if let Some(e) = &node.entry {
        f(&e.cache);
    }
    for child in node.children.values() {
        visit_rec(child, f);
    }
}

/// Remove the entry at `key`, decrementing `subtree_entries` on the way
/// out and pruning child nodes whose subtree no longer holds any entry.
fn remove_rec(node: &mut Node, key: &[u16]) -> Option<Entry> {
    let removed = if key.is_empty() {
        node.entry.take()
    } else {
        let child = node.children.get_mut(&key[0])?;
        let e = remove_rec(child, &key[1..]);
        if e.is_some() && child.subtree_entries == 0 {
            node.children.remove(&key[0]);
        }
        e
    };
    if removed.is_some() {
        node.subtree_entries -= 1;
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::{prefill, ExecModel, ExecState};
    use crate::model::{Model, TransformerConfig};
    use crate::util::rng::Rng;

    /// 8-token pages over a 32-token context: pins span 1–4 pages.
    fn setup() -> (ExecModel, ExecState, KvPagePool) {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let m = Model::random(cfg, &mut Rng::new(90));
        (ExecModel::dense(&m), ExecState::new(cfg), KvPagePool::with_page_tokens(cfg, 8))
    }

    fn pinned(
        model: &ExecModel,
        st: &mut ExecState,
        pool: &mut KvPagePool,
        prompt: &[u16],
    ) -> KvCache {
        let mut c = pool.take_cache();
        c.reserve(pool, prompt.len());
        let _ = prefill(model, &mut c, prompt, st);
        c
    }

    #[test]
    fn probe_finds_longest_shared_prefix_capped_at_len_minus_one() {
        let (model, mut st, mut pool) = setup();
        let page = pool.page_bytes();
        let mut pc = PrefixCache::new(4 * page);
        let c = pinned(&model, &mut st, &mut pool, &[1, 2, 3, 4]);
        pc.insert(&[1, 2, 3, 4], c, &mut pool);
        assert_eq!(pc.entries(), 1);
        assert_eq!(pc.resident_bytes(), page, "a 4-token pin holds one 8-token page");

        // identical prompt: full depth minus the mandatory final prefill
        assert_eq!(pc.probe(&[1, 2, 3, 4]), 3);
        // longer prompt sharing the whole key: the key's full depth
        assert_eq!(pc.probe(&[1, 2, 3, 4, 9, 9]), 4);
        // divergence mid-key
        assert_eq!(pc.probe(&[1, 2, 9, 9]), 2);
        // single-token prompts never reuse (their one position is the
        // logits source)
        assert_eq!(pc.probe(&[1]), 0);
        assert_eq!(pc.probe(&[7, 7]), 0);
    }

    #[test]
    fn share_reproduces_cold_prefill_bitwise_and_copies_nothing() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * pool.page_bytes());
        let prompt = [3u16, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let donor = pinned(&model, &mut st, &mut pool, &prompt);
        pc.insert(&prompt, donor, &mut pool);

        // cold reference over the same prompt
        let mut cold = KvCache::with_page_tokens(&model.config, 8);
        let want = prefill(&model, &mut cold, &prompt, &mut st);

        let free_before = pool.free_pages();
        let mut dst = pool.take_cache();
        let depth = pc.share_into(&prompt, &mut dst);
        assert_eq!(depth, prompt.len() - 1);
        assert_eq!(dst.len(), depth);
        assert_eq!(pool.free_pages(), free_before, "a hit takes no pages from the pool");
        // the destination references the donor's pages verbatim
        assert!(dst.page_stats().all(|s| s.shared));
        let got = prefill(&model, &mut dst, &prompt[depth..], &mut st);
        // tail prefill over the shared prefix is bit-identical to the
        // cold last-row logits
        assert_eq!(got.row(0), want.row(prompt.len() - 1));
        assert_eq!((pc.lookups(), pc.hits()), (1, 1));
        assert_eq!(pc.saved_tokens(), depth as u64);
        assert_eq!(pc.saved_bytes(), (depth * dst.token_bytes()) as u64);
    }

    #[test]
    fn duplicate_insert_returns_pages_to_pool() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * pool.page_bytes());
        let a = pinned(&model, &mut st, &mut pool, &[5, 6, 7]);
        let b = pinned(&model, &mut st, &mut pool, &[5, 6, 7]);
        pc.insert(&[5, 6, 7], a, &mut pool);
        assert_eq!(pool.free_pages(), 0);
        pc.insert(&[5, 6, 7], b, &mut pool);
        assert_eq!(pc.entries(), 1, "duplicate prompt must not pin twice");
        assert_eq!(pool.free_pages(), 1, "surplus page returns to the pool");
    }

    #[test]
    fn insert_releases_generated_pages_beyond_the_prompt() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * pool.page_bytes());
        // 4 prompt tokens + 14 "generated" positions = 18 → 3 pages; the
        // prompt needs only 1. truncate_into must free the other 2.
        let long: Vec<u16> = (0..18).map(|i| (i % 31) as u16).collect();
        let c = pinned(&model, &mut st, &mut pool, &long);
        pc.insert(&long[..4], c, &mut pool);
        assert_eq!(pc.resident_bytes(), pool.page_bytes());
        assert_eq!(pool.free_pages(), 2, "pages past the prompt rejoin the pool");
    }

    #[test]
    fn lru_eviction_holds_budget_and_refills_pool() {
        let (model, mut st, mut pool) = setup();
        let page = pool.page_bytes();
        let mut pc = PrefixCache::new(2 * page);

        let c1 = pinned(&model, &mut st, &mut pool, &[1, 1, 1]);
        let c2 = pinned(&model, &mut st, &mut pool, &[2, 2, 2]);
        let c3 = pinned(&model, &mut st, &mut pool, &[3, 3, 3]);
        pc.insert(&[1, 1, 1], c1, &mut pool);
        pc.insert(&[2, 2, 2], c2, &mut pool);
        // touch [1,1,1] so [2,2,2] becomes the LRU entry
        let mut scratch = pool.take_cache();
        assert_eq!(pc.share_into(&[1, 1, 1, 4], &mut scratch), 3);
        pool.put_cache(scratch);

        let free_before = pool.free_pages();
        pc.insert(&[3, 3, 3], c3, &mut pool);
        assert_eq!(pc.entries(), 2);
        assert_eq!(pc.resident_bytes(), 2 * page);
        assert_eq!(pc.evictions(), 1);
        assert_eq!(pool.free_pages(), free_before + 1, "evicted pages rejoin the pool");
        // the LRU victim was [2,2,2]; the touched and the new entries remain
        assert_eq!(pc.probe(&[2, 2, 2, 9]), 0);
        assert_eq!(pc.probe(&[1, 1, 1, 9]), 3);
        assert_eq!(pc.probe(&[3, 3, 3, 9]), 3);
    }

    #[test]
    fn oversized_cache_is_never_pinned() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(pool.page_bytes() / 2);
        let c = pinned(&model, &mut st, &mut pool, &[4, 5]);
        pc.insert(&[4, 5], c, &mut pool);
        assert_eq!(pc.entries(), 0);
        assert_eq!(pc.resident_bytes(), 0);
        assert_eq!(pool.free_pages(), 1);
    }

    #[test]
    fn drain_returns_every_page_even_while_shared() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * pool.page_bytes());
        let prompt = [9u16, 8, 7, 6, 5, 4, 3, 2, 1, 0];
        let donor = pinned(&model, &mut st, &mut pool, &prompt);
        pc.insert(&prompt, donor, &mut pool); // 10 tokens → 2 pages pinned

        // a live reader shares the pinned pages, then the trie drains:
        // the fully-shared page must NOT hit the free list twice
        let mut live = pool.take_cache();
        let depth = pc.share_into(&prompt, &mut live);
        assert_eq!(depth, 9);
        pc.drain(&mut pool);
        assert_eq!(pc.entries(), 0);
        // page 0 (full, still referenced by `live`) stayed out; page 1
        // dropped to refcount 1 via the entry release... but `live` also
        // holds it (9 < 16 tokens → both pages), so nothing is free yet
        assert_eq!(pool.free_pages(), 0, "shared pages only come home with the reader");
        pool.put_cache(live);
        assert_eq!(pool.free_pages() as u64, pool.pages_created(), "no leak, no double-free");
    }
}
