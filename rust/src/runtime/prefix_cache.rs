//! Prefix-sharing KV reuse: a token-sequence trie that pins retired
//! requests' KV caches so later requests with a shared prompt prefix skip
//! most of their prefill.
//!
//! Real serving fleets overwhelmingly share prompt prefixes (system
//! prompts, few-shot templates). Cold admission pays a full `prefill` for
//! every prompt token, yet the K/V rows of a position depend only on the
//! tokens at or before it (causal attention; RoPE is a function of the
//! absolute position). Two prompts that agree on their first `d` tokens
//! therefore produce **bit-identical** K/V rows for positions `0..d` —
//! the kernels are deterministic and batch/thread-invariant (DESIGN.md
//! §7) — so those rows can be copied out of a previously computed cache
//! instead of recomputed. Copying is a pair of `memcpy`s per layer; a
//! prefill is seven projections, attention, and an MLP per layer per
//! token. That asymmetry is the entire win.
//!
//! **Structure.** A radix trie keyed on prompt tokens ([`Node`] per
//! token). When the scheduler retires a request it offers the prompt and
//! the request's [`KvCache`]; the cache is truncated back to the prompt
//! (decoded-token positions are dropped) and pinned at the trie node at
//! that depth. Each node's `subtree_entries` counts the pinned caches at
//! or below it — the ref-count that keeps interior nodes alive and lets
//! eviction prune paths that no longer lead to an entry.
//!
//! **Lookup.** [`probe`](PrefixCache::probe) walks a new prompt down the
//! trie and returns the deepest match, capped at `prompt.len() - 1`: the
//! last prompt position is always prefilled, because its logits produce
//! the request's first token. [`fork_into`](PrefixCache::fork_into) then
//! copies the matched prefix out of *any* pinned entry below the matched
//! node (they all share those tokens, so their leading rows are
//! bit-identical) into a pool-provided destination cache via
//! [`KvCache::copy_prefix_from`], and the scheduler prefills only the
//! prompt tail on top of it.
//!
//! **Eviction.** Pinned caches are full-size buffers, so the cache is
//! byte-budgeted: inserts beyond `budget_bytes` evict the least-recently
//! used entry (clock ticks are unique, so the order is total) and return
//! its cache to the [`KvCachePool`] — pinning borrows from the pool's
//! working set, eviction pays it back. A duplicate insert refreshes the
//! existing entry's LRU stamp and returns the new cache to the pool.
//!
//! The trie uses `BTreeMap` children so every walk (including the
//! pick-any-entry descent in `fork_into`) is deterministic: serving
//! output never depends on it (any entry yields identical bytes), but
//! stats and eviction order stay reproducible run over run.
//! `tests/prefix_cache.rs` pins the end-to-end property: prefix-hit
//! serving is token-identical to cold prefill for both backends and both
//! admission policies.

use crate::model::exec::{KvCache, KvCachePool};
use std::collections::BTreeMap;

/// One pinned KV prefix. `cache.len()` equals the depth of the node that
/// owns the entry (the number of prompt tokens whose K/V rows it holds).
struct Entry {
    cache: KvCache,
    /// LRU clock tick of the last fork or insert that touched this entry.
    last_used: u64,
}

#[derive(Default)]
struct Node {
    children: BTreeMap<u16, Node>,
    /// A cache pinned at exactly this node's depth, if any.
    entry: Option<Entry>,
    /// Pinned entries at or below this node. Every live node has ≥ 1
    /// (nodes are pruned when their last entry is evicted), which is what
    /// makes any `probe` depth forkable.
    subtree_entries: usize,
}

/// The prefix-sharing KV cache. See the module docs for the design.
pub struct PrefixCache {
    root: Node,
    budget_bytes: usize,
    resident_bytes: usize,
    entries: usize,
    clock: u64,
    lookups: u64,
    hits: u64,
    saved_tokens: u64,
    evictions: u64,
}

impl PrefixCache {
    /// Byte budget covers the pinned caches' buffers; a single cache
    /// larger than the budget is never pinned (the cache degrades to a
    /// no-op rather than thrash).
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            root: Node::default(),
            budget_bytes,
            resident_bytes: 0,
            entries: 0,
            clock: 0,
            lookups: 0,
            hits: 0,
            saved_tokens: 0,
            evictions: 0,
        }
    }

    /// Longest reusable prefix of `prompt`, capped at `prompt.len() - 1`
    /// so the final prompt position (whose logits yield the first output
    /// token) is always prefilled. Read-only: no LRU touch, no counters —
    /// the scheduler probes for budget accounting before committing to an
    /// admission, then forks.
    pub fn probe(&self, prompt: &[u16]) -> usize {
        let cap = prompt.len().saturating_sub(1);
        let mut node = &self.root;
        let mut depth = 0;
        while depth < cap {
            match node.children.get(&prompt[depth]) {
                Some(child) => {
                    node = child;
                    depth += 1;
                }
                None => break,
            }
        }
        depth
    }

    /// Copy the longest cached prefix of `prompt` into `dst` (a fresh or
    /// reset cache from the pool) and return its length; `dst` ends at
    /// exactly that depth, ready for a tail prefill. Returns 0 on a miss
    /// (`dst` untouched). Counts the lookup, the hit, and the saved
    /// prefill tokens, and refreshes the source entry's LRU stamp.
    pub fn fork_into(&mut self, prompt: &[u16], dst: &mut KvCache) -> usize {
        self.lookups += 1;
        let depth = self.probe(prompt);
        if depth == 0 {
            return 0;
        }
        let mut node = &mut self.root;
        for &t in &prompt[..depth] {
            node = node.children.get_mut(&t).expect("probed path exists");
        }
        // Any entry below the matched node shares the first `depth`
        // tokens, so its leading rows are bit-identical; take the
        // smallest-token descent for determinism.
        while node.entry.is_none() {
            node = node
                .children
                .values_mut()
                .next()
                .expect("interior trie node with no entry below it");
        }
        let e = node.entry.as_mut().unwrap();
        debug_assert!(e.cache.len() >= depth, "pinned entry shorter than its trie depth");
        dst.copy_prefix_from(&e.cache, depth);
        e.last_used = self.clock;
        self.clock += 1;
        self.hits += 1;
        self.saved_tokens += depth as u64;
        depth
    }

    /// Pin a retired request's cache under its prompt. The cache is
    /// truncated back to the prompt (generated-token positions dropped);
    /// if an entry for this exact prompt already exists, or the cache
    /// alone exceeds the budget, the cache goes straight back to `pool`.
    /// Inserting may evict least-recently-used entries into `pool` until
    /// the byte budget holds again.
    pub fn insert(&mut self, prompt: &[u16], mut cache: KvCache, pool: &mut KvCachePool) {
        if prompt.is_empty() || cache.bytes() > self.budget_bytes {
            pool.put(cache);
            return;
        }
        assert!(
            cache.len() >= prompt.len(),
            "pinned cache ({} positions) must cover the prompt ({})",
            cache.len(),
            prompt.len()
        );
        cache.truncate(prompt.len());
        let bytes = cache.bytes();
        let stamp = self.clock;
        self.clock += 1;
        match insert_rec(&mut self.root, prompt, cache, stamp) {
            Ok(()) => {
                self.entries += 1;
                self.resident_bytes += bytes;
                self.evict_to_budget(pool);
            }
            // Exact prompt already pinned: its LRU stamp was refreshed;
            // the offered cache is surplus.
            Err(dup) => pool.put(dup),
        }
    }

    fn evict_to_budget(&mut self, pool: &mut KvCachePool) {
        while self.resident_bytes > self.budget_bytes {
            let mut path = Vec::new();
            let mut lru: Option<(u64, Vec<u16>)> = None;
            find_lru(&self.root, &mut path, &mut lru);
            let (_, key) = lru.expect("over budget implies at least one entry");
            let e = remove_rec(&mut self.root, &key).expect("LRU path resolves to an entry");
            self.resident_bytes -= e.cache.bytes();
            self.entries -= 1;
            self.evictions += 1;
            pool.put(e.cache);
        }
    }

    /// Pinned caches currently held.
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Bytes of the pinned caches' buffers.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Forks attempted (one per admission when the cache is enabled).
    pub fn lookups(&self) -> u64 {
        self.lookups
    }

    /// Forks that reused a non-empty prefix.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Prompt tokens served by copy instead of prefill.
    pub fn saved_tokens(&self) -> u64 {
        self.saved_tokens
    }

    /// Entries evicted back into the pool to hold the byte budget.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

/// Returns `Err(cache)` when an entry for this exact key already exists
/// (its LRU stamp is refreshed); `Ok` increments `subtree_entries` along
/// the inserted path on unwind.
fn insert_rec(node: &mut Node, key: &[u16], cache: KvCache, stamp: u64) -> Result<(), KvCache> {
    let inserted = if key.is_empty() {
        if let Some(e) = &mut node.entry {
            e.last_used = stamp;
            return Err(cache);
        }
        node.entry = Some(Entry { cache, last_used: stamp });
        Ok(())
    } else {
        let child = node.children.entry(key[0]).or_default();
        insert_rec(child, &key[1..], cache, stamp)
    };
    if inserted.is_ok() {
        node.subtree_entries += 1;
    }
    inserted
}

fn find_lru(node: &Node, path: &mut Vec<u16>, best: &mut Option<(u64, Vec<u16>)>) {
    if let Some(e) = &node.entry {
        if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
            *best = Some((e.last_used, path.clone()));
        }
    }
    for (&tok, child) in &node.children {
        path.push(tok);
        find_lru(child, path, best);
        path.pop();
    }
}

/// Remove the entry at `key`, decrementing `subtree_entries` on the way
/// out and pruning child nodes whose subtree no longer holds any entry.
fn remove_rec(node: &mut Node, key: &[u16]) -> Option<Entry> {
    let removed = if key.is_empty() {
        node.entry.take()
    } else {
        let child = node.children.get_mut(&key[0])?;
        let e = remove_rec(child, &key[1..]);
        if e.is_some() && child.subtree_entries == 0 {
            node.children.remove(&key[0]);
        }
        e
    };
    if removed.is_some() {
        node.subtree_entries -= 1;
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::{prefill, ExecModel, ExecState};
    use crate::model::{Model, TransformerConfig};
    use crate::util::rng::Rng;

    fn setup() -> (ExecModel, ExecState, KvCachePool) {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let m = Model::random(cfg, &mut Rng::new(90));
        (ExecModel::dense(&m), ExecState::new(cfg), KvCachePool::new(cfg))
    }

    fn pinned(
        model: &ExecModel,
        st: &mut ExecState,
        pool: &mut KvCachePool,
        prompt: &[u16],
    ) -> KvCache {
        let mut c = pool.take();
        let _ = prefill(model, &mut c, prompt, st);
        c
    }

    #[test]
    fn probe_finds_longest_shared_prefix_capped_at_len_minus_one() {
        let (model, mut st, mut pool) = setup();
        let cache_bytes = KvCache::new(&model.config).bytes();
        let mut pc = PrefixCache::new(4 * cache_bytes);
        let c = pinned(&model, &mut st, &mut pool, &[1, 2, 3, 4]);
        pc.insert(&[1, 2, 3, 4], c, &mut pool);
        assert_eq!(pc.entries(), 1);
        assert_eq!(pc.resident_bytes(), cache_bytes);

        // identical prompt: full depth minus the mandatory final prefill
        assert_eq!(pc.probe(&[1, 2, 3, 4]), 3);
        // longer prompt sharing the whole key: the key's full depth
        assert_eq!(pc.probe(&[1, 2, 3, 4, 9, 9]), 4);
        // divergence mid-key
        assert_eq!(pc.probe(&[1, 2, 9, 9]), 2);
        // single-token prompts never reuse (their one position is the
        // logits source)
        assert_eq!(pc.probe(&[1]), 0);
        assert_eq!(pc.probe(&[7, 7]), 0);
    }

    #[test]
    fn fork_reproduces_cold_prefill_bitwise() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * KvCache::new(&model.config).bytes());
        let prompt = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let donor = pinned(&model, &mut st, &mut pool, &prompt);
        pc.insert(&prompt, donor, &mut pool);

        // cold reference over the same prompt
        let mut cold = KvCache::new(&model.config);
        let want = prefill(&model, &mut cold, &prompt, &mut st);

        let mut dst = pool.take();
        let depth = pc.fork_into(&prompt, &mut dst);
        assert_eq!(depth, prompt.len() - 1);
        assert_eq!(dst.len(), depth);
        let got = prefill(&model, &mut dst, &prompt[depth..], &mut st);
        // tail prefill over the forked prefix is bit-identical to the
        // cold last-row logits
        assert_eq!(got.row(0), want.row(prompt.len() - 1));
        assert_eq!((pc.lookups(), pc.hits()), (1, 1));
        assert_eq!(pc.saved_tokens(), depth as u64);
    }

    #[test]
    fn duplicate_insert_returns_cache_to_pool() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(8 * KvCache::new(&model.config).bytes());
        let a = pinned(&model, &mut st, &mut pool, &[5, 6, 7]);
        let b = pinned(&model, &mut st, &mut pool, &[5, 6, 7]);
        pc.insert(&[5, 6, 7], a, &mut pool);
        assert_eq!(pool.free_caches(), 0);
        pc.insert(&[5, 6, 7], b, &mut pool);
        assert_eq!(pc.entries(), 1, "duplicate prompt must not pin twice");
        assert_eq!(pool.free_caches(), 1, "surplus cache returns to the pool");
    }

    #[test]
    fn lru_eviction_holds_budget_and_refills_pool() {
        let (model, mut st, mut pool) = setup();
        let cache_bytes = KvCache::new(&model.config).bytes();
        let mut pc = PrefixCache::new(2 * cache_bytes);

        let c1 = pinned(&model, &mut st, &mut pool, &[1, 1, 1]);
        let c2 = pinned(&model, &mut st, &mut pool, &[2, 2, 2]);
        let c3 = pinned(&model, &mut st, &mut pool, &[3, 3, 3]);
        pc.insert(&[1, 1, 1], c1, &mut pool);
        pc.insert(&[2, 2, 2], c2, &mut pool);
        // touch [1,1,1] so [2,2,2] becomes the LRU entry
        let mut scratch = pool.take();
        assert_eq!(pc.fork_into(&[1, 1, 1, 4], &mut scratch), 3);
        pool.put(scratch);

        let free_before = pool.free_caches();
        pc.insert(&[3, 3, 3], c3, &mut pool);
        assert_eq!(pc.entries(), 2);
        assert_eq!(pc.resident_bytes(), 2 * cache_bytes);
        assert_eq!(pc.evictions(), 1);
        assert_eq!(pool.free_caches(), free_before + 1, "evicted cache rejoins the pool");
        // the LRU victim was [2,2,2]; the touched and the new entries remain
        assert_eq!(pc.probe(&[2, 2, 2, 9]), 0);
        assert_eq!(pc.probe(&[1, 1, 1, 9]), 3);
        assert_eq!(pc.probe(&[3, 3, 3, 9]), 3);
    }

    #[test]
    fn oversized_cache_is_never_pinned() {
        let (model, mut st, mut pool) = setup();
        let mut pc = PrefixCache::new(KvCache::new(&model.config).bytes() / 2);
        let c = pinned(&model, &mut st, &mut pool, &[4, 5]);
        pc.insert(&[4, 5], c, &mut pool);
        assert_eq!(pc.entries(), 0);
        assert_eq!(pc.resident_bytes(), 0);
        assert_eq!(pool.free_caches(), 1);
    }
}
