//! Continuous-batching serving runtime over the packed execution backend.
//!
//! PR 1's serve path prefilled a *fixed* set of requests and decoded them
//! in lockstep; a slot whose request finished early sat idle until the
//! whole batch drained. The [`Scheduler`] here is the production shape:
//! requests are [`submit`](Scheduler::submit)ted into a FIFO admission
//! queue at any time, and every engine [`step`](Scheduler::step)
//!
//! 1. **admits** queued requests into free slots of the live batch, up to
//!    a slot bound and a per-step prefill token budget, prefilling each
//!    one (its first token comes from the prefill logits);
//! 2. runs **one fused [`decode_step`]** across every live request —
//!    requests sit at arbitrary, unequal cache depths, and per-row results
//!    are independent of batch composition, so outputs are token-identical
//!    to running each request alone (`tests/scheduler.rs` pins this);
//! 3. **retires** finished requests immediately (their [`KvCache`] pages
//!    go back to the [`KvPagePool`]) and **backfills** the freed slots
//!    from the queue in the same step.
//!
//! [`AdmissionPolicy::Wave`] disables backfill (admission only into an
//! empty batch), which reproduces the PR-1 static-batching behaviour on
//! the same engine — the baseline the example and the scheduler bench
//! compare against.
//!
//! **KV memory is paged** ([`SchedulerConfig::kv_page_tokens`]): a live
//! request holds `ceil(len / page_tokens)` fixed-size pages drawn from the
//! pool, not a full-context buffer, and the scheduler `reserve`s one
//! position per slot from the pool before each fused decode so the hot
//! loop never allocates. With [`SchedulerConfig::prefix_cache_bytes`] > 0,
//! admission consults a [`PrefixCache`]: retired requests pin their
//! prompt's KV pages in a token trie, and a new request whose prompt
//! shares a cached prefix **shares those pages** — O(pages) refcount
//! bumps, zero KV bytes copied ([`KvCache::share_prefix_from`]); the
//! memcpy the pre-paging fork paid is tracked as
//! [`SchedulerStats::shared_kv_bytes_saved`] — and prefills **only the
//! prompt tail**, whose first append forks the shared partial tail page
//! copy-on-write. Because prefill and decode are deterministic and
//! batch-invariant, prefix-hit paged serving is token-identical to cold
//! prefill (`tests/prefix_cache.rs` pins this); only the step at which a
//! request is admitted can shift, since saved tokens free prefill budget.
//!
//! With [`SchedulerConfig::kv_quant_bits`] > 0 (off by default), pages
//! that have fallen at least `kv_quant_margin` positions behind a
//! request's decode head are re-encoded after each step as per-page
//! k-means codebooks ([`KvCache::quantize_cold_pages`]) and read back
//! through scratch during attention. This is **lossy**: outputs are
//! tolerance-gated, never bit-compared (DESIGN.md §13), which is why it
//! is opt-in while paging itself is contract-identical.
//!
//! **Overload is a defined state, not an abort**: the pool can carry a
//! hard byte budget ([`SchedulerConfig::kv_budget_bytes`]) so a page take
//! can *fail*, and a failed take walks a degradation ladder — (1) evict a
//! pinned prefix, (2) force cold-page quantization (only when enabled —
//! it is lossy), (3) preempt the youngest live request, re-queueing it
//! with `prompt ++ generated` as the new prompt so resume is a plain
//! prefill, **bit-identical** to never having been preempted, and (4)
//! shed load: [`SchedulerConfig::max_queue`] overflow and requests that
//! could never fit the budget are answered with a structured
//! [`FinishReason::Rejected`] completion. Requests can also be
//! [`cancel`](Scheduler::cancel)led — queued or live, pages freed the
//! same step — and carry per-request step deadlines
//! ([`Scheduler::submit_with_deadline`]). `tests/chaos.rs` drives all of
//! this under seeded fault injection (`util/failpoint.rs`) and asserts
//! page hygiene plus survivor bit-identity; DESIGN.md §14 has the ladder
//! and the bit-identity argument.
//!
//! Residency accounting is distinct-page: [`SchedulerStats`] counts every
//! page once no matter how many tables (live slots, pinned prefixes)
//! reference it.
//!
//! The scheduler is deliberately synchronous and single-threaded: one
//! `step` call is one unit of engine work, and the caller owns the clock
//! (wall-time arrivals in `examples/serve_quantized.rs`, step-domain
//! arrivals in the bench and tests). Parallelism lives *below* it, in the
//! thread-sharded `LinearOp` kernels, which keeps admission decisions
//! deterministic and testable.

use super::prefix_cache::PrefixCache;
use crate::model::exec::{
    argmax, decode_step, prefill, ExecModel, ExecState, KvCache, KvPagePool, DEFAULT_PAGE_TOKENS,
};
use crate::model::TransformerConfig;
use crate::quant::kvpage::MAX_KV_QUANT_BITS;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<u16>,
    /// Generation stops after this many new tokens…
    pub max_new_tokens: usize,
    /// …or as soon as this token is produced (it is kept in the output).
    pub stop_token: Option<u16>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Produced the stop token.
    Stop,
    /// Removed by [`Scheduler::cancel`]; `tokens` holds the partial
    /// output generated so far (possibly empty when still queued).
    Cancelled,
    /// Still unfinished past its step deadline
    /// ([`Scheduler::submit_with_deadline`]); `tokens` holds the partial
    /// output.
    DeadlineExceeded,
    /// Shed at submission: the queue was full
    /// ([`SchedulerConfig::max_queue`]) or the request's full KV
    /// footprint could never fit [`SchedulerConfig::kv_budget_bytes`].
    Rejected,
}

impl FinishReason {
    /// Reasons carrying a complete generation — the only retirements
    /// whose caches are worth pinning in the prefix cache.
    pub fn is_success(self) -> bool {
        matches!(self, FinishReason::Length | FinishReason::Stop)
    }
}

/// A finished request, in retirement order.
#[derive(Clone, Debug)]
pub struct Completion {
    /// Id assigned by [`Scheduler::submit`].
    pub id: u64,
    pub prompt_len: usize,
    /// Generated continuation (first token from prefill, rest from decode
    /// steps; includes the stop token when one fired).
    pub tokens: Vec<u16>,
    pub reason: FinishReason,
    /// Engine step (1-based) that first prefilled the request — the step
    /// its first token appeared (preserved across preemptions, so TTFT
    /// math stays honest). `0` when the request never held a slot
    /// (rejected, or cancelled / deadlined while queued).
    pub admitted_step: u64,
    /// Engine step that produced its last token.
    pub finished_step: u64,
}

/// How freed slots are refilled.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Continuous batching: admit whenever a slot is free (including the
    /// backfill pass after retirement within the same step).
    #[default]
    Continuous,
    /// Static batching: admit only into an *empty* live batch, then drain
    /// the wave completely — the PR-1 lockstep serve path, kept as the
    /// comparison baseline.
    Wave,
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Upper bound on the live batch (decode rows per step). Must not
    /// exceed the `ExecState` row capacity the engine is driven with.
    pub max_slots: usize,
    /// Soft cap on prompt tokens prefilled per engine step; admission
    /// stops once the budget is spent. The first prefill of a step always
    /// goes through, so an oversized prompt cannot starve. Prefix-cache
    /// hits charge only the prompt tail they actually prefill.
    pub prefill_token_budget: usize,
    pub policy: AdmissionPolicy,
    /// Byte budget for the prefix-sharing KV cache (`0` disables it).
    /// Pinned prefixes hold page refcounts, so the budget bounds the
    /// extra KV pages serving keeps alive beyond the live batch.
    pub prefix_cache_bytes: usize,
    /// Tokens per KV page (`0` → [`DEFAULT_PAGE_TOKENS`]; clamped to
    /// `1..=max_seq` by the pool). Purely a memory-granularity knob:
    /// outputs are bit-identical across page sizes.
    pub kv_page_tokens: usize,
    /// Codebook width for cold-page KV quantization, `0` = off (the
    /// default — quantized KV is lossy and tolerance-gated).
    pub kv_quant_bits: u8,
    /// A page is re-encoded only once it lies wholly at least this many
    /// positions behind the request's decode head.
    pub kv_quant_margin: usize,
    /// Hard byte budget for the KV page pool (`0` = unbounded, the
    /// default). A take that would push the pool's f32 pages past the
    /// budget fails instead of allocating, and the scheduler walks the
    /// degradation ladder (module docs; DESIGN.md §14). Quantized cold
    /// pages live outside the pool and are not charged — they are what
    /// rung 2 converts budgeted f32 pages *into*.
    pub kv_budget_bytes: usize,
    /// Upper bound on queued (not yet admitted) requests; a submission
    /// past it is answered with [`FinishReason::Rejected`] instead of
    /// growing the queue forever. `0` = unbounded (the default).
    pub max_queue: usize,
    /// Default step deadline stamped on every
    /// [`submit`](Scheduler::submit): a request still unfinished once
    /// this many engine steps have elapsed past its submission step
    /// finishes as [`FinishReason::DeadlineExceeded`], whether queued or
    /// live. `0` = no deadline (the default); per-request override via
    /// [`Scheduler::submit_with_deadline`].
    pub deadline_steps: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_slots: 8,
            prefill_token_budget: 512,
            policy: AdmissionPolicy::Continuous,
            prefix_cache_bytes: 0,
            kv_page_tokens: DEFAULT_PAGE_TOKENS,
            kv_quant_bits: 0,
            kv_quant_margin: 128,
            kv_budget_bytes: 0,
            max_queue: 0,
            deadline_steps: 0,
        }
    }
}

impl SchedulerConfig {
    /// The validating front door for configs assembled from user input
    /// (CLI flags, bench scenarios, example drivers): unset knobs take the
    /// [`Default`] values, and [`SchedulerConfigBuilder::build`] rejects
    /// incoherent combinations with a typed error instead of letting them
    /// panic (or silently misbehave) inside the engine. Struct literals
    /// remain available for tests that construct configs wholesale.
    pub fn builder() -> SchedulerConfigBuilder {
        SchedulerConfigBuilder::default()
    }
}

/// An incoherent knob combination rejected by
/// [`SchedulerConfigBuilder::build`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchedulerConfigError {
    /// `max_slots == 0`: the engine needs at least one live slot.
    ZeroSlots,
    /// `prefill_token_budget == 0`: a zero budget admits nothing, ever.
    ZeroPrefillBudget,
    /// `kv_quant_bits` wider than the cold-page codec supports.
    KvQuantBitsTooWide { bits: u8 },
    /// `kv_quant_margin` was set while `kv_quant_bits` is 0 (cold-page
    /// quantization off) — the margin would silently do nothing.
    MarginWithoutQuant,
    /// A bounded `kv_budget_bytes` with `max_queue == 0`: under memory
    /// pressure preempted requests re-queue, so an unbounded queue turns a
    /// byte budget into unbounded buffering instead of shedding load.
    BudgetWithoutQueueBound,
}

impl std::fmt::Display for SchedulerConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ZeroSlots => write!(f, "max_slots must be >= 1"),
            Self::ZeroPrefillBudget => {
                write!(f, "prefill_token_budget must be >= 1 (a zero budget admits nothing)")
            }
            Self::KvQuantBitsTooWide { bits } => {
                write!(f, "kv_quant_bits ({bits}) exceeds the {MAX_KV_QUANT_BITS}-bit codec")
            }
            Self::MarginWithoutQuant => {
                write!(f, "kv_quant_margin set while kv_quant_bits is 0 (quantization off)")
            }
            Self::BudgetWithoutQueueBound => write!(
                f,
                "bounded kv_budget_bytes needs a bounded max_queue: preemption re-queues \
                 requests, so an unbounded queue defeats the byte budget"
            ),
        }
    }
}

impl std::error::Error for SchedulerConfigError {}

/// Builder for [`SchedulerConfig`]; see [`SchedulerConfig::builder`].
/// Every setter overrides one knob; `build` validates the combination.
/// Passing a knob its default value is always accepted (so CLI plumbing
/// can forward flag defaults unconditionally) — the cross-knob checks fire
/// only on combinations that cannot mean what they say, e.g. a quantizer
/// margin with quantization off.
#[derive(Clone, Debug, Default)]
pub struct SchedulerConfigBuilder {
    max_slots: Option<usize>,
    prefill_token_budget: Option<usize>,
    policy: Option<AdmissionPolicy>,
    prefix_cache_bytes: Option<usize>,
    kv_page_tokens: Option<usize>,
    kv_quant_bits: Option<u8>,
    kv_quant_margin: Option<usize>,
    kv_budget_bytes: Option<usize>,
    max_queue: Option<usize>,
    deadline_steps: Option<u64>,
}

impl SchedulerConfigBuilder {
    pub fn max_slots(mut self, v: usize) -> Self {
        self.max_slots = Some(v);
        self
    }

    pub fn prefill_token_budget(mut self, v: usize) -> Self {
        self.prefill_token_budget = Some(v);
        self
    }

    pub fn policy(mut self, v: AdmissionPolicy) -> Self {
        self.policy = Some(v);
        self
    }

    pub fn prefix_cache_bytes(mut self, v: usize) -> Self {
        self.prefix_cache_bytes = Some(v);
        self
    }

    pub fn kv_page_tokens(mut self, v: usize) -> Self {
        self.kv_page_tokens = Some(v);
        self
    }

    pub fn kv_quant_bits(mut self, v: u8) -> Self {
        self.kv_quant_bits = Some(v);
        self
    }

    pub fn kv_quant_margin(mut self, v: usize) -> Self {
        self.kv_quant_margin = Some(v);
        self
    }

    pub fn kv_budget_bytes(mut self, v: usize) -> Self {
        self.kv_budget_bytes = Some(v);
        self
    }

    pub fn max_queue(mut self, v: usize) -> Self {
        self.max_queue = Some(v);
        self
    }

    pub fn deadline_steps(mut self, v: u64) -> Self {
        self.deadline_steps = Some(v);
        self
    }

    pub fn build(self) -> Result<SchedulerConfig, SchedulerConfigError> {
        let d = SchedulerConfig::default();
        let cfg = SchedulerConfig {
            max_slots: self.max_slots.unwrap_or(d.max_slots),
            prefill_token_budget: self.prefill_token_budget.unwrap_or(d.prefill_token_budget),
            policy: self.policy.unwrap_or(d.policy),
            prefix_cache_bytes: self.prefix_cache_bytes.unwrap_or(d.prefix_cache_bytes),
            kv_page_tokens: self.kv_page_tokens.unwrap_or(d.kv_page_tokens),
            kv_quant_bits: self.kv_quant_bits.unwrap_or(d.kv_quant_bits),
            kv_quant_margin: self.kv_quant_margin.unwrap_or(d.kv_quant_margin),
            kv_budget_bytes: self.kv_budget_bytes.unwrap_or(d.kv_budget_bytes),
            max_queue: self.max_queue.unwrap_or(d.max_queue),
            deadline_steps: self.deadline_steps.unwrap_or(d.deadline_steps),
        };
        if cfg.max_slots == 0 {
            return Err(SchedulerConfigError::ZeroSlots);
        }
        if cfg.prefill_token_budget == 0 {
            return Err(SchedulerConfigError::ZeroPrefillBudget);
        }
        if cfg.kv_quant_bits > MAX_KV_QUANT_BITS {
            return Err(SchedulerConfigError::KvQuantBitsTooWide { bits: cfg.kv_quant_bits });
        }
        // Explicitly-set-to-zero bits means "quantization off" like unset
        // bits do; the margin check fires only when a margin was *set*
        // while quantization is off.
        if self.kv_quant_margin.is_some() && cfg.kv_quant_bits == 0 {
            return Err(SchedulerConfigError::MarginWithoutQuant);
        }
        if cfg.kv_budget_bytes > 0 && cfg.max_queue == 0 {
            return Err(SchedulerConfigError::BudgetWithoutQueueBound);
        }
        Ok(cfg)
    }
}

/// Counters for the serving report; pool numbers come straight from the
/// [`KvPagePool`], residency from a distinct-page walk over every live
/// and pinned page table (each shared page counted once).
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub steps: u64,
    /// Fused decode calls (≤ steps; idle steps don't decode).
    pub decode_batches: u64,
    /// Tokens produced by decode steps.
    pub decoded_tokens: u64,
    /// Tokens produced by prefill (one per admission).
    pub prefill_tokens_out: u64,
    /// Prompt tokens actually prefilled (prefix-cache hits skip the
    /// shared prefix, so this counts only the tails that ran).
    pub prefill_tokens_in: u64,
    /// Prompt tokens served by prefix-page sharing instead of prefill.
    pub prefill_tokens_saved: u64,
    pub completed: u64,
    pub peak_live: usize,
    /// Page takes served from the pool's free list / by allocation.
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// Bytes of the pooled (free) pages.
    pub pool_resident_bytes: usize,
    pub pool_hit_rate: f64,
    pub pool_free_pages: usize,
    /// Pages the pool ever allocated; equals `pool_free_pages` once every
    /// request retired and the prefix cache drained (no leak, no
    /// double-free — `tests/paged_kv.rs` pins this).
    pub pool_pages_created: u64,
    /// Prefix-cache probes (one per admission when enabled).
    pub prefix_lookups: u64,
    /// Admissions that reused a non-empty cached prefix.
    pub prefix_hits: u64,
    pub prefix_entries: usize,
    pub prefix_resident_bytes: usize,
    pub prefix_evictions: u64,
    /// KV bytes prefix hits would have memcpy'd under the pre-paging
    /// contiguous fork — now pure page sharing.
    pub shared_kv_bytes_saved: u64,
    /// Distinct KV pages (and their bytes) currently referenced by live
    /// slots + pinned prefixes, each page counted once.
    pub kv_pages_resident: usize,
    pub kv_pages_shared: usize,
    pub kv_pages_quantized: usize,
    pub kv_resident_bytes: usize,
    /// High-water mark of `kv_resident_bytes`, sampled after admission
    /// each step.
    pub peak_kv_resident_bytes: usize,
    /// Pages re-encoded by cold-page quantization over the run.
    pub kv_pages_quantized_total: u64,
    /// Submissions shed with [`FinishReason::Rejected`] (queue full or
    /// budget-infeasible).
    pub rejected: u64,
    /// Requests removed by [`Scheduler::cancel`] (queued or live).
    pub cancelled: u64,
    /// Requests retired past their step deadline.
    pub deadline_exceeded: u64,
    /// Times a live request was preempted back into the queue under
    /// memory pressure (one request may count more than once).
    pub preempted: u64,
    /// Admissions that resumed a previously preempted request.
    pub resumed: u64,
    /// Page takes the pool refused (byte budget exhausted, or an
    /// injected `pool_take` failpoint).
    pub pool_failed_takes: u64,
}

/// Distinct-page residency snapshot (shared pages counted once).
#[derive(Clone, Copy, Debug, Default)]
struct KvCensus {
    pages: usize,
    shared: usize,
    quantized: usize,
    bytes: usize,
}

/// A queued request: fresh from [`Scheduler::submit`], or a preempted
/// live request waiting to resume. For a preempted request `prompt` is
/// `original prompt ++ generated`, so resuming is a plain prefill — the
/// deterministic, batch-invariant kernels make it bit-identical to never
/// having been preempted (DESIGN.md §14) — and `generated` carries the
/// tokens produced before preemption so the final [`Completion`] reports
/// the full output.
struct Queued {
    id: u64,
    prompt: Vec<u16>,
    max_new: usize,
    stop: Option<u16>,
    generated: Vec<u16>,
    /// Length of the prompt as submitted ([`Completion::prompt_len`]
    /// reports this, not the preemption-extended prompt).
    orig_prompt_len: usize,
    /// `step_no` at submission — the deadline clock's epoch.
    submit_step: u64,
    /// Steps past `submit_step` this request may stay unfinished
    /// (`0` = no deadline).
    deadline_steps: u64,
    /// Step of the first admission (`0` = never admitted), preserved
    /// across preemptions for [`Completion::admitted_step`].
    first_admitted_step: u64,
}

/// A live request occupying one batch slot. The prompt is kept so the
/// retired cache can be pinned under it in the prefix cache.
struct Slot {
    id: u64,
    cache: KvCache,
    prompt: Vec<u16>,
    max_new: usize,
    stop: Option<u16>,
    generated: Vec<u16>,
    admitted_step: u64,
    orig_prompt_len: usize,
    submit_step: u64,
    deadline_steps: u64,
}

impl Slot {
    /// Invariant: admission seeds `generated` with the prefill token
    /// before a `Slot` is ever built, so it is never empty. Checked in
    /// debug; release falls back to "not finished" / token 0 instead of
    /// panicking mid-serve.
    fn finished(&self) -> bool {
        match self.generated.last() {
            Some(&last) => self.generated.len() >= self.max_new || self.stop == Some(last),
            None => {
                debug_assert!(false, "slot holds ≥1 generated token");
                false
            }
        }
    }

    /// The token the next decode step feeds (see [`Slot::finished`] for
    /// the non-empty invariant).
    fn last_token(&self) -> u16 {
        debug_assert!(!self.generated.is_empty(), "slot holds ≥1 generated token");
        self.generated.last().copied().unwrap_or_default()
    }
}

/// The continuous-batching engine front-end. See the module docs for the
/// step anatomy.
pub struct Scheduler {
    model_cfg: TransformerConfig,
    cfg: SchedulerConfig,
    queue: VecDeque<Queued>,
    slots: Vec<Slot>,
    pool: KvPagePool,
    prefix: Option<PrefixCache>,
    /// Completions produced *between* steps (submission-time rejections,
    /// so far), delivered by the next [`step`](Scheduler::step).
    pending: Vec<Completion>,
    next_id: u64,
    step_no: u64,
    decode_batches: u64,
    decoded_tokens: u64,
    prefill_tokens_in: u64,
    prefill_tokens_out: u64,
    completed: u64,
    rejected: u64,
    cancelled: u64,
    deadline_exceeded: u64,
    preempted: u64,
    resumed: u64,
    peak_live: usize,
    peak_kv_resident_bytes: usize,
    kv_pages_quantized_total: u64,
}

impl Scheduler {
    pub fn new(model_cfg: TransformerConfig, cfg: SchedulerConfig) -> Self {
        assert!(cfg.max_slots >= 1, "scheduler needs at least one slot");
        assert!(cfg.prefill_token_budget >= 1, "zero prefill budget admits nothing");
        assert!(
            cfg.kv_quant_bits <= MAX_KV_QUANT_BITS,
            "kv_quant_bits ({}) exceeds the {MAX_KV_QUANT_BITS}-bit codec",
            cfg.kv_quant_bits
        );
        let page_tokens =
            if cfg.kv_page_tokens == 0 { DEFAULT_PAGE_TOKENS } else { cfg.kv_page_tokens };
        // Pre-warm the pool to the live-batch bound (pages for max_slots
        // full-context requests): steady-state serving then allocates
        // nothing. Prefix pins hold refcounts on this working set; the
        // pool allocates replacement pages on demand.
        let pool =
            KvPagePool::with_budget_paged(model_cfg, page_tokens, cfg.kv_budget_bytes, cfg.max_slots);
        let prefix = (cfg.prefix_cache_bytes > 0).then(|| PrefixCache::new(cfg.prefix_cache_bytes));
        Self {
            model_cfg,
            cfg,
            queue: VecDeque::new(),
            slots: Vec::new(),
            pool,
            prefix,
            pending: Vec::new(),
            next_id: 0,
            step_no: 0,
            decode_batches: 0,
            decoded_tokens: 0,
            prefill_tokens_in: 0,
            prefill_tokens_out: 0,
            completed: 0,
            rejected: 0,
            cancelled: 0,
            deadline_exceeded: 0,
            preempted: 0,
            resumed: 0,
            peak_live: 0,
            peak_kv_resident_bytes: 0,
            kv_pages_quantized_total: 0,
        }
    }

    /// Arm (or replace) the page pool's failpoint set — the chaos suite's
    /// deterministic injection path. Production arming goes through the
    /// `CLAQ_FAILPOINTS` env var at pool construction.
    pub fn set_failpoints(&mut self, fp: std::sync::Arc<crate::util::failpoint::Failpoints>) {
        self.pool.set_failpoints(fp);
    }

    /// Enqueue a request; returns the id its [`Completion`] will carry.
    /// `Err` means a *caller* bug (empty prompt, zero token budget,
    /// prompt + generation overflowing the context window). *Overload*
    /// is not an error: a request shed because the queue is full or
    /// because its KV footprint could never fit the byte budget still
    /// gets an id, answered with a [`FinishReason::Rejected`] completion
    /// from the next [`step`](Scheduler::step).
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        self.submit_with_deadline(req, self.cfg.deadline_steps)
    }

    /// [`submit`](Scheduler::submit) with a per-request step deadline
    /// overriding [`SchedulerConfig::deadline_steps`] (`0` = none): a
    /// request still unfinished once `deadline_steps` engine steps have
    /// elapsed past its submission step is retired with
    /// [`FinishReason::DeadlineExceeded`], whether queued or live, its
    /// pages freed that same step.
    pub fn submit_with_deadline(&mut self, req: Request, deadline_steps: u64) -> Result<u64> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        anyhow::ensure!(
            req.prompt.len() + req.max_new_tokens <= self.model_cfg.max_seq,
            "prompt ({}) + max_new_tokens ({}) exceeds context window ({})",
            req.prompt.len(),
            req.max_new_tokens,
            self.model_cfg.max_seq
        );
        let id = self.next_id;
        self.next_id += 1;
        if self.cfg.max_queue > 0 && self.queue.len() >= self.cfg.max_queue {
            self.reject(id, req.prompt.len());
            return Ok(id);
        }
        // Rung 4 also sheds requests that could never be served: a
        // footprint past the byte budget would cycle through the ladder
        // forever (preempt, fail to resume, repeat), so it is refused up
        // front. `div_ceil` makes this a conservative (≥ actual pages)
        // bound — the worst case is one page per `page_tokens` positions
        // of `prompt ++ generated`.
        let worst_pages =
            (req.prompt.len() + req.max_new_tokens).div_ceil(self.pool.page_tokens());
        if worst_pages > self.pool.max_pages() {
            self.reject(id, req.prompt.len());
            return Ok(id);
        }
        let orig_prompt_len = req.prompt.len();
        self.queue.push_back(Queued {
            id,
            prompt: req.prompt,
            max_new: req.max_new_tokens,
            stop: req.stop_token,
            generated: Vec::new(),
            orig_prompt_len,
            submit_step: self.step_no,
            deadline_steps,
            first_admitted_step: 0,
        });
        Ok(id)
    }

    fn reject(&mut self, id: u64, prompt_len: usize) {
        self.account(FinishReason::Rejected);
        self.pending.push(Completion {
            id,
            prompt_len,
            tokens: Vec::new(),
            reason: FinishReason::Rejected,
            admitted_step: 0,
            finished_step: self.step_no,
        });
    }

    /// Cancel a request by id, queued or live. Pages are freed
    /// immediately (a cancelled generation is incomplete, so its cache
    /// recycles straight into the pool, never the prefix cache) and the
    /// [`FinishReason::Cancelled`] completion — carrying any partial
    /// output — is returned. `None` when the id is unknown or already
    /// finished.
    pub fn cancel(&mut self, id: u64) -> Option<Completion> {
        if let Some(i) = self.queue.iter().position(|q| q.id == id) {
            // position() just returned a valid index, so remove() hits.
            let q = self.queue.remove(i).expect("cancel target vanished from the queue");
            return Some(self.finish_queued(q, FinishReason::Cancelled));
        }
        if let Some(i) = self.slots.iter().position(|s| s.id == id) {
            let slot = self.slots.remove(i);
            return Some(self.finish_slot_early(slot, FinishReason::Cancelled));
        }
        None
    }

    /// Preempt a live request back to the *front* of the queue (rung 3
    /// of the degradation ladder, also callable directly): its pages are
    /// released immediately and it re-queues with `prompt ++ generated`
    /// as the new prompt, so resuming is a plain prefill — bit-identical
    /// to never having been preempted (`tests/preemption.rs` pins this at
    /// every decode step). Returns `false` when `id` is not live.
    pub fn preempt(&mut self, id: u64) -> bool {
        match self.slots.iter().position(|s| s.id == id) {
            Some(i) => {
                self.preempt_slot_at(i);
                true
            }
            None => false,
        }
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently holding a batch slot.
    pub fn live(&self) -> usize {
        self.slots.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.slots.is_empty() || !self.pending.is_empty()
    }

    /// Evict every pinned prefix back into the page pool (shutdown; the
    /// refcount-hygiene property drains here before checking the pool).
    pub fn drain_prefix_cache(&mut self) {
        if let Some(p) = &mut self.prefix {
            p.drain(&mut self.pool);
        }
    }

    /// Walk every live and pinned page table, counting each distinct page
    /// once — the fix for the pre-paging stats that attributed a full
    /// forked cache to every request.
    fn kv_census(&self) -> KvCensus {
        let mut seen = HashMap::new();
        for slot in &self.slots {
            for s in slot.cache.page_stats() {
                seen.insert(s.ptr, s);
            }
        }
        if let Some(p) = &self.prefix {
            p.visit_caches(&mut |c| {
                for s in c.page_stats() {
                    seen.insert(s.ptr, s);
                }
            });
        }
        let mut out = KvCensus::default();
        for s in seen.values() {
            out.pages += 1;
            out.bytes += s.bytes;
            if s.shared {
                out.shared += 1;
            }
            if s.quantized {
                out.quantized += 1;
            }
        }
        out
    }

    pub fn stats(&self) -> SchedulerStats {
        let p = self.prefix.as_ref();
        let census = self.kv_census();
        SchedulerStats {
            steps: self.step_no,
            decode_batches: self.decode_batches,
            decoded_tokens: self.decoded_tokens,
            prefill_tokens_out: self.prefill_tokens_out,
            prefill_tokens_in: self.prefill_tokens_in,
            prefill_tokens_saved: p.map_or(0, PrefixCache::saved_tokens),
            completed: self.completed,
            peak_live: self.peak_live,
            pool_hits: self.pool.hits(),
            pool_misses: self.pool.misses(),
            pool_resident_bytes: self.pool.resident_bytes(),
            pool_hit_rate: self.pool.hit_rate(),
            pool_free_pages: self.pool.free_pages(),
            pool_pages_created: self.pool.pages_created(),
            prefix_lookups: p.map_or(0, PrefixCache::lookups),
            prefix_hits: p.map_or(0, PrefixCache::hits),
            prefix_entries: p.map_or(0, PrefixCache::entries),
            prefix_resident_bytes: p.map_or(0, PrefixCache::resident_bytes),
            prefix_evictions: p.map_or(0, PrefixCache::evictions),
            shared_kv_bytes_saved: p.map_or(0, PrefixCache::saved_bytes),
            kv_pages_resident: census.pages,
            kv_pages_shared: census.shared,
            kv_pages_quantized: census.quantized,
            kv_resident_bytes: census.bytes,
            peak_kv_resident_bytes: self.peak_kv_resident_bytes.max(census.bytes),
            kv_pages_quantized_total: self.kv_pages_quantized_total,
            rejected: self.rejected,
            cancelled: self.cancelled,
            deadline_exceeded: self.deadline_exceeded,
            preempted: self.preempted,
            resumed: self.resumed,
            pool_failed_takes: self.pool.failed_takes(),
        }
    }

    /// One engine step: admit + prefill, one fused decode across the live
    /// batch, retire finished requests, backfill their slots (same step),
    /// then re-encode any pages that went cold. Returns the requests that
    /// finished during this step, in retirement order. `st` must have row
    /// capacity ≥ `max_slots` and ≥ the longest admitted prompt
    /// ([`ExecState::new`] covers both).
    pub fn step(&mut self, model: &ExecModel, st: &mut ExecState) -> Vec<Completion> {
        assert_eq!(model.config, self.model_cfg, "scheduler built for a different model config");
        assert!(
            self.cfg.max_slots <= st.capacity(),
            "max_slots ({}) exceeds ExecState row capacity ({}); a full batch could not decode",
            self.cfg.max_slots,
            st.capacity()
        );
        self.step_no += 1;
        // Deliver completions buffered between steps (submission-time
        // rejections): they belong to this serving clock, not to errors.
        let mut done = std::mem::take(&mut self.pending);
        self.expire_deadlines(&mut done);
        let mut budget = self.cfg.prefill_token_budget;
        let mut admitted_any = false;

        self.admit(model, st, &mut budget, &mut admitted_any, &mut done);
        let census = self.kv_census();
        self.peak_kv_resident_bytes = self.peak_kv_resident_bytes.max(census.bytes);
        if !self.slots.is_empty() {
            // Draw this step's page growth from the pool up front (a page
            // boundary crossing, or a CoW fork of a still-shared tail) so
            // the fused decode itself never allocates. Failed takes walk
            // the degradation ladder, which may preempt slots — hence the
            // re-check below.
            self.reserve_decode_pages();
        }
        if !self.slots.is_empty() {
            let toks: Vec<u16> = self.slots.iter().map(Slot::last_token).collect();
            let mut caches: Vec<&mut KvCache> =
                self.slots.iter_mut().map(|s| &mut s.cache).collect();
            let logits = decode_step(model, &mut caches, &toks, st);
            for (b, slot) in self.slots.iter_mut().enumerate() {
                slot.generated.push(argmax(logits.row(b)));
            }
            self.decode_batches += 1;
            self.decoded_tokens += toks.len() as u64;

            self.retire(&mut done);
            // Backfill freed slots so they decode from the very next step.
            self.admit(model, st, &mut budget, &mut admitted_any, &mut done);

            if self.cfg.kv_quant_bits > 0 {
                let (bits, margin) = (self.cfg.kv_quant_bits, self.cfg.kv_quant_margin);
                for s in self.slots.iter_mut() {
                    self.kv_pages_quantized_total +=
                        s.cache.quantize_cold_pages(bits, margin, Some(&mut self.pool)) as u64;
                }
            }
        }
        done
    }

    /// Drive steps until queue and live batch drain; completions come back
    /// in finish order.
    pub fn run_to_completion(&mut self, model: &ExecModel, st: &mut ExecState) -> Vec<Completion> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step(model, st));
        }
        out
    }

    /// Admit queued requests into free slots, prefilling each (only the
    /// prompt tail past the longest cached prefix when the prefix cache
    /// is enabled). A request whose first token already completes it
    /// (stop token, or `max_new_tokens == 1`) retires without ever
    /// holding a slot.
    fn admit(
        &mut self,
        model: &ExecModel,
        st: &mut ExecState,
        budget: &mut usize,
        admitted_any: &mut bool,
        done: &mut Vec<Completion>,
    ) {
        if self.cfg.policy == AdmissionPolicy::Wave && !self.slots.is_empty() {
            return;
        }
        while self.slots.len() < self.cfg.max_slots {
            let Some(front) = self.queue.front() else { break };
            let prompt_len = front.prompt.len();
            // Budget is a compute throttle, so a cached prefix (page
            // sharing, not a forward pass) charges only the tail it will
            // prefill.
            let reusable = self.prefix.as_ref().map_or(0, |p| p.probe(&front.prompt));
            if prompt_len - reusable > *budget && *admitted_any {
                break; // budget spent; the rest waits for the next step
            }

            let Some(mut q) = self.queue.pop_front() else {
                // Structurally unreachable: front() above just observed
                // an entry and nothing between touched the queue.
                debug_assert!(false, "queue emptied between front() and pop_front()");
                break;
            };
            let mut cache = self.pool.take_cache();
            let depth = match &mut self.prefix {
                Some(p) => p.share_into(&q.prompt, &mut cache),
                None => 0,
            };
            debug_assert_eq!(depth, reusable, "probe and share must agree within one admission");
            let tail_len = q.prompt.len() - depth;
            // Tail pages (and the CoW fork of a shared partial tail page)
            // come from the pool, walking rungs 1-2 of the ladder when a
            // take fails; prefill's own prepare_append is then a no-op.
            // Admission never preempts (rung 3): un-admitting one request
            // to admit another would thrash, so when the reclaim rungs
            // are exhausted the request goes back to the queue front and
            // waits for decode-side pressure (retirement or preemption)
            // to free pages. Prefix stats counted by share_into recount
            // on the retry — acceptable drift under overload.
            while !cache.try_reserve(&mut self.pool, tail_len) {
                if !self.relieve_memory_pressure() {
                    self.pool.put_cache(cache);
                    self.queue.push_front(q);
                    return;
                }
            }
            *admitted_any = true;
            *budget = budget.saturating_sub(tail_len);
            if q.first_admitted_step != 0 {
                self.resumed += 1;
            }

            let tail = &q.prompt[depth..];
            let logits = prefill(model, &mut cache, tail, st);
            let next = argmax(logits.row(tail.len() - 1));
            self.prefill_tokens_in += tail.len() as u64;
            self.prefill_tokens_out += 1;

            // A resumed request keeps its pre-preemption tokens: the
            // prefill of `prompt ++ generated` produced the *next* one.
            let mut generated = std::mem::take(&mut q.generated);
            generated.push(next);
            let slot = Slot {
                id: q.id,
                cache,
                prompt: q.prompt,
                max_new: q.max_new,
                stop: q.stop,
                generated,
                admitted_step: if q.first_admitted_step != 0 {
                    q.first_admitted_step
                } else {
                    self.step_no
                },
                orig_prompt_len: q.orig_prompt_len,
                submit_step: q.submit_step,
                deadline_steps: q.deadline_steps,
            };
            if slot.finished() {
                done.push(self.complete(slot));
            } else {
                self.slots.push(slot);
                self.peak_live = self.peak_live.max(self.slots.len());
            }
        }
    }

    /// Rungs 1-2 of the degradation ladder: reclaim memory without
    /// touching live requests — evict one pinned prefix back into the
    /// pool, else force cold-page quantization (margin 0: every full
    /// private page strictly behind a decode head; only when
    /// `kv_quant_bits` is enabled, because it is lossy). Returns `false`
    /// when neither rung produced anything, i.e. the caller must escalate
    /// (preempt) or back off. Each call consumes a finite resource
    /// (a trie entry, an unquantized page), so ladder loops terminate.
    fn relieve_memory_pressure(&mut self) -> bool {
        if let Some(p) = &mut self.prefix {
            if p.evict_one(&mut self.pool) {
                return true;
            }
        }
        if self.cfg.kv_quant_bits > 0 {
            let bits = self.cfg.kv_quant_bits;
            let mut quantized = 0usize;
            for s in self.slots.iter_mut() {
                quantized += s.cache.quantize_cold_pages(bits, 0, Some(&mut self.pool));
            }
            self.kv_pages_quantized_total += quantized as u64;
            return quantized > 0;
        }
        false
    }

    /// Reserve this step's one-position growth for every live slot,
    /// walking the full ladder on a failed take: reclaim
    /// ([`relieve_memory_pressure`](Self::relieve_memory_pressure)),
    /// then preempt the youngest live request and restart the walk.
    /// `try_reserve` is a no-op for slots whose tail is already writable,
    /// so restarting never double-reserves.
    fn reserve_decode_pages(&mut self) {
        loop {
            let mut failed = false;
            for i in 0..self.slots.len() {
                if !self.slots[i].cache.try_reserve(&mut self.pool, 1) {
                    failed = true;
                    if !self.relieve_memory_pressure() && !self.preempt_youngest() {
                        // Structurally unreachable: the walk only runs
                        // with live slots, so preempt_youngest() always
                        // has a victim. Defensive in release: give up on
                        // reserving; decode will then fall back to
                        // pool-less allocation in prepare_append.
                        debug_assert!(false, "pressure ladder exhausted with a live batch");
                        return;
                    }
                    break; // restart the walk after reclaim/preemption
                }
            }
            if !failed {
                return;
            }
        }
    }

    /// Rung 3: preempt the youngest live request (highest id — the one
    /// with the least service, whose re-prefill costs the least; the
    /// choice that can never starve the eldest request). `false` when no
    /// slot is live.
    fn preempt_youngest(&mut self) -> bool {
        match (0..self.slots.len()).max_by_key(|&i| self.slots[i].id) {
            Some(i) => {
                self.preempt_slot_at(i);
                true
            }
            None => false,
        }
    }

    fn preempt_slot_at(&mut self, i: usize) {
        let slot = self.slots.remove(i);
        self.pool.put_cache(slot.cache);
        self.preempted += 1;
        // Resume prompt = original prompt ++ everything generated. The
        // slot prompt of a request preempted once before already holds
        // its earlier tokens, so rebuild from the original length.
        let mut prompt = slot.prompt;
        prompt.truncate(slot.orig_prompt_len);
        prompt.extend_from_slice(&slot.generated);
        self.queue.push_front(Queued {
            id: slot.id,
            prompt,
            max_new: slot.max_new,
            stop: slot.stop,
            generated: slot.generated,
            orig_prompt_len: slot.orig_prompt_len,
            submit_step: slot.submit_step,
            deadline_steps: slot.deadline_steps,
            first_admitted_step: slot.admitted_step,
        });
    }

    /// Retire every queued or live request whose step deadline has
    /// passed (runs at the top of each step, before admission).
    fn expire_deadlines(&mut self, done: &mut Vec<Completion>) {
        let now = self.step_no;
        let mut i = 0;
        while i < self.queue.len() {
            let q = &self.queue[i];
            if q.deadline_steps > 0 && now > q.submit_step + q.deadline_steps {
                // The index was just observed in bounds, so remove() hits.
                let q = self.queue.remove(i).expect("expired entry vanished from the queue");
                done.push(self.finish_queued(q, FinishReason::DeadlineExceeded));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.slots.len() {
            let s = &self.slots[i];
            if s.deadline_steps > 0 && now > s.submit_step + s.deadline_steps {
                let slot = self.slots.remove(i);
                done.push(self.finish_slot_early(slot, FinishReason::DeadlineExceeded));
            } else {
                i += 1;
            }
        }
    }

    /// Retire every finished slot, releasing its pages to the prefix
    /// cache (when enabled) or the pool.
    fn retire(&mut self, done: &mut Vec<Completion>) {
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].finished() {
                let slot = self.slots.swap_remove(i);
                done.push(self.complete(slot));
            } else {
                i += 1;
            }
        }
    }

    fn complete(&mut self, slot: Slot) -> Completion {
        let last = slot.last_token();
        let Slot { id, cache, prompt, stop, generated, admitted_step, orig_prompt_len, .. } = slot;
        let reason = if stop == Some(last) { FinishReason::Stop } else { FinishReason::Length };
        // Retirement feeds the prefix cache: the cache (truncated back to
        // the prompt, decode pages released) pins its prompt pages for
        // future shared-prefix admissions, or every page recycles straight
        // into the pool when the cache is disabled / the prompt is already
        // pinned. (For a resumed request "the prompt" is the extended one
        // — exactly the tokens its first cache positions hold.)
        match &mut self.prefix {
            Some(p) => p.insert(&prompt, cache, &mut self.pool),
            None => self.pool.put_cache(cache),
        }
        self.account(reason);
        Completion {
            id,
            prompt_len: orig_prompt_len,
            tokens: generated,
            reason,
            admitted_step,
            finished_step: self.step_no,
        }
    }

    /// Retire a live slot early (cancel / deadline): its pages recycle
    /// straight into the pool — an incomplete generation is never pinned
    /// in the prefix cache — and the completion carries the partial
    /// output.
    fn finish_slot_early(&mut self, slot: Slot, reason: FinishReason) -> Completion {
        debug_assert!(!reason.is_success(), "successful finishes go through complete()");
        self.pool.put_cache(slot.cache);
        self.account(reason);
        Completion {
            id: slot.id,
            prompt_len: slot.orig_prompt_len,
            tokens: slot.generated,
            reason,
            admitted_step: slot.admitted_step,
            finished_step: self.step_no,
        }
    }

    /// Retire a queued entry without admission (cancel / deadline); a
    /// preempted entry's partial output still reaches its completion.
    fn finish_queued(&mut self, q: Queued, reason: FinishReason) -> Completion {
        self.account(reason);
        Completion {
            id: q.id,
            prompt_len: q.orig_prompt_len,
            tokens: q.generated,
            reason,
            admitted_step: q.first_admitted_step,
            finished_step: self.step_no,
        }
    }

    fn account(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Length | FinishReason::Stop => self.completed += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::DeadlineExceeded => self.deadline_exceeded += 1,
            FinishReason::Rejected => self.rejected += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Model;
    use crate::util::rng::Rng;

    fn small_setup() -> (ExecModel, ExecState) {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let m = Model::random(cfg, &mut Rng::new(40));
        (ExecModel::dense(&m), ExecState::new(cfg))
    }

    #[test]
    fn submit_validates_requests() {
        let (model, _) = small_setup();
        let mut s = Scheduler::new(model.config, SchedulerConfig::default());
        assert!(s
            .submit(Request { prompt: vec![], max_new_tokens: 4, stop_token: None })
            .is_err());
        assert!(s
            .submit(Request { prompt: vec![1], max_new_tokens: 0, stop_token: None })
            .is_err());
        assert!(s
            .submit(Request { prompt: vec![1; 30], max_new_tokens: 8, stop_token: None })
            .is_err());
        let id = s
            .submit(Request { prompt: vec![1, 2], max_new_tokens: 4, stop_token: None })
            .unwrap();
        assert_eq!(id, 0);
        assert_eq!(s.queued(), 1);
    }

    #[test]
    fn drains_queue_and_respects_max_new_tokens() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { max_slots: 2, ..SchedulerConfig::default() },
        );
        for i in 0..5u16 {
            s.submit(Request {
                prompt: vec![i, i + 1, i + 2],
                max_new_tokens: 3 + i as usize,
                stop_token: None,
            })
            .unwrap();
        }
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 5);
        assert!(!s.has_work());
        let mut by_id = done.clone();
        by_id.sort_by_key(|c| c.id);
        for (i, c) in by_id.iter().enumerate() {
            assert_eq!(c.id, i as u64);
            assert_eq!(c.tokens.len(), 3 + i);
            assert_eq!(c.reason, FinishReason::Length);
            assert!(c.admitted_step <= c.finished_step);
        }
        let stats = s.stats();
        assert_eq!(stats.completed, 5);
        assert!(stats.peak_live <= 2);
        // pre-warmed pool + page recycling: no allocation ever needed
        // (max_seq 32 fits one default page, so one take per request)
        assert_eq!(stats.pool_misses, 0);
        assert_eq!(stats.pool_hits, 5);
        // everything retired, nothing pinned: all pages are home
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
        assert_eq!(stats.kv_pages_resident, 0);
    }

    #[test]
    fn stop_token_ends_generation_early() {
        let (model, mut st) = small_setup();
        // run once without a stop token to learn the greedy stream
        let mut s = Scheduler::new(model.config, SchedulerConfig::default());
        s.submit(Request { prompt: vec![3, 1, 4], max_new_tokens: 8, stop_token: None })
            .unwrap();
        let free = &s.run_to_completion(&model, &mut st)[0];
        assert_eq!(free.tokens.len(), 8);
        let stop = free.tokens[3];
        // first occurrence of that token must now stop the request
        let mut s = Scheduler::new(model.config, SchedulerConfig::default());
        s.submit(Request { prompt: vec![3, 1, 4], max_new_tokens: 8, stop_token: Some(stop) })
            .unwrap();
        let stopped = &s.run_to_completion(&model, &mut st)[0];
        let cut = free.tokens.iter().position(|&t| t == stop).unwrap();
        assert_eq!(stopped.tokens, free.tokens[..=cut]);
        assert_eq!(stopped.reason, FinishReason::Stop);
    }

    #[test]
    fn prefill_budget_defers_admissions_but_never_starves() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig {
                max_slots: 4,
                prefill_token_budget: 5,
                policy: AdmissionPolicy::Continuous,
                ..SchedulerConfig::default()
            },
        );
        // 10-token prompt exceeds the whole budget: admitted anyway (first
        // of its step), alone.
        s.submit(Request { prompt: vec![7; 10], max_new_tokens: 6, stop_token: None }).unwrap();
        for _ in 0..3 {
            s.submit(Request { prompt: vec![2; 4], max_new_tokens: 4, stop_token: None })
                .unwrap();
        }
        s.step(&model, &mut st);
        // big prompt in, budget gone; one more 4-token prompt would fit
        // slot-wise but not budget-wise
        assert_eq!(s.live(), 1);
        assert_eq!(s.queued(), 3);
        s.step(&model, &mut st);
        assert!(s.live() >= 2, "next step admits under a fresh budget");
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 4);
        assert_eq!(s.stats().completed, 4);
    }

    #[test]
    fn prefix_cache_reuses_shared_prefixes_without_changing_tokens() {
        let (model, mut st) = small_setup();
        let system = [7u16, 3, 9, 1, 4, 4, 2, 8]; // shared "system prompt"
        let mk = |tail: &[u16]| Request {
            prompt: system.iter().copied().chain(tail.iter().copied()).collect(),
            max_new_tokens: 4,
            stop_token: None,
        };
        let tails: [&[u16]; 4] = [&[5, 6], &[6, 5], &[1], &[9, 9, 9]];

        // serve sequentially so each retirement can seed the next
        // admission; cold run is the reference
        let mut serve = |prefix_cache_bytes: usize| {
            let mut s = Scheduler::new(
                model.config,
                SchedulerConfig { prefix_cache_bytes, ..SchedulerConfig::default() },
            );
            let mut out = Vec::new();
            for t in tails {
                s.submit(mk(t)).unwrap();
                out.extend(s.run_to_completion(&model, &mut st));
            }
            (out, s.stats())
        };
        let (cold, cold_stats) = serve(0);
        let (warm, warm_stats) = serve(1 << 20);

        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.tokens, w.tokens, "prefix hit changed request {} tokens", c.id);
            assert_eq!(c.reason, w.reason);
        }
        assert_eq!(cold_stats.prefix_lookups, 0, "disabled cache must not probe");
        assert_eq!(cold_stats.prefill_tokens_saved, 0);
        assert_eq!(cold_stats.shared_kv_bytes_saved, 0);
        assert_eq!(warm_stats.prefix_lookups, 4);
        // requests 2..4 all share the 8-token system prefix of request 1
        assert_eq!(warm_stats.prefix_hits, 3);
        assert_eq!(warm_stats.prefill_tokens_saved, 3 * system.len() as u64);
        // every saved token is KV bytes that are now shared, not copied
        let token_bytes = KvCache::new(&model.config).token_bytes() as u64;
        assert_eq!(warm_stats.shared_kv_bytes_saved, warm_stats.prefill_tokens_saved * token_bytes);
        assert_eq!(
            warm_stats.prefill_tokens_in + warm_stats.prefill_tokens_saved,
            cold_stats.prefill_tokens_in,
            "saved + prefilled must cover every prompt token"
        );
        assert!(warm_stats.prefix_entries >= 1);
        assert!(warm_stats.prefix_resident_bytes > 0);
    }

    #[test]
    fn prefix_hits_extend_the_prefill_budget() {
        let (model, mut st) = small_setup();
        // Budget 6 admits one 6-token cold prompt per step; once the
        // 5-token prefix is cached, a hit costs only its 1-token tail, so
        // two more requests fit in a single step's budget.
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig {
                max_slots: 4,
                prefill_token_budget: 6,
                policy: AdmissionPolicy::Continuous,
                prefix_cache_bytes: 1 << 20,
                ..SchedulerConfig::default()
            },
        );
        let mk = |last: u16| Request {
            prompt: vec![3, 1, 4, 1, 5, last],
            max_new_tokens: 3,
            stop_token: None,
        };
        s.submit(mk(0)).unwrap();
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 1);

        for last in [1, 2, 3] {
            s.submit(mk(last)).unwrap();
        }
        s.step(&model, &mut st);
        assert_eq!(s.live(), 3, "three 1-token tails fit the 6-token budget at once");
        let stats = s.stats();
        assert_eq!(stats.prefix_hits, 3);
        assert_eq!(stats.prefill_tokens_saved, 15);
        s.run_to_completion(&model, &mut st);
    }

    #[test]
    fn wave_policy_never_backfills_a_partial_batch() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { max_slots: 2, policy: AdmissionPolicy::Wave, ..Default::default() },
        );
        for i in 0..4u16 {
            // staggered lengths so the wave drains unevenly
            s.submit(Request {
                prompt: vec![i + 1],
                max_new_tokens: 2 + 3 * i as usize,
                stop_token: None,
            })
            .unwrap();
        }
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 4);
        assert!(s.stats().peak_live <= 2);
        // Waves never overlap: any request admitted in an earlier wave has
        // finished by the step a later wave is admitted (a new wave may
        // start in the very step the old one drains, hence <=).
        for a in &done {
            for b in &done {
                if a.admitted_step < b.admitted_step {
                    assert!(
                        a.finished_step <= b.admitted_step,
                        "request {} (steps {}..={}) overlaps later wave admitted at {}",
                        a.id,
                        a.admitted_step,
                        a.finished_step,
                        b.admitted_step
                    );
                }
            }
        }
    }

    #[test]
    fn page_size_is_invisible_to_serving() {
        let (model, mut st) = small_setup();
        let mut serve = |pt: usize| {
            let mut s = Scheduler::new(
                model.config,
                SchedulerConfig {
                    max_slots: 3,
                    kv_page_tokens: pt,
                    prefix_cache_bytes: 1 << 20,
                    ..SchedulerConfig::default()
                },
            );
            for i in 0..6u16 {
                s.submit(Request {
                    prompt: vec![i % 3, 5, 6, i],
                    max_new_tokens: 5,
                    stop_token: None,
                })
                .unwrap();
            }
            let mut done = s.run_to_completion(&model, &mut st);
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        // one-page-per-request baseline vs small pages that force boundary
        // crossings, CoW tail forks, and multi-page shares
        let base = serve(32);
        for pt in [1, 3, 7] {
            assert_eq!(serve(pt), base, "page size {pt} changed served tokens");
        }
    }

    #[test]
    fn cold_page_quantization_runs_and_returns_pages() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig {
                max_slots: 1,
                kv_page_tokens: 4,
                kv_quant_bits: 8,
                kv_quant_margin: 4,
                ..SchedulerConfig::default()
            },
        );
        s.submit(Request {
            prompt: vec![3, 1, 4, 1, 5, 9, 2, 6],
            max_new_tokens: 12,
            stop_token: None,
        })
        .unwrap();
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 1);
        // lossy path: structure is asserted, tokens are not bit-compared
        assert_eq!(done[0].tokens.len(), 12);
        let stats = s.stats();
        assert!(stats.kv_pages_quantized_total > 0, "cold pages must have been re-encoded");
        assert_eq!(stats.pool_misses, 0, "quantization frees f32 pages back to the pool");
        // retirement drops quantized pages and returns every f32 page
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
        assert_eq!(stats.kv_pages_resident, 0);
    }

    #[test]
    fn drain_prefix_cache_returns_pinned_pages() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { prefix_cache_bytes: 1 << 20, ..SchedulerConfig::default() },
        );
        s.submit(Request { prompt: vec![1, 2, 3, 4, 5], max_new_tokens: 3, stop_token: None })
            .unwrap();
        s.run_to_completion(&model, &mut st);
        let before = s.stats();
        assert_eq!(before.prefix_entries, 1);
        assert!(before.kv_pages_resident > 0, "the pinned prefix keeps pages alive");
        assert!((before.pool_free_pages as u64) < before.pool_pages_created);
        s.drain_prefix_cache();
        let after = s.stats();
        assert_eq!(after.prefix_entries, 0);
        assert_eq!(after.kv_pages_resident, 0);
        assert_eq!(after.pool_free_pages as u64, after.pool_pages_created);
    }

    /// Bytes of one page at `small_setup` geometry (2 layers, d 16) for
    /// a given page size — for tests that count budgets in pages.
    fn page_bytes(cfg: &TransformerConfig, page_tokens: usize) -> usize {
        2 * cfg.n_layers * page_tokens * cfg.d_model * std::mem::size_of::<f32>()
    }

    #[test]
    fn full_queue_sheds_with_a_structured_rejection() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { max_slots: 1, max_queue: 2, ..SchedulerConfig::default() },
        );
        let ids: Vec<u64> = (0..3u16)
            .map(|i| {
                s.submit(Request { prompt: vec![i + 1], max_new_tokens: 2, stop_token: None })
                    .unwrap()
            })
            .collect();
        assert_eq!(s.queued(), 2, "the third submission must not grow the queue");
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 3, "rejections are completions, not silence");
        let rejected: Vec<_> =
            done.iter().filter(|c| c.reason == FinishReason::Rejected).collect();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].id, ids[2]);
        assert!(rejected[0].tokens.is_empty());
        assert_eq!(rejected[0].admitted_step, 0);
        let stats = s.stats();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
    }

    #[test]
    fn budget_infeasible_requests_are_rejected_up_front() {
        let (model, mut st) = small_setup();
        // Budget of exactly one 4-token page: prompt 3 + max_new 3 needs
        // two pages and can never be served; prompt 2 + max_new 2 fits.
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig {
                max_slots: 1,
                kv_page_tokens: 4,
                kv_budget_bytes: page_bytes(&model.config, 4),
                ..SchedulerConfig::default()
            },
        );
        s.submit(Request { prompt: vec![1, 2, 3], max_new_tokens: 3, stop_token: None }).unwrap();
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::Rejected);
        assert_eq!(s.stats().rejected, 1);

        s.submit(Request { prompt: vec![1, 2], max_new_tokens: 2, stop_token: None }).unwrap();
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 1);
        assert!(done[0].reason.is_success(), "a fitting request still serves: {done:?}");
        let stats = s.stats();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
    }

    #[test]
    fn cancel_works_queued_and_live_and_frees_pages() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { max_slots: 1, ..SchedulerConfig::default() },
        );
        let a = s
            .submit(Request { prompt: vec![1, 2], max_new_tokens: 6, stop_token: None })
            .unwrap();
        let b = s
            .submit(Request { prompt: vec![3, 4], max_new_tokens: 6, stop_token: None })
            .unwrap();
        s.step(&model, &mut st); // a live, b queued behind the single slot
        assert_eq!(s.live(), 1);
        assert_eq!(s.queued(), 1);

        let cb = s.cancel(b).expect("queued request cancels");
        assert_eq!(cb.reason, FinishReason::Cancelled);
        assert!(cb.tokens.is_empty());
        assert_eq!(cb.admitted_step, 0);

        let ca = s.cancel(a).expect("live request cancels");
        assert_eq!(ca.reason, FinishReason::Cancelled);
        assert!(!ca.tokens.is_empty(), "live cancel reports the partial output");
        assert!(ca.admitted_step >= 1);

        assert!(s.cancel(a).is_none(), "double cancel finds nothing");
        assert!(!s.has_work());
        let stats = s.stats();
        assert_eq!(stats.cancelled, 2);
        assert_eq!(stats.completed, 0);
        // the live cache went straight back: page hygiene holds now, not
        // at some later step
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
        assert_eq!(stats.kv_pages_resident, 0);
    }

    #[test]
    fn deadlines_expire_queued_and_live_requests() {
        let (model, mut st) = small_setup();
        let mut s = Scheduler::new(
            model.config,
            SchedulerConfig { max_slots: 1, ..SchedulerConfig::default() },
        );
        // a gets the slot but wants more steps than its deadline allows;
        // b never gets the slot before its own deadline passes
        let a = s
            .submit_with_deadline(
                Request { prompt: vec![1, 2], max_new_tokens: 10, stop_token: None },
                3,
            )
            .unwrap();
        let b = s
            .submit_with_deadline(
                Request { prompt: vec![3, 4], max_new_tokens: 10, stop_token: None },
                2,
            )
            .unwrap();
        let done = s.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 2);
        let ca = done.iter().find(|c| c.id == a).unwrap();
        assert_eq!(ca.reason, FinishReason::DeadlineExceeded);
        assert!(
            !ca.tokens.is_empty() && ca.tokens.len() < 10,
            "deadline returns the partial output: {:?}",
            ca.tokens
        );
        let cb = done.iter().find(|c| c.id == b).unwrap();
        assert_eq!(cb.reason, FinishReason::DeadlineExceeded);
        assert!(cb.tokens.is_empty());
        assert_eq!(cb.admitted_step, 0);
        let stats = s.stats();
        assert_eq!(stats.deadline_exceeded, 2);
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
        assert_eq!(stats.kv_pages_resident, 0);
    }

    #[test]
    fn budget_pressure_preempts_and_resumes_bit_identically() {
        let (model, mut st) = small_setup();
        let mut run = |budget_pages: usize| {
            let mut s = Scheduler::new(
                model.config,
                SchedulerConfig {
                    max_slots: 3,
                    kv_page_tokens: 4,
                    kv_budget_bytes: budget_pages * page_bytes(&model.config, 4),
                    ..SchedulerConfig::default()
                },
            );
            for i in 0..3u16 {
                s.submit(Request {
                    prompt: vec![i + 1, i + 2, i + 3],
                    max_new_tokens: 8,
                    stop_token: None,
                })
                .unwrap();
            }
            let mut done = s.run_to_completion(&model, &mut st);
            done.sort_by_key(|c| c.id);
            (done, s.stats())
        };
        let (free, free_stats) = run(0);
        assert_eq!(free_stats.preempted, 0);
        assert_eq!(free_stats.rejected, 0);
        // Three requests at 3 pages each need 9 pages concurrently; 5
        // cannot hold them, so the ladder must preempt — but each request
        // alone fits (3 ≤ 5), so nothing is rejected and everything
        // eventually completes.
        let (tight, tight_stats) = run(5);
        assert!(tight_stats.preempted > 0, "the budget never bit: {tight_stats:?}");
        assert!(tight_stats.resumed >= 1);
        assert_eq!(tight_stats.rejected, 0);
        assert_eq!(tight_stats.completed, 3);
        assert!(tight_stats.pool_failed_takes > 0);
        assert_eq!(free.len(), tight.len());
        for (f, t) in free.iter().zip(&tight) {
            assert_eq!(f.id, t.id);
            assert_eq!(f.tokens, t.tokens, "preemption changed tokens of request {}", f.id);
            assert_eq!(f.reason, t.reason);
            assert_eq!(f.prompt_len, t.prompt_len, "prompt_len must stay the submitted one");
        }
        assert_eq!(tight_stats.pool_free_pages as u64, tight_stats.pool_pages_created);
        assert_eq!(tight_stats.kv_pages_resident, 0);
    }

    #[test]
    fn explicit_preempt_round_trips_through_the_queue() {
        let (model, mut st) = small_setup();
        let mut run = |preempt_after: Option<u64>| {
            let mut s = Scheduler::new(model.config, SchedulerConfig::default());
            let id = s
                .submit(Request { prompt: vec![5, 6, 7], max_new_tokens: 7, stop_token: None })
                .unwrap();
            let mut out = Vec::new();
            let mut steps = 0u64;
            while s.has_work() {
                out.extend(s.step(&model, &mut st));
                steps += 1;
                if Some(steps) == preempt_after {
                    assert!(s.preempt(id), "request must be live at step {steps}");
                    assert_eq!(s.live(), 0);
                    assert_eq!(s.queued(), 1);
                }
                assert!(steps < 100, "preempted request failed to drain");
            }
            (out, s.stats())
        };
        let (base, _) = run(None);
        assert_eq!(base.len(), 1);
        let (preempted, stats) = run(Some(2));
        assert_eq!(preempted.len(), 1);
        assert_eq!(preempted[0].tokens, base[0].tokens, "resume must be bit-identical");
        assert_eq!(preempted[0].admitted_step, base[0].admitted_step, "TTFT step preserved");
        assert_eq!(stats.preempted, 1);
        assert_eq!(stats.resumed, 1);
        assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
    }

    #[test]
    fn builder_defaults_match_default() {
        let built = SchedulerConfig::builder().build().unwrap();
        let d = SchedulerConfig::default();
        assert_eq!(built.max_slots, d.max_slots);
        assert_eq!(built.prefill_token_budget, d.prefill_token_budget);
        assert_eq!(built.policy, d.policy);
        assert_eq!(built.prefix_cache_bytes, d.prefix_cache_bytes);
        assert_eq!(built.kv_page_tokens, d.kv_page_tokens);
        assert_eq!(built.kv_quant_bits, d.kv_quant_bits);
        assert_eq!(built.kv_quant_margin, d.kv_quant_margin);
        assert_eq!(built.kv_budget_bytes, d.kv_budget_bytes);
        assert_eq!(built.max_queue, d.max_queue);
        assert_eq!(built.deadline_steps, d.deadline_steps);
    }

    /// CLI plumbing forwards flag defaults unconditionally, so setting a
    /// knob to its default value must always build — including explicit
    /// zeros for the "off" knobs.
    #[test]
    fn builder_accepts_explicit_defaults() {
        let cfg = SchedulerConfig::builder()
            .max_slots(4)
            .prefill_token_budget(256)
            .policy(AdmissionPolicy::Continuous)
            .kv_page_tokens(64)
            .kv_quant_bits(0)
            .kv_budget_bytes(0)
            .max_queue(0)
            .deadline_steps(0)
            .build()
            .unwrap();
        assert_eq!(cfg.max_slots, 4);
        assert_eq!(cfg.kv_quant_bits, 0);
    }

    #[test]
    fn builder_rejects_degenerate_knobs() {
        assert_eq!(
            SchedulerConfig::builder().max_slots(0).build().unwrap_err(),
            SchedulerConfigError::ZeroSlots
        );
        assert_eq!(
            SchedulerConfig::builder().prefill_token_budget(0).build().unwrap_err(),
            SchedulerConfigError::ZeroPrefillBudget
        );
        assert_eq!(
            SchedulerConfig::builder().kv_quant_bits(MAX_KV_QUANT_BITS + 1).build().unwrap_err(),
            SchedulerConfigError::KvQuantBitsTooWide { bits: MAX_KV_QUANT_BITS + 1 }
        );
    }

    /// A margin with quantization off would silently do nothing — rejected
    /// whether bits were left unset or explicitly set to 0. Margins with
    /// bits on build fine.
    #[test]
    fn builder_rejects_margin_without_quant() {
        assert_eq!(
            SchedulerConfig::builder().kv_quant_margin(64).build().unwrap_err(),
            SchedulerConfigError::MarginWithoutQuant
        );
        assert_eq!(
            SchedulerConfig::builder().kv_quant_bits(0).kv_quant_margin(64).build().unwrap_err(),
            SchedulerConfigError::MarginWithoutQuant
        );
        let cfg =
            SchedulerConfig::builder().kv_quant_bits(4).kv_quant_margin(64).build().unwrap();
        assert_eq!((cfg.kv_quant_bits, cfg.kv_quant_margin), (4, 64));
    }

    /// A bounded byte budget re-queues preempted requests, so it demands a
    /// bounded queue; with a queue bound (or no budget) it builds.
    #[test]
    fn builder_rejects_budget_without_queue_bound() {
        assert_eq!(
            SchedulerConfig::builder().kv_budget_bytes(1 << 20).build().unwrap_err(),
            SchedulerConfigError::BudgetWithoutQueueBound
        );
        let cfg = SchedulerConfig::builder().kv_budget_bytes(1 << 20).max_queue(8).build().unwrap();
        assert_eq!(cfg.kv_budget_bytes, 1 << 20);
        assert!(SchedulerConfig::builder().kv_budget_bytes(0).max_queue(0).build().is_ok());
    }
}
