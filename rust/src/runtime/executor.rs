//! Typed executors over the AOT artifacts: the transformer logits graph
//! (weights passed as PJRT literals, built once per model) and the
//! standalone kernels (fused dequant-matmul, K-Means step) — plus
//! [`ColdStart`], the checkpoint-to-serving entry point of the Rust
//! execution path (no PJRT involved): one `CLAQMD01` file in, a packed
//! [`ExecModel`] out, with the load latency measured for the cold-start
//! benches.

use super::{literal_f32, literal_i32, Runtime};
use crate::model::checkpoint::Checkpoint;
use crate::model::exec::ExecModel;
use crate::model::Model;
use crate::tensor::Matrix;
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// A serving engine cold-started from a single-file checkpoint: the
/// quantize-once / serve-many path. Skips calibration and quantization
/// entirely — the dominant cost of bringing up a `serve_quantized`
/// process — and never materializes a dense projection matrix
/// (`ExecModel::from_checkpoint`). `bench_decode` tracks
/// load-to-first-token latency through this type.
pub struct ColdStart {
    /// The packed execution model, ready for the scheduler.
    pub exec: ExecModel,
    /// Method recorded in the checkpoint (e.g. `CLAQ*-2.12`).
    pub method_name: String,
    /// On-disk size of the checkpoint file.
    pub checkpoint_bytes: u64,
    /// Wall seconds from open to a ready `ExecModel`.
    pub load_seconds: f64,
}

impl ColdStart {
    /// Load a `CLAQMD01` checkpoint and build the packed execution model.
    pub fn from_path(path: &Path) -> Result<Self> {
        let t0 = Instant::now();
        let checkpoint_bytes = std::fs::metadata(path)
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        let ckpt = Checkpoint::load(path)?;
        let method_name = ckpt.method_name.clone();
        let exec = ExecModel::from_checkpoint(ckpt)?;
        Ok(Self {
            exec,
            method_name,
            checkpoint_bytes,
            load_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Executes the `model_{l,xl}.hlo.txt` logits graph for a concrete model.
/// The full argument vector (token slot + weight literals) is materialized
/// once at construction; each `logits` call only rebuilds the (1, seq)
/// token literal in slot 0 — weights are borrowed from the executor, never
/// cloned per request.
pub struct ModelExecutor {
    hlo_path: PathBuf,
    /// `args[0]` is the token slot; `args[1..]` are the weight literals.
    args: Vec<xla::Literal>,
    pub seq: usize,
    vocab: usize,
}

impl ModelExecutor {
    /// `hlo_path` must have been lowered for exactly `model.config`
    /// (argument order: tokens, then CLAQWT01 tensor order).
    pub fn new(hlo_path: PathBuf, model: &Model) -> Result<Self> {
        let c = &model.config;
        let d = c.d_model as i64;
        let f = c.d_ff as i64;
        let v = c.vocab as i64;
        let mut args: Vec<xla::Literal> = Vec::new();
        // Placeholder token literal; overwritten by every `logits` call.
        args.push(literal_i32(&vec![0i32; c.max_seq], &[1, c.max_seq as i64])?);
        args.push(literal_f32(&model.tok_embed.data, &[v, d])?);
        for l in &model.layers {
            args.push(literal_f32(&l.attn_norm, &[d])?);
            args.push(literal_f32(&l.wq.data, &[d, d])?);
            args.push(literal_f32(&l.wk.data, &[d, d])?);
            args.push(literal_f32(&l.wv.data, &[d, d])?);
            args.push(literal_f32(&l.wo.data, &[d, d])?);
            args.push(literal_f32(&l.mlp_norm, &[d])?);
            args.push(literal_f32(&l.w_gate.data, &[f, d])?);
            args.push(literal_f32(&l.w_up.data, &[f, d])?);
            args.push(literal_f32(&l.w_down.data, &[d, f])?);
        }
        args.push(literal_f32(&model.final_norm, &[d])?);
        args.push(literal_f32(&model.lm_head.data, &[v, d])?);
        Ok(Self { hlo_path, args, seq: c.max_seq, vocab: c.vocab })
    }

    /// Run the graph on exactly `seq` tokens → logits (seq × vocab).
    pub fn logits(&mut self, rt: &mut Runtime, tokens: &[u16]) -> Result<Matrix> {
        ensure!(
            tokens.len() == self.seq,
            "AOT graph is fixed-shape: expected {} tokens, got {}",
            self.seq,
            tokens.len()
        );
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        self.args[0] = literal_i32(&toks, &[1, self.seq as i64])?;
        let out = rt.execute(&self.hlo_path, &self.args)?;
        let logits = out.into_iter().next().context("empty result")?;
        let data = super::literal_to_vec_f32(&logits)?;
        ensure!(data.len() == self.seq * self.vocab, "bad logits size {}", data.len());
        Ok(Matrix::from_vec(self.seq, self.vocab, data))
    }

    /// Perplexity over a token stream using the PJRT graph (the runtime
    /// hot path; mirrors `eval::perplexity` on the Rust forward).
    pub fn perplexity(&mut self, rt: &mut Runtime, stream: &[u16], max_windows: usize) -> Result<f64> {
        let mut total_nll = 0.0f64;
        let mut total_tok = 0usize;
        let mut windows = 0usize;
        for chunk in stream.chunks_exact(self.seq) {
            let logits = self.logits(rt, chunk)?;
            for t in 0..self.seq - 1 {
                let row = logits.row(t);
                let lse = crate::util::stats::log_sum_exp(row);
                total_nll += lse - row[chunk[t + 1] as usize] as f64;
            }
            total_tok += self.seq - 1;
            windows += 1;
            if max_windows > 0 && windows >= max_windows {
                break;
            }
        }
        Ok((total_nll / total_tok.max(1) as f64).exp())
    }
}

/// Executor for the standalone fused dequant-matmul kernel artifact
/// (`quant_matmul.hlo.txt`, fixed shape m=k=n=128, L=16).
pub struct QuantMatmulExecutor {
    pub hlo_path: PathBuf,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub levels: usize,
}

impl QuantMatmulExecutor {
    pub fn standard(hlo_path: PathBuf) -> Self {
        Self { hlo_path, m: 128, k: 128, n: 128, levels: 16 }
    }

    /// y = x @ dequant(W).T with per-input-feature codebooks.
    pub fn run(
        &self,
        rt: &mut Runtime,
        x: &[f32],
        codebooks: &[f32],
        indices: &[i32],
    ) -> Result<Vec<f32>> {
        let args = vec![
            literal_f32(x, &[self.m as i64, self.k as i64])?,
            literal_f32(codebooks, &[self.k as i64, self.levels as i64])?,
            literal_i32(indices, &[self.n as i64, self.k as i64])?,
        ];
        let out = rt.execute(&self.hlo_path, &args)?;
        super::literal_to_vec_f32(&out[0])
    }
}

/// Executor for the K-Means Lloyd-step kernel artifact
/// (`kmeans_step.hlo.txt`, fixed shape c=128, n=128, K=16).
pub struct KMeansExecutor {
    pub hlo_path: PathBuf,
    pub c: usize,
    pub n: usize,
    pub k: usize,
}

impl KMeansExecutor {
    pub fn standard(hlo_path: PathBuf) -> Self {
        Self { hlo_path, c: 128, n: 128, k: 16 }
    }

    /// One Lloyd step → (new centroids (c×k), inertia (c)).
    pub fn step(
        &self,
        rt: &mut Runtime,
        values: &[f32],
        centroids: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let args = vec![
            literal_f32(values, &[self.c as i64, self.n as i64])?,
            literal_f32(centroids, &[self.c as i64, self.k as i64])?,
        ];
        let out = rt.execute(&self.hlo_path, &args)?;
        ensure!(out.len() == 2, "expected 2 outputs, got {}", out.len());
        Ok((
            super::literal_to_vec_f32(&out[0])?,
            super::literal_to_vec_f32(&out[1])?,
        ))
    }
}
