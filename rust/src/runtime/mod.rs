//! Runtime layer: everything that *serves* the model rather than builds
//! it.
//!
//! * [`scheduler`] — the continuous-batching serving engine (request
//!   admission, pooled KV caches, fused variable-length decode) over the
//!   `model::exec` execution backends.
//! * [`prefix_cache`] — prefix-sharing KV reuse: a token trie pinning
//!   retired requests' KV prefixes so shared-prompt admissions prefill
//!   only their tail (DESIGN.md §10).
//! * [`executor`] / [`Runtime`] — the PJRT path: loads the AOT-lowered
//!   HLO text artifacts (produced once by `python/compile/aot.py`) and
//!   executes them from the Rust side via the `xla` crate. Python is
//!   never on this path.

pub mod executor;
pub mod prefix_cache;
pub mod scheduler;

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled-executable cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            self.cache.insert(path.to_path_buf(), exe);
        }
        Ok(self.cache.get(path).unwrap())
    }

    /// Execute a cached executable. All aot.py graphs are lowered with
    /// `return_tuple=True`; the tuple is unpacked into its elements.
    pub fn execute(&mut self, path: &Path, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(path)?;
        let out = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {}", path.display()))?;
        let lit = out[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        lit.to_tuple().context("unpack result tuple")
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Read an f32 literal back into a Vec.
pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
