//! The GPTQ-style per-column quantization engine — the substrate the paper
//! builds on ("our experiments were built upon the GPTQ framework"), made
//! general enough to express every method in the evaluation:
//!
//! * **RTN**      = no error propagation + uniform codebooks
//! * **GPTQ**     = error propagation + uniform codebooks
//! * **CLAQ**     = error propagation + K-Means codebooks (§3.1)
//! * **CLAQ+AP**  = per-column bit widths from `precision.rs` (§3.3)
//! * **CLAQ+OR**  = per-column FP16 reservations from `reservation.rs` (§3.4)
//!
//! Error compensation follows Frantar et al.: with H = 2·E[x xᵀ] the
//! layer-local Hessian, let U be the upper Cholesky factor of H⁻¹
//! (H⁻¹ = Uᵀ·U). Quantizing column j to q introduces residual
//! e = (w_j − q)/U[j,j]; every not-yet-quantized column k > j is updated by
//! w_k −= e · U[j,k], which is optimal in the OBS sense.

use crate::quant::codebook::{uniform_codebook, Codebook};
use crate::quant::kmeans::{kmeans_1d, KMeansOpts};
use crate::quant::reservation::pick_reserved_rows;
use crate::tensor::linalg::{dampen, gptq_inverse_factor};
use crate::tensor::Matrix;

/// How codebook centroids are chosen per column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CentroidRule {
    /// §3.1 K-Means clustering (CLAQ).
    KMeans,
    /// Min–max uniform levels (RTN / GPTQ baselines).
    UniformMinMax,
}

/// Full quantization plan for one weight matrix (rows × cols, columns are
/// the quantization groups — for a Linear stored (out × in) each group is
/// an input feature, matching GPTQ's traversal).
#[derive(Clone, Debug)]
pub struct MatrixPlan {
    /// Index bits per column (from `BitPlan`).
    pub bits: Vec<u8>,
    /// FP16-reserved entries per column (from `ReservePlan`); may be empty
    /// meaning "no reservation anywhere".
    pub reserve: Vec<usize>,
    pub rule: CentroidRule,
    /// GPTQ error compensation on/off (RTN = off).
    pub propagate: bool,
    /// Hessian dampening (GPTQ default 0.01).
    pub damp_pct: f64,
}

impl MatrixPlan {
    pub fn uniform(cols: usize, bits: u8, rule: CentroidRule, propagate: bool) -> Self {
        Self {
            bits: vec![bits; cols],
            reserve: Vec::new(),
            rule,
            propagate,
            damp_pct: 0.01,
        }
    }

    fn reserve_at(&self, col: usize) -> usize {
        self.reserve.get(col).copied().unwrap_or(0)
    }
}

/// One quantized column: codebook + per-row indices.
#[derive(Clone, Debug)]
pub struct QuantizedColumn {
    pub codebook: Codebook,
    pub indices: Vec<u8>,
    pub bits: u8,
}

/// A reserved full-precision entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outlier {
    pub row: u32,
    pub col: u32,
    pub value: f32,
}

/// Quality metrics of one matrix quantization.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantMetrics {
    /// ‖W − Ŵ‖_F relative to ‖W‖_F.
    pub rel_frobenius_err: f64,
    /// GPTQ proxy loss Σ_j ‖e_j‖² (scaled residuals) when propagating.
    pub proxy_loss: f64,
}

/// The quantized representation of one matrix.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub columns: Vec<QuantizedColumn>,
    /// Sorted by (col, row).
    pub outliers: Vec<Outlier>,
    pub metrics: QuantMetrics,
}

impl QuantizedMatrix {
    /// Reconstruct the dense matrix (codebook decode + outlier overwrite).
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for (c, qc) in self.columns.iter().enumerate() {
            for r in 0..self.rows {
                m.data[r * self.cols + c] = qc.codebook.dequantize(qc.indices[r]);
            }
        }
        for o in &self.outliers {
            m.data[o.row as usize * self.cols + o.col as usize] = o.value;
        }
        m
    }

    /// Average index bits per parameter (excludes codebook + outlier cost;
    /// see `packed.rs` for full accounting).
    pub fn index_bits_per_param(&self) -> f64 {
        let total: f64 = self.columns.iter().map(|c| c.bits as f64 * self.rows as f64).sum();
        total / (self.rows * self.cols) as f64
    }

    /// Paper-accounting equivalent bits: index bits + 16 bits per reserved
    /// outlier, amortized per parameter.
    pub fn equivalent_bits_paper(&self) -> f64 {
        self.index_bits_per_param()
            + self.outliers.len() as f64 * 16.0 / (self.rows * self.cols) as f64
    }
}

/// Quantize `w` under `plan`, optionally compensating error through the
/// calibration Hessian `h` (cols × cols, row-major f64). Returns the packed
/// representation; `w` itself is not modified.
pub fn quantize_matrix(w: &Matrix, h: Option<&[f64]>, plan: &MatrixPlan) -> QuantizedMatrix {
    let (rows, cols) = (w.rows, w.cols);
    assert_eq!(plan.bits.len(), cols, "plan/matrix column mismatch");

    // Inverse-Hessian Cholesky factor for propagation.
    let u = if plan.propagate {
        let mut hd = match h {
            Some(h) => {
                assert_eq!(h.len(), cols * cols);
                h.to_vec()
            }
            // No calibration data: identity Hessian makes propagation a
            // no-op but keeps the code path uniform.
            None => {
                let mut id = vec![0.0f64; cols * cols];
                for i in 0..cols {
                    id[i * cols + i] = 1.0;
                }
                id
            }
        };
        dampen(&mut hd, cols, plan.damp_pct);
        // Increase dampening until the factorization succeeds (rank-deficient
        // calibration sets at small sample counts).
        let mut pct = plan.damp_pct;
        loop {
            match gptq_inverse_factor(&hd, cols) {
                Some(u) => break Some(u),
                None => {
                    pct *= 10.0;
                    assert!(pct < 1e6, "Hessian cannot be stabilized");
                    dampen(&mut hd, cols, pct);
                }
            }
        }
    } else {
        None
    };

    let mut work = w.clone(); // updated in place by propagation
    let mut columns: Vec<QuantizedColumn> = Vec::with_capacity(cols);
    let mut outliers: Vec<Outlier> = Vec::new();
    let mut proxy_loss = 0.0f64;
    let mut col_buf: Vec<f32> = vec![0.0; rows];
    let mut err: Vec<f32> = vec![0.0; rows];
    let kopts = KMeansOpts::default();

    for j in 0..cols {
        // Extract the current (already-updated) column.
        for r in 0..rows {
            col_buf[r] = work.data[r * cols + j];
        }

        // Outlier reservation: pick rows kept in FP16 for this column.
        let n_reserve = plan.reserve_at(j);
        let reserved = pick_reserved_rows(&col_buf, n_reserve);
        let mut is_reserved = vec![false; rows];
        for &r in &reserved {
            is_reserved[r] = true;
            outliers.push(Outlier { row: r as u32, col: j as u32, value: col_buf[r] });
        }

        // Codebook over the non-reserved entries.
        let clusterable: Vec<f32> = if reserved.is_empty() {
            col_buf.clone()
        } else {
            col_buf
                .iter()
                .enumerate()
                .filter(|(r, _)| !is_reserved[*r])
                .map(|(_, &v)| v)
                .collect()
        };
        let k = 1usize << plan.bits[j];
        let codebook = match plan.rule {
            CentroidRule::KMeans => kmeans_1d(&clusterable, k, &kopts).codebook,
            CentroidRule::UniformMinMax => uniform_codebook(&clusterable, k),
        };

        // Quantize + error.
        let mut indices = vec![0u8; rows];
        for r in 0..rows {
            if is_reserved[r] {
                err[r] = 0.0; // reserved entries are exact
                continue;
            }
            let q = codebook.quantize(col_buf[r]);
            indices[r] = q;
            err[r] = col_buf[r] - codebook.dequantize(q);
        }

        // OBS update of the not-yet-quantized columns.
        if let Some(u) = &u {
            let ujj = u[j * cols + j];
            debug_assert!(ujj > 0.0);
            let inv = 1.0 / ujj;
            let mut e2 = 0.0f64;
            for r in 0..rows {
                let e = err[r] as f64 * inv;
                e2 += e * e;
                if e != 0.0 {
                    let row = &mut work.data[r * cols..(r + 1) * cols];
                    for kcol in (j + 1)..cols {
                        row[kcol] -= (e * u[j * cols + kcol]) as f32;
                    }
                }
            }
            proxy_loss += e2;
        }

        columns.push(QuantizedColumn { codebook, indices, bits: plan.bits[j] });
    }

    outliers.sort_by_key(|o| (o.col, o.row));

    let mut qm = QuantizedMatrix { rows, cols, columns, outliers, metrics: QuantMetrics::default() };
    let deq = qm.dequantize();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in w.data.iter().zip(&deq.data) {
        let d = (*a - *b) as f64;
        num += d * d;
        den += (*a as f64) * (*a as f64);
    }
    qm.metrics = QuantMetrics {
        rel_frobenius_err: if den > 0.0 { (num / den).sqrt() } else { 0.0 },
        proxy_loss,
    };
    qm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg::gram;
    use crate::util::proptest::{check_default, gen_column};
    use crate::util::rng::Rng;

    fn random_w(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        for c in 0..cols {
            let col = gen_column(&mut rng, rows, 0.01);
            w.set_col(c, &col);
        }
        w
    }

    fn random_h(cols: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(cols * 3, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }
        h
    }

    #[test]
    fn dequantize_shape_and_range() {
        let w = random_w(32, 16, 1);
        let plan = MatrixPlan::uniform(16, 4, CentroidRule::KMeans, false);
        let q = quantize_matrix(&w, None, &plan);
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (32, 16));
        // every dequantized value must be a centroid of its column codebook
        for c in 0..16 {
            let cb = &q.columns[c].codebook;
            for r in 0..32 {
                assert!(cb.centroids.contains(&d.at(r, c)));
            }
        }
    }

    #[test]
    fn high_bits_low_error() {
        let w = random_w(64, 24, 2);
        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            let e2 = quantize_matrix(&w, None, &MatrixPlan::uniform(24, 2, rule, false))
                .metrics
                .rel_frobenius_err;
            let e4 = quantize_matrix(&w, None, &MatrixPlan::uniform(24, 4, rule, false))
                .metrics
                .rel_frobenius_err;
            assert!(e4 < e2, "{rule:?}: 4-bit {e4} !< 2-bit {e2}");
        }
    }

    #[test]
    fn kmeans_beats_uniform_weight_error() {
        let w = random_w(256, 16, 3);
        let km = quantize_matrix(&w, None, &MatrixPlan::uniform(16, 3, CentroidRule::KMeans, false));
        let un =
            quantize_matrix(&w, None, &MatrixPlan::uniform(16, 3, CentroidRule::UniformMinMax, false));
        assert!(
            km.metrics.rel_frobenius_err < un.metrics.rel_frobenius_err,
            "kmeans {} !< uniform {}",
            km.metrics.rel_frobenius_err,
            un.metrics.rel_frobenius_err
        );
    }

    /// The defining GPTQ property: propagation reduces *layer output* error
    /// E‖x·(W−Ŵ)ᵀ‖² (not necessarily the weight error itself).
    #[test]
    fn propagation_reduces_output_error() {
        let rows = 48;
        let cols = 32;
        let w = random_w(rows, cols, 4);
        let mut rng = Rng::new(5);
        let mut x = Matrix::zeros(200, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }

        let out_err = |q: &QuantizedMatrix| -> f64 {
            let dw = q.dequantize();
            let mut diff = w.clone();
            diff.axpy(-1.0, &dw);
            // E ||x (W-What)^T||^2 = tr((W-What) G (W-What)^T), G = X^T X / m
            let g = gram(&x, 0.0);
            let mut total = 0.0f64;
            for r in 0..rows {
                let row = diff.row(r);
                for i in 0..cols {
                    let di = row[i] as f64;
                    if di == 0.0 {
                        continue;
                    }
                    for j in 0..cols {
                        total += di * g[i * cols + j] * row[j] as f64;
                    }
                }
            }
            total
        };

        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            let no_prop = quantize_matrix(&w, None, &MatrixPlan::uniform(cols, 2, rule, false));
            let with_prop = quantize_matrix(&w, Some(&h), &MatrixPlan::uniform(cols, 2, rule, true));
            let (e0, e1) = (out_err(&no_prop), out_err(&with_prop));
            assert!(
                e1 < e0,
                "{rule:?}: propagation should reduce output error ({e1} !< {e0})"
            );
        }
    }

    #[test]
    fn reserved_outliers_are_exact() {
        let w = random_w(64, 8, 6);
        let mut plan = MatrixPlan::uniform(8, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![4; 8];
        let q = quantize_matrix(&w, None, &plan);
        assert_eq!(q.outliers.len(), 4 * 8);
        let d = q.dequantize();
        for o in &q.outliers {
            assert_eq!(d.at(o.row as usize, o.col as usize), o.value);
            // without propagation, the reserved value equals the original
            assert_eq!(o.value, w.at(o.row as usize, o.col as usize));
        }
    }

    #[test]
    fn reservation_lowers_error() {
        let w = random_w(128, 16, 7);
        let base = quantize_matrix(&w, None, &MatrixPlan::uniform(16, 2, CentroidRule::KMeans, false));
        let mut plan = MatrixPlan::uniform(16, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![8; 16];
        let with_or = quantize_matrix(&w, None, &plan);
        assert!(with_or.metrics.rel_frobenius_err < base.metrics.rel_frobenius_err);
    }

    #[test]
    fn mixed_bits_respected() {
        let w = random_w(32, 4, 8);
        let plan = MatrixPlan {
            bits: vec![4, 2, 2, 3],
            reserve: Vec::new(),
            rule: CentroidRule::KMeans,
            propagate: false,
            damp_pct: 0.01,
        };
        let q = quantize_matrix(&w, None, &plan);
        assert_eq!(q.columns[0].codebook.len(), 16);
        assert_eq!(q.columns[1].codebook.len(), 4);
        assert_eq!(q.columns[3].codebook.len(), 8);
        assert!((q.index_bits_per_param() - 11.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn equivalent_bits_accounting() {
        let w = random_w(100, 10, 9);
        let mut plan = MatrixPlan::uniform(10, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![2; 10]; // 20 outliers over 1000 params
        let q = quantize_matrix(&w, None, &plan);
        let expect = 2.0 + 20.0 * 16.0 / 1000.0;
        assert!((q.equivalent_bits_paper() - expect).abs() < 1e-9);
    }

    #[test]
    fn identity_hessian_propagation_matches_no_propagation_weights() {
        // With H = I the OBS update still fires but off-diagonal U is 0, so
        // dequantized weights match the non-propagating path.
        let w = random_w(16, 8, 10);
        let a = quantize_matrix(&w, None, &MatrixPlan::uniform(8, 3, CentroidRule::KMeans, false));
        let plan_p = MatrixPlan { propagate: true, ..MatrixPlan::uniform(8, 3, CentroidRule::KMeans, false) };
        let b = quantize_matrix(&w, None, &plan_p); // None -> identity H (dampened)
        let (da, db) = (a.dequantize(), b.dequantize());
        for (x, y) in da.data.iter().zip(&db.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn quantized_matrix_deterministic() {
        check_default("gptq deterministic", |rng| {
            let w = random_w(24, 12, rng.next_u64());
            let h = random_h(12, rng.next_u64());
            let plan = MatrixPlan::uniform(12, 2, CentroidRule::KMeans, true);
            let a = quantize_matrix(&w, Some(&h), &plan);
            let b = quantize_matrix(&w, Some(&h), &plan);
            assert_eq!(a.dequantize().data, b.dequantize().data);
        });
    }
}
