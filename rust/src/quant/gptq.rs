//! The GPTQ-style per-column quantization engine — the substrate the paper
//! builds on ("our experiments were built upon the GPTQ framework"), made
//! general enough to express every method in the evaluation:
//!
//! * **RTN**      = no error propagation + uniform codebooks
//! * **GPTQ**     = error propagation + uniform codebooks
//! * **CLAQ**     = error propagation + K-Means codebooks (§3.1)
//! * **CLAQ+AP**  = per-column bit widths from `precision.rs` (§3.3)
//! * **CLAQ+OR**  = per-column FP16 reservations from `reservation.rs` (§3.4)
//!
//! Error compensation follows Frantar et al.: with H = 2·E[x xᵀ] the
//! layer-local Hessian, let U be the upper Cholesky factor of H⁻¹
//! (H⁻¹ = Uᵀ·U). Quantizing column j to q introduces residual
//! e = (w_j − q)/U[j,j]; every not-yet-quantized column k > j is updated by
//! w_k −= e · U[j,k], which is optimal in the OBS sense.
//!
//! The propagation is **block-lazy** (GPTQ's "lazy batch" trick, DESIGN.md
//! §8): columns are processed in blocks of [`MatrixPlan::block_size`];
//! inside a block the error is propagated eagerly (the working set is B
//! columns and stays cache-resident), while the scaled residuals E (rows×B)
//! are accumulated and applied to the trailing columns once per block as a
//! rank-B update, row-sharded across the thread pool. Every output element
//! still receives its updates one at a time in ascending source-column
//! order, so serial, parallel, and every block size are bit-identical —
//! the same discipline the packed decode kernels pin (`model/linear.rs`).

use crate::quant::codebook::{uniform_codebook, Codebook};
use crate::quant::kmeans::{kmeans_1d_into, KMeansOpts, KMeansScratch};
use crate::quant::reservation::pick_reserved_rows_into;
use crate::quant::vq::{kmeans_nd_into, KMeansNdScratch, PlaneKind, VqGroup, VqPlanes};
use crate::tensor::linalg::stabilized_inverse_factor;
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;

/// How codebook centroids are chosen per column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CentroidRule {
    /// §3.1 K-Means clustering (CLAQ).
    KMeans,
    /// Min–max uniform levels (RTN / GPTQ baselines).
    UniformMinMax,
}

/// Default OBS lazy-update block width. 64 columns × f32 keeps a block's
/// working set (rows × 256 B) inside L2 for every production shape while
/// amortizing one trailing sweep over 64 columns.
pub const DEFAULT_BLOCK: usize = 64;

/// Full quantization plan for one weight matrix (rows × cols, columns are
/// the quantization groups — for a Linear stored (out × in) each group is
/// an input feature, matching GPTQ's traversal).
#[derive(Clone, Debug)]
pub struct MatrixPlan {
    /// Index bits per column (from `BitPlan`).
    pub bits: Vec<u8>,
    /// FP16-reserved entries per column (from `ReservePlan`); may be empty
    /// meaning "no reservation anywhere".
    pub reserve: Vec<usize>,
    pub rule: CentroidRule,
    /// GPTQ error compensation on/off (RTN = off).
    pub propagate: bool,
    /// Hessian dampening (GPTQ default 0.01).
    pub damp_pct: f64,
    /// OBS lazy-update block width B: error is propagated eagerly within a
    /// B-column block and batched into one row-parallel rank-B update of
    /// the trailing columns at block end. 0 means unblocked (B = cols).
    /// Purely a performance knob — every value produces bit-identical
    /// output. (Vector-group plans round B up to a multiple of the group
    /// dim so groups never straddle block boundaries — still bit-identical
    /// for every requested value.)
    pub block_size: usize,
    /// Plane representation: scalar per-column codebooks (the default) or
    /// vector codebooks over groups of `d` adjacent columns. Vector-group
    /// plans require uniform `bits` and always cluster with K-Means (the
    /// `rule` field is ignored — there is no uniform-grid analogue in R^d).
    pub plane: PlaneKind,
}

impl MatrixPlan {
    pub fn uniform(cols: usize, bits: u8, rule: CentroidRule, propagate: bool) -> Self {
        Self {
            bits: vec![bits; cols],
            reserve: Vec::new(),
            rule,
            propagate,
            damp_pct: 0.01,
            block_size: DEFAULT_BLOCK,
            plane: PlaneKind::Scalar,
        }
    }

    /// A uniform-bits vector-group plan: `2^bits` centroids in R^d per
    /// group of `d` adjacent columns (index cost `bits/d` per parameter).
    pub fn vector_group(cols: usize, d: usize, bits: u8, propagate: bool) -> Self {
        assert!(d >= 1, "group dim must be >= 1");
        Self {
            plane: PlaneKind::VectorGroup { d },
            ..Self::uniform(cols, bits, CentroidRule::KMeans, propagate)
        }
    }

    fn reserve_at(&self, col: usize) -> usize {
        self.reserve.get(col).copied().unwrap_or(0)
    }
}

/// One quantized column: codebook + per-row indices.
#[derive(Clone, Debug)]
pub struct QuantizedColumn {
    pub codebook: Codebook,
    pub indices: Vec<u8>,
    pub bits: u8,
}

/// A reserved full-precision entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outlier {
    pub row: u32,
    pub col: u32,
    pub value: f32,
}

/// Quality metrics of one matrix quantization.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantMetrics {
    /// ‖W − Ŵ‖_F relative to ‖W‖_F.
    pub rel_frobenius_err: f64,
    /// GPTQ proxy loss Σ_j ‖e_j‖² (scaled residuals) when propagating.
    pub proxy_loss: f64,
}

/// The plane payload of a [`QuantizedMatrix`]: one scalar codebook per
/// column (the original CLAQ form) or one vector codebook per group of
/// adjacent columns (the sub-2-bit VQ form). Every consumer of quantized
/// planes — container codec, checkpoint, gather kernels — dispatches on
/// this enum.
#[derive(Clone, Debug)]
pub enum QuantPlanes {
    Columns(Vec<QuantizedColumn>),
    Groups(VqPlanes),
}

/// The quantized representation of one matrix.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub planes: QuantPlanes,
    /// Sorted by (col, row).
    pub outliers: Vec<Outlier>,
    pub metrics: QuantMetrics,
}

impl QuantizedMatrix {
    /// The plane kind of this matrix.
    pub fn plane_kind(&self) -> PlaneKind {
        match &self.planes {
            QuantPlanes::Columns(_) => PlaneKind::Scalar,
            QuantPlanes::Groups(vp) => PlaneKind::VectorGroup { d: vp.group_dim },
        }
    }

    /// The scalar per-column planes. Panics on a vector-quantized matrix —
    /// scalar-only consumers must dispatch on [`Self::plane_kind`] first.
    pub fn columns(&self) -> &[QuantizedColumn] {
        match &self.planes {
            QuantPlanes::Columns(c) => c,
            QuantPlanes::Groups(_) => {
                panic!("scalar-plane access on a vector-quantized matrix")
            }
        }
    }

    /// The vector-group planes. Panics on a scalar matrix.
    pub fn vq_planes(&self) -> &VqPlanes {
        match &self.planes {
            QuantPlanes::Groups(vp) => vp,
            QuantPlanes::Columns(_) => {
                panic!("vector-plane access on a scalar-quantized matrix")
            }
        }
    }

    /// Reconstruct the dense matrix (codebook decode + outlier overwrite).
    /// Row-major traversal: each output row is filled contiguously instead
    /// of striding a whole column of cache lines per codebook.
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        match &self.planes {
            QuantPlanes::Columns(columns) => {
                for r in 0..self.rows {
                    let row = &mut m.data[r * self.cols..(r + 1) * self.cols];
                    for (out, qc) in row.iter_mut().zip(columns) {
                        *out = qc.codebook.dequantize(qc.indices[r]);
                    }
                }
            }
            QuantPlanes::Groups(vp) => {
                for r in 0..self.rows {
                    let row = &mut m.data[r * self.cols..(r + 1) * self.cols];
                    for (g, grp) in vp.groups.iter().enumerate() {
                        let (j0, j1) = vp.group_span(g, self.cols);
                        let c = grp.codebook.centroid(grp.indices[r] as usize);
                        row[j0..j1].copy_from_slice(c);
                    }
                }
            }
        }
        for o in &self.outliers {
            m.data[o.row as usize * self.cols + o.col as usize] = o.value;
        }
        m
    }

    /// Average index bits per parameter (excludes codebook + outlier cost;
    /// see `packed.rs` for full accounting). For vector groups each packed
    /// index covers `d` columns, so the per-parameter cost is `bits/d`.
    pub fn index_bits_per_param(&self) -> f64 {
        let total: f64 = match &self.planes {
            QuantPlanes::Columns(columns) => {
                columns.iter().map(|c| c.bits as f64 * self.rows as f64).sum()
            }
            QuantPlanes::Groups(vp) => {
                vp.groups.iter().map(|g| g.bits as f64 * self.rows as f64).sum()
            }
        };
        total / (self.rows * self.cols) as f64
    }

    /// Paper-accounting equivalent bits: index bits + 16 bits per reserved
    /// outlier, amortized per parameter.
    pub fn equivalent_bits_paper(&self) -> f64 {
        self.index_bits_per_param()
            + self.outliers.len() as f64 * 16.0 / (self.rows * self.cols) as f64
    }
}

/// Reusable workspace for [`quantize_matrix_pooled`]: every buffer the
/// per-column loop needs, allocated once and recycled, so the loop runs
/// with zero heap allocations in steady state (the per-column outputs —
/// index vector and codebook — are the only allocations left, and the
/// stable index sort behind outlier reservation may allocate its merge
/// buffer on columns that actually reserve).
#[derive(Default)]
pub struct QuantScratch {
    /// Current (already-updated) column, `rows` long.
    col: Vec<f32>,
    /// Per-entry quantization error of the current column.
    err: Vec<f32>,
    /// Reserved-row mask of the current column.
    reserved: Vec<bool>,
    /// Reserved row indices (ascending) of the current column.
    reserved_rows: Vec<usize>,
    /// Index sort buffer for `pick_reserved_rows_into`.
    sort_idx: Vec<usize>,
    /// Non-reserved entries handed to the codebook builder.
    clusterable: Vec<f32>,
    /// Scaled residuals E of the current block, row-major rows × B.
    eblock: Vec<f64>,
    /// K-Means working buffers (sorted values, d2, centroids, counts, sums).
    kmeans: KMeansScratch,
    /// Current group's row-vectors, row-major rows × width (VQ mode).
    gvec: Vec<f32>,
    /// Reserved-coordinate mask of the current group, rows × width.
    gmask: Vec<bool>,
    /// Training vectors (rows with no reserved coordinate), VQ mode.
    gtrain: Vec<f32>,
    /// Per-coordinate quantization error of the current group.
    gerr: Vec<f32>,
    /// R^d K-Means working buffers (VQ mode).
    kmeans_nd: KMeansNdScratch,
}

impl QuantScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Below this many f64 multiply-accumulates (rows × block × trailing) a
/// trailing update runs serially: pool dispatch costs more than it buys.
const PAR_MIN_MACS: usize = 64 * 1024;
/// Minimum rows per shard; smaller blocks don't amortize dispatch.
const PAR_MIN_ROWS: usize = 8;

/// Apply the deferred rank-B update `W[:, b1..] −= E · U[b0..b1, b1..]` at
/// block end, sharding rows across `pool` (rows are independent). Each
/// element receives its B subtractions one at a time in ascending
/// source-column order — the exact instruction stream of the eager serial
/// loop — so thread count and shard partition are invisible in the result.
fn apply_trailing_update(
    work: &mut [f32],
    cols: usize,
    b0: usize,
    b1: usize,
    eblock: &[f64],
    u: &[f64],
    pool: &ThreadPool,
) {
    let bw = b1 - b0;
    let rows = work.len() / cols;
    debug_assert_eq!(eblock.len(), rows * bw);
    debug_assert_eq!(work.len(), rows * cols);
    let kernel = |r0: usize, chunk: &mut [f32]| {
        for (lr, row) in chunk.chunks_exact_mut(cols).enumerate() {
            let r = r0 + lr;
            let erow = &eblock[r * bw..(r + 1) * bw];
            for (jb, &e) in erow.iter().enumerate() {
                if e == 0.0 {
                    continue;
                }
                let urow = &u[(b0 + jb) * cols..(b0 + jb + 1) * cols];
                for (x, &uv) in row[b1..].iter_mut().zip(&urow[b1..]) {
                    *x -= (e * uv) as f32;
                }
            }
        }
    };

    let macs = rows * bw * (cols - b1);
    let shards = if macs < PAR_MIN_MACS {
        1
    } else {
        pool.workers().min(rows / PAR_MIN_ROWS).max(1)
    };
    pool.run_row_chunks(work, cols, shards, kernel);
}

/// Upper Cholesky factor of the (dampened) inverse Hessian when the plan
/// propagates, shared by the scalar and vector-group paths. No calibration
/// data means an identity Hessian: propagation becomes a no-op but the
/// code path stays uniform.
fn inverse_factor_for(plan: &MatrixPlan, h: Option<&[f64]>, cols: usize) -> Option<Vec<f64>> {
    if !plan.propagate {
        return None;
    }
    let mut hd = match h {
        Some(h) => {
            assert_eq!(h.len(), cols * cols);
            h.to_vec()
        }
        None => {
            let mut id = vec![0.0f64; cols * cols];
            for i in 0..cols {
                id[i * cols + i] = 1.0;
            }
            id
        }
    };
    Some(stabilized_inverse_factor(&mut hd, cols, plan.damp_pct))
}

/// Quantize `w` under `plan`, optionally compensating error through the
/// calibration Hessian `h` (cols × cols, row-major f64). Returns the packed
/// representation; `w` itself is not modified. Trailing OBS updates shard
/// across [`ThreadPool::global`]; when called from inside another pool's
/// job (the coordinator's per-matrix fan-out) they fall back inline.
pub fn quantize_matrix(w: &Matrix, h: Option<&[f64]>, plan: &MatrixPlan) -> QuantizedMatrix {
    quantize_matrix_pooled(w, h, plan, ThreadPool::global(), &mut QuantScratch::new())
}

/// [`quantize_matrix`] with an explicit pool and reusable scratch — the
/// zero-steady-state-allocation entry point for callers quantizing many
/// matrices (and the handle tests use to pin thread-count invariance).
pub fn quantize_matrix_pooled(
    w: &Matrix,
    h: Option<&[f64]>,
    plan: &MatrixPlan,
    pool: &ThreadPool,
    scratch: &mut QuantScratch,
) -> QuantizedMatrix {
    let (rows, cols) = (w.rows, w.cols);
    assert_eq!(plan.bits.len(), cols, "plan/matrix column mismatch");

    if let PlaneKind::VectorGroup { d } = plan.plane {
        return quantize_matrix_vq(w, h, plan, d, pool, scratch);
    }

    // Inverse-Hessian Cholesky factor for propagation.
    let u = inverse_factor_for(plan, h, cols);

    let block = if plan.block_size == 0 { cols.max(1) } else { plan.block_size };
    let mut work = w.clone(); // updated in place by propagation
    let mut columns: Vec<QuantizedColumn> = Vec::with_capacity(cols);
    let mut outliers: Vec<Outlier> = Vec::new();
    let mut proxy_loss = 0.0f64;
    // Metrics fold: ‖W − Ŵ‖² / ‖W‖² accumulated per column as each one is
    // finalized, so no full-matrix dequantize() round-trip is needed. The
    // fold order is fixed (column-outer) regardless of block size, so the
    // metric is part of the bit-identity contract across blocks/threads —
    // but it sums in a different order than the row-major pass it
    // replaced, so the scalar may differ in final ULPs from pre-blocking
    // releases (the quantized payload itself is unchanged).
    let mut err_sq = 0.0f64;
    let mut w_sq = 0.0f64;
    let kopts = KMeansOpts::default();

    scratch.col.resize(rows, 0.0);
    scratch.err.resize(rows, 0.0);

    let mut b0 = 0usize;
    while b0 < cols {
        let b1 = (b0 + block).min(cols);
        let bw = b1 - b0;
        // Residuals are accumulated only when this block has trailing
        // columns to lazily update; the final (or only, when unblocked)
        // block would write a rows×bw buffer nobody reads.
        let defer = u.is_some() && b1 < cols;
        if defer {
            // Fully overwritten below (every column writes every row), but
            // resize needs a fill value for fresh capacity.
            scratch.eblock.clear();
            scratch.eblock.resize(rows * bw, 0.0);
        }

        for j in b0..b1 {
            // Extract the current (already-updated) column.
            for r in 0..rows {
                scratch.col[r] = work.data[r * cols + j];
            }

            // Outlier reservation: pick rows kept in FP16 for this column.
            // `reserved_rows` comes back ascending, so pushing here keeps
            // the outlier list in (col, row) order with no final sort.
            let n_reserve = plan.reserve_at(j);
            pick_reserved_rows_into(
                &scratch.col,
                n_reserve,
                &mut scratch.sort_idx,
                &mut scratch.reserved_rows,
            );
            scratch.reserved.clear();
            scratch.reserved.resize(rows, false);
            for &r in &scratch.reserved_rows {
                scratch.reserved[r] = true;
                outliers.push(Outlier { row: r as u32, col: j as u32, value: scratch.col[r] });
            }

            // Codebook over the non-reserved entries.
            let clusterable: &[f32] = if scratch.reserved_rows.is_empty() {
                &scratch.col
            } else {
                scratch.clusterable.clear();
                scratch.clusterable.extend(
                    scratch
                        .col
                        .iter()
                        .zip(&scratch.reserved)
                        .filter(|(_, &m)| !m)
                        .map(|(&v, _)| v),
                );
                &scratch.clusterable
            };
            let k = 1usize << plan.bits[j];
            let codebook = match plan.rule {
                CentroidRule::KMeans => {
                    kmeans_1d_into(clusterable, k, &kopts, &mut scratch.kmeans).codebook
                }
                CentroidRule::UniformMinMax => uniform_codebook(clusterable, k),
            };

            // Quantize + error.
            let mut indices = vec![0u8; rows];
            for r in 0..rows {
                if scratch.reserved[r] {
                    scratch.err[r] = 0.0; // reserved entries are exact
                    continue;
                }
                let q = codebook.quantize(scratch.col[r]);
                indices[r] = q;
                scratch.err[r] = scratch.col[r] - codebook.dequantize(q);
            }

            // Metrics contribution of this now-final column, against the
            // ORIGINAL weights (reserved entries reconstruct the updated
            // value exactly, which is what dequantize() stores).
            for r in 0..rows {
                let orig = w.data[r * cols + j];
                let deq = if scratch.reserved[r] {
                    scratch.col[r]
                } else {
                    codebook.dequantize(indices[r])
                };
                let d = (orig - deq) as f64;
                err_sq += d * d;
                w_sq += orig as f64 * orig as f64;
            }

            // Eager OBS update inside the block; residuals accumulate in E
            // for the lazy trailing update at block end.
            if let Some(u) = &u {
                let jb = j - b0;
                let urow = &u[j * cols..(j + 1) * cols];
                let ujj = urow[j];
                debug_assert!(ujj > 0.0);
                let inv = 1.0 / ujj;
                let mut e2 = 0.0f64;
                for r in 0..rows {
                    let e = scratch.err[r] as f64 * inv;
                    e2 += e * e;
                    if defer {
                        scratch.eblock[r * bw + jb] = e;
                    }
                    if e != 0.0 {
                        let row = &mut work.data[r * cols..(r + 1) * cols];
                        for (x, &uv) in row[j + 1..b1].iter_mut().zip(&urow[j + 1..b1]) {
                            *x -= (e * uv) as f32;
                        }
                    }
                }
                proxy_loss += e2;
            }

            columns.push(QuantizedColumn { codebook, indices, bits: plan.bits[j] });
        }

        // Lazy batched propagation into the trailing columns.
        if defer {
            let u = u.as_ref().expect("defer implies propagation");
            apply_trailing_update(&mut work.data, cols, b0, b1, &scratch.eblock, u, pool);
        }
        b0 = b1;
    }

    debug_assert!(
        outliers.windows(2).all(|p| (p[0].col, p[0].row) < (p[1].col, p[1].row)),
        "outliers must be emitted in (col, row) order"
    );

    QuantizedMatrix {
        rows,
        cols,
        planes: QuantPlanes::Columns(columns),
        outliers,
        metrics: QuantMetrics {
            rel_frobenius_err: if w_sq > 0.0 { (err_sq / w_sq).sqrt() } else { 0.0 },
            proxy_loss,
        },
    }
}

/// The vector-group mode of [`quantize_matrix_pooled`]: `d` adjacent
/// columns are quantized jointly per step — their row-vectors are
/// clustered in R^d ([`kmeans_nd_into`]) and one packed index per row
/// selects all `d` coordinates. OBS error compensation applies group-wise:
/// the group is final the moment it is quantized (no intra-group
/// propagation), and each of its columns contributes a scaled residual
/// that lands entirely on the trailing columns — eagerly inside the block,
/// deferred as part of the rank-B update at block end. Every target
/// element still receives its updates one at a time in ascending
/// source-column order, so serial, parallel, and every block size are
/// bit-identical, exactly as in the scalar path.
fn quantize_matrix_vq(
    w: &Matrix,
    h: Option<&[f64]>,
    plan: &MatrixPlan,
    d: usize,
    pool: &ThreadPool,
    scratch: &mut QuantScratch,
) -> QuantizedMatrix {
    let (rows, cols) = (w.rows, w.cols);
    assert!(d >= 1, "group dim must be >= 1");
    assert!(
        plan.bits.windows(2).all(|p| p[0] == p[1]),
        "vector-group plans require uniform bits"
    );
    let bits = plan.bits.first().copied().unwrap_or(0);
    assert!((1..=8).contains(&bits), "vector-group bits must be in 1..=8");
    let k = 1usize << bits;

    let u = inverse_factor_for(plan, h, cols);

    // Round the block width up to a multiple of d so no group straddles a
    // block boundary (a group is quantized in one step, so its deferred
    // residuals must land in one eblock). Still bit-identical for every
    // requested block size: the per-element update order stays ascending
    // in source column regardless of where block boundaries fall.
    let block = if plan.block_size == 0 { cols.max(1) } else { plan.block_size };
    let block = block.div_ceil(d) * d;

    let mut work = w.clone();
    let mut groups: Vec<VqGroup> = Vec::with_capacity(cols.div_ceil(d));
    let mut outliers: Vec<Outlier> = Vec::new();
    let mut proxy_loss = 0.0f64;
    let mut err_sq = 0.0f64;
    let mut w_sq = 0.0f64;
    let kopts = KMeansOpts::default();

    scratch.col.resize(rows, 0.0);

    let mut b0 = 0usize;
    while b0 < cols {
        let b1 = (b0 + block).min(cols);
        let bw = b1 - b0;
        let defer = u.is_some() && b1 < cols;
        if defer {
            scratch.eblock.clear();
            scratch.eblock.resize(rows * bw, 0.0);
        }

        let mut j0 = b0;
        while j0 < b1 {
            let j1 = (j0 + d).min(cols);
            let width = j1 - j0;

            // Gather the group's row-vectors from the updated working copy.
            scratch.gvec.clear();
            scratch.gvec.resize(rows * width, 0.0);
            for r in 0..rows {
                scratch.gvec[r * width..(r + 1) * width]
                    .copy_from_slice(&work.data[r * cols + j0..r * cols + j1]);
            }

            // Outlier reservation per column; ascending jj keeps the
            // outlier list in (col, row) order with no final sort.
            scratch.gmask.clear();
            scratch.gmask.resize(rows * width, false);
            for jj in 0..width {
                let j = j0 + jj;
                let n_reserve = plan.reserve_at(j);
                if n_reserve == 0 {
                    continue;
                }
                for r in 0..rows {
                    scratch.col[r] = scratch.gvec[r * width + jj];
                }
                pick_reserved_rows_into(
                    &scratch.col,
                    n_reserve,
                    &mut scratch.sort_idx,
                    &mut scratch.reserved_rows,
                );
                for &r in &scratch.reserved_rows {
                    scratch.gmask[r * width + jj] = true;
                    outliers.push(Outlier { row: r as u32, col: j as u32, value: scratch.col[r] });
                }
            }

            // Codebook over the rows with no reserved coordinate; when
            // every row reserves something, train on all rows (the masked
            // assignment below still keeps reserved coordinates exact).
            scratch.gtrain.clear();
            for r in 0..rows {
                if scratch.gmask[r * width..(r + 1) * width].iter().all(|&m| !m) {
                    scratch.gtrain.extend_from_slice(&scratch.gvec[r * width..(r + 1) * width]);
                }
            }
            let train: &[f32] =
                if scratch.gtrain.is_empty() { &scratch.gvec } else { &scratch.gtrain };
            let codebook = kmeans_nd_into(train, width, k, &kopts, &mut scratch.kmeans_nd).codebook;

            // Quantize each row-vector (reserved coordinates excluded from
            // the nearest-centroid distance) + per-coordinate error.
            scratch.gerr.clear();
            scratch.gerr.resize(rows * width, 0.0);
            let mut indices = vec![0u8; rows];
            for r in 0..rows {
                let v = &scratch.gvec[r * width..(r + 1) * width];
                let m = &scratch.gmask[r * width..(r + 1) * width];
                let q = if m.iter().any(|&x| x) {
                    codebook.quantize_masked(v, m)
                } else {
                    codebook.quantize(v)
                };
                indices[r] = q;
                let c = codebook.centroid(q as usize);
                for jj in 0..width {
                    // reserved entries are exact
                    scratch.gerr[r * width + jj] = if m[jj] { 0.0 } else { v[jj] - c[jj] };
                }
            }

            // Metrics contribution of the now-final group, against the
            // ORIGINAL weights, folded column-outer like the scalar path.
            for jj in 0..width {
                let j = j0 + jj;
                for r in 0..rows {
                    let orig = w.data[r * cols + j];
                    let deq = if scratch.gmask[r * width + jj] {
                        scratch.gvec[r * width + jj]
                    } else {
                        codebook.centroid(indices[r] as usize)[jj]
                    };
                    let dv = (orig - deq) as f64;
                    err_sq += dv * dv;
                    w_sq += orig as f64 * orig as f64;
                }
            }

            // Group-wise OBS: the rank-d residual of this group lands
            // entirely on the columns after it.
            if let Some(u) = &u {
                for jj in 0..width {
                    let j = j0 + jj;
                    let jb = j - b0;
                    let urow = &u[j * cols..(j + 1) * cols];
                    let ujj = urow[j];
                    debug_assert!(ujj > 0.0);
                    let inv = 1.0 / ujj;
                    let mut e2 = 0.0f64;
                    for r in 0..rows {
                        let e = scratch.gerr[r * width + jj] as f64 * inv;
                        e2 += e * e;
                        if defer {
                            scratch.eblock[r * bw + jb] = e;
                        }
                        if e != 0.0 && j1 < b1 {
                            let row = &mut work.data[r * cols..(r + 1) * cols];
                            for (x, &uv) in row[j1..b1].iter_mut().zip(&urow[j1..b1]) {
                                *x -= (e * uv) as f32;
                            }
                        }
                    }
                    proxy_loss += e2;
                }
            }

            groups.push(VqGroup { codebook, indices, bits });
            j0 = j1;
        }

        // Lazy batched propagation into the trailing columns.
        if defer {
            let u = u.as_ref().expect("defer implies propagation");
            apply_trailing_update(&mut work.data, cols, b0, b1, &scratch.eblock, u, pool);
        }
        b0 = b1;
    }

    debug_assert!(
        outliers.windows(2).all(|p| (p[0].col, p[0].row) < (p[1].col, p[1].row)),
        "outliers must be emitted in (col, row) order"
    );

    QuantizedMatrix {
        rows,
        cols,
        planes: QuantPlanes::Groups(VqPlanes { group_dim: d, groups }),
        outliers,
        metrics: QuantMetrics {
            rel_frobenius_err: if w_sq > 0.0 { (err_sq / w_sq).sqrt() } else { 0.0 },
            proxy_loss,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg::gram;
    use crate::util::proptest::{check_default, gen_column};
    use crate::util::rng::Rng;

    fn random_w(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        for c in 0..cols {
            let col = gen_column(&mut rng, rows, 0.01);
            w.set_col(c, &col);
        }
        w
    }

    fn random_h(cols: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(cols * 3, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }
        h
    }

    fn bits_of(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn dequantize_shape_and_range() {
        let w = random_w(32, 16, 1);
        let plan = MatrixPlan::uniform(16, 4, CentroidRule::KMeans, false);
        let q = quantize_matrix(&w, None, &plan);
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (32, 16));
        // every dequantized value must be a centroid of its column codebook
        for c in 0..16 {
            let cb = &q.columns()[c].codebook;
            for r in 0..32 {
                assert!(cb.centroids.contains(&d.at(r, c)));
            }
        }
    }

    #[test]
    fn high_bits_low_error() {
        let w = random_w(64, 24, 2);
        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            let e2 = quantize_matrix(&w, None, &MatrixPlan::uniform(24, 2, rule, false))
                .metrics
                .rel_frobenius_err;
            let e4 = quantize_matrix(&w, None, &MatrixPlan::uniform(24, 4, rule, false))
                .metrics
                .rel_frobenius_err;
            assert!(e4 < e2, "{rule:?}: 4-bit {e4} !< 2-bit {e2}");
        }
    }

    #[test]
    fn kmeans_beats_uniform_weight_error() {
        let w = random_w(256, 16, 3);
        let km = quantize_matrix(&w, None, &MatrixPlan::uniform(16, 3, CentroidRule::KMeans, false));
        let un =
            quantize_matrix(&w, None, &MatrixPlan::uniform(16, 3, CentroidRule::UniformMinMax, false));
        assert!(
            km.metrics.rel_frobenius_err < un.metrics.rel_frobenius_err,
            "kmeans {} !< uniform {}",
            km.metrics.rel_frobenius_err,
            un.metrics.rel_frobenius_err
        );
    }

    /// The defining GPTQ property: propagation reduces *layer output* error
    /// E‖x·(W−Ŵ)ᵀ‖² (not necessarily the weight error itself).
    #[test]
    fn propagation_reduces_output_error() {
        let rows = 48;
        let cols = 32;
        let w = random_w(rows, cols, 4);
        let mut rng = Rng::new(5);
        let mut x = Matrix::zeros(200, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }

        let out_err = |q: &QuantizedMatrix| -> f64 {
            let dw = q.dequantize();
            let mut diff = w.clone();
            diff.axpy(-1.0, &dw);
            // E ||x (W-What)^T||^2 = tr((W-What) G (W-What)^T), G = X^T X / m
            let g = gram(&x, 0.0);
            let mut total = 0.0f64;
            for r in 0..rows {
                let row = diff.row(r);
                for i in 0..cols {
                    let di = row[i] as f64;
                    if di == 0.0 {
                        continue;
                    }
                    for j in 0..cols {
                        total += di * g[i * cols + j] * row[j] as f64;
                    }
                }
            }
            total
        };

        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            let no_prop = quantize_matrix(&w, None, &MatrixPlan::uniform(cols, 2, rule, false));
            let with_prop = quantize_matrix(&w, Some(&h), &MatrixPlan::uniform(cols, 2, rule, true));
            let (e0, e1) = (out_err(&no_prop), out_err(&with_prop));
            assert!(
                e1 < e0,
                "{rule:?}: propagation should reduce output error ({e1} !< {e0})"
            );
        }
    }

    #[test]
    fn reserved_outliers_are_exact() {
        let w = random_w(64, 8, 6);
        let mut plan = MatrixPlan::uniform(8, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![4; 8];
        let q = quantize_matrix(&w, None, &plan);
        assert_eq!(q.outliers.len(), 4 * 8);
        let d = q.dequantize();
        for o in &q.outliers {
            assert_eq!(d.at(o.row as usize, o.col as usize), o.value);
            // without propagation, the reserved value equals the original
            assert_eq!(o.value, w.at(o.row as usize, o.col as usize));
        }
    }

    #[test]
    fn outliers_emitted_in_col_row_order() {
        let w = random_w(96, 12, 13);
        let mut plan = MatrixPlan::uniform(12, 2, CentroidRule::KMeans, true);
        plan.reserve = (0..12).map(|c| (c % 4) * 2).collect();
        plan.block_size = 5;
        let q = quantize_matrix(&w, Some(&random_h(12, 14)), &plan);
        assert!(!q.outliers.is_empty());
        for p in q.outliers.windows(2) {
            assert!((p[0].col, p[0].row) < (p[1].col, p[1].row), "unsorted outliers");
        }
    }

    #[test]
    fn reservation_lowers_error() {
        let w = random_w(128, 16, 7);
        let base = quantize_matrix(&w, None, &MatrixPlan::uniform(16, 2, CentroidRule::KMeans, false));
        let mut plan = MatrixPlan::uniform(16, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![8; 16];
        let with_or = quantize_matrix(&w, None, &plan);
        assert!(with_or.metrics.rel_frobenius_err < base.metrics.rel_frobenius_err);
    }

    #[test]
    fn mixed_bits_respected() {
        let w = random_w(32, 4, 8);
        let plan = MatrixPlan {
            bits: vec![4, 2, 2, 3],
            reserve: Vec::new(),
            rule: CentroidRule::KMeans,
            propagate: false,
            damp_pct: 0.01,
            block_size: DEFAULT_BLOCK,
            plane: PlaneKind::Scalar,
        };
        let q = quantize_matrix(&w, None, &plan);
        assert_eq!(q.columns()[0].codebook.len(), 16);
        assert_eq!(q.columns()[1].codebook.len(), 4);
        assert_eq!(q.columns()[3].codebook.len(), 8);
        assert!((q.index_bits_per_param() - 11.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn equivalent_bits_accounting() {
        let w = random_w(100, 10, 9);
        let mut plan = MatrixPlan::uniform(10, 2, CentroidRule::KMeans, false);
        plan.reserve = vec![2; 10]; // 20 outliers over 1000 params
        let q = quantize_matrix(&w, None, &plan);
        let expect = 2.0 + 20.0 * 16.0 / 1000.0;
        assert!((q.equivalent_bits_paper() - expect).abs() < 1e-9);
    }

    #[test]
    fn identity_hessian_propagation_matches_no_propagation_weights() {
        // With H = I the OBS update still fires but off-diagonal U is 0, so
        // dequantized weights match the non-propagating path.
        let w = random_w(16, 8, 10);
        let a = quantize_matrix(&w, None, &MatrixPlan::uniform(8, 3, CentroidRule::KMeans, false));
        let plan_p = MatrixPlan { propagate: true, ..MatrixPlan::uniform(8, 3, CentroidRule::KMeans, false) };
        let b = quantize_matrix(&w, None, &plan_p); // None -> identity H (dampened)
        let (da, db) = (a.dequantize(), b.dequantize());
        for (x, y) in da.data.iter().zip(&db.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn quantized_matrix_deterministic() {
        check_default("gptq deterministic", |rng| {
            let w = random_w(24, 12, rng.next_u64());
            let h = random_h(12, rng.next_u64());
            let plan = MatrixPlan::uniform(12, 2, CentroidRule::KMeans, true);
            let a = quantize_matrix(&w, Some(&h), &plan);
            let b = quantize_matrix(&w, Some(&h), &plan);
            assert_eq!(a.dequantize().data, b.dequantize().data);
        });
    }

    /// Quick in-crate version of the tests/property_quant.rs pin: block
    /// size is invisible in the output, bit for bit, metrics included.
    #[test]
    fn block_size_bit_identical_smoke() {
        let cols = 20;
        let w = random_w(40, cols, 21);
        let h = random_h(cols, 22);
        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            let mut plan = MatrixPlan::uniform(cols, 2, rule, true);
            plan.reserve = vec![2; cols];
            plan.block_size = 0; // unblocked reference
            let reference = quantize_matrix(&w, Some(&h), &plan);
            for bs in [1usize, 3, 7, cols] {
                plan.block_size = bs;
                let q = quantize_matrix(&w, Some(&h), &plan);
                assert_eq!(bits_of(&reference.dequantize()), bits_of(&q.dequantize()), "B={bs}");
                assert_eq!(reference.outliers, q.outliers, "B={bs}");
                assert_eq!(
                    reference.metrics.rel_frobenius_err.to_bits(),
                    q.metrics.rel_frobenius_err.to_bits(),
                    "B={bs}"
                );
                assert_eq!(
                    reference.metrics.proxy_loss.to_bits(),
                    q.metrics.proxy_loss.to_bits(),
                    "B={bs}"
                );
            }
        }
    }

    /// A shape big enough to clear the sharding gates (rows / 8 shards,
    /// ≥ 64Ki MACs per trailing update), so the pool-dispatched kernel is
    /// actually exercised: every worker count must match serial, bit for
    /// bit, for both centroid rules and with reservations in play.
    #[test]
    fn parallel_trailing_update_bit_identical_to_serial() {
        let (rows, cols) = (600, 40);
        let w = random_w(rows, cols, 51);
        let h = random_h(cols, 52);
        for rule in [CentroidRule::UniformMinMax, CentroidRule::KMeans] {
            for reserve in [0usize, 4] {
                let mut plan = MatrixPlan::uniform(cols, 2, rule, true);
                plan.reserve = vec![reserve; cols];
                plan.block_size = 8;
                let serial = quantize_matrix_pooled(
                    &w,
                    Some(&h),
                    &plan,
                    &ThreadPool::new(1),
                    &mut QuantScratch::new(),
                );
                for workers in [2usize, 4, 7] {
                    let pool = ThreadPool::new(workers);
                    let par =
                        quantize_matrix_pooled(&w, Some(&h), &plan, &pool, &mut QuantScratch::new());
                    assert_eq!(
                        bits_of(&serial.dequantize()),
                        bits_of(&par.dequantize()),
                        "{rule:?} reserve={reserve} workers={workers}"
                    );
                    assert_eq!(serial.outliers, par.outliers);
                    assert_eq!(
                        serial.metrics.proxy_loss.to_bits(),
                        par.metrics.proxy_loss.to_bits()
                    );
                }
            }
        }
    }

    /// The VQ analogue of `block_size_bit_identical_smoke`: group-wise OBS
    /// with every block size (rounded up to a multiple of d internally)
    /// must match the unblocked path bit for bit — on a ragged shape where
    /// the final group is narrower than d.
    #[test]
    fn vq_block_size_bit_identical_smoke() {
        let cols = 22; // d=4 → 5 full groups + a width-2 tail group
        let w = random_w(40, cols, 61);
        let h = random_h(cols, 62);
        let mut plan = MatrixPlan::vector_group(cols, 4, 3, true);
        plan.reserve = vec![2; cols];
        plan.block_size = 0; // unblocked reference
        let reference = quantize_matrix(&w, Some(&h), &plan);
        for bs in [1usize, 3, 8, cols] {
            plan.block_size = bs;
            let q = quantize_matrix(&w, Some(&h), &plan);
            assert_eq!(bits_of(&reference.dequantize()), bits_of(&q.dequantize()), "B={bs}");
            assert_eq!(reference.outliers, q.outliers, "B={bs}");
            assert_eq!(
                reference.metrics.rel_frobenius_err.to_bits(),
                q.metrics.rel_frobenius_err.to_bits(),
                "B={bs}"
            );
            assert_eq!(
                reference.metrics.proxy_loss.to_bits(),
                q.metrics.proxy_loss.to_bits(),
                "B={bs}"
            );
        }
    }

    /// VQ trailing updates shard across the pool exactly like scalar ones:
    /// every worker count matches serial bit for bit.
    #[test]
    fn vq_parallel_trailing_update_bit_identical_to_serial() {
        let (rows, cols) = (600, 40);
        let w = random_w(rows, cols, 71);
        let h = random_h(cols, 72);
        let mut plan = MatrixPlan::vector_group(cols, 4, 2, true);
        plan.reserve = vec![2; cols];
        plan.block_size = 8;
        let serial =
            quantize_matrix_pooled(&w, Some(&h), &plan, &ThreadPool::new(1), &mut QuantScratch::new());
        for workers in [2usize, 4, 7] {
            let pool = ThreadPool::new(workers);
            let par = quantize_matrix_pooled(&w, Some(&h), &plan, &pool, &mut QuantScratch::new());
            assert_eq!(bits_of(&serial.dequantize()), bits_of(&par.dequantize()), "workers={workers}");
            assert_eq!(serial.outliers, par.outliers);
            assert_eq!(serial.metrics.proxy_loss.to_bits(), par.metrics.proxy_loss.to_bits());
        }
    }

    /// VQ reserved entries are exact, emitted in (col, row) order, and the
    /// index cost lands at bits/d per parameter.
    #[test]
    fn vq_reserved_exact_and_sub2bit_accounting() {
        let w = random_w(64, 16, 81);
        let mut plan = MatrixPlan::vector_group(16, 4, 2, false);
        plan.reserve = vec![3; 16];
        let q = quantize_matrix(&w, None, &plan);
        assert_eq!(q.outliers.len(), 3 * 16);
        let dq = q.dequantize();
        for o in &q.outliers {
            assert_eq!(dq.at(o.row as usize, o.col as usize), o.value);
            assert_eq!(o.value, w.at(o.row as usize, o.col as usize));
        }
        for p in q.outliers.windows(2) {
            assert!((p[0].col, p[0].row) < (p[1].col, p[1].row), "unsorted outliers");
        }
        // 2 index bits per 4-wide group → 0.5 bits/param.
        assert!((q.index_bits_per_param() - 0.5).abs() < 1e-12);
        assert_eq!(q.plane_kind(), PlaneKind::VectorGroup { d: 4 });
        assert_eq!(q.vq_planes().groups.len(), 4);
    }

    /// Dequantized VQ values are centroids of their group's codebook
    /// (outliers aside), including the ragged tail group.
    #[test]
    fn vq_dequantize_draws_from_codebooks() {
        let w = random_w(32, 10, 91); // d=4 → groups of width 4, 4, 2
        let plan = MatrixPlan::vector_group(10, 4, 3, false);
        let q = quantize_matrix(&w, None, &plan);
        let vp = q.vq_planes();
        assert_eq!(vp.groups.len(), 3);
        assert_eq!(vp.groups[2].codebook.dim, 2);
        let dq = q.dequantize();
        for r in 0..32 {
            for (g, grp) in vp.groups.iter().enumerate() {
                let (j0, j1) = vp.group_span(g, q.cols);
                let c = grp.codebook.centroid(grp.indices[r] as usize);
                for (jj, j) in (j0..j1).enumerate() {
                    assert_eq!(dq.at(r, j), c[jj]);
                }
            }
        }
    }

    /// One scratch reused across matrices of different shapes must not
    /// leak state between calls.
    #[test]
    fn scratch_reuse_is_clean() {
        let mut scratch = QuantScratch::new();
        let pool = ThreadPool::new(2);
        for (rows, cols, seed) in [(48usize, 20usize, 31u64), (16, 9, 32), (64, 28, 33)] {
            let w = random_w(rows, cols, seed);
            let h = random_h(cols, seed ^ 0xFF);
            let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, true);
            plan.reserve = vec![2; cols];
            plan.block_size = 6;
            let reused = quantize_matrix_pooled(&w, Some(&h), &plan, &pool, &mut scratch);
            let fresh = quantize_matrix_pooled(&w, Some(&h), &plan, &pool, &mut QuantScratch::new());
            assert_eq!(bits_of(&reused.dequantize()), bits_of(&fresh.dequantize()));
            assert_eq!(reused.outliers, fresh.outliers);
        }
    }
}
