//! Quantization codebooks: a sorted list of centroids plus nearest-centroid
//! encode / table decode (paper Eq. 2). Shared by the K-Means (CLAQ) and
//! uniform (RTN/GPTQ-baseline) quantizers.

/// A per-column quantization codebook. Centroids are stored ascending so
/// nearest-centroid assignment is a binary search over midpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub centroids: Vec<f32>,
}

impl Codebook {
    /// Build from centroids; sorts them ascending.
    pub fn new(mut centroids: Vec<f32>) -> Self {
        assert!(!centroids.is_empty());
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { centroids }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.centroids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.centroids.is_empty()
    }

    /// Bits needed to index this codebook.
    pub fn bits(&self) -> u32 {
        (usize::BITS - (self.len() - 1).leading_zeros()).max(1)
    }

    /// Nearest-centroid index (argmin |c_q − x|, Eq. 2). Ties break toward
    /// the lower index.
    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        let c = &self.centroids;
        // binary search for insertion point
        let mut lo = 0usize;
        let mut hi = c.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if c[mid] < x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        // candidates: lo-1 and lo
        if lo == 0 {
            return 0;
        }
        if lo >= c.len() {
            return (c.len() - 1) as u8;
        }
        let d_lo = (x - c[lo - 1]).abs();
        let d_hi = (c[lo] - x).abs();
        if d_lo <= d_hi {
            (lo - 1) as u8
        } else {
            lo as u8
        }
    }

    #[inline]
    pub fn dequantize(&self, idx: u8) -> f32 {
        self.centroids[idx as usize]
    }

    /// Encode a whole column.
    pub fn quantize_slice(&self, xs: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.quantize(x)));
    }

    /// Decode a whole column.
    pub fn dequantize_slice(&self, idx: &[u8], out: &mut Vec<f32>) {
        out.clear();
        out.extend(idx.iter().map(|&i| self.dequantize(i)));
    }
}

/// Uniform min–max codebook over `values` with `k` levels — the RTN /
/// GPTQ-baseline centroid rule (equally spaced levels, the paper's "prior
/// techniques adopt uniform quantization levels").
pub fn uniform_codebook(values: &[f32], k: usize) -> Codebook {
    assert!(k >= 1);
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || lo == hi {
        let c = if lo.is_finite() { lo } else { 0.0 };
        return Codebook::new(vec![c; k]);
    }
    let step = (hi - lo) / (k - 1).max(1) as f32;
    Codebook::new((0..k).map(|i| lo + step * i as f32).collect())
}

/// Symmetric uniform codebook (zero-centered, like absmax int quant).
/// Used by the AWQ baseline after scaling.
pub fn symmetric_codebook(values: &[f32], k: usize) -> Codebook {
    assert!(k >= 2);
    let absmax = values.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    if absmax == 0.0 {
        return Codebook::new(vec![0.0; k]);
    }
    let half = (k / 2) as f32;
    let step = absmax / half;
    // levels: -half..half-1 scaled (k levels), includes 0
    Codebook::new((0..k).map(|i| (i as f32 - half) * step).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_picks_nearest() {
        let cb = Codebook::new(vec![0.0, 1.0, 10.0]);
        assert_eq!(cb.quantize(-5.0), 0);
        assert_eq!(cb.quantize(0.4), 0);
        assert_eq!(cb.quantize(0.6), 1);
        assert_eq!(cb.quantize(5.6), 2);
        assert_eq!(cb.quantize(100.0), 2);
    }

    #[test]
    fn tie_breaks_low() {
        let cb = Codebook::new(vec![0.0, 2.0]);
        assert_eq!(cb.quantize(1.0), 0);
    }

    #[test]
    fn new_sorts() {
        let cb = Codebook::new(vec![3.0, -1.0, 2.0]);
        assert_eq!(cb.centroids, vec![-1.0, 2.0, 3.0]);
    }

    #[test]
    fn bits_accounting() {
        assert_eq!(Codebook::new(vec![0.0; 2]).bits(), 1);
        assert_eq!(Codebook::new(vec![0.0; 4]).bits(), 2);
        assert_eq!(Codebook::new(vec![0.0; 8]).bits(), 3);
        assert_eq!(Codebook::new(vec![0.0; 16]).bits(), 4);
    }

    #[test]
    fn uniform_covers_range() {
        let vals = [-2.0f32, 0.0, 6.0];
        let cb = uniform_codebook(&vals, 4);
        assert_eq!(cb.centroids[0], -2.0);
        assert_eq!(*cb.centroids.last().unwrap(), 6.0);
    }

    #[test]
    fn uniform_constant_input() {
        let cb = uniform_codebook(&[3.0; 5], 4);
        assert!(cb.centroids.iter().all(|&c| c == 3.0));
    }

    #[test]
    fn symmetric_contains_zero() {
        let cb = symmetric_codebook(&[-1.0, 2.0], 4);
        assert!(cb.centroids.iter().any(|&c| c == 0.0));
    }

    #[test]
    fn slice_round_trip() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0, 2.0]);
        let xs = [-0.9f32, 0.1, 1.4, 5.0];
        let mut idx = Vec::new();
        cb.quantize_slice(&xs, &mut idx);
        let mut deq = Vec::new();
        cb.dequantize_slice(&idx, &mut deq);
        assert_eq!(deq, vec![-1.0, 0.0, 1.0, 2.0]);
    }
}
