//! Method / configuration surface: every quantization scheme evaluated in
//! the paper expressed as a [`Method`], plus the Appendix F fusion presets
//! (CLAQ* 2.12 / 2.24 / 3.12 / 3.23).

use crate::quant::gptq::{CentroidRule, MatrixPlan, DEFAULT_BLOCK};
use crate::quant::outliers::{ColumnMetric, OutlierStats};
use crate::quant::precision::{allocate_ap, BitPair, BitPlan};
use crate::quant::reservation::{allocate_fixed, allocate_or, OrSetting, ReservePlan};
use crate::tensor::Matrix;

/// Default outlier standard (Appendix B: S = 13 in all main experiments).
pub const DEFAULT_S: f64 = 13.0;

/// A quantization method with its hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// No quantization (the FP16 rows of every table).
    Fp16,
    /// Round-to-nearest uniform, no error compensation.
    Rtn { bits: u8 },
    /// GPTQ: uniform codebooks + OBS error compensation.
    Gptq { bits: u8 },
    /// Simplified AWQ: activation-aware scaling + uniform RTN.
    Awq { bits: u8 },
    /// CLAQ single precision: K-Means codebooks + error compensation (§3.1).
    Claq { bits: u8 },
    /// CLAQ + column-level Adaptive Precision (§3.3).
    ClaqAp {
        pair: BitPair,
        target_bits: f64,
        metric: ColumnMetric,
        s: f64,
    },
    /// CLAQ + column-level adaptive Outlier Reservation (§3.4).
    ClaqOr {
        bits: u8,
        budget_bits: f64,
        setting: OrSetting,
        s: f64,
    },
    /// CLAQ + *fixed* (uniform-per-column) outlier reservation — the
    /// "Outlier fix" baseline of Table 4.
    ClaqOrFixed { bits: u8, budget_bits: f64 },
    /// Fusion CLAQ*: AP + OR together (the paper's best low-bit results).
    ClaqFusion {
        pair: BitPair,
        ap_target_bits: f64,
        or_budget_bits: f64,
        setting: OrSetting,
        s: f64,
    },
    /// Vector-quantized column groups (VPTQ direction): one codebook of
    /// `2^bits` centroids in R^d per group of `d` adjacent columns, with
    /// group-wise OBS error compensation. Index cost is `bits/d` per
    /// parameter — the sub-2-bit operating point.
    ClaqVq { d: usize, bits: u8 },
}

impl Method {
    /// Appendix F preset: CLAQ* 2.12 — 2&4 AP with +0.05 bits, +0.07 bits
    /// of FP16 outliers (Setting 2), S = 13.
    pub fn fusion_2_12() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 2),
            ap_target_bits: 2.05,
            or_budget_bits: 0.07,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 2.24 — +0.1 AP bits, +0.13 outlier bits.
    pub fn fusion_2_24() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 2),
            ap_target_bits: 2.1,
            or_budget_bits: 0.13,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 3.12 (base 3, 3&4 AP).
    pub fn fusion_3_12() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 3),
            ap_target_bits: 3.05,
            or_budget_bits: 0.07,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 3.23.
    pub fn fusion_3_23() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 3),
            ap_target_bits: 3.1,
            or_budget_bits: 0.13,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Short display name used in table rows.
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn { bits } => format!("RTN-{bits}"),
            Method::Gptq { bits } => format!("GPTQ-{bits}"),
            Method::Awq { bits } => format!("AWQ-{bits}"),
            Method::Claq { bits } => format!("CLAQ-{bits}"),
            Method::ClaqAp { target_bits, metric, .. } => {
                let m = match metric {
                    ColumnMetric::OutlierRatio => "AP",
                    ColumnMetric::Magnitude => "MP(mag)",
                    ColumnMetric::Salience => "MP(sal)",
                };
                format!("CLAQ+{m}-{target_bits:.2}")
            }
            Method::ClaqOr { bits, budget_bits, .. } => {
                format!("CLAQ+OR-{:.2}", *bits as f64 + budget_bits)
            }
            Method::ClaqOrFixed { bits, budget_bits } => {
                format!("CLAQ+OutlierFix-{:.2}", *bits as f64 + budget_bits)
            }
            Method::ClaqFusion { ap_target_bits, or_budget_bits, .. } => {
                format!("CLAQ*-{:.2}", ap_target_bits + or_budget_bits)
            }
            Method::ClaqVq { d, bits } => format!("CLAQ-VQ-d{d}-{bits}b"),
        }
    }

    /// Nominal equivalent bits per parameter under paper accounting (16 for
    /// FP16 rows).
    pub fn nominal_bits(&self) -> f64 {
        match self {
            Method::Fp16 => 16.0,
            Method::Rtn { bits } | Method::Gptq { bits } | Method::Awq { bits } | Method::Claq { bits } => {
                *bits as f64
            }
            Method::ClaqAp { target_bits, .. } => *target_bits,
            Method::ClaqOr { bits, budget_bits, .. } | Method::ClaqOrFixed { bits, budget_bits } => {
                *bits as f64 + budget_bits
            }
            Method::ClaqFusion { ap_target_bits, or_budget_bits, .. } => {
                ap_target_bits + or_budget_bits
            }
            Method::ClaqVq { d, bits } => *bits as f64 / *d as f64,
        }
    }

    /// Does this method need the calibration Hessian?
    pub fn needs_hessian(&self) -> bool {
        !matches!(self, Method::Fp16 | Method::Rtn { .. })
    }

    /// Build the per-matrix quantization plan. `hess_diag` feeds the
    /// salience comparator metric when present.
    pub fn plan_for(&self, w: &Matrix, hess_diag: Option<&[f64]>) -> Option<MatrixPlan> {
        let cols = w.cols;
        match self {
            Method::Fp16 => None,
            Method::Awq { .. } => None, // AWQ has its own path (quant/awq.rs)
            Method::Rtn { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::UniformMinMax, false))
            }
            Method::Gptq { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::UniformMinMax, true))
            }
            Method::Claq { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::KMeans, true))
            }
            Method::ClaqAp { pair, target_bits, metric, s } => {
                let scores = crate::quant::outliers::column_scores(w, *metric, *s, hess_diag);
                let bitplan = allocate_ap(&scores, *pair, *target_bits);
                Some(MatrixPlan {
                    bits: bitplan.bits,
                    reserve: Vec::new(),
                    rule: CentroidRule::KMeans,
                    propagate: true,
                    damp_pct: 0.01,
                    block_size: DEFAULT_BLOCK,
                    plane: crate::quant::vq::PlaneKind::Scalar,
                })
            }
            Method::ClaqOr { bits, budget_bits, setting, s } => {
                let stats = OutlierStats::compute(w, *s);
                let rp = allocate_or(&stats, w.rows, *budget_bits, *setting);
                Some(plan_with_reserve(BitPlan::uniform(cols, *bits), rp))
            }
            Method::ClaqOrFixed { bits, budget_bits } => {
                let rp = allocate_fixed(w.rows, cols, *budget_bits);
                Some(plan_with_reserve(BitPlan::uniform(cols, *bits), rp))
            }
            Method::ClaqFusion { pair, ap_target_bits, or_budget_bits, setting, s } => {
                let stats = OutlierStats::compute(w, *s);
                let bitplan = allocate_ap(&stats.ratios, *pair, *ap_target_bits);
                let rp = allocate_or(&stats, w.rows, *or_budget_bits, *setting);
                Some(plan_with_reserve(bitplan, rp))
            }
            Method::ClaqVq { d, bits } => Some(MatrixPlan::vector_group(cols, *d, *bits, true)),
        }
    }
}

fn plan_with_reserve(bits: BitPlan, reserve: ReservePlan) -> MatrixPlan {
    MatrixPlan {
        bits: bits.bits,
        reserve: reserve.counts,
        rule: CentroidRule::KMeans,
        propagate: true,
        damp_pct: 0.01,
        block_size: DEFAULT_BLOCK,
        plane: crate::quant::vq::PlaneKind::Scalar,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_w() -> Matrix {
        let mut rng = Rng::new(42);
        let mut w = Matrix::zeros(64, 40);
        rng.fill_normal(&mut w.data, 0.02);
        for r in 0..10 {
            *w.at_mut(r, 3) = 0.8; // outlier column
        }
        w
    }

    #[test]
    fn preset_budgets() {
        assert!((Method::fusion_2_12().nominal_bits() - 2.12).abs() < 1e-9);
        assert!((Method::fusion_2_24().nominal_bits() - 2.23).abs() < 0.011);
        assert!((Method::fusion_3_12().nominal_bits() - 3.12).abs() < 1e-9);
        assert!((Method::fusion_3_23().nominal_bits() - 3.23).abs() < 0.011);
    }

    #[test]
    fn plans_produced_for_each_method() {
        let w = sample_w();
        for m in [
            Method::Rtn { bits: 4 },
            Method::Gptq { bits: 3 },
            Method::Claq { bits: 2 },
            Method::fusion_2_12(),
            Method::ClaqOr { bits: 2, budget_bits: 0.14, setting: OrSetting::SETTING2, s: 3.0 },
            Method::ClaqOrFixed { bits: 2, budget_bits: 0.14 },
        ] {
            let plan = m.plan_for(&w, None).expect("plan");
            assert_eq!(plan.bits.len(), w.cols);
        }
        assert!(Method::Fp16.plan_for(&w, None).is_none());
    }

    #[test]
    fn fusion_plan_promotes_outlier_column() {
        let w = sample_w();
        let plan = Method::fusion_2_12().plan_for(&w, None).unwrap();
        // with a single strongly-spiked column and +0.05 AP bits over 40
        // cols, exactly 1 column is promoted to 4 bits: column 3
        assert_eq!(plan.bits[3], 4);
        assert_eq!(plan.bits.iter().filter(|&&b| b == 4).count(), 1);
        // and OR grants it the largest reservation
        let max = plan.reserve.iter().max().unwrap();
        assert_eq!(plan.reserve[3], *max);
    }

    #[test]
    fn names_stable() {
        assert_eq!(Method::Rtn { bits: 4 }.name(), "RTN-4");
        assert_eq!(Method::fusion_2_12().name(), "CLAQ*-2.12");
        assert_eq!(Method::ClaqVq { d: 4, bits: 2 }.name(), "CLAQ-VQ-d4-2b");
    }

    #[test]
    fn vq_method_plan_and_bits() {
        let w = sample_w();
        let m = Method::ClaqVq { d: 4, bits: 2 };
        assert!((m.nominal_bits() - 0.5).abs() < 1e-12);
        assert!(m.needs_hessian());
        let plan = m.plan_for(&w, None).expect("plan");
        assert_eq!(plan.plane, crate::quant::vq::PlaneKind::VectorGroup { d: 4 });
        assert!(plan.propagate);
        assert_eq!(plan.bits, vec![2u8; w.cols]);
    }

    #[test]
    fn hessian_requirement() {
        assert!(!Method::Fp16.needs_hessian());
        assert!(!Method::Rtn { bits: 4 }.needs_hessian());
        assert!(Method::Claq { bits: 2 }.needs_hessian());
    }
}
