//! Method / configuration surface: every quantization scheme evaluated in
//! the paper expressed as a [`Method`], plus the Appendix F fusion presets
//! (CLAQ* 2.12 / 2.24 / 3.12 / 3.23).

use crate::quant::gptq::{CentroidRule, MatrixPlan, DEFAULT_BLOCK};
use crate::quant::outliers::{ColumnMetric, OutlierStats};
use crate::quant::precision::{allocate_ap, BitPair, BitPlan};
use crate::quant::reservation::{allocate_fixed, allocate_or, OrSetting, ReservePlan};
use crate::tensor::Matrix;

/// Default outlier standard (Appendix B: S = 13 in all main experiments).
pub const DEFAULT_S: f64 = 13.0;

/// A quantization method with its hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// No quantization (the FP16 rows of every table).
    Fp16,
    /// Round-to-nearest uniform, no error compensation.
    Rtn { bits: u8 },
    /// GPTQ: uniform codebooks + OBS error compensation.
    Gptq { bits: u8 },
    /// Simplified AWQ: activation-aware scaling + uniform RTN.
    Awq { bits: u8 },
    /// CLAQ single precision: K-Means codebooks + error compensation (§3.1).
    Claq { bits: u8 },
    /// CLAQ + column-level Adaptive Precision (§3.3).
    ClaqAp {
        pair: BitPair,
        target_bits: f64,
        metric: ColumnMetric,
        s: f64,
    },
    /// CLAQ + column-level adaptive Outlier Reservation (§3.4).
    ClaqOr {
        bits: u8,
        budget_bits: f64,
        setting: OrSetting,
        s: f64,
    },
    /// CLAQ + *fixed* (uniform-per-column) outlier reservation — the
    /// "Outlier fix" baseline of Table 4.
    ClaqOrFixed { bits: u8, budget_bits: f64 },
    /// Fusion CLAQ*: AP + OR together (the paper's best low-bit results).
    ClaqFusion {
        pair: BitPair,
        ap_target_bits: f64,
        or_budget_bits: f64,
        setting: OrSetting,
        s: f64,
    },
    /// Vector-quantized column groups (VPTQ direction): one codebook of
    /// `2^bits` centroids in R^d per group of `d` adjacent columns, with
    /// group-wise OBS error compensation. Index cost is `bits/d` per
    /// parameter — the sub-2-bit operating point.
    ClaqVq { d: usize, bits: u8 },
}

impl Method {
    /// Appendix F preset: CLAQ* 2.12 — 2&4 AP with +0.05 bits, +0.07 bits
    /// of FP16 outliers (Setting 2), S = 13.
    pub fn fusion_2_12() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 2),
            ap_target_bits: 2.05,
            or_budget_bits: 0.07,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 2.24 — +0.1 AP bits, +0.13 outlier bits.
    pub fn fusion_2_24() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 2),
            ap_target_bits: 2.1,
            or_budget_bits: 0.13,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 3.12 (base 3, 3&4 AP).
    pub fn fusion_3_12() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 3),
            ap_target_bits: 3.05,
            or_budget_bits: 0.07,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Appendix F preset: CLAQ* 3.23.
    pub fn fusion_3_23() -> Method {
        Method::ClaqFusion {
            pair: BitPair::new(4, 3),
            ap_target_bits: 3.1,
            or_budget_bits: 0.13,
            setting: OrSetting::SETTING2,
            s: DEFAULT_S,
        }
    }

    /// Short display name used in table rows.
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn { bits } => format!("RTN-{bits}"),
            Method::Gptq { bits } => format!("GPTQ-{bits}"),
            Method::Awq { bits } => format!("AWQ-{bits}"),
            Method::Claq { bits } => format!("CLAQ-{bits}"),
            Method::ClaqAp { target_bits, metric, .. } => {
                let m = match metric {
                    ColumnMetric::OutlierRatio => "AP",
                    ColumnMetric::Magnitude => "MP(mag)",
                    ColumnMetric::Salience => "MP(sal)",
                };
                format!("CLAQ+{m}-{target_bits:.2}")
            }
            Method::ClaqOr { bits, budget_bits, .. } => {
                format!("CLAQ+OR-{:.2}", *bits as f64 + budget_bits)
            }
            Method::ClaqOrFixed { bits, budget_bits } => {
                format!("CLAQ+OutlierFix-{:.2}", *bits as f64 + budget_bits)
            }
            Method::ClaqFusion { ap_target_bits, or_budget_bits, .. } => {
                format!("CLAQ*-{:.2}", ap_target_bits + or_budget_bits)
            }
            Method::ClaqVq { d, bits } => format!("CLAQ-VQ-d{d}-{bits}b"),
        }
    }

    /// Nominal equivalent bits per parameter under paper accounting (16 for
    /// FP16 rows).
    pub fn nominal_bits(&self) -> f64 {
        match self {
            Method::Fp16 => 16.0,
            Method::Rtn { bits } | Method::Gptq { bits } | Method::Awq { bits } | Method::Claq { bits } => {
                *bits as f64
            }
            Method::ClaqAp { target_bits, .. } => *target_bits,
            Method::ClaqOr { bits, budget_bits, .. } | Method::ClaqOrFixed { bits, budget_bits } => {
                *bits as f64 + budget_bits
            }
            Method::ClaqFusion { ap_target_bits, or_budget_bits, .. } => {
                ap_target_bits + or_budget_bits
            }
            Method::ClaqVq { d, bits } => *bits as f64 / *d as f64,
        }
    }

    /// Does this method need the calibration Hessian?
    pub fn needs_hessian(&self) -> bool {
        !matches!(self, Method::Fp16 | Method::Rtn { .. })
    }

    /// Build the per-matrix quantization plan. `hess_diag` feeds the
    /// salience comparator metric when present.
    pub fn plan_for(&self, w: &Matrix, hess_diag: Option<&[f64]>) -> Option<MatrixPlan> {
        let cols = w.cols;
        match self {
            Method::Fp16 => None,
            Method::Awq { .. } => None, // AWQ has its own path (quant/awq.rs)
            Method::Rtn { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::UniformMinMax, false))
            }
            Method::Gptq { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::UniformMinMax, true))
            }
            Method::Claq { bits } => {
                Some(MatrixPlan::uniform(cols, *bits, CentroidRule::KMeans, true))
            }
            Method::ClaqAp { pair, target_bits, metric, s } => {
                let scores = crate::quant::outliers::column_scores(w, *metric, *s, hess_diag);
                let bitplan = allocate_ap(&scores, *pair, *target_bits);
                Some(MatrixPlan {
                    bits: bitplan.bits,
                    reserve: Vec::new(),
                    rule: CentroidRule::KMeans,
                    propagate: true,
                    damp_pct: 0.01,
                    block_size: DEFAULT_BLOCK,
                    plane: crate::quant::vq::PlaneKind::Scalar,
                })
            }
            Method::ClaqOr { bits, budget_bits, setting, s } => {
                let stats = OutlierStats::compute(w, *s);
                let rp = allocate_or(&stats, w.rows, *budget_bits, *setting);
                Some(plan_with_reserve(BitPlan::uniform(cols, *bits), rp))
            }
            Method::ClaqOrFixed { bits, budget_bits } => {
                let rp = allocate_fixed(w.rows, cols, *budget_bits);
                Some(plan_with_reserve(BitPlan::uniform(cols, *bits), rp))
            }
            Method::ClaqFusion { pair, ap_target_bits, or_budget_bits, setting, s } => {
                let stats = OutlierStats::compute(w, *s);
                let bitplan = allocate_ap(&stats.ratios, *pair, *ap_target_bits);
                let rp = allocate_or(&stats, w.rows, *or_budget_bits, *setting);
                Some(plan_with_reserve(bitplan, rp))
            }
            Method::ClaqVq { d, bits } => Some(MatrixPlan::vector_group(cols, *d, *bits, true)),
        }
    }
}

// ------------------------------------------------------------ MethodSpec ----

/// A [`Method`] parsed from (and printable as) the typed spec grammar —
/// the single method-selection surface shared by the CLI (`--method`),
/// the benches, and the tests:
///
/// ```text
/// fp16                      no quantization
/// rtn:B | gptq:B | awq:B    uniform baselines, integer B in 1..=8
/// claq:B                    CLAQ single precision (K-Means + OBS)
/// claq-ap:LO+HI@T           adaptive precision: pair (LO, HI), target T
/// claq-or:B+E               outlier reservation: base B, budget E bits
/// claq-or-fixed:B+E         uniform-per-column reservation baseline
/// claq-vq:dDbB              vector groups of D columns, B index bits
/// fusion-2.12               Appendix F presets (2.12 / 2.24 / 3.12 / 3.23)
/// fusion:LO+HI@A+O          generic fusion: AP target A, OR budget O
/// ```
///
/// `claq-fusion-…` is accepted as an alias for `fusion-…`. Parsing is
/// case-insensitive; [`std::fmt::Display`] prints the canonical lowercase
/// spelling, and `parse(display(spec))` returns an equal [`Method`] for
/// every spec the parser can produce (pinned by `tests/mixed_bits.rs`).
/// The legacy `--bits`/`--hi`/`--lo`/`--group-dim` flag spelling survives
/// one more release as a documented alias in `tables/cli_entry.rs`.
#[derive(Clone, Debug, PartialEq)]
pub struct MethodSpec(pub Method);

impl MethodSpec {
    /// One-line grammar reminder, embedded in every parse error.
    pub const GRAMMAR: &'static str = "fp16 | rtn:B | gptq:B | awq:B | claq:B | \
         claq-ap:LO+HI@T | claq-or:B+E | claq-or-fixed:B+E | claq-vq:dDbB | \
         fusion-2.12|2.24|3.12|3.23 | fusion:LO+HI@A+O";

    pub fn method(&self) -> &Method {
        &self.0
    }

    pub fn into_method(self) -> Method {
        self.0
    }
}

fn spec_bits(s: &str, what: &str) -> Result<u8, String> {
    let b: u8 = s
        .parse()
        .map_err(|_| format!("{what}: '{s}' is not an integer bit width (want 1..=8)"))?;
    if !(1..=8).contains(&b) {
        return Err(format!("{what}: bit width {b} out of range (the container packs 1..=8-bit index planes)"));
    }
    Ok(b)
}

fn spec_f64(s: &str, what: &str) -> Result<f64, String> {
    s.parse::<f64>().map_err(|_| format!("{what}: '{s}' is not a number"))
}

/// Parse `LO+HI` into a validated [`BitPair`].
fn spec_pair(s: &str, what: &str) -> Result<BitPair, String> {
    let (lo_s, hi_s) = s
        .split_once('+')
        .ok_or_else(|| format!("{what}: expected LO+HI (e.g. 2+4), got '{s}'"))?;
    let lo = spec_bits(lo_s, what)?;
    let hi = spec_bits(hi_s, what)?;
    if lo >= hi {
        return Err(format!("{what}: require LO < HI, got {lo}+{hi}"));
    }
    Ok(BitPair::new(hi, lo))
}

fn spec_fusion_preset(tag: &str) -> Result<Method, String> {
    match tag {
        "2.12" => Ok(Method::fusion_2_12()),
        "2.24" => Ok(Method::fusion_2_24()),
        "3.12" => Ok(Method::fusion_3_12()),
        "3.23" => Ok(Method::fusion_3_23()),
        other => Err(format!(
            "unknown fusion preset '{other}' (Appendix F defines 2.12, 2.24, 3.12, 3.23; \
             arbitrary budgets spell fusion:LO+HI@A+O)"
        )),
    }
}

impl std::str::FromStr for MethodSpec {
    type Err = String;

    fn from_str(raw: &str) -> Result<Self, Self::Err> {
        let s = raw.trim().to_ascii_lowercase();
        let fail = |msg: String| format!("bad method spec '{raw}': {msg} [grammar: {}]", MethodSpec::GRAMMAR);
        if s == "fp16" {
            return Ok(MethodSpec(Method::Fp16));
        }
        // Preset sugar (and its historical alias) uses '-', not ':'.
        if let Some(tag) = s.strip_prefix("fusion-").or_else(|| s.strip_prefix("claq-fusion-")) {
            return spec_fusion_preset(tag).map(MethodSpec).map_err(fail);
        }
        let (head, rest) = s
            .split_once(':')
            .ok_or_else(|| fail(format!("no ':' found and '{s}' is not fp16 or a fusion-X.YZ preset")))?;
        let m = match head {
            "rtn" => Method::Rtn { bits: spec_bits(rest, "rtn").map_err(fail)? },
            "gptq" => Method::Gptq { bits: spec_bits(rest, "gptq").map_err(fail)? },
            "awq" => Method::Awq { bits: spec_bits(rest, "awq").map_err(fail)? },
            "claq" => Method::Claq { bits: spec_bits(rest, "claq").map_err(fail)? },
            "claq-ap" => {
                let (pair_s, t_s) = rest
                    .split_once('@')
                    .ok_or_else(|| fail("claq-ap: expected LO+HI@TARGET (e.g. 2+4@2.05)".into()))?;
                let pair = spec_pair(pair_s, "claq-ap").map_err(fail)?;
                let target = spec_f64(t_s, "claq-ap target").map_err(fail)?;
                if !(pair.lo as f64 <= target && target <= pair.hi as f64) {
                    return Err(fail(format!(
                        "claq-ap: target {target} outside [{}, {}] — no column mix of the pair can hit it",
                        pair.lo, pair.hi
                    )));
                }
                Method::ClaqAp { pair, target_bits: target, metric: ColumnMetric::OutlierRatio, s: DEFAULT_S }
            }
            "claq-or" | "claq-or-fixed" => {
                let (b_s, e_s) = rest
                    .split_once('+')
                    .ok_or_else(|| fail(format!("{head}: expected B+E (e.g. 2+0.14)")))?;
                let bits = spec_bits(b_s, head).map_err(fail)?;
                let budget = spec_f64(e_s, "reservation budget").map_err(fail)?;
                if !(0.0..=16.0).contains(&budget) {
                    return Err(fail(format!("{head}: budget {budget} bits/param out of range [0, 16]")));
                }
                if head == "claq-or" {
                    Method::ClaqOr { bits, budget_bits: budget, setting: OrSetting::SETTING2, s: DEFAULT_S }
                } else {
                    Method::ClaqOrFixed { bits, budget_bits: budget }
                }
            }
            "claq-vq" => {
                let body = rest
                    .strip_prefix('d')
                    .ok_or_else(|| fail("claq-vq: expected dDbB (e.g. d4b2)".into()))?;
                let (d_s, b_s) = body
                    .split_once('b')
                    .ok_or_else(|| fail("claq-vq: expected dDbB (e.g. d4b2)".into()))?;
                let d: usize = d_s
                    .parse()
                    .map_err(|_| fail(format!("claq-vq: '{d_s}' is not a group dim")))?;
                if !(1..=255).contains(&d) {
                    return Err(fail(format!(
                        "claq-vq: group dim {d} out of range [1, 255] — the CLAQVQ01 header stores it as u8"
                    )));
                }
                Method::ClaqVq { d, bits: spec_bits(b_s, "claq-vq").map_err(fail)? }
            }
            "fusion" => {
                let (pair_s, budgets) = rest
                    .split_once('@')
                    .ok_or_else(|| fail("fusion: expected LO+HI@A+O (e.g. 2+4@2.05+0.07)".into()))?;
                let pair = spec_pair(pair_s, "fusion").map_err(fail)?;
                let (a_s, o_s) = budgets
                    .split_once('+')
                    .ok_or_else(|| fail("fusion: expected AP+OR budgets after '@' (e.g. 2.05+0.07)".into()))?;
                let ap = spec_f64(a_s, "fusion AP target").map_err(fail)?;
                let or = spec_f64(o_s, "fusion OR budget").map_err(fail)?;
                if !(pair.lo as f64 <= ap && ap <= pair.hi as f64) {
                    return Err(fail(format!(
                        "fusion: AP target {ap} outside [{}, {}]",
                        pair.lo, pair.hi
                    )));
                }
                if !(0.0..=16.0).contains(&or) {
                    return Err(fail(format!("fusion: OR budget {or} bits/param out of range [0, 16]")));
                }
                Method::ClaqFusion {
                    pair,
                    ap_target_bits: ap,
                    or_budget_bits: or,
                    setting: OrSetting::SETTING2,
                    s: DEFAULT_S,
                }
            }
            other => {
                return Err(fail(format!("unknown method family '{other}'")));
            }
        };
        Ok(MethodSpec(m))
    }
}

impl std::fmt::Display for MethodSpec {
    /// The canonical spelling — preset sugar for the four Appendix F
    /// fusion points, the generic grammar everywhere else.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (preset, tag) in [
            (Method::fusion_2_12(), "2.12"),
            (Method::fusion_2_24(), "2.24"),
            (Method::fusion_3_12(), "3.12"),
            (Method::fusion_3_23(), "3.23"),
        ] {
            if self.0 == preset {
                return write!(f, "fusion-{tag}");
            }
        }
        match &self.0 {
            Method::Fp16 => write!(f, "fp16"),
            Method::Rtn { bits } => write!(f, "rtn:{bits}"),
            Method::Gptq { bits } => write!(f, "gptq:{bits}"),
            Method::Awq { bits } => write!(f, "awq:{bits}"),
            Method::Claq { bits } => write!(f, "claq:{bits}"),
            Method::ClaqAp { pair, target_bits, .. } => {
                write!(f, "claq-ap:{}+{}@{}", pair.lo, pair.hi, target_bits)
            }
            Method::ClaqOr { bits, budget_bits, .. } => write!(f, "claq-or:{bits}+{budget_bits}"),
            Method::ClaqOrFixed { bits, budget_bits } => {
                write!(f, "claq-or-fixed:{bits}+{budget_bits}")
            }
            Method::ClaqFusion { pair, ap_target_bits, or_budget_bits, .. } => {
                write!(f, "fusion:{}+{}@{}+{}", pair.lo, pair.hi, ap_target_bits, or_budget_bits)
            }
            Method::ClaqVq { d, bits } => write!(f, "claq-vq:d{d}b{bits}"),
        }
    }
}

fn plan_with_reserve(bits: BitPlan, reserve: ReservePlan) -> MatrixPlan {
    MatrixPlan {
        bits: bits.bits,
        reserve: reserve.counts,
        rule: CentroidRule::KMeans,
        propagate: true,
        damp_pct: 0.01,
        block_size: DEFAULT_BLOCK,
        plane: crate::quant::vq::PlaneKind::Scalar,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_w() -> Matrix {
        let mut rng = Rng::new(42);
        let mut w = Matrix::zeros(64, 40);
        rng.fill_normal(&mut w.data, 0.02);
        for r in 0..10 {
            *w.at_mut(r, 3) = 0.8; // outlier column
        }
        w
    }

    #[test]
    fn preset_budgets() {
        assert!((Method::fusion_2_12().nominal_bits() - 2.12).abs() < 1e-9);
        assert!((Method::fusion_2_24().nominal_bits() - 2.23).abs() < 0.011);
        assert!((Method::fusion_3_12().nominal_bits() - 3.12).abs() < 1e-9);
        assert!((Method::fusion_3_23().nominal_bits() - 3.23).abs() < 0.011);
    }

    #[test]
    fn plans_produced_for_each_method() {
        let w = sample_w();
        for m in [
            Method::Rtn { bits: 4 },
            Method::Gptq { bits: 3 },
            Method::Claq { bits: 2 },
            Method::fusion_2_12(),
            Method::ClaqOr { bits: 2, budget_bits: 0.14, setting: OrSetting::SETTING2, s: 3.0 },
            Method::ClaqOrFixed { bits: 2, budget_bits: 0.14 },
        ] {
            let plan = m.plan_for(&w, None).expect("plan");
            assert_eq!(plan.bits.len(), w.cols);
        }
        assert!(Method::Fp16.plan_for(&w, None).is_none());
    }

    #[test]
    fn fusion_plan_promotes_outlier_column() {
        let w = sample_w();
        let plan = Method::fusion_2_12().plan_for(&w, None).unwrap();
        // with a single strongly-spiked column and +0.05 AP bits over 40
        // cols, exactly 1 column is promoted to 4 bits: column 3
        assert_eq!(plan.bits[3], 4);
        assert_eq!(plan.bits.iter().filter(|&&b| b == 4).count(), 1);
        // and OR grants it the largest reservation
        let max = plan.reserve.iter().max().unwrap();
        assert_eq!(plan.reserve[3], *max);
    }

    #[test]
    fn names_stable() {
        assert_eq!(Method::Rtn { bits: 4 }.name(), "RTN-4");
        assert_eq!(Method::fusion_2_12().name(), "CLAQ*-2.12");
        assert_eq!(Method::ClaqVq { d: 4, bits: 2 }.name(), "CLAQ-VQ-d4-2b");
    }

    #[test]
    fn vq_method_plan_and_bits() {
        let w = sample_w();
        let m = Method::ClaqVq { d: 4, bits: 2 };
        assert!((m.nominal_bits() - 0.5).abs() < 1e-12);
        assert!(m.needs_hessian());
        let plan = m.plan_for(&w, None).expect("plan");
        assert_eq!(plan.plane, crate::quant::vq::PlaneKind::VectorGroup { d: 4 });
        assert!(plan.propagate);
        assert_eq!(plan.bits, vec![2u8; w.cols]);
    }

    #[test]
    fn hessian_requirement() {
        assert!(!Method::Fp16.needs_hessian());
        assert!(!Method::Rtn { bits: 4 }.needs_hessian());
        assert!(Method::Claq { bits: 2 }.needs_hessian());
    }

    #[test]
    fn method_spec_parses_every_family() {
        let cases: [(&str, Method); 10] = [
            ("fp16", Method::Fp16),
            ("rtn:4", Method::Rtn { bits: 4 }),
            ("gptq:3", Method::Gptq { bits: 3 }),
            ("awq:4", Method::Awq { bits: 4 }),
            ("claq:2", Method::Claq { bits: 2 }),
            (
                "claq-ap:2+4@2.05",
                Method::ClaqAp {
                    pair: BitPair::new(4, 2),
                    target_bits: 2.05,
                    metric: ColumnMetric::OutlierRatio,
                    s: DEFAULT_S,
                },
            ),
            (
                "claq-or:2+0.14",
                Method::ClaqOr {
                    bits: 2,
                    budget_bits: 0.14,
                    setting: OrSetting::SETTING2,
                    s: DEFAULT_S,
                },
            ),
            ("claq-or-fixed:2+0.14", Method::ClaqOrFixed { bits: 2, budget_bits: 0.14 }),
            ("claq-vq:d4b2", Method::ClaqVq { d: 4, bits: 2 }),
            ("fusion-2.12", Method::fusion_2_12()),
        ];
        for (spec, want) in cases {
            let got: MethodSpec = spec.parse().unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(got.0, want, "{spec}");
        }
        // alias + case insensitivity + generic fusion
        assert_eq!("claq-fusion-3.12".parse::<MethodSpec>().unwrap().0, Method::fusion_3_12());
        assert_eq!("CLAQ:4".parse::<MethodSpec>().unwrap().0, Method::Claq { bits: 4 });
        assert_eq!("fusion:2+4@2.05+0.07".parse::<MethodSpec>().unwrap().0, Method::fusion_2_12());
    }

    #[test]
    fn method_spec_rejects_malformed_with_context() {
        for (spec, needle) in [
            ("claq:9", "out of range"),
            ("claq:two", "not an integer"),
            ("claq-ap:4+2@3", "LO < HI"),
            ("claq-ap:2+4@5", "outside"),
            ("claq-ap:2+4", "TARGET"),
            ("claq-vq:d4b12", "out of range"),
            ("claq-vq:d0b2", "group dim"),
            ("claq-vq:4x2", "dDbB"),
            ("fusion-2.5", "unknown fusion preset"),
            ("warp:3", "unknown method family"),
            ("claq", "no ':'"),
        ] {
            let err = spec.parse::<MethodSpec>().unwrap_err();
            assert!(err.contains(needle), "{spec}: error '{err}' missing '{needle}'");
            assert!(err.contains("grammar"), "{spec}: error '{err}' should cite the grammar");
        }
    }

    #[test]
    fn method_spec_display_round_trips() {
        for spec in [
            "fp16",
            "rtn:4",
            "gptq:3",
            "awq:4",
            "claq:2",
            "claq-ap:2+4@2.05",
            "claq-or:2+0.14",
            "claq-or-fixed:3+0.07",
            "claq-vq:d4b2",
            "fusion-2.12",
            "fusion-2.24",
            "fusion:2+4@2.2+0.1",
        ] {
            let parsed: MethodSpec = spec.parse().unwrap();
            let shown = parsed.to_string();
            let reparsed: MethodSpec = shown.parse().unwrap_or_else(|e| panic!("{shown}: {e}"));
            assert_eq!(parsed, reparsed, "{spec} -> {shown}");
        }
        // presets canonicalize to their sugar, aliases included
        assert_eq!("claq-fusion-2.12".parse::<MethodSpec>().unwrap().to_string(), "fusion-2.12");
        assert_eq!("fusion:2+4@2.05+0.07".parse::<MethodSpec>().unwrap().to_string(), "fusion-2.12");
    }
}
