//! Cold-KV-page codec: per-page k-means codebooks over cached K/V rows.
//!
//! The paged KV cache (`model/exec.rs`) re-encodes pages that have fallen
//! behind the decode head as one k-means [`Codebook`] per tensor (K and V
//! separately, the two have very different distributions) plus one `u8`
//! index per element — the same centroid machinery the weight quantizer
//! uses (`quant/kmeans.rs`, paper §3.1), pointed at activations instead
//! of weights. Encoding is deterministic (fixed k-means seed), so a given
//! f32 page always quantizes to the same bytes; decoding is a table
//! gather into caller scratch on attention read.
//!
//! Accounting is honest about the in-memory representation: indices are
//! stored one byte each regardless of `bits` (there is no bit-packing on
//! this path — pages are transient serving state, not a checkpoint), so
//! `bytes()` reports `len` bytes per tensor plus the f32 centroid tables.
//! The compression claim vs. an f32 page (8 bytes per element pair) is
//! therefore ~4× at the default 8 bits, not 8/bits×.

use super::codebook::Codebook;
use super::kmeans::{kmeans_1d, KMeansOpts};

/// Highest supported codebook width: indices are `u8`.
pub const MAX_KV_QUANT_BITS: u8 = 8;

/// One immutable quantized KV page: K and V of `len` elements each,
/// encoded against private per-page codebooks.
pub struct QuantKvPage {
    bits: u8,
    k_codebook: Codebook,
    v_codebook: Codebook,
    k_idx: Vec<u8>,
    v_idx: Vec<u8>,
}

impl QuantKvPage {
    /// Encode a full page (`k`/`v` must be the same length, the page's
    /// `n_layers × page_tokens × d` layout flattened). `bits` ∈ 1..=8;
    /// the codebook is clamped to the element count when a (tiny, test-
    /// sized) page has fewer elements than `1 << bits` levels, and to
    /// `len / 2` (floor 16) so the f32 centroid tables always amortize —
    /// an encoded page is guaranteed smaller than its f32 original
    /// whenever `len ≥ 32` (`len` is `n_layers × page_tokens × d`, a
    /// multiple of `d`, so this always holds in practice). Production
    /// pages are thousands of elements; only the `1 << bits` term binds
    /// there.
    pub fn encode(k: &[f32], v: &[f32], bits: u8) -> Self {
        assert!(
            (1..=MAX_KV_QUANT_BITS).contains(&bits),
            "kv page quantization supports 1..=8 bits, got {bits}"
        );
        assert_eq!(k.len(), v.len(), "K and V planes of a page match in size");
        assert!(!k.is_empty(), "cannot encode an empty page");
        let levels = (1usize << bits).min(k.len()).min((k.len() / 2).max(16));
        let opts = KMeansOpts::default(); // fixed seed: deterministic encode
        let k_codebook = kmeans_1d(k, levels, &opts).codebook;
        let v_codebook = kmeans_1d(v, levels, &opts).codebook;
        let mut k_idx = Vec::new();
        let mut v_idx = Vec::new();
        k_codebook.quantize_slice(k, &mut k_idx);
        v_codebook.quantize_slice(v, &mut v_idx);
        Self { bits, k_codebook, v_codebook, k_idx, v_idx }
    }

    /// Requested codebook width.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Elements per tensor (K and V each hold this many).
    pub fn len(&self) -> usize {
        self.k_idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.k_idx.is_empty()
    }

    /// Exact resident bytes of this page: one index byte per element per
    /// tensor plus both f32 centroid tables (see module docs).
    pub fn bytes(&self) -> usize {
        self.k_idx.len()
            + self.v_idx.len()
            + (self.k_codebook.len() + self.v_codebook.len()) * std::mem::size_of::<f32>()
    }

    /// Decode `out.len()` K elements starting at flat offset `start`.
    pub fn dequantize_k_into(&self, start: usize, out: &mut [f32]) {
        Self::gather(&self.k_codebook, &self.k_idx[start..start + out.len()], out);
    }

    /// Decode `out.len()` V elements starting at flat offset `start`.
    pub fn dequantize_v_into(&self, start: usize, out: &mut [f32]) {
        Self::gather(&self.v_codebook, &self.v_idx[start..start + out.len()], out);
    }

    #[inline]
    fn gather(cb: &Codebook, idx: &[u8], out: &mut [f32]) {
        for (o, &i) in out.iter_mut().zip(idx) {
            *o = cb.dequantize(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn page(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let k = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let v = (0..n).map(|_| rng.next_f32() * 0.5).collect();
        (k, v)
    }

    #[test]
    fn round_trip_error_is_bounded_at_8_bits() {
        let (k, v) = page(512, 1);
        let q = QuantKvPage::encode(&k, &v, 8);
        let mut dk = vec![0.0; k.len()];
        let mut dv = vec![0.0; v.len()];
        q.dequantize_k_into(0, &mut dk);
        q.dequantize_v_into(0, &mut dv);
        // 256 k-means levels over a unit-range page: tiny per-element error
        for (x, y) in k.iter().zip(&dk) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
        for (x, y) in v.iter().zip(&dv) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
    }

    #[test]
    fn encode_is_deterministic() {
        let (k, v) = page(256, 2);
        let a = QuantKvPage::encode(&k, &v, 4);
        let b = QuantKvPage::encode(&k, &v, 4);
        assert_eq!(a.k_idx, b.k_idx);
        assert_eq!(a.v_idx, b.v_idx);
        assert_eq!(a.k_codebook.centroids, b.k_codebook.centroids);
    }

    #[test]
    fn ranged_decode_matches_full_decode() {
        let (k, v) = page(128, 3);
        let q = QuantKvPage::encode(&k, &v, 6);
        let mut full = vec![0.0; k.len()];
        q.dequantize_k_into(0, &mut full);
        let mut part = vec![0.0; 32];
        q.dequantize_k_into(40, &mut part);
        assert_eq!(&full[40..72], &part[..]);
    }

    #[test]
    fn bytes_accounting_is_exact() {
        let (k, v) = page(64, 4);
        let q = QuantKvPage::encode(&k, &v, 8);
        assert_eq!(
            q.bytes(),
            q.k_idx.len()
                + q.v_idx.len()
                + 4 * (q.k_codebook.len() + q.v_codebook.len())
        );
        assert!(q.bytes() < (k.len() + v.len()) * 4, "quant page smaller than f32 page");
    }

    #[test]
    fn tiny_page_clamps_codebook_to_element_count() {
        let k = [0.5f32, -0.5];
        let v = [1.0f32, 2.0];
        let q = QuantKvPage::encode(&k, &v, 8);
        assert!(q.k_codebook.len() <= 2);
        let mut out = vec![0.0; 2];
        q.dequantize_k_into(0, &mut out);
        // exactly representable: 2 levels for 2 distinct values
        assert_eq!(out, vec![0.5, -0.5]);
    }

    #[test]
    #[should_panic(expected = "1..=8 bits")]
    fn rejects_zero_bits() {
        let _ = QuantKvPage::encode(&[1.0], &[1.0], 0);
    }

    #[test]
    fn constant_page_survives_encoding() {
        let k = vec![0.0f32; 96];
        let v = vec![0.25f32; 96];
        let q = QuantKvPage::encode(&k, &v, 8);
        let mut out = vec![1.0; 96];
        q.dequantize_k_into(0, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
        q.dequantize_v_into(0, &mut out);
        assert!(out.iter().all(|&x| x == 0.25));
    }
}
