//! Simplified AWQ baseline (Lin et al., 2023) for the Table 1 comparison.
//!
//! AWQ protects salient weight channels by scaling them up before uniform
//! quantization: W' = W·diag(s), x' = x·diag(s)⁻¹ with s_j = a_j^α where
//! a_j is the mean activation magnitude of input channel j. α is grid-
//! searched to minimize the layer output reconstruction error on the
//! calibration set. This reproduces the method's *mechanism* (activation-
//! aware scaling + uniform quant); the full paper also folds scales into
//! preceding layers, which is out of scope here and documented in DESIGN.md.

use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan, QuantizedMatrix};
use crate::tensor::Matrix;

/// Result of an AWQ quantization: the quantized scaled weights plus the
/// per-column scales the runtime must fold into the activations.
#[derive(Clone, Debug)]
pub struct AwqResult {
    pub quantized: QuantizedMatrix,
    pub scales: Vec<f32>,
    pub alpha: f64,
    /// Output reconstruction error (proxy) of the chosen alpha.
    pub err: f64,
}

/// Per-channel activation magnitude from the calibration Hessian diagonal:
/// H = 2·E[x xᵀ] ⇒ E[x_j²] = H_jj/2 ⇒ a_j = sqrt(H_jj/2).
pub fn act_scales_from_hessian(h_diag: &[f64]) -> Vec<f32> {
    h_diag.iter().map(|&d| ((d / 2.0).max(0.0)).sqrt() as f32).collect()
}

/// Output-error proxy for a candidate dequantized weight matrix:
/// tr(ΔW · H · ΔWᵀ) where ΔW = W − Ŵ (expected squared output error).
fn output_err(w: &Matrix, wq: &Matrix, h: &[f64]) -> f64 {
    let cols = w.cols;
    let mut total = 0.0f64;
    let mut diff_row = vec![0.0f64; cols];
    for r in 0..w.rows {
        let a = w.row(r);
        let b = wq.row(r);
        for j in 0..cols {
            diff_row[j] = (a[j] - b[j]) as f64;
        }
        for i in 0..cols {
            let di = diff_row[i];
            if di == 0.0 {
                continue;
            }
            let hrow = &h[i * cols..(i + 1) * cols];
            for j in 0..cols {
                total += di * hrow[j] * diff_row[j];
            }
        }
    }
    total
}

/// Quantize with activation-aware scaling. `h` is the calibration Hessian
/// (cols×cols); `bits` the uniform index width.
pub fn quantize_awq(w: &Matrix, h: &[f64], bits: u8) -> AwqResult {
    let cols = w.cols;
    assert_eq!(h.len(), cols * cols);
    let act: Vec<f32> = act_scales_from_hessian(&(0..cols).map(|i| h[i * cols + i]).collect::<Vec<_>>());

    let mut best: Option<AwqResult> = None;
    for step in 0..=10 {
        let alpha = step as f64 / 10.0;
        let scales: Vec<f32> = act
            .iter()
            .map(|&a| {
                let s = (a.max(1e-8) as f64).powf(alpha) as f32;
                if s.is_finite() && s > 1e-8 {
                    s
                } else {
                    1.0
                }
            })
            .collect();
        // Scale columns up, quantize, scale back down.
        let mut ws = w.clone();
        for r in 0..w.rows {
            let row = ws.row_mut(r);
            for j in 0..cols {
                row[j] *= scales[j];
            }
        }
        let plan = MatrixPlan::uniform(cols, bits, CentroidRule::UniformMinMax, false);
        let q = quantize_matrix(&ws, None, &plan);
        let mut deq = q.dequantize();
        for r in 0..w.rows {
            let row = deq.row_mut(r);
            for j in 0..cols {
                row[j] /= scales[j];
            }
        }
        let err = output_err(w, &deq, h);
        if best.as_ref().map(|b| err < b.err).unwrap_or(true) {
            best = Some(AwqResult { quantized: q, scales, alpha, err });
        }
    }
    best.unwrap()
}

/// Dequantize an AWQ result back to the original weight space.
pub fn dequantize_awq(r: &AwqResult) -> Matrix {
    let mut deq = r.quantized.dequantize();
    for row in 0..deq.rows {
        let cols = deq.cols;
        let rr = deq.row_mut(row);
        for j in 0..cols {
            rr[j] /= r.scales[j];
        }
    }
    deq
}

/// Plain per-column uniform RTN error for comparison in tests.
pub fn rtn_err(w: &Matrix, h: &[f64], bits: u8) -> f64 {
    let plan = MatrixPlan::uniform(w.cols, bits, CentroidRule::UniformMinMax, false);
    let q = quantize_matrix(w, None, &plan);
    output_err(w, &q.dequantize(), h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::uniform_codebook;
    use crate::tensor::linalg::gram;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let (rows, cols) = (32, 24);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.05);
        // activations with very uneven channel magnitudes (AWQ's motivation)
        let mut x = Matrix::zeros(128, cols);
        for r in 0..128 {
            for c in 0..cols {
                let scale = if c < 4 { 8.0 } else { 0.3 };
                *x.at_mut(r, c) = rng.normal_f32() * scale;
            }
        }
        let mut h = gram(&x, 1e-6);
        for v in h.iter_mut() {
            *v *= 2.0;
        }
        (w, h)
    }

    #[test]
    fn act_scales_sqrt_of_half_diag() {
        let s = act_scales_from_hessian(&[2.0, 8.0]);
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn awq_beats_rtn_on_skewed_activations() {
        let (w, h) = setup(1);
        let awq = quantize_awq(&w, &h, 3);
        let rtn = rtn_err(&w, &h, 3);
        assert!(
            awq.err < rtn,
            "AWQ err {} should beat RTN err {}",
            awq.err,
            rtn
        );
    }

    #[test]
    fn alpha_zero_equals_rtn() {
        let (w, h) = setup(2);
        // With alpha=0 all scales are 1 => identical to RTN.
        let scales: Vec<f32> = vec![1.0; w.cols];
        let plan = MatrixPlan::uniform(w.cols, 3, CentroidRule::UniformMinMax, false);
        let q = quantize_matrix(&w, None, &plan);
        let mut deq = q.dequantize();
        for r in 0..w.rows {
            for j in 0..w.cols {
                let v = deq.at(r, j) / scales[j];
                *deq.at_mut(r, j) = v;
            }
        }
        let err = output_err(&w, &deq, &h);
        assert!((err - rtn_err(&w, &h, 3)).abs() < 1e-9);
    }

    #[test]
    fn round_trip_shapes() {
        let (w, h) = setup(3);
        let awq = quantize_awq(&w, &h, 4);
        let deq = dequantize_awq(&awq);
        assert_eq!((deq.rows, deq.cols), (w.rows, w.cols));
        // 4-bit AWQ should be a decent reconstruction
        let mut num = 0.0;
        let mut den = 0.0;
        for (a, b) in w.data.iter().zip(&deq.data) {
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        assert!((num / den).sqrt() < 0.2);
    }

    #[test]
    fn uniform_codebook_is_equally_spaced() {
        let cb = uniform_codebook(&[0.0, 1.0, 0.5, 0.25], 4);
        let c = &cb.centroids;
        let d0 = c[1] - c[0];
        for w in c.windows(2) {
            assert!((w[1] - w[0] - d0).abs() < 1e-6);
        }
    }
}
