//! K-Means in R^d — the centroid generator for vector-quantized
//! column-group planes (the VPTQ direction; DESIGN.md §15).
//!
//! Scalar CLAQ clusters the entries of one column (`kmeans_1d`); the VQ
//! plane kind clusters the *row-vectors* of a group of `d` adjacent
//! columns, so each codebook entry is a point in R^d and one packed index
//! per row selects all `d` coordinates at once — index cost `bits/d` per
//! parameter, which is how the container reaches below 2 bits. The
//! implementation mirrors `kmeans_1d` deliberately: k-means++ seeding,
//! Lloyd iterations out of a caller-owned scratch (zero steady-state
//! allocations), the same deterministic seeding rule
//! (`seed ^ n.rotate_left(17)`), and the same widest-cluster empty-repair
//! policy — the repaired centroid lands exactly on the donor cluster's
//! farthest member, each donor is used at most once per pass, and the
//! degenerate fallback (fewer distinct points than clusters) doesn't
//! count as a repair. The 1-D specialization sorts its inputs to make the
//! Lloyd step a linear merge; in R^d there is no such order, so
//! assignment is the plain O(n·k·d) nearest-centroid scan with a strict
//! `<` improvement rule (ties resolve to the lowest centroid index).

use crate::quant::kmeans::KMeansOpts;
use crate::util::rng::Rng;

/// Which plane representation a quantization plan produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneKind {
    /// One scalar codebook of `2^bits` centroids per column (CLAQPK01).
    Scalar,
    /// One vector codebook of `2^bits` centroids in R^d per group of `d`
    /// adjacent columns (CLAQVQ01); index cost is `bits/d` per parameter.
    VectorGroup { d: usize },
}

impl PlaneKind {
    pub fn name(self) -> &'static str {
        match self {
            PlaneKind::Scalar => "scalar",
            PlaneKind::VectorGroup { .. } => "vq",
        }
    }
}

/// A vector codebook: `len()` centroids in R^`dim`, centroid-major.
#[derive(Clone, Debug, PartialEq)]
pub struct VqCodebook {
    pub dim: usize,
    /// `len·dim` coordinates, centroid-major.
    pub centroids: Vec<f32>,
}

impl VqCodebook {
    pub fn new(dim: usize, centroids: Vec<f32>) -> Self {
        assert!(dim >= 1, "codebook dim must be >= 1");
        assert_eq!(centroids.len() % dim, 0, "centroid buffer not a multiple of dim");
        Self { dim, centroids }
    }

    pub fn len(&self) -> usize {
        self.centroids.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.centroids.is_empty()
    }

    pub fn centroid(&self, i: usize) -> &[f32] {
        &self.centroids[i * self.dim..(i + 1) * self.dim]
    }

    /// Nearest centroid by squared Euclidean distance (f64 accumulation,
    /// coordinate order fixed). Strict `<` improvement, so ties resolve to
    /// the lowest index — the vector analogue of `Codebook::quantize`.
    pub fn quantize(&self, v: &[f32]) -> u8 {
        debug_assert_eq!(v.len(), self.dim);
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, c) in self.centroids.chunks_exact(self.dim).enumerate() {
            let mut d = 0.0f64;
            for (&x, &cc) in v.iter().zip(c) {
                let e = x as f64 - cc as f64;
                d += e * e;
            }
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best as u8
    }

    /// Nearest centroid ignoring masked coordinates: outlier-reserved
    /// entries are stored exactly in FP and must not steer the assignment
    /// of the coordinates that *are* represented by the codebook.
    pub fn quantize_masked(&self, v: &[f32], mask: &[bool]) -> u8 {
        debug_assert_eq!(v.len(), self.dim);
        debug_assert_eq!(mask.len(), self.dim);
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, c) in self.centroids.chunks_exact(self.dim).enumerate() {
            let mut d = 0.0f64;
            for jj in 0..self.dim {
                if mask[jj] {
                    continue;
                }
                let e = v[jj] as f64 - c[jj] as f64;
                d += e * e;
            }
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best as u8
    }
}

/// One quantized column group: vector codebook + one index per row.
#[derive(Clone, Debug)]
pub struct VqGroup {
    pub codebook: VqCodebook,
    pub indices: Vec<u8>,
    pub bits: u8,
}

/// The vector-quantized planes of one matrix: groups of `group_dim`
/// adjacent columns (the final group may be narrower when `cols` is not a
/// multiple of `group_dim` — its codebook's `dim` is the ragged width).
#[derive(Clone, Debug)]
pub struct VqPlanes {
    pub group_dim: usize,
    pub groups: Vec<VqGroup>,
}

impl VqPlanes {
    /// Column range `[start, end)` covered by group `g`.
    pub fn group_span(&self, g: usize, cols: usize) -> (usize, usize) {
        let start = g * self.group_dim;
        (start, (start + self.group_dim).min(cols))
    }
}

/// Result of clustering one column group.
#[derive(Clone, Debug)]
pub struct KMeansNdResult {
    pub codebook: VqCodebook,
    pub inertia: f64,
    pub iters: usize,
}

/// Reusable clustering workspace for [`kmeans_nd_into`]; buffers grow to
/// the largest (n, k, dim) seen and are then recycled.
#[derive(Default)]
pub struct KMeansNdScratch {
    /// d2[i] = squared distance of point i to its nearest chosen centroid
    /// (k-means++ table).
    d2: Vec<f64>,
    centroids: Vec<f64>,
    /// assign[i] = cluster of point i from the latest Lloyd sweep.
    assign: Vec<u32>,
    counts: Vec<usize>,
    sums: Vec<f64>,
    far_d2: Vec<f64>,
    far_idx: Vec<usize>,
    consumed: Vec<bool>,
}

impl KMeansNdScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

fn dist2_to(p: &[f32], c: &[f64]) -> f64 {
    let mut d = 0.0f64;
    for (&x, &cc) in p.iter().zip(c) {
        let e = x as f64 - cc;
        d += e * e;
    }
    d
}

fn dist2_pts(a: &[f32], b: &[f32]) -> f64 {
    let mut d = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let e = x as f64 - y as f64;
        d += e * e;
    }
    d
}

/// K-means++ seeding over R^dim points: `k` initial centroids, each an
/// actual data point, sampled proportional to squared distance from the
/// already-chosen set (uniform when all residual distances vanish).
fn kmeanspp_init_nd(
    points: &[f32],
    dim: usize,
    k: usize,
    rng: &mut Rng,
    centroids: &mut Vec<f64>,
    d2: &mut Vec<f64>,
) {
    let n = points.len() / dim;
    centroids.clear();
    centroids.reserve(k * dim);
    let p0 = rng.below_usize(n);
    centroids.extend(points[p0 * dim..(p0 + 1) * dim].iter().map(|&x| x as f64));
    d2.clear();
    d2.extend(points.chunks_exact(dim).map(|p| dist2_to(p, &centroids[..dim])));
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            // All points coincide with chosen centroids; pick uniformly.
            rng.below_usize(n)
        } else {
            let mut t = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let chosen = &points[pick * dim..(pick + 1) * dim];
        centroids.extend(chosen.iter().map(|&x| x as f64));
        for (i, p) in points.chunks_exact(dim).enumerate() {
            let dd = dist2_pts(p, chosen);
            if dd < d2[i] {
                d2[i] = dd;
            }
        }
    }
}

/// Reseed empty clusters by splitting the widest populated cluster at its
/// extreme — same policy as the 1-D `repair_empty`: the donor is the
/// populated cluster (≥ 2 members, not yet consumed this pass) whose
/// farthest member lies farthest from its freshly updated centroid, and
/// the repaired centroid is placed exactly on that member. When no such
/// donor exists (fewer distinct points than clusters) the centroid falls
/// back to the first data point, which keeps the codebook well-formed
/// without counting as a repair.
#[allow(clippy::too_many_arguments)]
fn repair_empty_nd(
    points: &[f32],
    dim: usize,
    centroids: &mut [f64],
    assign: &[u32],
    counts: &[usize],
    far_d2: &mut Vec<f64>,
    far_idx: &mut Vec<usize>,
    consumed: &mut Vec<bool>,
) -> bool {
    let k = counts.len();
    if counts.iter().all(|&c| c > 0) {
        return false;
    }
    // Rare path: one sweep computing each cluster's farthest member
    // against the post-Lloyd centroids (member sets are the last
    // assignment, mirroring the prefix-sum runs of the 1-D repair).
    far_d2.clear();
    far_d2.resize(k, 0.0);
    far_idx.clear();
    far_idx.resize(k, usize::MAX);
    for (i, p) in points.chunks_exact(dim).enumerate() {
        let c = assign[i] as usize;
        let dd = dist2_to(p, &centroids[c * dim..(c + 1) * dim]);
        if far_idx[c] == usize::MAX || dd > far_d2[c] {
            far_d2[c] = dd;
            far_idx[c] = i;
        }
    }
    consumed.clear();
    consumed.resize(k, false);
    let mut repaired = false;
    for i in 0..k {
        if counts[i] > 0 {
            continue;
        }
        let mut best: Option<(usize, f64)> = None; // (donor, spread)
        for j in 0..k {
            if counts[j] >= 2 && !consumed[j] && far_d2[j] > 0.0 {
                let better = match best {
                    Some((_, bs)) => far_d2[j] > bs,
                    None => true,
                };
                if better {
                    best = Some((j, far_d2[j]));
                }
            }
        }
        match best {
            Some((donor, _)) => {
                let src = &points[far_idx[donor] * dim..(far_idx[donor] + 1) * dim];
                for (c, &x) in centroids[i * dim..(i + 1) * dim].iter_mut().zip(src) {
                    *c = x as f64;
                }
                consumed[donor] = true;
                repaired = true;
            }
            // Degenerate (fewer distinct points than clusters); place at
            // an arbitrary data point to keep the codebook well-formed.
            None => {
                for (c, &x) in centroids[i * dim..(i + 1) * dim].iter_mut().zip(&points[..dim]) {
                    *c = x as f64;
                }
            }
        }
    }
    repaired
}

/// Cluster `points` (n × dim, row-major) into `k` centroids in R^dim.
/// Empty input yields an all-zero codebook; a constant point set yields
/// `k` copies of that point. Allocates a fresh workspace per call — hot
/// loops should hold a [`KMeansNdScratch`] and call [`kmeans_nd_into`].
pub fn kmeans_nd(points: &[f32], dim: usize, k: usize, opts: &KMeansOpts) -> KMeansNdResult {
    kmeans_nd_into(points, dim, k, opts, &mut KMeansNdScratch::new())
}

/// [`kmeans_nd`] running out of a caller-owned workspace: zero heap
/// allocations in steady state besides the returned codebook.
pub fn kmeans_nd_into(
    points: &[f32],
    dim: usize,
    k: usize,
    opts: &KMeansOpts,
    scratch: &mut KMeansNdScratch,
) -> KMeansNdResult {
    assert!(k >= 1, "k must be >= 1");
    assert!(dim >= 1, "dim must be >= 1");
    assert_eq!(points.len() % dim, 0, "points not a multiple of dim");
    let n = points.len() / dim;
    if n == 0 {
        return KMeansNdResult {
            codebook: VqCodebook::new(dim, vec![0.0; k * dim]),
            inertia: 0.0,
            iters: 0,
        };
    }
    debug_assert!(points.iter().all(|v| v.is_finite()), "non-finite weight");

    // Degenerate: constant point set → all centroids equal that point.
    let first = &points[..dim];
    if points.chunks_exact(dim).all(|p| p == first) {
        let mut c = Vec::with_capacity(k * dim);
        for _ in 0..k {
            c.extend_from_slice(first);
        }
        return KMeansNdResult { codebook: VqCodebook::new(dim, c), inertia: 0.0, iters: 0 };
    }

    let KMeansNdScratch { d2, centroids, assign, counts, sums, far_d2, far_idx, consumed } =
        scratch;
    let mut rng = Rng::new(opts.seed ^ (n as u64).rotate_left(17));
    kmeanspp_init_nd(points, dim, k, &mut rng, centroids, d2);

    let mut inertia = f64::INFINITY;
    let mut iters = 0usize;
    for it in 0..opts.max_iters {
        iters = it + 1;
        // Assignment + accumulation (O(n·k·dim) nearest-centroid scan).
        counts.clear();
        counts.resize(k, 0);
        sums.clear();
        sums.resize(k * dim, 0.0);
        assign.clear();
        assign.resize(n, 0);
        let mut in_ = 0.0f64;
        for (i, p) in points.chunks_exact(dim).enumerate() {
            let mut bc = 0usize;
            let mut bd = dist2_to(p, &centroids[..dim]);
            for c in 1..k {
                let dd = dist2_to(p, &centroids[c * dim..(c + 1) * dim]);
                if dd < bd {
                    bd = dd;
                    bc = c;
                }
            }
            assign[i] = bc as u32;
            counts[bc] += 1;
            for (s, &x) in sums[bc * dim..(bc + 1) * dim].iter_mut().zip(p) {
                *s += x as f64;
            }
            in_ += bd;
        }
        inertia = in_;
        let mut moved = 0.0f64;
        for c in 0..k {
            if counts[c] > 0 {
                for jj in 0..dim {
                    let nc = sums[c * dim + jj] / counts[c] as f64;
                    moved = moved.max((nc - centroids[c * dim + jj]).abs());
                    centroids[c * dim + jj] = nc;
                }
            }
            // empty clusters handled below (reseed)
        }
        let repaired =
            repair_empty_nd(points, dim, centroids, assign, counts, far_d2, far_idx, consumed);
        if !repaired && moved < opts.tol {
            break;
        }
    }
    KMeansNdResult {
        codebook: VqCodebook::new(dim, centroids.iter().map(|&c| c as f32).collect()),
        inertia,
        iters,
    }
}

/// Total squared quantization error of `points` against a vector codebook.
pub fn inertia_nd(points: &[f32], cb: &VqCodebook) -> f64 {
    points
        .chunks_exact(cb.dim)
        .map(|p| {
            let c = cb.centroid(cb.quantize(p) as usize);
            dist2_pts(p, c)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_default;

    #[test]
    fn recovers_separated_blobs() {
        // Three well-separated 2-D blobs; k=3 must land near the blob means.
        let mut pts = Vec::new();
        for i in 0..100 {
            let j = 0.001 * (i as f32);
            pts.extend_from_slice(&[-1.0 + j, -1.0 + j]);
            pts.extend_from_slice(&[0.0 + j, 3.0 + j]);
            pts.extend_from_slice(&[5.0 + j, -2.0 + j]);
        }
        let r = kmeans_nd(&pts, 2, 3, &KMeansOpts::default());
        let mut found = [false; 3];
        for c in r.codebook.centroids.chunks_exact(2) {
            for (b, target) in found.iter_mut().zip([[-0.95, -0.95], [0.05, 3.05], [5.05, -1.95]])
            {
                if (c[0] - target[0]).abs() < 0.1 && (c[1] - target[1]).abs() < 0.1 {
                    *b = true;
                }
            }
        }
        assert!(found.iter().all(|&b| b), "blob means not recovered: {:?}", r.codebook.centroids);
    }

    #[test]
    fn constant_points() {
        let pts: Vec<f32> = [0.5f32, -0.25].repeat(64);
        let r = kmeans_nd(&pts, 2, 4, &KMeansOpts::default());
        assert_eq!(r.inertia, 0.0);
        for c in r.codebook.centroids.chunks_exact(2) {
            assert_eq!(c, &[0.5, -0.25]);
        }
    }

    #[test]
    fn empty_input_zero_codebook() {
        let r = kmeans_nd(&[], 3, 4, &KMeansOpts::default());
        assert_eq!(r.codebook.centroids, vec![0.0; 12]);
    }

    #[test]
    fn k_larger_than_distinct_points() {
        let pts = vec![1.0f32, 0.0, 2.0, 1.0, 1.0, 0.0, 2.0, 1.0];
        let r = kmeans_nd(&pts, 2, 8, &KMeansOpts::default());
        assert!(inertia_nd(&pts, &r.codebook) < 1e-10);
    }

    #[test]
    fn quantize_matches_nearest_centroid() {
        check_default("vq nearest centroid", |rng| {
            let dim = 1 + rng.below_usize(4);
            let n = 32 + rng.below_usize(128);
            let mut pts = vec![0.0f32; n * dim];
            rng.fill_normal(&mut pts, 1.0);
            let r = kmeans_nd(&pts, dim, 8, &KMeansOpts::default());
            let cb = &r.codebook;
            for p in pts.chunks_exact(dim).take(32) {
                let qi = cb.quantize(p) as usize;
                let qd = dist2_pts(p, cb.centroid(qi));
                for i in 0..cb.len() {
                    assert!(qd <= dist2_pts(p, cb.centroid(i)) + 1e-9);
                }
            }
        });
    }

    #[test]
    fn quantize_ties_resolve_low() {
        // Two identical centroids: the lower index must win.
        let cb = VqCodebook::new(2, vec![1.0, 1.0, 1.0, 1.0, 9.0, 9.0]);
        assert_eq!(cb.quantize(&[1.0, 1.0]), 0);
    }

    #[test]
    fn masked_quantize_ignores_reserved_coords() {
        // Point (0, 100): coordinate 1 is reserved. Unmasked, the huge
        // second coordinate drags the pick to centroid 1; masked, only the
        // first coordinate counts and centroid 0 wins.
        let cb = VqCodebook::new(2, vec![0.0, 0.0, 50.0, 80.0]);
        assert_eq!(cb.quantize(&[0.0, 100.0]), 1);
        assert_eq!(cb.quantize_masked(&[0.0, 100.0], &[false, true]), 0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_alloc() {
        check_default("vq scratch reuse", |rng| {
            let mut scratch = KMeansNdScratch::new();
            for _ in 0..4 {
                let dim = 1 + rng.below_usize(4);
                let n = 8 + rng.below_usize(200);
                let mut pts = vec![0.0f32; n * dim];
                rng.fill_normal(&mut pts, 1.0);
                let k = 1 << (1 + rng.below_usize(4));
                let a = kmeans_nd(&pts, dim, k, &KMeansOpts::default());
                let b = kmeans_nd_into(&pts, dim, k, &KMeansOpts::default(), &mut scratch);
                assert_eq!(a.codebook.centroids, b.codebook.centroids);
                assert_eq!(a.inertia.to_bits(), b.inertia.to_bits());
                assert_eq!(a.iters, b.iters);
            }
        });
    }

    #[test]
    fn repair_places_centroid_on_widest_cluster_extreme() {
        // Cluster 0 owns five points around the origin plus one far
        // outlier at (4, 0); cluster 1 owns one point; cluster 2 is empty.
        // The widest donor is cluster 0 and the repaired centroid must
        // land exactly on its farthest member (4, 0).
        let pts = [0.0f32, 0.0, 1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0, 20.0, 0.0];
        let mut centroids = vec![2.0f64, 0.0, 30.0, 0.0, 100.0, 0.0];
        let assign = vec![0u32, 0, 0, 0, 0, 1];
        let counts = vec![5usize, 1, 0];
        let (mut fd, mut fi, mut cons) = (Vec::new(), Vec::new(), Vec::new());
        let repaired =
            repair_empty_nd(&pts, 2, &mut centroids, &assign, &counts, &mut fd, &mut fi, &mut cons);
        assert!(repaired);
        assert_eq!(&centroids[4..6], &[4.0, 0.0], "expected split at (4,0), got {centroids:?}");
    }

    #[test]
    fn beats_scalar_on_correlated_pairs() {
        // Adjacent-coordinate correlation is the whole point of VQ: with
        // y ≈ x, 16 centroids in R^2 (4 bits/pair = 2 bits/param) track
        // the diagonal much better than two independent 4-centroid scalar
        // codebooks (2 bits/coord, the same 4 bits/pair index budget).
        let mut rng = crate::util::rng::Rng::new(11);
        let n = 512;
        let mut pts = vec![0.0f32; n * 2];
        for i in 0..n {
            let x = rng.next_f64() as f32 * 2.0 - 1.0;
            let eps = (rng.next_f64() as f32 - 0.5) * 0.05;
            pts[i * 2] = x;
            pts[i * 2 + 1] = x + eps;
        }
        let vq = kmeans_nd(&pts, 2, 16, &KMeansOpts::default());
        let e_vq = inertia_nd(&pts, &vq.codebook);
        // Scalar baseline at the same 4 bits per pair: 2 centroids/coord.
        let xs: Vec<f32> = (0..n).map(|i| pts[i * 2]).collect();
        let ys: Vec<f32> = (0..n).map(|i| pts[i * 2 + 1]).collect();
        let kx = crate::quant::kmeans::kmeans_1d(&xs, 4, &KMeansOpts::default());
        let ky = crate::quant::kmeans::kmeans_1d(&ys, 4, &KMeansOpts::default());
        let e_sc = crate::quant::kmeans::inertia(&xs, &kx.codebook)
            + crate::quant::kmeans::inertia(&ys, &ky.codebook);
        assert!(
            e_vq < e_sc * 0.8,
            "VQ {e_vq} should beat independent scalar codebooks {e_sc} on correlated pairs"
        );
    }
}
