//! The deployable CLAQ container: bit-packed index planes, per-column
//! codebooks, and a sparse outlier plane, with exact byte accounting.
//!
//! The paper reports model sizes in "equivalent bits" (index bits + 16 per
//! reserved outlier). A real deployment also pays for codebooks and outlier
//! coordinates; both accountings are exposed so the experiment tables can
//! quote paper-comparable numbers *and* honest container sizes.
//!
//! Two container kinds share the codec, distinguished by magic. The
//! scalar per-column layout (little-endian):
//! ```text
//! magic "CLAQPK01" | rows u32 | cols u32 | n_outliers u32
//! per column: bits u8 | 2^bits centroids (f16) | ceil(rows*bits/8) packed bytes
//! outliers:   (row u32, col u32, value f32) × n_outliers
//! ```
//! and the vector-group layout (DESIGN.md §15), whose fixed-offset prefix
//! (rows/cols/n_outliers at bytes 8..20) matches CLAQPK01 byte for byte so
//! header validators only need to accept either magic:
//! ```text
//! magic "CLAQVQ01" | rows u32 | cols u32 | n_outliers u32 | group_dim u8 | bits u8
//! per group: 2^bits centroids in R^width (f16, centroid-major)
//!            | ceil(rows*bits/8) packed bytes
//! outliers:  (row u32, col u32, value f32) × n_outliers
//! ```
//! Group `g` covers columns `[g·d, min((g+1)·d, cols))`; the final group's
//! `width` may be smaller than `group_dim` (the ragged tail), and every
//! width is derivable from the header, so the stream stays self-framing.

use crate::quant::codebook::Codebook;
use crate::quant::gptq::{Outlier, QuantPlanes, QuantizedColumn, QuantizedMatrix};
use crate::quant::vq::{PlaneKind, VqCodebook, VqGroup, VqPlanes};
use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 8] = b"CLAQPK01";
pub const VQ_MAGIC: &[u8; 8] = b"CLAQVQ01";

// ---------------------------------------------------------------- f16 ----

/// f32 → IEEE 754 binary16 (round-to-nearest-even), no crate available.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xFF) as i32;
    let mut man = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf/nan
        return sign | 0x7C00 | if man != 0 { 0x200 } else { 0 };
    }
    exp = exp - 127 + 15;
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if exp <= 0 {
        // subnormal (or zero)
        if exp < -10 {
            return sign;
        }
        man |= 0x80_0000;
        let shift = 14 - exp;
        let half = man >> shift;
        let rem = man & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && half & 1 == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = (exp as u32) << 10 | (man >> 13);
    let rem = man & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE 754 binary16 → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((114 + e) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ------------------------------------------------------------- packing ----

/// Pack `bits`-wide indices into bytes (LSB-first within each byte).
pub fn pack_indices(idx: &[u8], bits: u8) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = idx.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mask = ((1u16 << bits) - 1) as u8;
    let mut bitpos = 0usize;
    for &v in idx {
        debug_assert!(v & !mask == 0, "index {v} exceeds {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= v << off;
        let spill = off + bits as usize;
        if spill > 8 {
            out[byte + 1] |= v >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Fused unpack + codebook gather: decode `out.len()` indices of `bits`
/// width from `packed` and map each through `centroids`. This is the inner
/// loop of the packed execution backend (`model/linear.rs`): one weight
/// column is decoded per call, so a forward pass touches only the packed
/// planes and never materializes a dense matrix.
pub fn decode_plane_into(packed: &[u8], bits: u8, centroids: &[f32], out: &mut [f32]) {
    decode_plane_range_into(packed, bits, centroids, 0, out)
}

/// Row-block variant of [`decode_plane_into`]: decode the `out.len()`
/// indices starting at row `start` (an arbitrary bit offset into the
/// plane). This is what lets the thread-sharded kernel of
/// `model/linear.rs` split one column across workers without any shard
/// re-decoding rows it does not own.
pub fn decode_plane_range_into(
    packed: &[u8],
    bits: u8,
    centroids: &[f32],
    start: usize,
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    debug_assert!(centroids.len() >= (mask as usize) + 1, "codebook too small for bit width");
    let mut bitpos = start * bits as usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        *o = centroids[(v & mask) as usize];
        bitpos += bits as usize;
    }
}

/// Bulk unpack: decode `out.len()` indices of `bits` width starting at
/// index `start` into a caller-owned byte buffer. This is the fast path
/// under the tiled decode kernel (`model/linear.rs`): instead of walking
/// the plane bit-by-bit, byte-aligned widths (1/2/4/8 — every stored
/// index of a byte decodes in one pass over that byte) and the odd widths
/// (3/5/6/7 — eight indices extracted from one unaligned little-endian
/// u64 window; `7 bit offset + 8×7 index bits = 63 ≤ 64`) both consume
/// whole bytes per step. Produces exactly the indices
/// [`unpack_indices`] would.
pub fn unpack_indices_range_into(packed: &[u8], bits: u8, start: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let b = bits as usize;
    let n = out.len();
    let mask = ((1u16 << bits) - 1) as u8;
    match bits {
        8 => out.copy_from_slice(&packed[start..start + n]),
        1 | 2 | 4 => {
            let per = 8 / b; // indices per byte
            let mut i = 0usize;
            let mut pos = start;
            // head: finish the partially consumed first byte
            while i < n && pos % per != 0 {
                out[i] = (packed[pos / per] >> ((pos % per) * b)) & mask;
                i += 1;
                pos += 1;
            }
            // body: one full byte -> `per` indices
            while i + per <= n {
                let byte = packed[pos / per];
                for k in 0..per {
                    out[i + k] = (byte >> (k * b)) & mask;
                }
                i += per;
                pos += per;
            }
            // tail: the last partial byte
            while i < n {
                out[i] = (packed[pos / per] >> ((pos % per) * b)) & mask;
                i += 1;
                pos += 1;
            }
        }
        _ => {
            // 3/5/6/7 bits: 8 indices per unaligned u64 window
            let mut i = 0usize;
            let mut bitpos = start * b;
            while i + 8 <= n && bitpos / 8 + 8 <= packed.len() {
                let byte0 = bitpos / 8;
                let word = u64::from_le_bytes(packed[byte0..byte0 + 8].try_into().unwrap());
                let mut w = word >> (bitpos % 8);
                for k in 0..8 {
                    out[i + k] = (w as u8) & mask;
                    w >>= b;
                }
                i += 8;
                bitpos += 8 * b;
            }
            // tail (and the end-of-plane rows where a full u64 would read
            // past the buffer): the plain two-byte extraction
            while i < n {
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let mut v = packed[byte] >> off;
                if off + b > 8 {
                    v |= packed[byte + 1] << (8 - off);
                }
                out[i] = v & mask;
                i += 1;
                bitpos += b;
            }
        }
    }
}

/// Tile-granular fused unpack + codebook gather: decode `out.len()` rows
/// of a plane starting at row `start`, going through the bulk index
/// unpack ([`unpack_indices_range_into`]) instead of the bit-by-bit walk
/// of [`decode_plane_range_into`]. Indices are exact integers either way,
/// so the gathered values are identical; only the decode cost differs.
/// This is the per-column decode of the tiled kernel in `model/linear.rs`.
pub fn decode_plane_tile_into(
    packed: &[u8],
    bits: u8,
    centroids: &[f32],
    start: usize,
    out: &mut [f32],
) {
    debug_assert!(
        centroids.len() >= (1usize << bits),
        "codebook too small for bit width"
    );
    let mut idx = [0u8; 64];
    let mut done = 0usize;
    while done < out.len() {
        let chunk = (out.len() - done).min(64);
        unpack_indices_range_into(packed, bits, start + done, &mut idx[..chunk]);
        for (o, &i) in out[done..done + chunk].iter_mut().zip(&idx[..chunk]) {
            *o = centroids[i as usize];
        }
        done += chunk;
    }
}

// -------------------------------------------------- mixed-bit run tiles ----

/// A maximal run of adjacent columns sharing one bit width — the unit the
/// mixed-bit tiled kernel decodes with a single bit-width dispatch
/// (DESIGN.md §16). Runs partition `[0, cols)` in column order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitRun {
    /// First column of the run.
    pub c0: usize,
    /// Number of columns in the run.
    pub len: usize,
    /// The shared index bit width.
    pub bits: u8,
}

impl BitRun {
    /// One past the last column of the run.
    pub fn end(&self) -> usize {
        self.c0 + self.len
    }
}

/// Decompose a per-column bit-width map into maximal equal-bit runs. An
/// adaptive-precision plan (`BitPlan`) promotes a *set* of columns to the
/// hi width, so a mixed-bit matrix is typically a handful of long runs —
/// each of which the tiled kernel can hand to the PR 6 bulk per-bit-width
/// unpackers with one dispatch, instead of re-dispatching per column.
pub fn equal_bit_runs(bits: &[u8]) -> Vec<BitRun> {
    let mut runs: Vec<BitRun> = Vec::new();
    for (c, &b) in bits.iter().enumerate() {
        match runs.last_mut() {
            Some(r) if r.bits == b => r.len += 1,
            _ => runs.push(BitRun { c0: c, len: 1, bits: b }),
        }
    }
    runs
}

/// Multi-lane variant of [`decode_plane_tile_into`] for an equal-bit run:
/// decode the same `[start, start + out.len()/lanes)` row window of
/// `lanes` adjacent columns that share one `bits` width, with a single
/// bit-width dispatch covering every lane. Lane `l` reads the packed
/// plane at `planes[l·plane_stride ..]`, gathers through the `2^bits`
/// centroids at `centroids[l·cent_stride ..]`, and writes
/// `out[l·bl .. (l+1)·bl]` (`bl = out.len()/lanes`, the kernels'
/// lane-major tile layout). Exactly the values per-column
/// [`decode_plane_tile_into`] produces — bit-identical, not just close —
/// so swapping the per-column loop for the run decode is invisible to the
/// serial/sharded/batched identity contract of `model/linear.rs`.
#[allow(clippy::too_many_arguments)]
pub fn decode_run_tile_into(
    planes: &[u8],
    plane_stride: usize,
    bits: u8,
    centroids: &[f32],
    cent_stride: usize,
    lanes: usize,
    start: usize,
    out: &mut [f32],
) {
    debug_assert!(lanes > 0 && out.len() % lanes == 0, "ragged lane tile");
    let bl = out.len() / lanes;
    let k = 1usize << bits;
    let mut idx = [0u8; 64];
    for (l, dst) in out.chunks_exact_mut(bl).enumerate() {
        let plane = &planes[l * plane_stride..(l + 1) * plane_stride];
        let cb = &centroids[l * cent_stride..l * cent_stride + k];
        let mut done = 0usize;
        while done < bl {
            let chunk = (bl - done).min(64);
            unpack_indices_range_into(plane, bits, start + done, &mut idx[..chunk]);
            for (o, &i) in dst[done..done + chunk].iter_mut().zip(&idx[..chunk]) {
                *o = cb[i as usize];
            }
            done += chunk;
        }
    }
}

/// Unpack `n` indices of `bits` width from a packed byte stream.
pub fn unpack_indices(packed: &[u8], bits: u8, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        let spill = off + bits as usize;
        if spill > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    out
}

// ------------------------------------------------------------ container ----

/// Serialized CLAQ matrix container.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub bytes: Vec<u8>,
}

/// Size accounting for one packed matrix, tagged with the plane kind so
/// model-level reports can break container bytes down per kind.
#[derive(Clone, Copy, Debug)]
pub struct SizeReport {
    /// Which container layout this matrix packed into.
    pub kind: PlaneKind,
    pub params: usize,
    pub index_bytes: usize,
    pub codebook_bytes: usize,
    pub outlier_bytes: usize,
    pub header_bytes: usize,
    /// Index bits + 16·outliers per param — the paper's accounting. For
    /// vector groups one packed index covers `d` columns, so the index
    /// term is `bits/d` per parameter.
    pub paper_equivalent_bits: f64,
}

impl Default for SizeReport {
    fn default() -> Self {
        Self {
            kind: PlaneKind::Scalar,
            params: 0,
            index_bytes: 0,
            codebook_bytes: 0,
            outlier_bytes: 0,
            header_bytes: 0,
            paper_equivalent_bits: 0.0,
        }
    }
}

impl SizeReport {
    pub fn container_bytes(&self) -> usize {
        self.index_bytes + self.codebook_bytes + self.outlier_bytes + self.header_bytes
    }

    /// True container bits per parameter (everything included).
    pub fn container_bits_per_param(&self) -> f64 {
        self.container_bytes() as f64 * 8.0 / self.params.max(1) as f64
    }
}

/// Serialize a quantized matrix. Codebook centroids are stored f16 (the
/// deployment format; dequantization error from f16 codebooks is part of
/// the measured pipeline, as it would be on device).
///
/// The writer enforces the container invariants the reader assumes:
/// [`unpack`] reads exactly `1 << bits` centroids per column, so a column
/// whose codebook is shorter (or longer) would silently desync the byte
/// stream — every later column would be decoded from the wrong offset.
/// Such a matrix is rejected here with a clear error instead.
pub fn pack(qm: &QuantizedMatrix) -> Result<(PackedMatrix, SizeReport)> {
    match &qm.planes {
        QuantPlanes::Columns(columns) => pack_scalar(qm, columns),
        QuantPlanes::Groups(vp) => pack_vq(qm, vp),
    }
}

fn pack_scalar(qm: &QuantizedMatrix, columns: &[QuantizedColumn]) -> Result<(PackedMatrix, SizeReport)> {
    if columns.len() != qm.cols {
        bail!("matrix has {} columns but {} quantized planes", qm.cols, columns.len());
    }
    for (c, col) in columns.iter().enumerate() {
        if !(1..=8).contains(&col.bits) {
            bail!("column {c}: invalid bit width {}", col.bits);
        }
        let want = 1usize << col.bits;
        if col.codebook.len() != want {
            bail!(
                "column {c}: codebook has {} centroids but bit width {} requires exactly {want} \
                 (a shorter codebook would desync the container byte stream)",
                col.codebook.len(),
                col.bits
            );
        }
        if col.indices.len() != qm.rows {
            bail!("column {c}: {} indices for {} rows", col.indices.len(), qm.rows);
        }
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(qm.rows as u32).to_le_bytes());
    bytes.extend_from_slice(&(qm.cols as u32).to_le_bytes());
    bytes.extend_from_slice(&(qm.outliers.len() as u32).to_le_bytes());
    let header_bytes = bytes.len();

    let mut index_bytes = 0usize;
    let mut codebook_bytes = 0usize;
    for col in columns {
        bytes.push(col.bits);
        for &c in &col.codebook.centroids {
            bytes.extend_from_slice(&f32_to_f16_bits(c).to_le_bytes());
        }
        codebook_bytes += 1 + 2 * col.codebook.len();
        let packed = pack_indices(&col.indices, col.bits);
        index_bytes += packed.len();
        bytes.extend_from_slice(&packed);
    }
    let outlier_bytes = write_outliers(&mut bytes, &qm.outliers);
    let params = qm.rows * qm.cols;
    let index_bits: f64 = columns.iter().map(|c| c.bits as f64 * qm.rows as f64).sum();
    let report = SizeReport {
        kind: PlaneKind::Scalar,
        params,
        index_bytes,
        codebook_bytes,
        outlier_bytes,
        header_bytes,
        paper_equivalent_bits: (index_bits + 16.0 * qm.outliers.len() as f64) / params as f64,
    };
    Ok((PackedMatrix { bytes }, report))
}

/// Expected width of group `g` for `cols` columns in groups of `d`.
fn group_width(g: usize, d: usize, cols: usize) -> usize {
    (cols - g * d).min(d)
}

fn write_outliers(bytes: &mut Vec<u8>, outliers: &[Outlier]) -> usize {
    for o in outliers {
        bytes.extend_from_slice(&o.row.to_le_bytes());
        bytes.extend_from_slice(&o.col.to_le_bytes());
        bytes.extend_from_slice(&o.value.to_le_bytes());
    }
    12 * outliers.len()
}

/// Serialize a vector-quantized matrix into a CLAQVQ01 container. The same
/// desync discipline as [`pack_scalar`]: the reader consumes exactly
/// `2^bits · width` f16 centroids and `ceil(rows·bits/8)` index bytes per
/// group, so any group whose codebook or index plane disagrees with the
/// header-derived layout is rejected here with a clear error.
fn pack_vq(qm: &QuantizedMatrix, vp: &VqPlanes) -> Result<(PackedMatrix, SizeReport)> {
    let d = vp.group_dim;
    if d == 0 || d > 255 {
        bail!("group dim {d} out of range (1..=255)");
    }
    let n_groups = qm.cols.div_ceil(d);
    if vp.groups.len() != n_groups {
        bail!(
            "matrix has {} columns in groups of {d} ({n_groups} groups) but {} quantized groups",
            qm.cols,
            vp.groups.len()
        );
    }
    let bits = vp.groups.first().map(|g| g.bits).unwrap_or(0);
    if !(1..=8).contains(&bits) {
        bail!("invalid vector-group bit width {bits}");
    }
    for (g, grp) in vp.groups.iter().enumerate() {
        if grp.bits != bits {
            bail!("group {g}: bit width {} differs from group 0's {bits} (uniform required)", grp.bits);
        }
        let width = group_width(g, d, qm.cols);
        if grp.codebook.dim != width {
            bail!("group {g}: codebook dim {} but group covers {width} columns", grp.codebook.dim);
        }
        let want = 1usize << bits;
        if grp.codebook.len() != want {
            bail!(
                "group {g}: codebook has {} centroids but bit width {bits} requires exactly {want} \
                 (a shorter codebook would desync the container byte stream)",
                grp.codebook.len()
            );
        }
        if grp.indices.len() != qm.rows {
            bail!("group {g}: {} indices for {} rows", grp.indices.len(), qm.rows);
        }
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(VQ_MAGIC);
    bytes.extend_from_slice(&(qm.rows as u32).to_le_bytes());
    bytes.extend_from_slice(&(qm.cols as u32).to_le_bytes());
    bytes.extend_from_slice(&(qm.outliers.len() as u32).to_le_bytes());
    bytes.push(d as u8);
    bytes.push(bits);
    let header_bytes = bytes.len();

    let mut index_bytes = 0usize;
    let mut codebook_bytes = 0usize;
    for grp in &vp.groups {
        for &c in &grp.codebook.centroids {
            bytes.extend_from_slice(&f32_to_f16_bits(c).to_le_bytes());
        }
        codebook_bytes += 2 * grp.codebook.centroids.len();
        let packed = pack_indices(&grp.indices, bits);
        index_bytes += packed.len();
        bytes.extend_from_slice(&packed);
    }
    let outlier_bytes = write_outliers(&mut bytes, &qm.outliers);
    let params = qm.rows * qm.cols;
    let index_bits: f64 = vp.groups.iter().map(|g| g.bits as f64 * qm.rows as f64).sum();
    let report = SizeReport {
        kind: PlaneKind::VectorGroup { d },
        params,
        index_bytes,
        codebook_bytes,
        outlier_bytes,
        header_bytes,
        paper_equivalent_bits: (index_bits + 16.0 * qm.outliers.len() as f64) / params as f64,
    };
    Ok((PackedMatrix { bytes }, report))
}

/// Deserialize a container produced by [`pack`], dispatching on the
/// container magic (CLAQPK01 scalar vs CLAQVQ01 vector-group).
pub fn unpack(pm: &PackedMatrix) -> Result<QuantizedMatrix> {
    let b = &pm.bytes;
    if b.len() >= 8 && &b[..8] == VQ_MAGIC {
        return unpack_vq(b);
    }
    unpack_scalar(b)
}

fn unpack_scalar(b: &[u8]) -> Result<QuantizedMatrix> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > b.len() {
            bail!("truncated container at offset {pos}");
        }
        let s = &b[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic = take(&mut pos, 8)?;
    if magic != MAGIC {
        bail!("bad magic");
    }
    let rows = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let n_out = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;

    let mut columns = Vec::with_capacity(cols);
    for c in 0..cols {
        let bits = take(&mut pos, 1)?[0];
        if !(1..=8).contains(&bits) {
            bail!("column {c}: invalid bit width {bits}");
        }
        let k = 1usize << bits;
        let mut centroids = Vec::with_capacity(k);
        for _ in 0..k {
            let h = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap());
            centroids.push(f16_bits_to_f32(h));
        }
        let packed_len = (rows * bits as usize).div_ceil(8);
        let packed = take(&mut pos, packed_len)?;
        let indices = unpack_indices(packed, bits, rows);
        columns.push(QuantizedColumn { codebook: Codebook::new(centroids), indices, bits });
    }
    let mut outliers = Vec::with_capacity(n_out);
    for _ in 0..n_out {
        let row = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let col = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let value = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if row as usize >= rows || col as usize >= cols {
            bail!("outlier out of range ({row},{col})");
        }
        outliers.push(Outlier { row, col, value });
    }
    if pos != b.len() {
        bail!("trailing bytes ({} unread)", b.len() - pos);
    }
    Ok(QuantizedMatrix {
        rows,
        cols,
        planes: QuantPlanes::Columns(columns),
        outliers,
        metrics: Default::default(),
    })
}

fn unpack_vq(b: &[u8]) -> Result<QuantizedMatrix> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > b.len() {
            bail!("truncated container at offset {pos}");
        }
        let s = &b[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic = take(&mut pos, 8)?;
    if magic != VQ_MAGIC {
        bail!("bad magic");
    }
    let rows = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let n_out = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let d = take(&mut pos, 1)?[0] as usize;
    let bits = take(&mut pos, 1)?[0];
    if d == 0 {
        bail!("invalid group dim 0");
    }
    if !(1..=8).contains(&bits) {
        bail!("invalid vector-group bit width {bits}");
    }

    let n_groups = cols.div_ceil(d);
    let mut groups = Vec::with_capacity(n_groups);
    for g in 0..n_groups {
        let width = group_width(g, d, cols);
        let k = 1usize << bits;
        let mut centroids = Vec::with_capacity(k * width);
        for _ in 0..k * width {
            let h = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap());
            centroids.push(f16_bits_to_f32(h));
        }
        let packed_len = (rows * bits as usize).div_ceil(8);
        let packed = take(&mut pos, packed_len)?;
        let indices = unpack_indices(packed, bits, rows);
        groups.push(VqGroup { codebook: VqCodebook::new(width, centroids), indices, bits });
    }
    let mut outliers = Vec::with_capacity(n_out);
    for _ in 0..n_out {
        let row = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let col = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let value = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if row as usize >= rows || col as usize >= cols {
            bail!("outlier out of range ({row},{col})");
        }
        outliers.push(Outlier { row, col, value });
    }
    if pos != b.len() {
        bail!("trailing bytes ({} unread)", b.len() - pos);
    }
    Ok(QuantizedMatrix {
        rows,
        cols,
        planes: QuantPlanes::Groups(VqPlanes { group_dim: d, groups }),
        outliers,
        metrics: Default::default(),
    })
}

/// Write a container to disk.
pub fn save(pm: &PackedMatrix, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, &pm.bytes).with_context(|| format!("write {}", path.display()))
}

/// Read a container from disk.
pub fn load(path: &std::path::Path) -> Result<PackedMatrix> {
    Ok(PackedMatrix { bytes: std::fs::read(path).with_context(|| format!("read {}", path.display()))? })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::tensor::Matrix;
    use crate::util::proptest::check_default;
    use crate::util::rng::Rng;

    #[test]
    fn f16_round_trip_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.1035156e-5] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{x}");
        }
    }

    #[test]
    fn f16_precision_bound() {
        check_default("f16 rel err < 2^-10", |rng| {
            let x = (rng.normal() as f32) * 10.0;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            if x.abs() > 1e-4 {
                assert!(((x - y) / x).abs() < 1.0 / 1024.0, "{x} -> {y}");
            }
        });
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f32_to_f16_bits(1e10), 0x7C00); // overflow -> inf
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        // subnormal round-trip
        let sub = f16_bits_to_f32(0x0001);
        assert!(sub > 0.0 && sub < 1e-7);
        assert_eq!(f32_to_f16_bits(sub), 0x0001);
    }

    #[test]
    fn decode_plane_matches_unpack_then_lookup() {
        check_default("decode plane", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let n = 1 + rng.below_usize(200);
            let k = 1usize << bits;
            let idx: Vec<u8> = (0..n).map(|_| rng.below(k as u64) as u8).collect();
            let centroids: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let packed = pack_indices(&idx, bits);
            let mut out = vec![0.0f32; n];
            decode_plane_into(&packed, bits, &centroids, &mut out);
            for (o, &i) in out.iter().zip(&idx) {
                assert_eq!(*o, centroids[i as usize]);
            }
        });
    }

    #[test]
    fn decode_plane_range_matches_full_decode() {
        check_default("decode plane range", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let n = 1 + rng.below_usize(200);
            let k = 1usize << bits;
            let idx: Vec<u8> = (0..n).map(|_| rng.below(k as u64) as u8).collect();
            let centroids: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let packed = pack_indices(&idx, bits);
            let mut full = vec![0.0f32; n];
            decode_plane_into(&packed, bits, &centroids, &mut full);
            // an arbitrary [start, start+len) window decodes the same rows
            let start = rng.below_usize(n);
            let len = 1 + rng.below_usize(n - start);
            let mut window = vec![0.0f32; len];
            decode_plane_range_into(&packed, bits, &centroids, start, &mut window);
            assert_eq!(window, full[start..start + len]);
        });
    }

    #[test]
    fn bulk_unpack_range_matches_unpack_indices() {
        check_default("bulk unpack range", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let n = 1 + rng.below_usize(300);
            let idx: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_indices(&idx, bits);
            // an arbitrary window, including ones ending at the ragged
            // plane tail where the u64 fast path must hand off to the
            // scalar extraction
            let start = rng.below_usize(n);
            let len = 1 + rng.below_usize(n - start);
            let mut out = vec![0u8; len];
            unpack_indices_range_into(&packed, bits, start, &mut out);
            assert_eq!(out, idx[start..start + len]);
        });
    }

    #[test]
    fn tile_decode_matches_range_decode() {
        check_default("tile decode", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let n = 1 + rng.below_usize(300);
            let k = 1usize << bits;
            let idx: Vec<u8> = (0..n).map(|_| rng.below(k as u64) as u8).collect();
            let centroids: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let packed = pack_indices(&idx, bits);
            let start = rng.below_usize(n);
            let len = 1 + rng.below_usize(n - start);
            let mut want = vec![0.0f32; len];
            decode_plane_range_into(&packed, bits, &centroids, start, &mut want);
            let mut got = vec![0.0f32; len];
            decode_plane_tile_into(&packed, bits, &centroids, start, &mut got);
            // same indices, same gather: bit-identical, not just close
            assert_eq!(got, want);
        });
    }

    #[test]
    fn equal_bit_runs_partition_in_order() {
        assert_eq!(equal_bit_runs(&[]), vec![]);
        assert_eq!(equal_bit_runs(&[4]), vec![BitRun { c0: 0, len: 1, bits: 4 }]);
        let runs = equal_bit_runs(&[2, 2, 4, 4, 4, 2, 8]);
        assert_eq!(
            runs,
            vec![
                BitRun { c0: 0, len: 2, bits: 2 },
                BitRun { c0: 2, len: 3, bits: 4 },
                BitRun { c0: 5, len: 1, bits: 2 },
                BitRun { c0: 6, len: 1, bits: 8 },
            ]
        );
        // runs tile [0, cols) exactly, in column order
        let mut next = 0usize;
        for r in &runs {
            assert_eq!(r.c0, next);
            next = r.end();
        }
        assert_eq!(next, 7);
    }

    #[test]
    fn equal_bit_runs_property() {
        check_default("equal-bit runs", |rng| {
            let n = 1 + rng.below_usize(40);
            let bits: Vec<u8> = (0..n).map(|_| 1 + rng.below(4) as u8).collect();
            let runs = equal_bit_runs(&bits);
            let mut next = 0usize;
            for (i, r) in runs.iter().enumerate() {
                assert_eq!(r.c0, next, "runs must tile in order");
                assert!(r.len > 0);
                assert!(bits[r.c0..r.end()].iter().all(|&b| b == r.bits));
                if i > 0 {
                    assert_ne!(runs[i - 1].bits, r.bits, "adjacent runs must differ (maximal)");
                }
                next = r.end();
            }
            assert_eq!(next, n);
        });
    }

    #[test]
    fn run_tile_decode_matches_per_column_decode() {
        check_default("run tile decode", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let k = 1usize << bits;
            let rows = 1 + rng.below_usize(150);
            let lanes = 1 + rng.below_usize(4);
            let plane_stride = (rows * bits as usize).div_ceil(8);
            // lane-concatenated planes and codebooks, as PackedRun stores
            let mut planes = Vec::new();
            let mut centroids = Vec::new();
            let mut per_lane_idx = Vec::new();
            for _ in 0..lanes {
                let idx: Vec<u8> = (0..rows).map(|_| rng.below(k as u64) as u8).collect();
                let packed = pack_indices(&idx, bits);
                assert_eq!(packed.len(), plane_stride);
                planes.extend_from_slice(&packed);
                centroids.extend((0..k).map(|_| rng.normal_f32()));
                per_lane_idx.push(idx);
            }
            let start = rng.below_usize(rows);
            let bl = 1 + rng.below_usize(rows - start);
            let mut got = vec![0.0f32; lanes * bl];
            decode_run_tile_into(
                &planes,
                plane_stride,
                bits,
                &centroids,
                k,
                lanes,
                start,
                &mut got,
            );
            // reference: the per-column tile decode, lane by lane
            for l in 0..lanes {
                let mut want = vec![0.0f32; bl];
                decode_plane_tile_into(
                    &planes[l * plane_stride..(l + 1) * plane_stride],
                    bits,
                    &centroids[l * k..(l + 1) * k],
                    start,
                    &mut want,
                );
                assert_eq!(got[l * bl..(l + 1) * bl], want, "lane {l} differs");
                for (r, &i) in want.iter().zip(&per_lane_idx[l][start..start + bl]) {
                    assert_eq!(*r, centroids[l * k + i as usize]);
                }
            }
        });
    }

    #[test]
    fn pack_unpack_identity_all_widths() {
        check_default("pack round trip", |rng| {
            let bits = 1 + rng.below_usize(8) as u8;
            let n = 1 + rng.below_usize(300);
            let idx: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_indices(&idx, bits);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
            assert_eq!(unpack_indices(&packed, bits, n), idx);
        });
    }

    fn sample_qm(seed: u64) -> QuantizedMatrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(40, 12);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(12, 3, CentroidRule::KMeans, false);
        plan.bits[0] = 4;
        plan.bits[5] = 2;
        plan.reserve = vec![2; 12];
        quantize_matrix(&w, None, &plan)
    }

    #[test]
    fn container_round_trip() {
        let qm = sample_qm(1);
        let (pm, _) = pack(&qm).unwrap();
        let back = unpack(&pm).unwrap();
        assert_eq!(back.rows, qm.rows);
        assert_eq!(back.cols, qm.cols);
        assert_eq!(back.outliers, qm.outliers);
        for (a, b) in back.columns().iter().zip(qm.columns()) {
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.indices, b.indices);
            // centroids round-trip through f16
            for (&x, &y) in a.codebook.centroids.iter().zip(&b.codebook.centroids) {
                assert_eq!(x, f16_bits_to_f32(f32_to_f16_bits(y)));
            }
        }
    }

    fn sample_vq_qm(seed: u64, rows: usize, cols: usize, d: usize, bits: u8) -> QuantizedMatrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::vector_group(cols, d, bits, false);
        plan.reserve = vec![1; cols];
        quantize_matrix(&w, None, &plan)
    }

    #[test]
    fn vq_container_round_trip() {
        // cols=10, d=4 → groups of width 4, 4, 2 (ragged tail exercised)
        let qm = sample_vq_qm(5, 40, 10, 4, 3);
        let (pm, rep) = pack(&qm).unwrap();
        assert_eq!(&pm.bytes[..8], VQ_MAGIC);
        assert_eq!(pm.bytes.len(), rep.container_bytes());
        assert_eq!(rep.kind, PlaneKind::VectorGroup { d: 4 });
        let back = unpack(&pm).unwrap();
        assert_eq!((back.rows, back.cols), (qm.rows, qm.cols));
        assert_eq!(back.outliers, qm.outliers);
        let (bv, qv) = (back.vq_planes(), qm.vq_planes());
        assert_eq!(bv.group_dim, 4);
        assert_eq!(bv.groups.len(), qv.groups.len());
        for (a, b) in bv.groups.iter().zip(&qv.groups) {
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.codebook.dim, b.codebook.dim);
            for (&x, &y) in a.codebook.centroids.iter().zip(&b.codebook.centroids) {
                assert_eq!(x, f16_bits_to_f32(f32_to_f16_bits(y)));
            }
        }
    }

    /// Hand-computed byte accounting for the VQ container: rows=8, cols=6,
    /// d=2, bits=2 → header 22 B; 3 groups, each 4 centroids × 2 coords
    /// × 2 B = 16 B of codebook + ceil(8·2/8) = 2 B of indices; plus
    /// 12 B per outlier. Paper bits: 2/2 = 1 index bit/param plus
    /// 16·n_out/48.
    #[test]
    fn vq_size_report_hand_computed() {
        let mut rng = Rng::new(6);
        let mut w = Matrix::zeros(8, 6);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::vector_group(6, 2, 2, false);
        plan.reserve = vec![1, 0, 0, 0, 0, 0]; // exactly one outlier
        let qm = quantize_matrix(&w, None, &plan);
        assert_eq!(qm.outliers.len(), 1);
        let (pm, rep) = pack(&qm).unwrap();
        assert_eq!(rep.header_bytes, 22);
        assert_eq!(rep.codebook_bytes, 3 * 16);
        assert_eq!(rep.index_bytes, 3 * 2);
        assert_eq!(rep.outlier_bytes, 12);
        assert_eq!(rep.params, 48);
        assert_eq!(pm.bytes.len(), 22 + 48 + 6 + 12);
        assert_eq!(pm.bytes.len(), rep.container_bytes());
        let want_paper = 1.0 + 16.0 / 48.0;
        assert!((rep.paper_equivalent_bits - want_paper).abs() < 1e-12);
        assert!((rep.paper_equivalent_bits - qm.equivalent_bits_paper()).abs() < 1e-12);
    }

    /// Hand-computed scalar accounting alongside, pinning the kind tag:
    /// rows=8, cols=3, bits=2 → header 20 B; per column 1 B bits +
    /// 4 centroids × 2 B + 2 B indices = 11 B.
    #[test]
    fn scalar_size_report_hand_computed() {
        let mut rng = Rng::new(7);
        let mut w = Matrix::zeros(8, 3);
        rng.fill_normal(&mut w.data, 0.1);
        let plan = MatrixPlan::uniform(3, 2, CentroidRule::KMeans, false);
        let qm = quantize_matrix(&w, None, &plan);
        let (pm, rep) = pack(&qm).unwrap();
        assert_eq!(rep.kind, PlaneKind::Scalar);
        assert_eq!(rep.header_bytes, 20);
        assert_eq!(rep.codebook_bytes, 3 * 9);
        assert_eq!(rep.index_bytes, 3 * 2);
        assert_eq!(rep.outlier_bytes, 0);
        assert_eq!(pm.bytes.len(), 20 + 27 + 6);
    }

    /// The sub-2-bit acceptance shape: d=4, bits=2 over a 64×64 matrix
    /// lands under 2.0 container bits per parameter (0.5 index bits +
    /// codebooks + header), something no scalar config can reach.
    #[test]
    fn vq_container_bits_below_two() {
        let mut rng = Rng::new(8);
        let mut w = Matrix::zeros(64, 64);
        rng.fill_normal(&mut w.data, 0.1);
        let plan = MatrixPlan::vector_group(64, 4, 2, false);
        let qm = quantize_matrix(&w, None, &plan);
        let (_, rep) = pack(&qm).unwrap();
        assert!(
            rep.container_bits_per_param() < 2.0,
            "container bits {} not sub-2.0",
            rep.container_bits_per_param()
        );
        assert!(rep.paper_equivalent_bits < 1.0);
    }

    #[test]
    fn vq_corrupt_containers_rejected() {
        let qm = sample_vq_qm(9, 40, 12, 4, 3);
        let (pm, _) = pack(&qm).unwrap();
        // bad magic
        let mut bad = pm.clone();
        bad.bytes[0] = b'X';
        assert!(unpack(&bad).is_err());
        // truncated mid-codebook (first group's centroids start at 22)
        let mut trunc = pm.clone();
        trunc.bytes.truncate(30);
        assert!(unpack(&trunc).is_err());
        // group-dim byte corrupted: derived group layout no longer matches
        // the byte stream (desync → truncation/trailing rejection)
        let mut gd = pm.clone();
        gd.bytes[20] = 3;
        assert!(unpack(&gd).is_err());
        // group dim 0 is invalid outright
        let mut gd0 = pm.clone();
        gd0.bytes[20] = 0;
        assert!(unpack(&gd0).is_err());
        // bits byte corrupted: codebook/plane sizes change → desync
        let mut bb = pm.clone();
        bb.bytes[21] = 7;
        assert!(unpack(&bb).is_err());
        // bits byte out of range
        let mut b0 = pm.clone();
        b0.bytes[21] = 0;
        assert!(unpack(&b0).is_err());
        // trailing garbage
        let mut long = pm.clone();
        long.bytes.push(0);
        assert!(unpack(&long).is_err());
    }

    /// Desync-rejecting validation at pack time for hand-built VQ planes:
    /// wrong codebook size, wrong codebook dim, wrong group count, mixed
    /// bit widths, and wrong index length are all caught.
    #[test]
    fn malformed_vq_planes_rejected_at_pack() {
        let make = |groups: Vec<VqGroup>, d: usize| QuantizedMatrix {
            rows: 4,
            cols: 4,
            planes: QuantPlanes::Groups(VqPlanes { group_dim: d, groups }),
            outliers: Vec::new(),
            metrics: Default::default(),
        };
        let good_group = |bits: u8| VqGroup {
            codebook: VqCodebook::new(2, vec![0.0; (1usize << bits) * 2]),
            indices: vec![0; 4],
            bits,
        };
        // well-formed baseline packs
        assert!(pack(&make(vec![good_group(2), good_group(2)], 2)).is_ok());
        // wrong group count
        assert!(pack(&make(vec![good_group(2)], 2)).is_err());
        // short codebook (desync)
        let mut short = good_group(2);
        short.codebook.centroids.truncate(6);
        assert!(pack(&make(vec![short, good_group(2)], 2)).is_err());
        // codebook dim disagrees with group width
        let wrong_dim = VqGroup {
            codebook: VqCodebook::new(1, vec![0.0; 4]),
            indices: vec![0; 4],
            bits: 2,
        };
        assert!(pack(&make(vec![wrong_dim, good_group(2)], 2)).is_err());
        // mixed bit widths
        assert!(pack(&make(vec![good_group(2), good_group(3)], 2)).is_err());
        // wrong index length
        let mut short_idx = good_group(2);
        short_idx.indices.pop();
        assert!(pack(&make(vec![good_group(2), short_idx], 2)).is_err());
    }

    #[test]
    fn size_report_consistent() {
        let qm = sample_qm(2);
        let (pm, rep) = pack(&qm).unwrap();
        assert_eq!(pm.bytes.len(), rep.container_bytes());
        assert_eq!(rep.params, 40 * 12);
        assert!((rep.paper_equivalent_bits - qm.equivalent_bits_paper()).abs() < 1e-12);
        // paper accounting excludes codebooks/coords, so container >= paper
        assert!(rep.container_bits_per_param() > rep.paper_equivalent_bits);
    }

    #[test]
    fn corrupt_containers_rejected() {
        let qm = sample_qm(3);
        let (pm, _) = pack(&qm).unwrap();
        // bad magic
        let mut bad = pm.clone();
        bad.bytes[0] = b'X';
        assert!(unpack(&bad).is_err());
        // truncated
        let mut trunc = pm.clone();
        trunc.bytes.truncate(pm.bytes.len() - 3);
        assert!(unpack(&trunc).is_err());
        // trailing garbage
        let mut long = pm.clone();
        long.bytes.push(0);
        assert!(unpack(&long).is_err());
    }

    /// The reader consumes exactly `1 << bits` centroids per column, so a
    /// hand-built matrix whose codebook is shorter (a degenerate column
    /// with fewer distinct values than levels) must be rejected at pack
    /// time — writing it would silently desync every later column.
    #[test]
    fn short_codebook_rejected_at_pack() {
        let make = |centroids: Vec<f32>, bits: u8| QuantizedMatrix {
            rows: 4,
            cols: 1,
            planes: QuantPlanes::Columns(vec![QuantizedColumn {
                codebook: Codebook::new(centroids),
                indices: vec![0, 1, 1, 0],
                bits,
            }]),
            outliers: Vec::new(),
            metrics: Default::default(),
        };
        // 3-bit column with only 5 centroids: under-full codebook
        let err = pack(&make(vec![-1.0, -0.5, 0.0, 0.5, 1.0], 3)).unwrap_err();
        assert!(err.to_string().contains("codebook"), "{err}");
        // over-full codebook is just as much of a desync
        assert!(pack(&make(vec![0.0, 0.25, 0.5, 0.75, 1.0], 2)).is_err());
        // the well-formed versions of both pack fine
        let ok2 = make(vec![-1.0, 0.0, 0.5, 1.0], 2);
        let (pm, _) = pack(&ok2).unwrap();
        assert_eq!(unpack(&pm).unwrap().columns()[0].indices, ok2.columns()[0].indices);
        // row-count mismatch is caught too
        let mut bad_rows = make(vec![-1.0, 0.0, 0.5, 1.0], 2);
        if let QuantPlanes::Columns(cs) = &mut bad_rows.planes {
            cs[0].indices.pop();
        }
        assert!(pack(&bad_rows).is_err());
    }

    #[test]
    fn disk_round_trip() {
        let qm = sample_qm(4);
        let (pm, _) = pack(&qm).unwrap();
        let dir = std::env::temp_dir().join("claq_packed_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.claq");
        save(&pm, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.bytes, pm.bytes);
        let _ = std::fs::remove_file(&path);
    }
}
