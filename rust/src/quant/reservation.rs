//! §3.4 — Column-Level Adaptive Outlier Reservation (OR).
//!
//! A small budget of parameters is kept in full precision. Guided by the
//! Outlier Order, the top 10% most outlier-concentrated columns receive a
//! share o₁ of the total reservation budget and the remaining 90% share o₂
//! (paper Eq. 5). Within each column, the same number of largest and
//! smallest parameters are reserved (the paper's rule).

use crate::quant::outliers::OutlierStats;

/// The grid-searched budget split of Appendix C: fraction of the total
/// reserved-parameter budget granted to the top-10% columns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrSetting {
    /// Share of the budget for the top `top_frac` columns (o₁ side).
    pub hi_share: f64,
    /// Fraction of columns considered "high outlier ratio" (paper: 0.10).
    pub top_frac: f64,
}

impl OrSetting {
    /// Appendix C settings.
    pub const SETTING1: OrSetting = OrSetting { hi_share: 0.19, top_frac: 0.10 };
    pub const SETTING2: OrSetting = OrSetting { hi_share: 0.28, top_frac: 0.10 };
    pub const SETTING3: OrSetting = OrSetting { hi_share: 0.37, top_frac: 0.10 };

    pub fn by_id(id: usize) -> OrSetting {
        match id {
            1 => Self::SETTING1,
            2 => Self::SETTING2,
            3 => Self::SETTING3,
            other => panic!("unknown OR setting {other}"),
        }
    }
}

/// Per-column reservation counts for one matrix.
#[derive(Clone, Debug)]
pub struct ReservePlan {
    /// Number of FP16-reserved parameters per column (always even: half
    /// largest, half smallest).
    pub counts: Vec<usize>,
    /// Total reserved parameters.
    pub total: usize,
    /// Extra bits per parameter this plan costs under paper accounting
    /// (16 bits per reserved value).
    pub overhead_bits: f64,
}

/// Paper accounting: a reserved FP16 outlier costs 16 bits. (The real
/// container also stores a 16-bit row index; `packed.rs` reports both.)
pub const PAPER_BITS_PER_OUTLIER: f64 = 16.0;

/// Allocate reservation counts. `budget_bits` is the extra equivalent
/// bits/parameter to spend on outliers (e.g. 0.07 for the 2.12 fusion
/// preset). Counts are clamped to the column height and rounded down to
/// even so the largest/smallest split is exact.
pub fn allocate_or(
    stats: &OutlierStats,
    rows: usize,
    budget_bits: f64,
    setting: OrSetting,
) -> ReservePlan {
    let cols = stats.ratios.len();
    assert!(cols > 0 && rows > 0);
    let total_params = rows * cols;
    let budget = ((budget_bits * total_params as f64) / PAPER_BITS_PER_OUTLIER).floor() as usize;

    let top: Vec<usize> = stats.top_columns(setting.top_frac);
    let is_top = {
        let mut mask = vec![false; cols];
        for &c in &top {
            mask[c] = true;
        }
        mask
    };
    let n_top = top.len().max(1);
    let n_rest = (cols - top.len()).max(1);

    let hi_budget = (budget as f64 * setting.hi_share) as usize;
    let lo_budget = budget - hi_budget;
    let _ = (n_top, n_rest);

    // Distribute each tier's budget in PAIRS (one largest + one smallest
    // per grant, keeping the per-column count even as the paper requires),
    // round-robin in Outlier Order so higher-ratio columns absorb any
    // remainder first. This uses small budgets exactly instead of
    // truncating them to zero per column.
    let order = stats.order();
    let rest: Vec<usize> = order.iter().copied().filter(|c| !is_top[*c]).collect();
    let mut counts = vec![0usize; cols];
    let max_even = make_even(rows);
    let grant = |tier: &[usize], tier_budget: usize, counts: &mut Vec<usize>| {
        if tier.is_empty() {
            return;
        }
        let mut pairs = tier_budget / 2;
        let mut i = 0usize;
        let mut stalled = 0usize;
        while pairs > 0 && stalled < tier.len() {
            let c = tier[i % tier.len()];
            if counts[c] + 2 <= max_even {
                counts[c] += 2;
                pairs -= 1;
                stalled = 0;
            } else {
                stalled += 1;
            }
            i += 1;
        }
    };
    grant(&top, hi_budget, &mut counts);
    grant(&rest, lo_budget, &mut counts);
    let total: usize = counts.iter().sum();
    let overhead_bits = total as f64 * PAPER_BITS_PER_OUTLIER / total_params as f64;
    ReservePlan { counts, total, overhead_bits }
}

/// The "Outlier fix" baseline of Table 4: the same total budget spread
/// uniformly over all columns (no Outlier Order guidance).
pub fn allocate_fixed(rows: usize, cols: usize, budget_bits: f64) -> ReservePlan {
    assert!(cols > 0 && rows > 0);
    let total_params = rows * cols;
    let budget = ((budget_bits * total_params as f64) / PAPER_BITS_PER_OUTLIER).floor() as usize;
    // Uniform pair-granular spread (no sensitivity guidance): every column
    // receives the same even count; the remainder pairs go to the lowest
    // column indices (fixed, metric-blind).
    let base = make_even((budget / cols).min(rows));
    let mut counts = vec![base; cols];
    let mut leftover_pairs = budget.saturating_sub(base * cols) / 2;
    let max_even = make_even(rows);
    for c in 0..cols {
        if leftover_pairs == 0 {
            break;
        }
        if counts[c] + 2 <= max_even {
            counts[c] += 2;
            leftover_pairs -= 1;
        }
    }
    let total: usize = counts.iter().sum();
    let overhead_bits = total as f64 * PAPER_BITS_PER_OUTLIER / total_params as f64;
    ReservePlan { counts, total, overhead_bits }
}

fn make_even(n: usize) -> usize {
    n - (n % 2)
}

/// Pick the reserved entries of one column: the `count/2` largest and
/// `count/2` smallest values (by signed value — reserving both tails is the
/// paper's rule). Returns row indices.
pub fn pick_reserved_rows(column: &[f32], count: usize) -> Vec<usize> {
    let mut idx = Vec::new();
    let mut out = Vec::new();
    pick_reserved_rows_into(column, count, &mut idx, &mut out);
    out
}

/// [`pick_reserved_rows`] writing into caller-owned buffers: `idx` is the
/// index sort buffer, `out` receives the ascending reserved row indices.
/// Allocation-free once the buffers are warm, except that the stable index
/// sort (stability is load-bearing: ties between equal values must resolve
/// to the lowest rows) may allocate its merge buffer.
pub fn pick_reserved_rows_into(
    column: &[f32],
    count: usize,
    idx: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    let count = count.min(make_even(column.len()));
    if count == 0 {
        return;
    }
    let half = count / 2;
    idx.clear();
    idx.extend(0..column.len());
    idx.sort_by(|&a, &b| column[a].partial_cmp(&column[b]).unwrap());
    out.extend_from_slice(&idx[..half]); // smallest
    out.extend_from_slice(&idx[idx.len() - half..]); // largest
    out.sort_unstable();
    out.dedup();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::proptest::check_default;
    use crate::util::rng::Rng;

    fn stats_for(rows: usize, cols: usize, seed: u64) -> (OutlierStats, usize) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.02);
        // spike the first column so the top tier is deterministic
        for r in 0..rows / 4 {
            *w.at_mut(r, 0) = 0.9;
        }
        (OutlierStats::compute(&w, 3.0), rows)
    }

    #[test]
    fn budget_respected() {
        let (st, rows) = stats_for(128, 40, 1);
        let plan = allocate_or(&st, rows, 0.13, OrSetting::SETTING2);
        // Achieved overhead must not exceed the requested budget.
        assert!(plan.overhead_bits <= 0.13 + 1e-9, "got {}", plan.overhead_bits);
        assert!(plan.total > 0);
    }

    #[test]
    fn top_columns_get_more() {
        let (st, rows) = stats_for(256, 50, 2);
        let plan = allocate_or(&st, rows, 0.2, OrSetting::SETTING2);
        let top = st.top_columns(0.10);
        let top_count = plan.counts[top[0]];
        let rest_max = (0..50)
            .filter(|c| !top.contains(c))
            .map(|c| plan.counts[c])
            .max()
            .unwrap();
        assert!(
            top_count > rest_max,
            "top column got {top_count}, rest max {rest_max}"
        );
    }

    #[test]
    fn counts_even_and_bounded() {
        check_default("or counts even", |rng| {
            let rows = 16 + rng.below_usize(200);
            let cols = 10 + rng.below_usize(64);
            let (st, _) = stats_for(rows, cols, rng.next_u64());
            let plan = allocate_or(&st, rows, rng.next_f64() * 0.5, OrSetting::by_id(1 + rng.below_usize(3)));
            for &c in &plan.counts {
                assert_eq!(c % 2, 0);
                assert!(c <= rows);
            }
        });
    }

    #[test]
    fn fixed_is_uniform() {
        let plan = allocate_fixed(128, 16, 0.25);
        assert!(plan.counts.windows(2).all(|w| w[0] == w[1]));
        assert!(plan.overhead_bits <= 0.25 + 1e-9);
    }

    #[test]
    fn pick_reserved_takes_both_tails() {
        let col = vec![-5.0f32, -0.1, 0.0, 0.2, 7.0, 0.05];
        let rows = pick_reserved_rows(&col, 2);
        assert_eq!(rows, vec![0, 4]); // -5 and 7
    }

    #[test]
    fn pick_reserved_full_column() {
        let col = vec![1.0f32, 2.0, 3.0, 4.0];
        let rows = pick_reserved_rows(&col, 100);
        assert_eq!(rows, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pick_reserved_zero() {
        assert!(pick_reserved_rows(&[1.0, 2.0], 0).is_empty());
    }

    #[test]
    fn pick_reserved_into_reuses_buffers() {
        let mut idx = Vec::new();
        let mut out = Vec::new();
        // successive calls with different columns must match the
        // allocating variant exactly (including tie-breaks to low rows)
        for (col, count) in [
            (vec![-5.0f32, -0.1, 0.0, 0.2, 7.0, 0.05], 2usize),
            (vec![1.0f32, 1.0, 1.0, 1.0], 2),
            (vec![3.0f32, -3.0], 100),
        ] {
            pick_reserved_rows_into(&col, count, &mut idx, &mut out);
            assert_eq!(out, pick_reserved_rows(&col, count));
        }
    }

    #[test]
    fn settings_order() {
        assert!(OrSetting::SETTING1.hi_share < OrSetting::SETTING2.hi_share);
        assert!(OrSetting::SETTING2.hi_share < OrSetting::SETTING3.hi_share);
    }
}
