//! §3.2 — the Outlier Order quantization-sensitivity metric.
//!
//! For an i×j weight matrix W, the outlier ratio of column j is
//! `R_j = Card(|W_j| > mean(|W|) · S) / i` (paper Eq. 3): the fraction of
//! entries whose magnitude exceeds S times the mean absolute value of the
//! *whole matrix*. Ranking columns by R_j ("Outlier Order") drives both the
//! Adaptive Precision allocator (§3.3) and Outlier Reservation (§3.4).

use crate::tensor::Matrix;

/// Column outlier statistics for one weight matrix.
#[derive(Clone, Debug)]
pub struct OutlierStats {
    /// R_j per column (Eq. 3).
    pub ratios: Vec<f64>,
    /// mean(|W|) over the whole matrix.
    pub mean_abs: f64,
    /// The scale coefficient S used.
    pub s: f64,
    /// Total outliers counted.
    pub total_outliers: usize,
}

impl OutlierStats {
    /// Compute Eq. 3 for every column. `w` is (rows × cols) with columns as
    /// quantization groups (rows = output features for a Linear layer
    /// stored (out × in), so a "column" is all output weights of one input
    /// feature — the GPTQ quantization group).
    pub fn compute(w: &Matrix, s: f64) -> Self {
        let mean_abs = if w.data.is_empty() {
            0.0
        } else {
            w.data.iter().map(|&x| (x as f64).abs()).sum::<f64>() / w.data.len() as f64
        };
        let thresh = (mean_abs * s) as f32;
        let mut counts = vec![0usize; w.cols];
        for r in 0..w.rows {
            let row = w.row(r);
            for (c, &x) in row.iter().enumerate() {
                if x.abs() > thresh {
                    counts[c] += 1;
                }
            }
        }
        let total_outliers = counts.iter().sum();
        let ratios = counts
            .iter()
            .map(|&c| c as f64 / w.rows.max(1) as f64)
            .collect();
        Self { ratios, mean_abs, s, total_outliers }
    }

    /// Column indices sorted by outlier ratio, descending — the paper's
    /// "Outlier Order". Ties break by column index for determinism.
    pub fn order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.ratios.len()).collect();
        idx.sort_by(|&a, &b| {
            self.ratios[b]
                .partial_cmp(&self.ratios[a])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx
    }

    /// Threshold value T such that exactly the top `frac` of columns have
    /// R_j ranking above it (used for T_AP / T_OR). Returns the set of
    /// selected top columns (by index) — using rank rather than a raw
    /// threshold sidesteps ties producing over/under-sized selections.
    pub fn top_columns(&self, frac: f64) -> Vec<usize> {
        let n = self.ratios.len();
        let k = ((n as f64) * frac).round() as usize;
        self.order().into_iter().take(k.min(n)).collect()
    }

    /// Exact top-k variant.
    pub fn top_k_columns(&self, k: usize) -> Vec<usize> {
        self.order().into_iter().take(k.min(self.ratios.len())).collect()
    }

    /// Fraction of all outliers captured by the top `frac` of columns —
    /// the paper's Appendix A concentration statistic ("90% of outliers
    /// are in the top 10% of columns").
    pub fn concentration(&self, frac: f64) -> f64 {
        if self.total_outliers == 0 {
            return 0.0;
        }
        let n_rows_f = 1.0; // ratios are already counts/rows; sum proportionally
        let _ = n_rows_f;
        let top = self.top_columns(frac);
        let top_sum: f64 = top.iter().map(|&c| self.ratios[c]).sum();
        let all_sum: f64 = self.ratios.iter().sum();
        if all_sum == 0.0 {
            0.0
        } else {
            top_sum / all_sum
        }
    }

    /// Overall outlier ratio of the matrix (for the Figure 5 per-layer plot).
    pub fn overall_ratio(&self) -> f64 {
        if self.ratios.is_empty() {
            0.0
        } else {
            self.ratios.iter().sum::<f64>() / self.ratios.len() as f64
        }
    }
}

/// Alternative column-sensitivity metrics for the Table 3 ablation (the
/// paper's MP† comparator uses a magnitude/activation criterion from
/// SparseGPT [14]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnMetric {
    /// Paper's Outlier Order (Eq. 3).
    OutlierRatio,
    /// Mean |W_j| per column — plain magnitude.
    Magnitude,
    /// SparseGPT-style salience: ‖W_j‖² · H_jj (needs the Hessian diagonal;
    /// falls back to Magnitude when it is absent).
    Salience,
}

/// Compute per-column sensitivity scores under the chosen metric.
/// `hess_diag` is diag(H) from calibration (length = cols) when available.
pub fn column_scores(
    w: &Matrix,
    metric: ColumnMetric,
    s: f64,
    hess_diag: Option<&[f64]>,
) -> Vec<f64> {
    match metric {
        ColumnMetric::OutlierRatio => OutlierStats::compute(w, s).ratios,
        ColumnMetric::Magnitude => (0..w.cols)
            .map(|c| {
                (0..w.rows).map(|r| (w.at(r, c) as f64).abs()).sum::<f64>() / w.rows.max(1) as f64
            })
            .collect(),
        ColumnMetric::Salience => {
            let hd = match hess_diag {
                Some(h) if h.len() == w.cols => h,
                _ => return column_scores(w, ColumnMetric::Magnitude, s, None),
            };
            (0..w.cols)
                .map(|c| {
                    let norm2: f64 =
                        (0..w.rows).map(|r| (w.at(r, c) as f64).powi(2)).sum();
                    norm2 * hd[c]
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_default;
    use crate::util::rng::Rng;

    /// A matrix where column 2 is stuffed with outliers.
    fn spiked_matrix() -> Matrix {
        let mut rng = Rng::new(1);
        let mut w = Matrix::zeros(64, 8);
        rng.fill_normal(&mut w.data, 0.01);
        for r in 0..32 {
            *w.at_mut(r, 2) = 1.0;
        }
        w
    }

    #[test]
    fn ratio_counts_eq3() {
        let w = Matrix::from_vec(2, 2, vec![0.1, 10.0, 0.1, 10.0]);
        // mean|W| = 5.05; S=1 -> threshold 5.05; col1 has 2 outliers.
        let st = OutlierStats::compute(&w, 1.0);
        assert_eq!(st.ratios, vec![0.0, 1.0]);
        assert_eq!(st.total_outliers, 2);
    }

    #[test]
    fn spiked_column_ranks_first() {
        let st = OutlierStats::compute(&spiked_matrix(), 3.0);
        assert_eq!(st.order()[0], 2);
        assert_eq!(st.top_columns(0.125), vec![2]);
    }

    #[test]
    fn larger_s_fewer_outliers() {
        check_default("S monotone", |rng| {
            let mut w = Matrix::zeros(32, 16);
            rng.fill_normal(&mut w.data, 1.0);
            let a = OutlierStats::compute(&w, 2.0).total_outliers;
            let b = OutlierStats::compute(&w, 5.0).total_outliers;
            assert!(b <= a, "S=5 gave more outliers ({b}) than S=2 ({a})");
        });
    }

    #[test]
    fn ratios_in_unit_interval() {
        check_default("ratio bounds", |rng| {
            let rows = 8 + rng.below_usize(64);
            let cols = 1 + rng.below_usize(32);
            let mut w = Matrix::zeros(rows, cols);
            rng.fill_normal(&mut w.data, 0.5);
            let st = OutlierStats::compute(&w, 1.0 + rng.next_f64() * 12.0);
            for &r in &st.ratios {
                assert!((0.0..=1.0).contains(&r));
            }
        });
    }

    #[test]
    fn concentration_of_spiked_matrix_high() {
        let st = OutlierStats::compute(&spiked_matrix(), 3.0);
        assert!(st.concentration(0.125) > 0.9);
    }

    #[test]
    fn magnitude_metric_orders_by_size() {
        let mut w = Matrix::zeros(16, 3);
        for r in 0..16 {
            *w.at_mut(r, 0) = 0.01;
            *w.at_mut(r, 1) = 1.0;
            *w.at_mut(r, 2) = 0.1;
        }
        let s = column_scores(&w, ColumnMetric::Magnitude, 13.0, None);
        assert!(s[1] > s[2] && s[2] > s[0]);
    }

    #[test]
    fn salience_uses_hessian() {
        let mut w = Matrix::zeros(4, 2);
        for r in 0..4 {
            *w.at_mut(r, 0) = 1.0;
            *w.at_mut(r, 1) = 1.0;
        }
        let s = column_scores(&w, ColumnMetric::Salience, 13.0, Some(&[1.0, 100.0]));
        assert!(s[1] > s[0]);
    }

    #[test]
    fn top_k_exact() {
        let st = OutlierStats::compute(&spiked_matrix(), 3.0);
        assert_eq!(st.top_k_columns(1), vec![2]);
        assert_eq!(st.top_k_columns(0), Vec::<usize>::new());
        assert_eq!(st.top_k_columns(100).len(), 8);
    }
}
