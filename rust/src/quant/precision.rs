//! §3.3 — Column-Level Adaptive Precision (AP) quantization.
//!
//! Given a per-column sensitivity score (Outlier Order by default, or the
//! magnitude/salience comparators for the Table 3 ablation), a candidate
//! bit set B = {p₁, p₂} with p₁ > p₂, and a target *equivalent* bit-width,
//! promote the top-scoring fraction of columns to p₁ so the average
//! index-bit cost hits the target (paper Eq. 4: P_j = p₁ if R_j > T_AP).

use crate::quant::outliers::{column_scores, ColumnMetric};
use crate::tensor::Matrix;

/// The dual-level bit candidate set (paper keeps |B| = 2 "for the
/// convenience of CUDA kernel development").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitPair {
    pub hi: u8,
    pub lo: u8,
}

impl BitPair {
    pub fn new(hi: u8, lo: u8) -> Self {
        assert!(hi > lo, "require p1 > p2");
        assert!((1..=8).contains(&lo) && hi <= 8);
        Self { hi, lo }
    }

    /// Fraction of columns that must be promoted to `hi` so the average
    /// bits/param equals `target`. Clamped to [0, 1].
    pub fn promote_fraction(&self, target: f64) -> f64 {
        ((target - self.lo as f64) / (self.hi as f64 - self.lo as f64)).clamp(0.0, 1.0)
    }
}

/// Per-column bit assignment for one matrix.
#[derive(Clone, Debug)]
pub struct BitPlan {
    pub bits: Vec<u8>,
    /// Columns that were promoted to the high precision (sorted).
    pub promoted: Vec<usize>,
    /// Achieved average index bits per parameter.
    pub equivalent_bits: f64,
}

impl BitPlan {
    /// Uniform single-precision plan.
    pub fn uniform(cols: usize, bits: u8) -> Self {
        Self { bits: vec![bits; cols], promoted: Vec::new(), equivalent_bits: bits as f64 }
    }

    pub fn from_bits(bits: Vec<u8>) -> Self {
        let eq = bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len().max(1) as f64;
        Self { bits, promoted: Vec::new(), equivalent_bits: eq }
    }
}

/// Allocate adaptive precision for one matrix: promote the columns with the
/// highest `scores` until the equivalent bit target is met.
pub fn allocate_ap(scores: &[f64], pair: BitPair, target_bits: f64) -> BitPlan {
    let n = scores.len();
    assert!(n > 0);
    let f = pair.promote_fraction(target_bits);
    let n_hi = ((n as f64) * f).round() as usize;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let mut bits = vec![pair.lo; n];
    let mut promoted: Vec<usize> = order.into_iter().take(n_hi).collect();
    for &c in &promoted {
        bits[c] = pair.hi;
    }
    promoted.sort_unstable();
    let eq = bits.iter().map(|&b| b as f64).sum::<f64>() / n as f64;
    BitPlan { bits, promoted, equivalent_bits: eq }
}

/// Convenience: compute scores from a weight matrix under `metric` and
/// allocate. `hess_diag` feeds the salience comparator.
pub fn allocate_ap_for_matrix(
    w: &Matrix,
    metric: ColumnMetric,
    s: f64,
    hess_diag: Option<&[f64]>,
    pair: BitPair,
    target_bits: f64,
) -> BitPlan {
    let scores = column_scores(w, metric, s, hess_diag);
    allocate_ap(&scores, pair, target_bits)
}

/// The threshold T_AP implied by a plan — the lowest promoted score (paper
/// Eq. 4 presents the rule as a threshold; we derive it from the rank cut
/// so the target size is met exactly even with tied scores).
pub fn implied_threshold(scores: &[f64], plan: &BitPlan) -> Option<f64> {
    plan.promoted
        .iter()
        .map(|&c| scores[c])
        .min_by(|a, b| a.partial_cmp(b).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_default;

    #[test]
    fn fraction_math() {
        let p = BitPair::new(4, 2);
        assert!((p.promote_fraction(2.2) - 0.1).abs() < 1e-12);
        assert!((p.promote_fraction(2.0) - 0.0).abs() < 1e-12);
        assert!((p.promote_fraction(4.0) - 1.0).abs() < 1e-12);
        assert_eq!(p.promote_fraction(5.0), 1.0); // clamped
    }

    #[test]
    fn promotes_highest_scores() {
        let scores = vec![0.0, 0.9, 0.1, 0.5];
        let plan = allocate_ap(&scores, BitPair::new(4, 2), 3.0); // 50% promoted
        assert_eq!(plan.promoted, vec![1, 3]);
        assert_eq!(plan.bits, vec![2, 4, 2, 4]);
        assert!((plan.equivalent_bits - 3.0).abs() < 1e-12);
    }

    #[test]
    fn paper_example_2p2_bits() {
        // "a 2.2-bit quantized model is derived by allocating top 10%
        //  outlier-concentrated columns to 4-bit, 2-bit to the rest"
        let scores: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let plan = allocate_ap(&scores, BitPair::new(4, 2), 2.2);
        assert_eq!(plan.promoted.len(), 10);
        assert!(plan.promoted.iter().all(|&c| c >= 90));
    }

    #[test]
    fn equivalent_bits_hits_target() {
        check_default("ap hits budget", |rng| {
            let n = 16 + rng.below_usize(512);
            let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
            let pair = if rng.next_f64() < 0.5 { BitPair::new(4, 2) } else { BitPair::new(3, 2) };
            let target = pair.lo as f64 + rng.next_f64() * (pair.hi - pair.lo) as f64;
            let plan = allocate_ap(&scores, pair, target);
            // rounding to whole columns: at most (hi-lo)/n off target
            let tol = (pair.hi - pair.lo) as f64 / n as f64;
            assert!(
                (plan.equivalent_bits - target).abs() <= tol + 1e-9,
                "target {target}, got {} (n={n})",
                plan.equivalent_bits
            );
        });
    }

    #[test]
    fn threshold_separates_promoted() {
        let scores = vec![0.3, 0.8, 0.1, 0.9, 0.5];
        let plan = allocate_ap(&scores, BitPair::new(4, 2), 2.8); // 40% -> 2 cols
        let t = implied_threshold(&scores, &plan).unwrap();
        for (c, &s) in scores.iter().enumerate() {
            if plan.bits[c] == 4 {
                assert!(s >= t);
            } else {
                assert!(s <= t);
            }
        }
    }

    #[test]
    fn uniform_plan() {
        let p = BitPlan::uniform(7, 3);
        assert_eq!(p.bits, vec![3; 7]);
        assert_eq!(p.equivalent_bits, 3.0);
    }
}
