//! The CLAQ quantization core: K-Means codebooks (§3.1), the Outlier Order
//! sensitivity metric (§3.2), adaptive precision (§3.3), outlier
//! reservation (§3.4), the GPTQ error-compensation substrate, baselines
//! (RTN / GPTQ / AWQ), the Appendix G heuristic search, and the packed
//! deployment container.

pub mod awq;
pub mod codebook;
pub mod config;
pub mod gptq;
pub mod kmeans;
pub mod kvpage;
pub mod outliers;
pub mod packed;
pub mod precision;
pub mod reservation;
pub mod search;
pub mod vq;

pub use codebook::Codebook;
pub use config::Method;
pub use gptq::{
    quantize_matrix, quantize_matrix_pooled, CentroidRule, MatrixPlan, QuantPlanes, QuantScratch,
    QuantizedMatrix, DEFAULT_BLOCK,
};
pub use outliers::OutlierStats;
pub use vq::PlaneKind;
