//! Appendix G — heuristic adaptive-precision search.
//!
//! For wider budgets (e.g. 2.5 equivalent bits) the simple dual-level AP of
//! §3.3 is not optimal. The paper's heuristic: rank weight matrices by
//! overall outlier ratio, discretize each matrix's precision class into
//! {2-bit, 2&3-bit, 2&4-bit}, enumerate feasible combinations under the
//! size budget, and pick the one maximizing the precision score
//! PS_total = OR₄·PS₄·p₄·M₄ + OR₃·PS₃·p₃·M₃ (paper Eq. 6–8).

/// Precision class of one matrix in the search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatrixClass {
    /// All columns at the base low precision.
    Lo,
    /// Mixture of base and 3-bit columns (2&3).
    Mix3,
    /// Mixture of base and 4-bit columns (2&4).
    Mix4,
}

/// Search configuration (paper: PS₃ = 3, PS₄ = 4, base 2-bit).
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub base_bits: u8,
    pub ps3: f64,
    pub ps4: f64,
    /// Candidate high-precision column fractions (discretized search).
    pub fractions: Vec<f64>,
    /// Target equivalent bits across all matrices.
    pub target_bits: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            base_bits: 2,
            ps3: 3.0,
            ps4: 4.0,
            fractions: vec![0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
            target_bits: 2.5,
        }
    }
}

/// Per-matrix input: its outlier ratio (matrix-level, Appendix A Figure 5)
/// and parameter count (for budget accounting).
#[derive(Clone, Debug)]
pub struct MatrixInfo {
    pub name: String,
    pub outlier_ratio: f64,
    pub params: usize,
}

/// The chosen configuration for one matrix.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub class: MatrixClass,
    /// Fraction of columns promoted to the class's high precision.
    pub hi_fraction: f64,
}

impl Assignment {
    pub fn equivalent_bits(&self, base: u8) -> f64 {
        let b = base as f64;
        match self.class {
            MatrixClass::Lo => b,
            MatrixClass::Mix3 => b + self.hi_fraction * (3.0 - b),
            MatrixClass::Mix4 => b + self.hi_fraction * (4.0 - b),
        }
    }
}

/// Search result.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub assignments: Vec<Assignment>,
    pub score: f64,
    pub achieved_bits: f64,
}

fn mean_or(matrices: &[MatrixInfo], sel: &[bool]) -> f64 {
    let mut s = 0.0;
    let mut n = 0usize;
    for (m, &take) in matrices.iter().zip(sel) {
        if take {
            s += m.outlier_ratio;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// Run the heuristic search. Matrices with higher outlier ratio are
/// considered first for higher-precision classes (the paper's ranking
/// step); we then enumerate (M₄ prefix length, p₄, p₃) and for each
/// candidate compute the p₃ that exhausts the remaining budget.
pub fn search(matrices: &[MatrixInfo], cfg: &SearchConfig) -> SearchResult {
    let n = matrices.len();
    assert!(n > 0);
    let total_params: usize = matrices.iter().map(|m| m.params).sum();
    let base = cfg.base_bits as f64;
    let budget_extra = (cfg.target_bits - base) * total_params as f64; // in bit·params

    // Rank matrices by outlier ratio descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        matrices[b]
            .outlier_ratio
            .partial_cmp(&matrices[a].outlier_ratio)
            .unwrap()
            .then(a.cmp(&b))
    });

    let mut best: Option<SearchResult> = None;
    // M4 = how many top-ranked matrices go 2&4; the rest are 2&3 (and fall
    // back to Lo when the budget runs out).
    for m4 in 0..=n {
        for &p4 in &cfg.fractions {
            // bits consumed by the 2&4 group
            let params4: usize = order[..m4].iter().map(|&i| matrices[i].params).sum();
            let cost4 = p4 * (4.0 - base) * params4 as f64;
            if cost4 > budget_extra * (1.0 + 1e-9) {
                continue;
            }
            let remaining = budget_extra - cost4;
            let params3: usize = order[m4..].iter().map(|&i| matrices[i].params).sum();
            // p3 chosen to exhaust the remaining budget exactly (clamped).
            let p3 = if params3 == 0 {
                0.0
            } else {
                (remaining / ((3.0 - base) * params3 as f64)).clamp(0.0, 1.0)
            };

            let mut sel4 = vec![false; n];
            for &i in &order[..m4] {
                sel4[i] = true;
            }
            let sel3: Vec<bool> = sel4.iter().map(|&s| !s).collect();
            let or4 = mean_or(matrices, &sel4);
            let or3 = mean_or(matrices, &sel3);
            let m3 = n - m4;
            // Paper Eq. 7.
            let score = or4 * cfg.ps4 * p4 * m4 as f64 + or3 * cfg.ps3 * p3 * m3 as f64;

            let mut assignments = vec![
                Assignment { class: MatrixClass::Lo, hi_fraction: 0.0 };
                n
            ];
            for &i in &order[..m4] {
                assignments[i] = Assignment { class: MatrixClass::Mix4, hi_fraction: p4 };
            }
            for &i in &order[m4..] {
                assignments[i] = if p3 > 0.0 {
                    Assignment { class: MatrixClass::Mix3, hi_fraction: p3 }
                } else {
                    Assignment { class: MatrixClass::Lo, hi_fraction: 0.0 }
                };
            }
            let achieved: f64 = assignments
                .iter()
                .zip(matrices)
                .map(|(a, m)| a.equivalent_bits(cfg.base_bits) * m.params as f64)
                .sum::<f64>()
                / total_params as f64;
            if achieved > cfg.target_bits * (1.0 + 1e-6) {
                continue;
            }
            let cand = SearchResult { assignments, score, achieved_bits: achieved };
            if best.as_ref().map(|b| cand.score > b.score).unwrap_or(true) {
                best = Some(cand);
            }
        }
    }
    best.expect("search space non-empty (Lo-only is always feasible)")
}

// ---------------------------------------------------------------------------
// `claq tune` — measured per-layer bit-budget allocation (DESIGN.md §16)
// ---------------------------------------------------------------------------

/// The autotuner's search space: one adaptive-precision [`BitPair`] shared
/// by every layer, a global equivalent-bits target, and the granularity at
/// which budget is handed out (per-layer targets land on the `step_bits`
/// grid, except when a layer saturates at `hi`).
#[derive(Clone, Copy, Debug)]
pub struct TuneSpace {
    pub pair: crate::quant::precision::BitPair,
    /// Global parameter-weighted equivalent-bits target across all layers.
    pub target_bits: f64,
    /// Allocation granularity in equivalent bits (e.g. 0.125).
    pub step_bits: f64,
}

/// One layer's measured response to precision: the perplexity drop per
/// equivalent bit added to this layer (from the lo→hi probe run against
/// `perplexity_exec`), and its parameter count (budget accounting weight).
#[derive(Clone, Debug)]
pub struct LayerSensitivity {
    pub layer: usize,
    pub params: usize,
    pub ppl_drop_per_bit: f64,
}

/// Greedy per-layer target allocation under a global equivalent-bits
/// budget. Layers are ranked by marginal utility density — measured
/// perplexity drop per bit·param (`ppl_drop_per_bit / params`) — and
/// filled to `hi` in that order until the budget `(target - lo) ·
/// Σparams` runs out; partial grants snap *down* to the `step_bits` grid
/// so the achieved average never exceeds the target. Layers with
/// non-positive measured sensitivity stay at `lo` (promoting them spends
/// budget for no measured gain), so the achieved average may undershoot
/// the target when few layers respond. Deterministic: ties in density
/// break toward the lower layer index.
pub fn allocate_layer_targets(space: &TuneSpace, layers: &[LayerSensitivity]) -> Vec<f64> {
    assert!(!layers.is_empty(), "no layers to allocate over");
    assert!(space.step_bits > 0.0, "step_bits must be positive");
    let lo = space.pair.lo as f64;
    let hi = space.pair.hi as f64;
    assert!(
        lo <= space.target_bits && space.target_bits <= hi,
        "target {} outside [{lo}, {hi}]",
        space.target_bits
    );

    let density = |l: &LayerSensitivity| l.ppl_drop_per_bit / l.params.max(1) as f64;
    let mut order: Vec<usize> =
        (0..layers.len()).filter(|&i| layers[i].ppl_drop_per_bit > 0.0).collect();
    order.sort_by(|&a, &b| {
        density(&layers[b]).partial_cmp(&density(&layers[a])).unwrap().then(a.cmp(&b))
    });

    let total: f64 = layers.iter().map(|l| l.params as f64).sum();
    let mut budget = (space.target_bits - lo) * total; // bit·params to hand out
    let mut targets = vec![lo; layers.len()];
    for &i in &order {
        if budget <= 1e-9 {
            break;
        }
        let params = layers[i].params.max(1) as f64;
        let mut grant = (hi - lo).min(budget / params);
        if grant < hi - lo {
            // partial grant: snap down to the step grid
            grant = (grant / space.step_bits).floor() * space.step_bits;
        }
        if grant <= 0.0 {
            continue;
        }
        targets[i] = lo + grant;
        budget -= grant * params;
    }
    targets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::precision::BitPair;

    fn mk(n: usize, spread: f64) -> Vec<MatrixInfo> {
        (0..n)
            .map(|i| MatrixInfo {
                name: format!("m{i}"),
                // descending outlier ratios with the given spread
                outlier_ratio: 0.05 + spread * (n - i) as f64 / n as f64,
                params: 4096,
            })
            .collect()
    }

    #[test]
    fn respects_budget() {
        let ms = mk(16, 0.2);
        let cfg = SearchConfig { target_bits: 2.5, ..Default::default() };
        let r = search(&ms, &cfg);
        assert!(r.achieved_bits <= 2.5 + 1e-6, "got {}", r.achieved_bits);
        assert!(r.achieved_bits > 2.2, "budget underused: {}", r.achieved_bits);
    }

    #[test]
    fn high_outlier_matrices_get_mix4() {
        let ms = mk(10, 0.5);
        let r = search(&ms, &SearchConfig::default());
        // wherever Mix4 is assigned, it must be on the highest-OR matrices
        let min_or_mix4 = r
            .assignments
            .iter()
            .zip(&ms)
            .filter(|(a, _)| a.class == MatrixClass::Mix4)
            .map(|(_, m)| m.outlier_ratio)
            .fold(f64::INFINITY, f64::min);
        let max_or_other = r
            .assignments
            .iter()
            .zip(&ms)
            .filter(|(a, _)| a.class != MatrixClass::Mix4)
            .map(|(_, m)| m.outlier_ratio)
            .fold(0.0, f64::max);
        if min_or_mix4.is_finite() {
            assert!(min_or_mix4 >= max_or_other);
        }
    }

    #[test]
    fn small_budget_prefers_max_mix4_paper_observation() {
        // "in scenarios where the incremental bit-width is modest (2.1),
        //  the search results favor ... 2&4-bit matrices"
        let ms = mk(12, 0.3);
        let cfg = SearchConfig { target_bits: 2.1, ..Default::default() };
        let r = search(&ms, &cfg);
        let n4 = r.assignments.iter().filter(|a| a.class == MatrixClass::Mix4).count();
        assert!(n4 >= 1, "expected some 2&4 matrices at 2.1 bits");
    }

    #[test]
    fn equivalent_bits_formula() {
        let a = Assignment { class: MatrixClass::Mix4, hi_fraction: 0.25 };
        assert!((a.equivalent_bits(2) - 2.5).abs() < 1e-12);
        let b = Assignment { class: MatrixClass::Mix3, hi_fraction: 0.5 };
        assert!((b.equivalent_bits(2) - 2.5).abs() < 1e-12);
        let c = Assignment { class: MatrixClass::Lo, hi_fraction: 0.0 };
        assert!((c.equivalent_bits(2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn score_beats_uniform_assignment() {
        // The chosen config's score must be at least that of "all Mix3 at
        // uniform fraction", which is in the search space.
        let ms = mk(8, 0.4);
        let cfg = SearchConfig::default();
        let r = search(&ms, &cfg);
        let uniform_p3 = ((cfg.target_bits - 2.0) / 1.0).clamp(0.0, 1.0);
        let or_all: f64 = ms.iter().map(|m| m.outlier_ratio).sum::<f64>() / ms.len() as f64;
        let uniform_score = or_all * cfg.ps3 * uniform_p3 * ms.len() as f64;
        assert!(r.score >= uniform_score - 1e-9);
    }

    fn sens(drops: &[f64]) -> Vec<LayerSensitivity> {
        drops
            .iter()
            .enumerate()
            .map(|(layer, &d)| LayerSensitivity { layer, params: 1000, ppl_drop_per_bit: d })
            .collect()
    }

    fn weighted_mean(targets: &[f64], layers: &[LayerSensitivity]) -> f64 {
        let total: f64 = layers.iter().map(|l| l.params as f64).sum();
        targets.iter().zip(layers).map(|(t, l)| t * l.params as f64).sum::<f64>() / total
    }

    #[test]
    fn tune_allocation_respects_budget_and_bounds() {
        let layers = sens(&[5.0, 1.0, 0.2, 0.0]);
        let space =
            TuneSpace { pair: BitPair::new(4, 2), target_bits: 2.5, step_bits: 0.125 };
        let targets = allocate_layer_targets(&space, &layers);
        assert!(targets.iter().all(|&t| (2.0..=4.0).contains(&t)), "{targets:?}");
        let mean = weighted_mean(&targets, &layers);
        assert!(mean <= 2.5 + 1e-9, "over budget: {mean}");
        // budget = 0.5·4000 bit·params; the most sensitive layer absorbs
        // exactly all of it by saturating to hi
        assert_eq!(targets, vec![4.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn tune_allocation_prefers_sensitive_layers() {
        let layers = sens(&[0.3, 2.0, 0.1, 0.7]);
        let space =
            TuneSpace { pair: BitPair::new(4, 2), target_bits: 2.75, step_bits: 0.125 };
        let targets = allocate_layer_targets(&space, &layers);
        // fill order must follow sensitivity order: 1, 3, 0, 2
        assert!(targets[1] >= targets[3] && targets[3] >= targets[0] && targets[0] >= targets[2]);
        assert_eq!(targets[1], 4.0, "most sensitive layer saturates first: {targets:?}");
    }

    #[test]
    fn tune_allocation_zero_sensitivity_stays_lo() {
        let layers = sens(&[0.0, -0.1, 0.0]);
        let space =
            TuneSpace { pair: BitPair::new(4, 2), target_bits: 3.0, step_bits: 0.25 };
        // nothing measured as responding: keep every layer at lo rather
        // than spending bits for no gain
        assert_eq!(allocate_layer_targets(&space, &layers), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn tune_allocation_snaps_partial_grants_to_step_grid() {
        let layers = sens(&[1.0, 0.5]);
        let space =
            TuneSpace { pair: BitPair::new(4, 2), target_bits: 2.3, step_bits: 0.25 };
        let targets = allocate_layer_targets(&space, &layers);
        // budget 0.6·2000: layer 0 gets 0.6 snapped down to 0.5; the
        // 0.1-bit remainder is below one step on layer 1
        assert_eq!(targets, vec![2.5, 2.0]);
        for t in &targets {
            let frac = (t - 2.0) / 0.25;
            assert!((frac - frac.round()).abs() < 1e-9, "off-grid target {t}");
        }
        let mean = weighted_mean(&targets, &layers);
        assert!(mean <= 2.3 + 1e-9 && mean >= 2.3 - 0.25, "mean {mean}");
    }

    #[test]
    fn tune_allocation_full_budget_saturates_everything() {
        let layers = sens(&[0.4, 0.2, 0.9]);
        let space =
            TuneSpace { pair: BitPair::new(4, 2), target_bits: 4.0, step_bits: 0.125 };
        assert_eq!(allocate_layer_targets(&space, &layers), vec![4.0, 4.0, 4.0]);
    }
}
