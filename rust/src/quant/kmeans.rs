//! 1-D K-Means clustering — the paper's §3.1 centroid generator.
//!
//! The clustering samples are the entries of one weight-matrix column; the
//! `2^bits` cluster centroids become that column's quantization codebook
//! (paper Eq. 1–2). The paper calls into scikit-learn-intelex; this is a
//! from-scratch implementation: k-means++ seeding followed by Lloyd
//! iterations, specialized for 1-D where sorting the inputs makes each
//! Lloyd step a linear merge instead of an O(n·k) nearest-centroid scan.

use crate::quant::codebook::Codebook;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct KMeansOpts {
    pub max_iters: usize,
    /// Stop when no centroid moves more than this.
    pub tol: f64,
    /// Seed for k-means++ sampling (deterministic per column by default).
    pub seed: u64,
}

impl Default for KMeansOpts {
    fn default() -> Self {
        Self { max_iters: 50, tol: 1e-7, seed: 0x5EED }
    }
}

/// Result of clustering one column.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub codebook: Codebook,
    pub inertia: f64,
    pub iters: usize,
}

/// K-means++ seeding on sorted values. Returns `k` initial centroids
/// (ascending). `values` must be non-empty and sorted.
fn kmeanspp_init(sorted: &[f32], k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = sorted.len();
    let mut centroids: Vec<f64> = Vec::with_capacity(k);
    centroids.push(sorted[rng.below_usize(n)] as f64);
    // d2[i] = squared distance of point i to its nearest chosen centroid
    let mut d2: Vec<f64> = sorted
        .iter()
        .map(|&x| {
            let d = x as f64 - centroids[0];
            d * d
        })
        .collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with chosen centroids; pick uniformly.
            sorted[rng.below_usize(n)] as f64
        } else {
            let mut t = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = i;
                    break;
                }
            }
            sorted[pick] as f64
        };
        centroids.push(next);
        for (i, &x) in sorted.iter().enumerate() {
            let d = x as f64 - next;
            let dd = d * d;
            if dd < d2[i] {
                d2[i] = dd;
            }
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centroids
}

/// One Lloyd step over sorted values with sorted centroids. Assignment
/// boundaries are centroid midpoints, so points map to clusters with a
/// single linear sweep. Returns (new centroids asc, inertia, moved).
fn lloyd_step(sorted: &[f32], centroids: &mut Vec<f64>, counts: &mut Vec<usize>, sums: &mut Vec<f64>) -> (f64, f64) {
    let k = centroids.len();
    counts.clear();
    counts.resize(k, 0);
    sums.clear();
    sums.resize(k, 0.0);
    let mut inertia = 0.0f64;
    let mut c = 0usize;
    for &xf in sorted {
        let x = xf as f64;
        // advance cluster while the next centroid is closer
        while c + 1 < k && (centroids[c + 1] - x).abs() <= (x - centroids[c]).abs() {
            c += 1;
        }
        // `c` is monotone over sorted x, but when x jumps back is impossible
        counts[c] += 1;
        sums[c] += x;
        let d = x - centroids[c];
        inertia += d * d;
    }
    let mut moved = 0.0f64;
    for i in 0..k {
        if counts[i] > 0 {
            let nc = sums[i] / counts[i] as f64;
            moved = moved.max((nc - centroids[i]).abs());
            centroids[i] = nc;
        }
        // empty clusters handled by caller (reseed)
    }
    (inertia, moved)
}

/// Reseed any empty cluster at the point farthest from its centroid within
/// the largest cluster — standard Lloyd empty-cluster repair, 1-D flavour:
/// split the widest cluster at its extreme.
fn repair_empty(sorted: &[f32], centroids: &mut [f64], counts: &[usize]) -> bool {
    let mut repaired = false;
    for i in 0..centroids.len() {
        if counts[i] == 0 {
            // find the largest-spread cluster boundary pair to split
            let (mut best_j, mut best_spread) = (0usize, -1.0f64);
            for j in 0..centroids.len() {
                if counts[j] > 1 {
                    let spread = counts[j] as f64;
                    if spread > best_spread {
                        best_spread = spread;
                        best_j = j;
                    }
                }
            }
            if best_spread <= 0.0 {
                // Degenerate (fewer distinct points than clusters); place at
                // an arbitrary data point to keep the codebook well-formed.
                centroids[i] = sorted[0] as f64;
                continue;
            }
            centroids[i] = centroids[best_j] + 1e-6 + (i as f64) * 1e-9;
            repaired = true;
        }
    }
    if repaired {
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    repaired
}

/// Cluster `values` into `k` centroids. Not-a-number inputs are rejected by
/// debug assertion; empty input yields a single zero centroid codebook.
pub fn kmeans_1d(values: &[f32], k: usize, opts: &KMeansOpts) -> KMeansResult {
    assert!(k >= 1, "k must be >= 1");
    if values.is_empty() {
        return KMeansResult { codebook: Codebook::new(vec![0.0; k]), inertia: 0.0, iters: 0 };
    }
    debug_assert!(values.iter().all(|v| v.is_finite()), "non-finite weight");
    let mut sorted: Vec<f32> = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Degenerate: constant column → all centroids equal that value.
    if sorted[0] == sorted[sorted.len() - 1] {
        return KMeansResult {
            codebook: Codebook::new(vec![sorted[0]; k]),
            inertia: 0.0,
            iters: 0,
        };
    }

    let mut rng = Rng::new(opts.seed ^ (values.len() as u64).rotate_left(17));
    let mut centroids = kmeanspp_init(&sorted, k, &mut rng);
    let mut counts: Vec<usize> = Vec::with_capacity(k);
    let mut sums: Vec<f64> = Vec::with_capacity(k);
    let mut inertia = f64::INFINITY;
    let mut iters = 0usize;
    for it in 0..opts.max_iters {
        iters = it + 1;
        let (in_, moved) = lloyd_step(&sorted, &mut centroids, &mut counts, &mut sums);
        inertia = in_;
        let repaired = repair_empty(&sorted, &mut centroids, &counts);
        if !repaired && moved < opts.tol {
            break;
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    KMeansResult {
        codebook: Codebook::new(centroids.iter().map(|&c| c as f32).collect()),
        inertia,
        iters,
    }
}

/// Total squared quantization error of `values` against a codebook.
pub fn inertia(values: &[f32], cb: &Codebook) -> f64 {
    values
        .iter()
        .map(|&x| {
            let q = cb.dequantize(cb.quantize(x));
            let d = x as f64 - q as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::uniform_codebook;
    use crate::util::proptest::{check_default, gen_column};

    #[test]
    fn recovers_separated_clusters() {
        // Three well-separated blobs; k=3 must land near the blob means.
        let mut vals = Vec::new();
        for i in 0..100 {
            vals.push(-1.0 + 0.001 * (i as f32));
            vals.push(0.0 + 0.001 * (i as f32));
            vals.push(5.0 + 0.001 * (i as f32));
        }
        let r = kmeans_1d(&vals, 3, &KMeansOpts::default());
        let c = &r.codebook.centroids;
        assert!((c[0] - -0.95).abs() < 0.1, "{c:?}");
        assert!((c[1] - 0.05).abs() < 0.1, "{c:?}");
        assert!((c[2] - 5.05).abs() < 0.1, "{c:?}");
    }

    #[test]
    fn constant_column() {
        let vals = vec![0.5f32; 64];
        let r = kmeans_1d(&vals, 4, &KMeansOpts::default());
        assert_eq!(r.inertia, 0.0);
        assert!(r.codebook.centroids.iter().all(|&c| c == 0.5));
    }

    #[test]
    fn k_larger_than_distinct_values() {
        let vals = vec![1.0f32, 2.0, 1.0, 2.0];
        let r = kmeans_1d(&vals, 8, &KMeansOpts::default());
        // must quantize each point exactly
        assert!(inertia(&vals, &r.codebook) < 1e-10);
    }

    #[test]
    fn beats_uniform_on_outlier_columns() {
        // The paper's core claim (§3.1): K-Means codebooks track the true
        // distribution better than uniform levels, especially with outliers.
        let mut rng = crate::util::rng::Rng::new(7);
        let col = gen_column(&mut rng, 2048, 0.01);
        let k = 8; // 3-bit
        let km = kmeans_1d(&col, k, &KMeansOpts::default());
        let uni = uniform_codebook(&col, k);
        let e_km = inertia(&col, &km.codebook);
        let e_uni = inertia(&col, &uni);
        assert!(
            e_km < e_uni * 0.8,
            "kmeans {e_km} should beat uniform {e_uni} clearly"
        );
    }

    #[test]
    fn centroids_sorted_ascending() {
        check_default("kmeans centroids sorted", |rng| {
            let n = 16 + rng.below_usize(256);
            let col = gen_column(rng, n, 0.02);
            let bits = 1 + rng.below_usize(4); // 1..=4 bits
            let r = kmeans_1d(&col, 1 << bits, &KMeansOpts::default());
            let c = &r.codebook.centroids;
            for w in c.windows(2) {
                assert!(w[0] <= w[1], "unsorted centroids {c:?}");
            }
        });
    }

    #[test]
    fn lloyd_never_increases_inertia() {
        check_default("lloyd monotone", |rng| {
            let n = 128 + rng.below_usize(128);
            let col = gen_column(rng, n, 0.02);
            let mut sorted = col.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut centroids = kmeanspp_init(&sorted, 8, rng);
            let mut counts = Vec::new();
            let mut sums = Vec::new();
            let mut prev = f64::INFINITY;
            for _ in 0..10 {
                let (inertia, _) = lloyd_step(&sorted, &mut centroids, &mut counts, &mut sums);
                // Lloyd's algorithm is monotone when no repair happens.
                if repair_empty(&sorted, &mut centroids, &counts) {
                    prev = f64::INFINITY; // repair may bump inertia; reset
                    continue;
                }
                assert!(
                    inertia <= prev + 1e-9,
                    "inertia increased {prev} -> {inertia}"
                );
                prev = inertia;
            }
        });
    }

    #[test]
    fn quantize_matches_nearest_centroid() {
        check_default("nearest centroid", |rng| {
            let col = gen_column(rng, 200, 0.02);
            let r = kmeans_1d(&col, 4, &KMeansOpts::default());
            let cb = &r.codebook;
            for &x in col.iter().take(50) {
                let qi = cb.quantize(x) as usize;
                let qd = (cb.centroids[qi] - x).abs();
                for &c in &cb.centroids {
                    assert!(qd <= (c - x).abs() + 1e-6);
                }
            }
        });
    }
}
