//! 1-D K-Means clustering — the paper's §3.1 centroid generator.
//!
//! The clustering samples are the entries of one weight-matrix column; the
//! `2^bits` cluster centroids become that column's quantization codebook
//! (paper Eq. 1–2). The paper calls into scikit-learn-intelex; this is a
//! from-scratch implementation: k-means++ seeding followed by Lloyd
//! iterations, specialized for 1-D where sorting the inputs makes each
//! Lloyd step a linear merge instead of an O(n·k) nearest-centroid scan.
//!
//! The quantizer calls this once per column, so the working buffers matter:
//! [`kmeans_1d_into`] runs entirely out of a caller-owned
//! [`KMeansScratch`], making repeat calls allocation-free in steady state
//! (the output codebook is the one remaining allocation).

use crate::quant::codebook::Codebook;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct KMeansOpts {
    pub max_iters: usize,
    /// Stop when no centroid moves more than this.
    pub tol: f64,
    /// Seed for k-means++ sampling (deterministic per column by default).
    pub seed: u64,
}

impl Default for KMeansOpts {
    fn default() -> Self {
        Self { max_iters: 50, tol: 1e-7, seed: 0x5EED }
    }
}

/// Result of clustering one column.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub codebook: Codebook,
    pub inertia: f64,
    pub iters: usize,
}

/// Reusable clustering workspace: sorted input copy, k-means++ distance
/// table, and the Lloyd accumulators. One instance serves any sequence of
/// [`kmeans_1d_into`] calls; buffers grow to the largest column seen and
/// are then recycled.
#[derive(Default)]
pub struct KMeansScratch {
    sorted: Vec<f32>,
    /// d2[i] = squared distance of point i to its nearest chosen centroid.
    d2: Vec<f64>,
    centroids: Vec<f64>,
    counts: Vec<usize>,
    sums: Vec<f64>,
}

impl KMeansScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// K-means++ seeding on sorted values: writes `k` initial centroids
/// (ascending) into `centroids`, using `d2` as the distance table.
/// `sorted` must be non-empty and sorted.
fn kmeanspp_init(sorted: &[f32], k: usize, rng: &mut Rng, centroids: &mut Vec<f64>, d2: &mut Vec<f64>) {
    let n = sorted.len();
    centroids.clear();
    centroids.reserve(k);
    centroids.push(sorted[rng.below_usize(n)] as f64);
    d2.clear();
    d2.extend(sorted.iter().map(|&x| {
        let d = x as f64 - centroids[0];
        d * d
    }));
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with chosen centroids; pick uniformly.
            sorted[rng.below_usize(n)] as f64
        } else {
            let mut t = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = i;
                    break;
                }
            }
            sorted[pick] as f64
        };
        centroids.push(next);
        for (i, &x) in sorted.iter().enumerate() {
            let d = x as f64 - next;
            let dd = d * d;
            if dd < d2[i] {
                d2[i] = dd;
            }
        }
    }
    centroids.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
}

/// One Lloyd step over sorted values with sorted centroids. Assignment
/// boundaries are centroid midpoints, so points map to clusters with a
/// single linear sweep. Returns (new centroids asc, inertia, moved).
fn lloyd_step(sorted: &[f32], centroids: &mut Vec<f64>, counts: &mut Vec<usize>, sums: &mut Vec<f64>) -> (f64, f64) {
    let k = centroids.len();
    counts.clear();
    counts.resize(k, 0);
    sums.clear();
    sums.resize(k, 0.0);
    let mut inertia = 0.0f64;
    let mut c = 0usize;
    for &xf in sorted {
        let x = xf as f64;
        // advance cluster while the next centroid is closer
        while c + 1 < k && (centroids[c + 1] - x).abs() <= (x - centroids[c]).abs() {
            c += 1;
        }
        // `c` is monotone over sorted x, but when x jumps back is impossible
        counts[c] += 1;
        sums[c] += x;
        let d = x - centroids[c];
        inertia += d * d;
    }
    let mut moved = 0.0f64;
    for i in 0..k {
        if counts[i] > 0 {
            let nc = sums[i] / counts[i] as f64;
            moved = moved.max((nc - centroids[i]).abs());
            centroids[i] = nc;
        }
        // empty clusters handled by caller (reseed)
    }
    (inertia, moved)
}

/// Reseed empty clusters by splitting the widest populated cluster at its
/// extreme: the repaired centroid is placed exactly on the member of that
/// cluster farthest from its centroid, so the donor sheds its worst-fit
/// point at the next assignment. Cluster `i` owns the contiguous run of
/// `sorted` given by the prefix sums of `counts` (assignment is monotone
/// over sorted input), so the candidate extremes are the run's endpoints.
/// Each donor is used at most once per pass; when no populated cluster has
/// ≥ 2 members and nonzero spread (fewer distinct points than clusters),
/// the centroid falls back to the smallest data point, which keeps the
/// codebook well-formed without counting as a repair.
fn repair_empty(sorted: &[f32], centroids: &mut [f64], counts: &[usize]) -> bool {
    let k = centroids.len();
    debug_assert_eq!(counts.len(), k);
    if counts.iter().all(|&c| c > 0) {
        return false;
    }
    let mut consumed = vec![false; k]; // rare path: empty clusters only
    let mut repaired = false;
    for i in 0..k {
        if counts[i] > 0 {
            continue;
        }
        // Widest donor: the populated cluster whose extreme member lies
        // farthest from its (freshly updated) centroid.
        let mut best: Option<(usize, f64, f64)> = None; // (donor, spread, extreme)
        let mut start = 0usize;
        for (j, &cnt) in counts.iter().enumerate() {
            if cnt >= 2 && !consumed[j] {
                let lo = sorted[start] as f64;
                let hi = sorted[start + cnt - 1] as f64;
                let c = centroids[j];
                let (spread, extreme) = if (hi - c).abs() >= (c - lo).abs() {
                    ((hi - c).abs(), hi)
                } else {
                    ((c - lo).abs(), lo)
                };
                if spread > 0.0 && best.is_none_or(|(_, bs, _)| spread > bs) {
                    best = Some((j, spread, extreme));
                }
            }
            start += cnt;
        }
        match best {
            Some((donor, _, extreme)) => {
                centroids[i] = extreme;
                consumed[donor] = true;
                repaired = true;
            }
            // Degenerate (fewer distinct points than clusters); place at
            // an arbitrary data point to keep the codebook well-formed.
            // Doesn't count as a repair (no reassignment worth iterating
            // for), but still needs the re-sort below.
            None => centroids[i] = sorted[0] as f64,
        }
    }
    // At least one empty cluster was filled (the early return above rules
    // out the none-empty case), and any placement can break the ascending
    // order the Lloyd sweep depends on — a degenerate placement lands the
    // minimum at an arbitrary index — so always restore it.
    centroids.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    repaired
}

/// Cluster `values` into `k` centroids. Not-a-number inputs are rejected by
/// debug assertion; empty input yields a single zero centroid codebook.
/// Allocates a fresh workspace per call — hot loops should hold a
/// [`KMeansScratch`] and call [`kmeans_1d_into`] instead.
pub fn kmeans_1d(values: &[f32], k: usize, opts: &KMeansOpts) -> KMeansResult {
    kmeans_1d_into(values, k, opts, &mut KMeansScratch::new())
}

/// [`kmeans_1d`] running out of a caller-owned workspace: zero heap
/// allocations in steady state besides the returned codebook.
pub fn kmeans_1d_into(
    values: &[f32],
    k: usize,
    opts: &KMeansOpts,
    scratch: &mut KMeansScratch,
) -> KMeansResult {
    assert!(k >= 1, "k must be >= 1");
    if values.is_empty() {
        return KMeansResult { codebook: Codebook::new(vec![0.0; k]), inertia: 0.0, iters: 0 };
    }
    debug_assert!(values.iter().all(|v| v.is_finite()), "non-finite weight");
    let KMeansScratch { sorted, d2, centroids, counts, sums } = scratch;
    sorted.clear();
    sorted.extend_from_slice(values);
    sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());

    // Degenerate: constant column → all centroids equal that value.
    if sorted[0] == sorted[sorted.len() - 1] {
        return KMeansResult {
            codebook: Codebook::new(vec![sorted[0]; k]),
            inertia: 0.0,
            iters: 0,
        };
    }

    let mut rng = Rng::new(opts.seed ^ (values.len() as u64).rotate_left(17));
    kmeanspp_init(sorted, k, &mut rng, centroids, d2);
    let mut inertia = f64::INFINITY;
    let mut iters = 0usize;
    for it in 0..opts.max_iters {
        iters = it + 1;
        let (in_, moved) = lloyd_step(sorted, centroids, counts, sums);
        inertia = in_;
        let repaired = repair_empty(sorted, centroids, counts);
        if !repaired && moved < opts.tol {
            break;
        }
    }
    centroids.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    KMeansResult {
        codebook: Codebook::new(centroids.iter().map(|&c| c as f32).collect()),
        inertia,
        iters,
    }
}

/// Total squared quantization error of `values` against a codebook.
pub fn inertia(values: &[f32], cb: &Codebook) -> f64 {
    values
        .iter()
        .map(|&x| {
            let q = cb.dequantize(cb.quantize(x));
            let d = x as f64 - q as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::uniform_codebook;
    use crate::util::proptest::{check_default, gen_column};

    #[test]
    fn recovers_separated_clusters() {
        // Three well-separated blobs; k=3 must land near the blob means.
        let mut vals = Vec::new();
        for i in 0..100 {
            vals.push(-1.0 + 0.001 * (i as f32));
            vals.push(0.0 + 0.001 * (i as f32));
            vals.push(5.0 + 0.001 * (i as f32));
        }
        let r = kmeans_1d(&vals, 3, &KMeansOpts::default());
        let c = &r.codebook.centroids;
        assert!((c[0] - -0.95).abs() < 0.1, "{c:?}");
        assert!((c[1] - 0.05).abs() < 0.1, "{c:?}");
        assert!((c[2] - 5.05).abs() < 0.1, "{c:?}");
    }

    #[test]
    fn constant_column() {
        let vals = vec![0.5f32; 64];
        let r = kmeans_1d(&vals, 4, &KMeansOpts::default());
        assert_eq!(r.inertia, 0.0);
        assert!(r.codebook.centroids.iter().all(|&c| c == 0.5));
    }

    #[test]
    fn k_larger_than_distinct_values() {
        let vals = vec![1.0f32, 2.0, 1.0, 2.0];
        let r = kmeans_1d(&vals, 8, &KMeansOpts::default());
        // must quantize each point exactly
        assert!(inertia(&vals, &r.codebook) < 1e-10);
    }

    #[test]
    fn beats_uniform_on_outlier_columns() {
        // The paper's core claim (§3.1): K-Means codebooks track the true
        // distribution better than uniform levels, especially with outliers.
        let mut rng = crate::util::rng::Rng::new(7);
        let col = gen_column(&mut rng, 2048, 0.01);
        let k = 8; // 3-bit
        let km = kmeans_1d(&col, k, &KMeansOpts::default());
        let uni = uniform_codebook(&col, k);
        let e_km = inertia(&col, &km.codebook);
        let e_uni = inertia(&col, &uni);
        assert!(
            e_km < e_uni * 0.8,
            "kmeans {e_km} should beat uniform {e_uni} clearly"
        );
    }

    #[test]
    fn centroids_sorted_ascending() {
        check_default("kmeans centroids sorted", |rng| {
            let n = 16 + rng.below_usize(256);
            let col = gen_column(rng, n, 0.02);
            let bits = 1 + rng.below_usize(4); // 1..=4 bits
            let r = kmeans_1d(&col, 1 << bits, &KMeansOpts::default());
            let c = &r.codebook.centroids;
            for w in c.windows(2) {
                assert!(w[0] <= w[1], "unsorted centroids {c:?}");
            }
        });
    }

    #[test]
    fn scratch_reuse_matches_fresh_alloc() {
        // kmeans_1d_into with a recycled workspace (columns of varying
        // sizes, in sequence) must equal kmeans_1d exactly.
        check_default("kmeans scratch reuse", |rng| {
            let mut scratch = KMeansScratch::new();
            for _ in 0..4 {
                let n = 8 + rng.below_usize(300);
                let col = gen_column(rng, n, 0.02);
                let k = 1 << (1 + rng.below_usize(4));
                let a = kmeans_1d(&col, k, &KMeansOpts::default());
                let b = kmeans_1d_into(&col, k, &KMeansOpts::default(), &mut scratch);
                assert_eq!(a.codebook.centroids, b.codebook.centroids);
                assert_eq!(a.inertia.to_bits(), b.inertia.to_bits());
                assert_eq!(a.iters, b.iters);
            }
        });
    }

    #[test]
    fn repair_places_centroid_on_widest_cluster_extreme() {
        // Cluster layout (assignment boundaries are centroid midpoints):
        // centroid 2.0 owns {0,1,2,3,4}, centroid 30.0 owns {20},
        // centroid 100.0 is empty. The widest populated cluster is the
        // first one; its extreme member (farthest from 2.0, ties toward
        // the high end) is 4.0 — the repaired centroid must land exactly
        // on that data point.
        let sorted = [0.0f32, 1.0, 2.0, 3.0, 4.0, 20.0];
        let mut centroids = vec![2.0f64, 30.0, 100.0];
        let counts = vec![5usize, 1, 0];
        let repaired = repair_empty(&sorted, &mut centroids, &counts);
        assert!(repaired);
        assert!(centroids.contains(&4.0), "expected split at 4.0, got {centroids:?}");
        for w in centroids.windows(2) {
            assert!(w[0] <= w[1], "centroids must stay sorted: {centroids:?}");
        }
        // and the new centroid is a data point, not an epsilon-offset copy
        for &c in &centroids {
            assert!(
                sorted.iter().any(|&x| x as f64 == c) || [2.0, 30.0].contains(&c),
                "repaired centroid {c} is neither a data point nor a survivor"
            );
        }
    }

    #[test]
    fn repair_prefers_farther_tail() {
        // One populated cluster whose low tail is farther from the mean
        // than the high tail: the repair must pick the low extreme.
        let sorted = [-10.0f32, 1.0, 2.0, 3.0];
        let mut centroids = vec![-1.0f64, 50.0];
        let counts = vec![4usize, 0];
        assert!(repair_empty(&sorted, &mut centroids, &counts));
        assert!(centroids.contains(&-10.0), "expected split at -10, got {centroids:?}");
    }

    #[test]
    fn lloyd_never_increases_inertia() {
        check_default("lloyd monotone", |rng| {
            let n = 128 + rng.below_usize(128);
            let col = gen_column(rng, n, 0.02);
            let mut sorted = col.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut centroids = Vec::new();
            let mut d2 = Vec::new();
            kmeanspp_init(&sorted, 8, rng, &mut centroids, &mut d2);
            let mut counts = Vec::new();
            let mut sums = Vec::new();
            let mut prev = f64::INFINITY;
            for _ in 0..10 {
                let (inertia, _) = lloyd_step(&sorted, &mut centroids, &mut counts, &mut sums);
                // Lloyd's algorithm is monotone when no repair happens.
                if repair_empty(&sorted, &mut centroids, &counts) {
                    prev = f64::INFINITY; // repair may bump inertia; reset
                    continue;
                }
                assert!(
                    inertia <= prev + 1e-9,
                    "inertia increased {prev} -> {inertia}"
                );
                prev = inertia;
            }
        });
    }

    #[test]
    fn quantize_matches_nearest_centroid() {
        check_default("nearest centroid", |rng| {
            let col = gen_column(rng, 200, 0.02);
            let r = kmeans_1d(&col, 4, &KMeansOpts::default());
            let cb = &r.codebook;
            for &x in col.iter().take(50) {
                let qi = cb.quantize(x) as usize;
                let qd = (cb.centroids[qi] - x).abs();
                for &c in &cb.centroids {
                    assert!(qd <= (c - x).abs() + 1e-6);
                }
            }
        });
    }
}
