//! Evaluation harnesses: perplexity (the paper's Table 1 metric) and
//! zero-shot multiple-choice accuracy (Table 2, lm-eval-harness
//! convention).

pub mod perplexity;
pub mod zeroshot;
