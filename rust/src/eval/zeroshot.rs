//! Zero-shot multiple-choice scoring, lm-eval-harness `acc_norm`
//! convention: each choice is scored by its length-normalized continuation
//! log-likelihood under the model; the argmax choice is the prediction.

use crate::data::tasks::TaskItem;
use crate::model::forward::{continuation_logprob, ForwardState};
use crate::model::Model;

/// Accuracy of `model` on a set of items.
pub fn accuracy(model: &Model, items: &[TaskItem]) -> f64 {
    let mut state = ForwardState::new(model.config);
    let mut correct = 0usize;
    for item in items {
        if predict(model, item, &mut state) == item.answer {
            correct += 1;
        }
    }
    correct as f64 / items.len().max(1) as f64
}

/// Predicted choice index for one item.
pub fn predict(model: &Model, item: &TaskItem, state: &mut ForwardState) -> usize {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, cont) in item.choices.iter().enumerate() {
        let lp = continuation_logprob(model, &item.prefix, cont, state) / cont.len() as f64;
        if lp > best.0 {
            best = (lp, ci);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusKind, VOCAB};
    use crate::data::tasks::{generate_task, TASKS};
    use crate::model::TransformerConfig;
    use crate::util::rng::Rng;

    #[test]
    fn random_model_near_chance() {
        let cfg = TransformerConfig {
            vocab: VOCAB,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let m = crate::model::Model::random(cfg, &mut Rng::new(2));
        // 2-choice task: untrained model should hover near 50%
        let items = generate_task(&TASKS[0], CorpusKind::SynthWiki, 60);
        let acc = accuracy(&m, &items);
        assert!(acc > 0.2 && acc < 0.8, "acc {acc}");
    }

    #[test]
    fn accuracy_bounds() {
        let cfg = TransformerConfig {
            vocab: VOCAB,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let m = crate::model::Model::random(cfg, &mut Rng::new(3));
        let items = generate_task(&TASKS[1], CorpusKind::SynthC4, 10);
        let acc = accuracy(&m, &items);
        assert!((0.0..=1.0).contains(&acc));
    }
}
