//! Perplexity evaluation. Follows the GPTQ/CLAQ protocol: the held-out
//! stream is cut into non-overlapping windows of the model's context
//! length; NLL is accumulated over every next-token prediction inside each
//! window; PPL = exp(total NLL / total predicted tokens).

use crate::model::exec::{prefill, ExecModel, ExecState, KvCache};
use crate::model::forward::{sequence_nll, ForwardState};
use crate::model::Model;
use crate::util::stats::log_sum_exp;

/// Perplexity result.
#[derive(Clone, Copy, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub windows: usize,
}

/// Evaluate perplexity of `model` on `stream`, using windows of the
/// model's `max_seq`. `max_windows` caps cost (0 = all).
pub fn perplexity(model: &Model, stream: &[u16], max_windows: usize) -> PplResult {
    let seq = model.config.max_seq;
    assert!(stream.len() >= seq, "stream shorter than one window");
    let mut state = ForwardState::new(model.config);
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let mut windows = 0usize;
    for chunk in stream.chunks_exact(seq) {
        let (nll, n) = sequence_nll(model, chunk, &mut state);
        total_nll += nll;
        total_tok += n;
        windows += 1;
        if max_windows > 0 && windows >= max_windows {
            break;
        }
    }
    let per_tok = total_nll / total_tok.max(1) as f64;
    PplResult { ppl: per_tok.exp(), nll_per_token: per_tok, tokens: total_tok, windows }
}

/// Perplexity through an [`ExecModel`] backend — the packed serving path
/// scores held-out text without ever materializing dense weights (for the
/// dense backend this mirrors [`perplexity`] exactly). Windows run through
/// [`prefill`] with a reset KV cache each.
pub fn perplexity_exec(model: &ExecModel, stream: &[u16], max_windows: usize) -> PplResult {
    let seq = model.config.max_seq;
    assert!(stream.len() >= seq, "stream shorter than one window");
    let mut state = ExecState::new(model.config);
    let mut cache = KvCache::new(&model.config);
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let mut windows = 0usize;
    for chunk in stream.chunks_exact(seq) {
        cache.reset();
        let logits = prefill(model, &mut cache, chunk, &mut state);
        for t in 0..seq - 1 {
            let row = logits.row(t);
            let lse = log_sum_exp(row);
            total_nll += lse - row[chunk[t + 1] as usize] as f64;
        }
        total_tok += seq - 1;
        windows += 1;
        if max_windows > 0 && windows >= max_windows {
            break;
        }
    }
    let per_tok = total_nll / total_tok.max(1) as f64;
    PplResult { ppl: per_tok.exp(), nll_per_token: per_tok, tokens: total_tok, windows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusKind, VOCAB};
    use crate::model::TransformerConfig;
    use crate::util::rng::Rng;

    fn small_model() -> Model {
        let cfg = TransformerConfig {
            vocab: VOCAB,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(1))
    }

    #[test]
    fn random_model_near_uniform_ppl() {
        let m = small_model();
        let stream = generate(CorpusKind::SynthWiki, 512, 1);
        let r = perplexity(&m, &stream, 0);
        // untrained model ≈ uniform over 256 tokens
        assert!(r.ppl > 100.0 && r.ppl < 600.0, "ppl {}", r.ppl);
        assert_eq!(r.windows, 512 / 32);
        assert_eq!(r.tokens, r.windows * 31);
    }

    #[test]
    fn max_windows_cap() {
        let m = small_model();
        let stream = generate(CorpusKind::SynthWiki, 512, 2);
        let r = perplexity(&m, &stream, 3);
        assert_eq!(r.windows, 3);
    }

    #[test]
    fn deterministic() {
        let m = small_model();
        let stream = generate(CorpusKind::SynthC4, 256, 3);
        let a = perplexity(&m, &stream, 0);
        let b = perplexity(&m, &stream, 0);
        assert_eq!(a.ppl, b.ppl);
    }

    #[test]
    fn exec_dense_matches_reference() {
        let m = small_model();
        let stream = generate(CorpusKind::SynthWiki, 256, 4);
        let a = perplexity(&m, &stream, 0);
        let b = perplexity_exec(&ExecModel::dense(&m), &stream, 0);
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.tokens, b.tokens);
        assert!((a.ppl / b.ppl - 1.0).abs() < 1e-5, "{} vs {}", a.ppl, b.ppl);
    }

    #[test]
    fn exec_packed_matches_dense_path() {
        // Acceptance gate: eval::perplexity on the packed path matches the
        // dense path to within float tolerance.
        use crate::model::quantized::QuantizedModel;
        use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
        use std::collections::HashMap;
        let m = small_model();
        let mut matrices = HashMap::new();
        for id in m.matrix_ids() {
            let w = m.matrix(id);
            let mut plan = MatrixPlan::uniform(w.cols, 3, CentroidRule::KMeans, false);
            plan.reserve = vec![2; w.cols];
            matrices.insert(id, quantize_matrix(w, None, &plan));
        }
        let qm = QuantizedModel {
            base: m.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: "test-3b".into(),
        };
        let stream = generate(CorpusKind::SynthC4, 256, 5);
        let dense = perplexity(&qm.to_dense(), &stream, 0);
        let packed = perplexity_exec(&qm.to_exec(), &stream, 0);
        assert!(
            (dense.ppl / packed.ppl - 1.0).abs() < 1e-4,
            "dense {} vs packed {}",
            dense.ppl,
            packed.ppl
        );
    }
}
