// `std::simd` is unstable; the `simd` cargo feature opts into it on a
// nightly toolchain. The default build uses the unrolled-scalar lanes in
// `model/linear.rs`, which are bit-identical to the SIMD path.
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # CLAQ — Column-Level Adaptive weight Quantization for LLMs
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *CLAQ: Pushing the Limits of Low-Bit Post-Training Quantization for
//! LLMs* (Wang et al., 2024). See DESIGN.md for the system inventory;
//! measured results live in the run registry (`artifacts/runs.csv`).
//!
//! * [`quant`] — the paper's contribution: K-Means codebooks, Outlier
//!   Order, adaptive precision, outlier reservation, fusion presets, plus
//!   the GPTQ substrate and the RTN/GPTQ/AWQ baselines.
//! * [`model`] — the LLaMA-style transformer the experiments quantize,
//!   including the `LinearOp` execution backends (dense f32 and packed
//!   CLAQ planes), the KV-cached serving path (`model::exec`), and the
//!   single-file `CLAQMD01` deployment checkpoint with cold-start loading
//!   (`model::checkpoint`, DESIGN.md §9).
//! * [`runtime`] — the serving layer: the continuous-batching scheduler
//!   with pooled KV caches (`runtime::scheduler`) and the PJRT executor
//!   for the AOT-compiled graphs.
//! * [`data`] — synthetic corpora / calibration / zero-shot tasks.
//! * [`eval`] — perplexity and zero-shot harnesses.
//! * [`tensor`], [`util`] — from-scratch substrates (matrix/linalg, RNG,
//!   stats, persistent thread pool, property tests, bench harness, CLI).

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tables;
pub mod tensor;
pub mod util;
