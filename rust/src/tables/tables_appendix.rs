//! Tables 12–13 — the heuristic AP search (Appendix G) and the
//! calibration-data ablation (Appendix H).

use super::runner::{emit, render_table, Harness, ModelKey, Row};
use crate::coordinator::pipeline::{quantize_model_heuristic, PipelineOpts};
use crate::data::corpus::CorpusKind;
use crate::eval::perplexity::perplexity;
use crate::eval::zeroshot::accuracy;
use crate::data::tasks::{generate_task, TASKS};
use crate::quant::config::{Method, DEFAULT_S};
use crate::quant::outliers::ColumnMetric;
use crate::quant::precision::BitPair;
use crate::quant::search::SearchConfig;
use anyhow::Result;

/// Table 12: plain dual-level AP vs the heuristic search at 2.5 bits.
pub fn table12(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    rows.push(h.fp16_row(ModelKey::TinyL, true, "table12")?);
    for m in [Method::Claq { bits: 3 }, Method::Claq { bits: 2 }] {
        rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table12")?);
    }
    let plain = Method::ClaqAp {
        pair: BitPair::new(4, 2),
        target_bits: 2.5,
        metric: ColumnMetric::OutlierRatio,
        s: DEFAULT_S,
    };
    eprintln!("[table12] plain AP 2.5");
    rows.push(h.run(ModelKey::TinyL, &plain, CorpusKind::SynthC4, true, "table12")?);

    // Heuristic search (its own pipeline entry point).
    eprintln!("[table12] heuristic search 2.5");
    let model = h.model(ModelKey::TinyL)?;
    let cfg = SearchConfig { target_bits: 2.5, ..Default::default() };
    let (qm, _, result) =
        quantize_model_heuristic(model, &cfg, DEFAULT_S, &h.calib_c4, &PipelineOpts::default());
    let dense = qm.to_dense();
    let rep = qm.size_report();
    let mut zeroshot = Vec::new();
    for spec in &TASKS {
        let items = generate_task(spec, CorpusKind::SynthWiki, h.budget.zs_items);
        zeroshot.push((spec.name.to_string(), accuracy(&dense, &items)));
    }
    rows.push(Row {
        model: ModelKey::TinyL.name().to_string(),
        method: "+AP(Heuristic search)".to_string(),
        nominal_bits: 2.5,
        achieved_bits: rep.paper_equivalent_bits,
        container_bits: rep.container_bits_per_param,
        ppl_wiki: perplexity(&dense, &h.held_wiki, h.budget.ppl_windows).ppl,
        ppl_c4: perplexity(&dense, &h.held_c4, h.budget.ppl_windows).ppl,
        zeroshot,
        mean_rel_err: qm.mean_rel_err(),
    });
    eprintln!(
        "[table12] search score {:.4}, achieved bits {:.3}",
        result.score, result.achieved_bits
    );
    emit(h, "table12", &render_table("Table 12 (App. G) — heuristic AP search @2.5", &rows, true))?;
    Ok(rows)
}

/// Table 13: calibration on synth-wiki vs synth-c4 (CLAQ 4 / 3 / 2).
pub fn table13(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    rows.push(h.fp16_row(ModelKey::TinyL, false, "table13")?);
    for bits in [4u8, 3, 2] {
        for calib in [CorpusKind::SynthWiki, CorpusKind::SynthC4] {
            let m = Method::Claq { bits };
            eprintln!("[table13] CLAQ-{bits} calibrated on {}", calib.name());
            let mut row = h.run(ModelKey::TinyL, &m, calib, false, "table13")?;
            row.method = format!("CLAQ-{bits} (calib {})", calib.name());
            rows.push(row);
        }
    }
    emit(h, "table13", &render_table("Table 13 (App. H) — calibration-set ablation", &rows, false))?;
    Ok(rows)
}
