//! CLI subcommand implementations: dispatch to the table/figure
//! generators, ad-hoc `quantize` / `eval` / `outliers` commands, and the
//! deployment pair `pack` (quantize once → single-file CLAQMD01
//! checkpoint) / `serve` (cold-start the packed engine from a checkpoint,
//! skipping calibration and quantization entirely).

use super::runner::{emit, render_table, Harness, ModelKey};
use super::{figures, tables_ablation, tables_appendix, tables_main};
use crate::coordinator::pipeline::{quantize_model, quantize_model_tuned, PipelineOpts};
use crate::coordinator::registry::artifacts_dir;
use crate::data::calibration::default_calibration;
use crate::data::corpus::{generate, CorpusKind};
use crate::eval::perplexity::perplexity_exec;
use crate::model::exec::{argmax, decode_step, prefill, ExecState, KvCache, DEFAULT_PAGE_TOKENS};
use crate::model::io::load_model;
use crate::model::{MatrixId, MatrixKind, Model, TransformerConfig};
use crate::quant::config::{Method, MethodSpec, DEFAULT_S};
use crate::quant::outliers::{ColumnMetric, OutlierStats};
use crate::quant::precision::BitPair;
use crate::quant::reservation::OrSetting;
use crate::quant::search::{allocate_layer_targets, LayerSensitivity, TuneSpace};
use crate::runtime::executor::ColdStart;
use crate::runtime::scheduler::{AdmissionPolicy, Request, Scheduler, SchedulerConfig};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Parse the adaptive-precision candidate pair from `--hi`/`--lo`
/// (defaulting to the paper's 4-bit high level and `floor(--bits)` low
/// level), validated here so a bad pair fails with a usage error instead
/// of a panic in `BitPair::new`.
fn parse_bit_pair(args: &Args, bits: f64) -> Result<BitPair> {
    let hi: u8 = args.get_parse_or("hi", 4).map_err(anyhow::Error::msg)?;
    let lo: u8 = args.get_parse_or("lo", bits.floor() as u8).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        (1..=8).contains(&lo) && lo < hi && hi <= 8,
        "--hi/--lo must satisfy 1 <= lo < hi <= 8 (got hi={hi}, lo={lo})"
    );
    anyhow::ensure!(
        (lo as f64) <= bits && bits <= hi as f64,
        "--bits {bits} is outside the [{lo}, {hi}] range of --lo/--hi — no column mix can hit it"
    );
    Ok(BitPair::new(hi, lo))
}

/// Parse `--method`. The front door is the typed spec grammar
/// (`quant/config.rs::MethodSpec`): anything containing a `:` — plus the
/// `fusion-X.YZ` presets and `fp16` — goes through `FromStr` with
/// parse-time validation and exhaustive errors, e.g.
/// `--method claq-ap:2+4@2.05`, `--method claq-vq:d4b2`,
/// `--method fusion-2.12`.
///
/// Bare legacy names (`claq`, `claq-ap`, …) still take the historical
/// `--bits B [--s S] [--setting N] [--hi H --lo L] [--group-dim D]` flag
/// spelling — kept as documented aliases for one release; prefer the spec
/// grammar.
pub fn parse_method(args: &Args) -> Result<Method> {
    let name = args.get_or("method", "claq");
    if name == "fp16"
        || name.contains(':')
        || name.starts_with("fusion-")
        || name.starts_with("claq-fusion-")
    {
        return name
            .parse::<MethodSpec>()
            .map(MethodSpec::into_method)
            .map_err(anyhow::Error::msg);
    }
    parse_method_legacy(args, name)
}

/// The pre-MethodSpec flag plumbing (deprecated alias path).
fn parse_method_legacy(args: &Args, name: &str) -> Result<Method> {
    let bits: f64 = args.get_parse_or("bits", 4.0).map_err(anyhow::Error::msg)?;
    // The container packs 1..=8-bit index planes; reject degenerate widths
    // here instead of panicking deep in the quantizer/pack path. FP16
    // ignores --bits entirely (16 is a natural thing to type for it).
    anyhow::ensure!(
        name == "fp16" || (1.0..=8.0).contains(&bits),
        "--bits must be in [1, 8] for method {name} (got {bits})"
    );
    let s: f64 = args.get_parse_or("s", DEFAULT_S).map_err(anyhow::Error::msg)?;
    let setting: usize = args.get_parse_or("setting", 2).map_err(anyhow::Error::msg)?;
    let ibits = bits.round() as u8;
    Ok(match name {
        "fp16" => Method::Fp16,
        "rtn" => Method::Rtn { bits: ibits },
        "gptq" => Method::Gptq { bits: ibits },
        "awq" => Method::Awq { bits: ibits },
        "claq" => {
            if (bits - ibits as f64).abs() < 1e-9 {
                Method::Claq { bits: ibits }
            } else {
                // fractional bits => fusion preset style split
                match format!("{bits:.2}").as_str() {
                    "2.12" => Method::fusion_2_12(),
                    "2.24" => Method::fusion_2_24(),
                    "3.12" => Method::fusion_3_12(),
                    "3.23" => Method::fusion_3_23(),
                    _ => Method::ClaqAp {
                        pair: parse_bit_pair(args, bits)?,
                        target_bits: bits,
                        metric: ColumnMetric::OutlierRatio,
                        s,
                    },
                }
            }
        }
        "claq-ap" => Method::ClaqAp {
            pair: parse_bit_pair(args, bits)?,
            target_bits: bits,
            metric: ColumnMetric::OutlierRatio,
            s,
        },
        "claq-vq" => {
            anyhow::ensure!(
                (bits - ibits as f64).abs() < 1e-9 && (1..=8).contains(&ibits),
                "--bits must be an integer in [1, 8] for claq-vq (got {bits}); sub-bit \
                 budgets come from --group-dim, not fractional index widths"
            );
            let d: usize = args.get_parse_or("group-dim", 4).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(
                (1..=255).contains(&d),
                "--group-dim must be in [1, 255] (got {d}) — the CLAQVQ01 header stores it as u8"
            );
            Method::ClaqVq { d, bits: ibits }
        }
        "claq-or" => Method::ClaqOr {
            bits: bits.floor() as u8,
            budget_bits: bits - bits.floor(),
            setting: OrSetting::by_id(setting),
            s,
        },
        "claq-or-fixed" => Method::ClaqOrFixed {
            bits: bits.floor() as u8,
            budget_bits: bits - bits.floor(),
        },
        "claq-fusion" => Method::fusion_2_12(),
        other => bail!("unknown method '{other}'"),
    })
}

fn model_key(args: &Args) -> ModelKey {
    match args.get_or("model", "l") {
        "xl" | "tiny-xl" => ModelKey::TinyXl,
        _ => ModelKey::TinyL,
    }
}

/// `claq quantize --method M --bits B [--model l|xl]`
pub fn quantize(args: &Args) -> Result<()> {
    let h = Harness::load(args.has("fast"))?;
    let method = parse_method(args)?;
    let key = model_key(args);
    eprintln!("quantizing {} with {} ...", key.name(), method.name());
    let row = h.run(key, &method, CorpusKind::SynthC4, false, "quantize")?;
    println!("{}", render_table("quantize result", &[row], false));
    Ok(())
}

/// `claq eval --model l|xl [--method M --bits B]` — with zero-shot.
pub fn eval(args: &Args) -> Result<()> {
    let h = Harness::load(args.has("fast"))?;
    let method = if args.get("method").is_some() { parse_method(args)? } else { Method::Fp16 };
    let key = model_key(args);
    let row = h.run(key, &method, CorpusKind::SynthC4, true, "eval")?;
    println!("{}", render_table("eval result", &[row], true));
    Ok(())
}

/// `claq table <n> [--fast]`
pub fn table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .context("usage: claq table <n>")?
        .parse()
        .map_err(|_| anyhow::anyhow!("table id must be a number"))?;
    let h = Harness::load(args.has("fast"))?;
    match n {
        1 => tables_main::table1(&h).map(|_| ()),
        2 => tables_main::table2(&h).map(|_| ()),
        3 => tables_ablation::table3(&h).map(|_| ()),
        4 => tables_ablation::table4(&h).map(|_| ()),
        5 => tables_ablation::table5(&h).map(|_| ()),
        6 => tables_ablation::table6(&h).map(|_| ()),
        7 => tables_ablation::table7(&h).map(|_| ()),
        8 | 9 => tables_main::table8(&h).map(|_| ()),
        10 | 11 => tables_main::table10(&h).map(|_| ()),
        12 => tables_appendix::table12(&h).map(|_| ()),
        13 => tables_appendix::table13(&h).map(|_| ()),
        other => bail!("no generator for table {other} (1-13; figures are `claq figure`)"),
    }
}

/// `claq figure <n>`
pub fn figure(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .context("usage: claq figure <3|4|5>")?
        .parse()
        .map_err(|_| anyhow::anyhow!("figure id must be a number"))?;
    let h = Harness::load(args.has("fast"))?;
    match n {
        3 => figures::figure3(&h),
        4 => figures::figure4(&h),
        5 => figures::figure5(&h),
        other => bail!("no generator for figure {other} (3-5; 1-2 are architecture diagrams)"),
    }
}

/// `claq pack --out model.claq [--model l|xl|PATH] [--method M --bits B]
/// [--random] [--fast]` — quantize once and write the single-file
/// CLAQMD01 checkpoint (the quantize-once / serve-many artifact).
pub fn pack(args: &Args) -> Result<()> {
    let method = parse_method(args)?;
    if matches!(method, Method::Fp16) {
        bail!("FP16 has nothing to pack — choose a quantized method (see `claq help`)");
    }
    let out = PathBuf::from(args.get_or("out", "model.claq"));
    let dir = artifacts_dir();
    let model = if args.has("random") {
        // toolchain smoke path: no artifacts needed
        Model::random(TransformerConfig::tiny_l(), &mut Rng::new(17))
    } else {
        let path = match args.get_or("model", "l") {
            "l" | "tiny-l" => dir.join(ModelKey::TinyL.weights_file()),
            "xl" | "tiny-xl" => dir.join(ModelKey::TinyXl.weights_file()),
            p => PathBuf::from(p),
        };
        load_model(&path).with_context(|| {
            format!(
                "load weights from {} — run `make artifacts`, pass --model PATH, or use --random",
                path.display()
            )
        })?
    };
    let n_segments = if args.has("fast") { 8 } else { 24 };
    let calib = default_calibration(&dir, model.config.max_seq, n_segments);

    let opts = PipelineOpts {
        save_checkpoint: Some(out.clone()),
        verbose: args.has("verbose"),
        ..Default::default()
    };
    let t0 = Instant::now();
    let (qm, stats) = quantize_model(&model, &method, &calib, &opts);
    if let Some(err) = stats.checkpoint_error {
        bail!("checkpoint save to {} failed: {err}", out.display());
    }
    let rep = qm.size_report();
    let fp_artifact_bytes = crate::model::io::model_file_byte_len(&model.config);
    println!(
        "packed {} with {} in {:.1}s -> {}",
        model.config.n_params(),
        qm.method_name,
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    println!(
        "  checkpoint: {} B  (FP parts {} B, containers {} B, AWQ scales {} B)",
        rep.checkpoint_bytes, rep.fp_bytes, rep.container_bytes, rep.awq_scale_bytes
    );
    println!(
        "  {:.2} bits/param paper accounting, {:.2} bits/param container; {:.1}% of the {} B FP artifact",
        rep.paper_equivalent_bits,
        rep.container_bits_per_param,
        100.0 * rep.checkpoint_bytes as f64 / fp_artifact_bytes as f64,
        fp_artifact_bytes
    );
    if rep.vq_matrices > 0 {
        println!(
            "  plane kinds: {} scalar (CLAQPK01, {} B) + {} vector-group (CLAQVQ01, {} B)",
            rep.scalar_matrices, rep.scalar_container_bytes, rep.vq_matrices, rep.vq_container_bytes
        );
    }
    println!("  cold-start it with: claq serve --checkpoint {}", out.display());
    Ok(())
}

/// `claq serve --checkpoint model.claq [--requests N --slots S --seed K]
/// [--kv-page-tokens P] [--kv-quant-bits B] [--kv-budget-mb M]
/// [--max-queue Q] [--deadline-steps D]` — cold-start the
/// continuous-batching engine from a checkpoint (no calibration, no
/// quantization, no dense weights) and drive a short greedy-decode
/// workload over the paged KV cache. The three overload knobs expose the
/// degradation ladder (DESIGN.md §14): a hard KV byte budget (0 =
/// unbounded), a queue bound past which submissions are shed as
/// `Rejected`, and a per-request step deadline (0 = none).
pub fn serve(args: &Args) -> Result<()> {
    let path = args
        .get("checkpoint")
        .context("usage: claq serve --checkpoint <model.claq> [--requests N --slots S --seed K]")?;
    let cold = ColdStart::from_path(Path::new(path))?;
    let cfg = cold.exec.config;
    println!(
        "cold start: {} ({:.2} MB, method {}) -> packed ExecModel in {:.1} ms",
        path,
        cold.checkpoint_bytes as f64 / 1e6,
        cold.method_name,
        cold.load_seconds * 1e3
    );

    let n_requests: usize = args.get_parse_or("requests", 16).map_err(anyhow::Error::msg)?;
    let n_requests = n_requests.max(1);
    let slots: usize = args.get_parse_or("slots", 4).map_err(anyhow::Error::msg)?;
    let slots = slots.clamp(1, cfg.max_seq);
    let seed: u64 = args.get_parse_or("seed", 17).map_err(anyhow::Error::msg)?;
    let kv_page_tokens: usize =
        args.get_parse_or("kv-page-tokens", DEFAULT_PAGE_TOKENS).map_err(anyhow::Error::msg)?;
    let kv_quant_bits: u8 =
        args.get_parse_or("kv-quant-bits", 0).map_err(anyhow::Error::msg)?;
    let kv_budget_mb: usize =
        args.get_parse_or("kv-budget-mb", 0).map_err(anyhow::Error::msg)?;
    let max_queue: usize = args.get_parse_or("max-queue", 0).map_err(anyhow::Error::msg)?;
    let deadline_steps: u64 =
        args.get_parse_or("deadline-steps", 0).map_err(anyhow::Error::msg)?;

    // The validating builder rejects incoherent flag combinations (e.g. a
    // bounded --kv-budget-mb with an unbounded queue) with a usage error
    // instead of serving a configuration that can only melt down.
    let sched_cfg = SchedulerConfig::builder()
        .max_slots(slots)
        .prefill_token_budget(2 * cfg.max_seq)
        .policy(AdmissionPolicy::Continuous)
        .kv_page_tokens(kv_page_tokens)
        .kv_quant_bits(kv_quant_bits)
        .kv_budget_bytes(kv_budget_mb * (1 << 20))
        .max_queue(max_queue)
        .deadline_steps(deadline_steps)
        .build()
        .map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let mut sched = Scheduler::new(cfg, sched_cfg);
    // Prompts are sized to the checkpoint's own config (vocab, max_seq).
    let mut rng = Rng::new(seed);
    for _ in 0..n_requests {
        let prompt_len = 1 + rng.below_usize((cfg.max_seq / 2).clamp(1, 16));
        let max_new = 1 + rng.below_usize((cfg.max_seq - prompt_len).clamp(1, 16));
        let prompt = (0..prompt_len).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
        sched.submit(Request { prompt, max_new_tokens: max_new, stop_token: None })?;
    }

    let mut st = ExecState::new(cfg);
    let t0 = Instant::now();
    let mut first_token_s = f64::NAN;
    let mut completions = Vec::new();
    while sched.has_work() {
        completions.extend(sched.step(&cold.exec, &mut st));
        if first_token_s.is_nan() {
            first_token_s = t0.elapsed().as_secs_f64();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let generated: usize = completions.iter().map(|c| c.tokens.len()).sum();
    let stats = sched.stats();
    println!(
        "served {n_requests} requests / {generated} tokens in {:.2}s ({:.0} tok/s, peak batch {})",
        wall,
        generated as f64 / wall.max(1e-9),
        stats.peak_live
    );
    if kv_budget_mb > 0 || max_queue > 0 || deadline_steps > 0 || stats.pool_failed_takes > 0 {
        println!(
            "overload: {} completed, {} rejected, {} deadline-exceeded, {} cancelled; \
             {} preemptions / {} resumes, {} failed page takes",
            stats.completed,
            stats.rejected,
            stats.deadline_exceeded,
            stats.cancelled,
            stats.preempted,
            stats.resumed,
            stats.pool_failed_takes
        );
    }
    println!(
        "load -> first token: {:.1} ms  (load {:.1} ms + first engine step {:.1} ms)",
        (cold.load_seconds + first_token_s) * 1e3,
        cold.load_seconds * 1e3,
        first_token_s * 1e3
    );
    println!(
        "kv pages: {}-token pages, peak {:.2} MB resident ({:.2} MB contiguous equivalent), \
         {} quantized over the run, {:.2} MB copy saved by sharing",
        kv_page_tokens,
        stats.peak_kv_resident_bytes as f64 / 1e6,
        (stats.peak_live * crate::model::exec::KvCache::contiguous_bytes(&cfg)) as f64 / 1e6,
        stats.kv_pages_quantized_total,
        stats.shared_kv_bytes_saved as f64 / 1e6
    );
    Ok(())
}

/// `claq tune [--target 2.5] [--hi 4 --lo 2] [--windows 8]
/// [--decode-tokens 64] [--out tuned.claq] [--model l|xl|PATH] [--random]
/// [--seed 17] [--fast] [--smoke]` — the per-layer bit-budget autotuner
/// (DESIGN.md §16).
///
/// Probes each layer's perplexity sensitivity (an all-`lo` baseline run
/// plus one run per layer with only that layer promoted to `hi`, all
/// scored with `perplexity_exec` on the packed engine), hands the global
/// `--target` equivalent-bits budget out greedily across layers
/// (`quant/search.rs::allocate_layer_targets`), quantizes with the chosen
/// per-layer `BitPlan` targets, measures the resulting packed engine's
/// greedy-decode tok/s, and (with `--out`) writes the tuned mixed-bit
/// CLAQMD01 checkpoint. `--smoke` is the CI mode: a tiny random 2-layer
/// model, minimal calibration, and a couple of probe windows — exercises
/// the whole probe → allocate → quantize → serve loop in seconds.
pub fn tune(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let hi: u8 = args.get_parse_or("hi", 4).map_err(anyhow::Error::msg)?;
    let lo: u8 = args.get_parse_or("lo", 2).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        (1..=8).contains(&lo) && lo < hi && hi <= 8,
        "--hi/--lo must satisfy 1 <= lo < hi <= 8 (got hi={hi}, lo={lo})"
    );
    let pair = BitPair::new(hi, lo);
    let target: f64 = args.get_parse_or("target", 2.5).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        (lo as f64) <= target && target <= hi as f64,
        "--target {target} is outside the [{lo}, {hi}] range of --lo/--hi"
    );
    let seed: u64 = args.get_parse_or("seed", 17).map_err(anyhow::Error::msg)?;
    let windows: usize = args
        .get_parse_or("windows", if smoke { 2 } else { 8 })
        .map_err(anyhow::Error::msg)?;
    let windows = windows.max(1);
    let decode_tokens: usize = args
        .get_parse_or("decode-tokens", if smoke { 16 } else { 64 })
        .map_err(anyhow::Error::msg)?;
    let out = args.get("out").map(PathBuf::from);

    let dir = artifacts_dir();
    let model = if smoke {
        // CI smoke: a tiny 2-layer model keeps the n_layers+2 pipeline
        // runs below a second each; the loop exercised is the real one.
        let cfg = TransformerConfig {
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            max_seq: 32,
            ..TransformerConfig::tiny_l()
        };
        Model::random(cfg, &mut Rng::new(seed))
    } else if args.has("random") {
        Model::random(TransformerConfig::tiny_l(), &mut Rng::new(seed))
    } else {
        let path = match args.get_or("model", "l") {
            "l" | "tiny-l" => dir.join(ModelKey::TinyL.weights_file()),
            "xl" | "tiny-xl" => dir.join(ModelKey::TinyXl.weights_file()),
            p => PathBuf::from(p),
        };
        load_model(&path).with_context(|| {
            format!(
                "load weights from {} — run `make artifacts`, pass --model PATH, or use --random",
                path.display()
            )
        })?
    };
    let cfg = model.config;
    let n_segments = if smoke { 4 } else if args.has("fast") { 8 } else { 24 };
    let calib = default_calibration(&dir, cfg.max_seq, n_segments);
    // Heldout probe stream, disjoint seed from the calibration sampler.
    let stream = generate(CorpusKind::SynthC4, windows * cfg.max_seq + cfg.max_seq, 2);
    let opts = PipelineOpts { verbose: args.has("verbose"), ..Default::default() };

    println!(
        "tune: {} layers / {} params, pair {lo}+{hi}, target {target:.2} bits/param, \
         {windows} probe windows",
        cfg.n_layers,
        cfg.n_params()
    );
    let t0 = Instant::now();

    // 1. all-lo baseline
    let lo_targets = vec![lo as f64; cfg.n_layers];
    let (qm_lo, _) = quantize_model_tuned(&model, pair, &lo_targets, DEFAULT_S, &calib, &opts);
    let ppl_lo = perplexity_exec(&qm_lo.to_exec(), &stream, windows).ppl;
    println!("  baseline all-{lo}-bit: ppl {ppl_lo:.3}");

    // 2. one probe per layer: only that layer promoted to hi
    let mut sens = Vec::with_capacity(cfg.n_layers);
    for layer in 0..cfg.n_layers {
        let mut t = lo_targets.clone();
        t[layer] = hi as f64;
        let (qm_probe, _) = quantize_model_tuned(&model, pair, &t, DEFAULT_S, &calib, &opts);
        let ppl = perplexity_exec(&qm_probe.to_exec(), &stream, windows).ppl;
        let params: usize = MatrixKind::ALL
            .iter()
            .map(|&kind| {
                let w = model.matrix(MatrixId { layer, kind });
                w.rows * w.cols
            })
            .sum();
        let drop_per_bit = (ppl_lo - ppl) / (hi - lo) as f64;
        println!("  probe layer {layer} at {hi}-bit: ppl {ppl:.3} (drop {drop_per_bit:+.4}/bit)");
        sens.push(LayerSensitivity { layer, params, ppl_drop_per_bit: drop_per_bit });
    }

    // 3. greedy budget allocation, then the final tuned quantization
    let space = TuneSpace { pair, target_bits: target, step_bits: 0.125 };
    let targets = allocate_layer_targets(&space, &sens);
    let final_opts = PipelineOpts { save_checkpoint: out.clone(), ..opts };
    let (qm, stats) = quantize_model_tuned(&model, pair, &targets, DEFAULT_S, &calib, &final_opts);
    if let Some(err) = stats.checkpoint_error {
        bail!("checkpoint save failed: {err}");
    }
    let exec = qm.to_exec();
    let ppl = perplexity_exec(&exec, &stream, windows).ppl;

    // 4. measured greedy-decode throughput of the tuned packed engine
    let prompt_len = (cfg.max_seq / 4).clamp(1, 8);
    let decode_tokens = decode_tokens.clamp(1, cfg.max_seq - prompt_len);
    let prompt: Vec<u16> = stream[..prompt_len].to_vec();
    let mut st = ExecState::new(cfg);
    let mut cache = KvCache::new(&cfg);
    let logits = prefill(&exec, &mut cache, &prompt, &mut st);
    let mut tok = argmax(logits.row(prompt_len - 1));
    let td = Instant::now();
    for _ in 0..decode_tokens {
        let logits = decode_step(&exec, &mut [&mut cache], &[tok], &mut st);
        tok = argmax(logits.row(0));
    }
    let tok_s = decode_tokens as f64 / td.elapsed().as_secs_f64().max(1e-9);

    let total_params: f64 = sens.iter().map(|l| l.params as f64).sum();
    let achieved: f64 =
        targets.iter().zip(&sens).map(|(t, l)| t * l.params as f64).sum::<f64>() / total_params;
    for (layer, t) in targets.iter().enumerate() {
        println!("  layer {layer}: chosen target {t:.3} bits");
    }
    let rep = qm.size_report();
    println!(
        "tuned in {:.1}s: {:.3} bits/param allocated ({:.2} container), ppl {ppl:.3} \
         (all-lo {ppl_lo:.3}), decode {tok_s:.0} tok/s over {decode_tokens} tokens",
        t0.elapsed().as_secs_f64(),
        achieved,
        rep.container_bits_per_param,
    );
    if let Some(out) = out {
        println!("  wrote {} ({} B) — serve it with: claq serve --checkpoint {}",
            out.display(), rep.checkpoint_bytes, out.display());
    }
    Ok(())
}

/// `claq bench-check [--baseline DIR] [--fresh DIR] [--tol 0.25]
/// [--update]` — the CI bench-regression gate (DESIGN.md §11). Every
/// `BENCH_*.json` in the baseline dir is compared against its freshly
/// produced counterpart in the fresh dir; any metric beyond
/// `baseline × (1 + tol)` (time/size ceilings, plus `tok_s` /
/// `bytes_decoded_per_s` throughput floors), or a cell/file missing from
/// the fresh run, fails the command (non-zero exit fails the CI job).
/// `--update`
/// instead copies the fresh files over the baselines — the refresh path
/// after an intentional perf change or a runner-speed shift.
pub fn bench_check(args: &Args) -> Result<()> {
    let baseline_dir = PathBuf::from(args.get_or("baseline", "ci/bench_baseline"));
    let fresh_dir = PathBuf::from(args.get_or("fresh", "."));
    let tol: f64 = args.get_parse_or("tol", 0.25).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(tol >= 0.0, "--tol must be non-negative (got {tol})");

    let mut names: Vec<String> = std::fs::read_dir(&baseline_dir)
        .with_context(|| format!("read baseline dir {}", baseline_dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();
    anyhow::ensure!(
        !names.is_empty(),
        "no BENCH_*.json baselines in {} — nothing to gate",
        baseline_dir.display()
    );

    if args.has("update") {
        for name in &names {
            let fresh = fresh_dir.join(name);
            let text = std::fs::read_to_string(&fresh).with_context(|| {
                format!("read fresh {} (run the benches first)", fresh.display())
            })?;
            // refuse to bless an unparsable document as a baseline
            crate::util::benchlib::parse_bench_json(&text)
                .map_err(|e| anyhow::anyhow!("{}: {e}", fresh.display()))?;
            std::fs::write(baseline_dir.join(name), text)
                .with_context(|| format!("write baseline {name}"))?;
            println!("baseline refreshed: {name}");
        }
        return Ok(());
    }

    let mut total = 0usize;
    for name in &names {
        let base_path = baseline_dir.join(name);
        let fresh_path = fresh_dir.join(name);
        let base_text = std::fs::read_to_string(&base_path)
            .with_context(|| format!("read baseline {}", base_path.display()))?;
        let base = crate::util::benchlib::parse_bench_json(&base_text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", base_path.display()))?;
        let fresh_text = std::fs::read_to_string(&fresh_path).with_context(|| {
            format!("read fresh {} (did its bench run?)", fresh_path.display())
        })?;
        let fresh = crate::util::benchlib::parse_bench_json(&fresh_text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", fresh_path.display()))?;
        let violations = crate::util::benchlib::compare_bench(&base, &fresh, tol);
        let armed = base
            .cells
            .iter()
            .filter(|c| {
                c.ns_per_elem.is_some()
                    || c.median_ns > 0.0
                    || c.extras.iter().any(|(k, v)| {
                        crate::util::benchlib::GATED_RATE_EXTRAS.contains(&k.as_str()) && *v > 0.0
                    })
            })
            .count();
        if violations.is_empty() {
            println!(
                "{name}: OK ({} cells, {armed} armed, tol {:.0}%)",
                base.cells.len(),
                tol * 100.0
            );
        } else {
            for v in &violations {
                eprintln!("REGRESSION {v}");
            }
            total += violations.len();
        }
    }
    if total > 0 {
        bail!(
            "{total} bench regression(s) beyond {:.0}% tolerance — if intentional, refresh with \
             `claq bench-check --update --baseline <dir> --fresh <dir>`",
            tol * 100.0
        );
    }
    Ok(())
}

/// `claq outliers [--s S] [--model l|xl]` — Outlier Order diagnostics.
pub fn outliers(args: &Args) -> Result<()> {
    let h = Harness::load(true)?;
    let s: f64 = args.get_parse_or("s", DEFAULT_S).map_err(anyhow::Error::msg)?;
    let model = h.model(model_key(args))?;
    println!("{:<22} {:>10} {:>12} {:>14}", "matrix", "outliers", "overall R", "top10% conc.");
    for layer in 0..model.config.n_layers {
        for kind in MatrixKind::ALL {
            let id = MatrixId { layer, kind };
            let st = OutlierStats::compute(model.matrix(id), s);
            println!(
                "{:<22} {:>10} {:>12.5} {:>13.1}%",
                id.name(),
                st.total_outliers,
                st.overall_ratio(),
                st.concentration(0.10) * 100.0
            );
        }
    }
    let _ = emit(&h, "outliers", ""); // ensure tables dir exists for tooling
    Ok(())
}
