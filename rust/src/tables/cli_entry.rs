//! CLI subcommand implementations: dispatch to the table/figure
//! generators, plus ad-hoc `quantize` / `eval` / `outliers` commands.

use super::runner::{emit, render_table, Harness, ModelKey};
use super::{figures, tables_ablation, tables_appendix, tables_main};
use crate::data::corpus::CorpusKind;
use crate::model::{MatrixId, MatrixKind};
use crate::quant::config::{Method, DEFAULT_S};
use crate::quant::outliers::{ColumnMetric, OutlierStats};
use crate::quant::precision::BitPair;
use crate::quant::reservation::OrSetting;
use crate::util::cli::Args;
use anyhow::{bail, Context, Result};

/// Parse a `--method NAME --bits B [--s S] [--setting N]` triple.
pub fn parse_method(args: &Args) -> Result<Method> {
    let name = args.get_or("method", "claq");
    let bits: f64 = args.get_parse_or("bits", 4.0).map_err(anyhow::Error::msg)?;
    let s: f64 = args.get_parse_or("s", DEFAULT_S).map_err(anyhow::Error::msg)?;
    let setting: usize = args.get_parse_or("setting", 2).map_err(anyhow::Error::msg)?;
    let ibits = bits.round() as u8;
    Ok(match name {
        "fp16" => Method::Fp16,
        "rtn" => Method::Rtn { bits: ibits },
        "gptq" => Method::Gptq { bits: ibits },
        "awq" => Method::Awq { bits: ibits },
        "claq" => {
            if (bits - ibits as f64).abs() < 1e-9 {
                Method::Claq { bits: ibits }
            } else {
                // fractional bits => fusion preset style split
                match format!("{bits:.2}").as_str() {
                    "2.12" => Method::fusion_2_12(),
                    "2.24" => Method::fusion_2_24(),
                    "3.12" => Method::fusion_3_12(),
                    "3.23" => Method::fusion_3_23(),
                    _ => Method::ClaqAp {
                        pair: BitPair::new(4, bits.floor() as u8),
                        target_bits: bits,
                        metric: ColumnMetric::OutlierRatio,
                        s,
                    },
                }
            }
        }
        "claq-ap" => Method::ClaqAp {
            pair: BitPair::new(4, bits.floor() as u8),
            target_bits: bits,
            metric: ColumnMetric::OutlierRatio,
            s,
        },
        "claq-or" => Method::ClaqOr {
            bits: bits.floor() as u8,
            budget_bits: bits - bits.floor(),
            setting: OrSetting::by_id(setting),
            s,
        },
        "claq-or-fixed" => Method::ClaqOrFixed {
            bits: bits.floor() as u8,
            budget_bits: bits - bits.floor(),
        },
        "claq-fusion" => Method::fusion_2_12(),
        other => bail!("unknown method '{other}'"),
    })
}

fn model_key(args: &Args) -> ModelKey {
    match args.get_or("model", "l") {
        "xl" | "tiny-xl" => ModelKey::TinyXl,
        _ => ModelKey::TinyL,
    }
}

/// `claq quantize --method M --bits B [--model l|xl]`
pub fn quantize(args: &Args) -> Result<()> {
    let h = Harness::load(args.has("fast"))?;
    let method = parse_method(args)?;
    let key = model_key(args);
    eprintln!("quantizing {} with {} ...", key.name(), method.name());
    let row = h.run(key, &method, CorpusKind::SynthC4, false, "quantize")?;
    println!("{}", render_table("quantize result", &[row], false));
    Ok(())
}

/// `claq eval --model l|xl [--method M --bits B]` — with zero-shot.
pub fn eval(args: &Args) -> Result<()> {
    let h = Harness::load(args.has("fast"))?;
    let method = if args.get("method").is_some() { parse_method(args)? } else { Method::Fp16 };
    let key = model_key(args);
    let row = h.run(key, &method, CorpusKind::SynthC4, true, "eval")?;
    println!("{}", render_table("eval result", &[row], true));
    Ok(())
}

/// `claq table <n> [--fast]`
pub fn table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .context("usage: claq table <n>")?
        .parse()
        .map_err(|_| anyhow::anyhow!("table id must be a number"))?;
    let h = Harness::load(args.has("fast"))?;
    match n {
        1 => tables_main::table1(&h).map(|_| ()),
        2 => tables_main::table2(&h).map(|_| ()),
        3 => tables_ablation::table3(&h).map(|_| ()),
        4 => tables_ablation::table4(&h).map(|_| ()),
        5 => tables_ablation::table5(&h).map(|_| ()),
        6 => tables_ablation::table6(&h).map(|_| ()),
        7 => tables_ablation::table7(&h).map(|_| ()),
        8 | 9 => tables_main::table8(&h).map(|_| ()),
        10 | 11 => tables_main::table10(&h).map(|_| ()),
        12 => tables_appendix::table12(&h).map(|_| ()),
        13 => tables_appendix::table13(&h).map(|_| ()),
        other => bail!("no generator for table {other} (1-13; figures are `claq figure`)"),
    }
}

/// `claq figure <n>`
pub fn figure(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .context("usage: claq figure <3|4|5>")?
        .parse()
        .map_err(|_| anyhow::anyhow!("figure id must be a number"))?;
    let h = Harness::load(args.has("fast"))?;
    match n {
        3 => figures::figure3(&h),
        4 => figures::figure4(&h),
        5 => figures::figure5(&h),
        other => bail!("no generator for figure {other} (3-5; 1-2 are architecture diagrams)"),
    }
}

/// `claq outliers [--s S] [--model l|xl]` — Outlier Order diagnostics.
pub fn outliers(args: &Args) -> Result<()> {
    let h = Harness::load(true)?;
    let s: f64 = args.get_parse_or("s", DEFAULT_S).map_err(anyhow::Error::msg)?;
    let model = h.model(model_key(args))?;
    println!("{:<22} {:>10} {:>12} {:>14}", "matrix", "outliers", "overall R", "top10% conc.");
    for layer in 0..model.config.n_layers {
        for kind in MatrixKind::ALL {
            let id = MatrixId { layer, kind };
            let st = OutlierStats::compute(model.matrix(id), s);
            println!(
                "{:<22} {:>10} {:>12.5} {:>13.1}%",
                id.name(),
                st.total_outliers,
                st.overall_ratio(),
                st.concentration(0.10) * 100.0
            );
        }
    }
    let _ = emit(&h, "outliers", ""); // ensure tables dir exists for tooling
    Ok(())
}
