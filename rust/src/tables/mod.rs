//! Experiment harness: regenerates every table and figure of the paper
//! (see DESIGN.md §5 for the per-experiment index) plus the data bootstrap
//! and CLI glue.

pub mod bootstrap;
pub mod cli_entry;
pub mod figures;
pub mod runner;
pub mod tables_ablation;
pub mod tables_appendix;
pub mod tables_main;
