//! Data bootstrap: generates the synthetic corpora the whole stack shares.
//! `make artifacts` runs this *before* the JAX trainer, which reads the
//! token files so both layers see an identical language.

use crate::coordinator::registry::artifacts_dir;
use crate::data::corpus::{generate, save_tokens, CorpusKind};
use crate::util::cli::Args;
use anyhow::Result;
use std::path::PathBuf;

/// Default token counts: enough for 128-segment calibration + training +
/// held-out evaluation at seq 128.
pub const TRAIN_TOKENS: usize = 600_000;
pub const HELDOUT_TOKENS: usize = 40_000;

/// Corpus files written into the artifacts directory.
pub fn corpus_paths(dir: &std::path::Path) -> Vec<(CorpusKind, &'static str, PathBuf, usize)> {
    vec![
        (CorpusKind::SynthWiki, "train", dir.join("corpus_wiki_train.bin"), TRAIN_TOKENS),
        (CorpusKind::SynthWiki, "heldout", dir.join("corpus_wiki_heldout.bin"), HELDOUT_TOKENS),
        (CorpusKind::SynthC4, "train", dir.join("corpus_c4_train.bin"), TRAIN_TOKENS),
        (CorpusKind::SynthC4, "heldout", dir.join("corpus_c4_heldout.bin"), HELDOUT_TOKENS),
    ]
}

/// `claq datagen [--out DIR] [--tokens N]`
pub fn datagen(args: &Args) -> Result<()> {
    let dir = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    std::fs::create_dir_all(&dir)?;
    let scale: f64 = args.get_parse_or("tokens", TRAIN_TOKENS as f64).map_err(anyhow::Error::msg)?
        / TRAIN_TOKENS as f64;
    for (kind, split, path, base_n) in corpus_paths(&dir) {
        let n = ((base_n as f64) * scale) as usize;
        // train/heldout come from disjoint generator seeds (see corpus.rs)
        let seed = if split == "train" { 1 } else { 2 };
        let toks = generate(kind, n, seed);
        save_tokens(&toks, &path)?;
        println!(
            "wrote {} ({} {} tokens: {})",
            path.display(),
            kind.name(),
            split,
            toks.len()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::load_tokens;

    #[test]
    fn datagen_writes_all_corpora() {
        let dir = std::env::temp_dir().join("claq_bootstrap_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = Args::parse(
            vec![
                "--out".to_string(),
                dir.to_str().unwrap().to_string(),
                "--tokens".to_string(),
                "6000".to_string(),
            ],
            &["out", "tokens"],
        )
        .unwrap();
        datagen(&args).unwrap();
        for (_, _, path, _) in corpus_paths(&dir) {
            let toks = load_tokens(&path).unwrap();
            assert!(!toks.is_empty());
        }
        // scaled: train ≈ 6000 tokens
        let train = load_tokens(&dir.join("corpus_wiki_train.bin")).unwrap();
        assert_eq!(train.len(), 6000);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
