//! Figures 3–5 (Appendix A): outlier-distribution diagnostics of the
//! trained model — the evidence behind the Outlier Order metric. Output is
//! printed as data series and written as CSV under artifacts/figures/.

use super::runner::{Harness, ModelKey};
use crate::model::{MatrixId, MatrixKind};
use crate::quant::outliers::OutlierStats;
use anyhow::Result;
use std::fmt::Write as _;

fn write_figure(h: &Harness, stem: &str, text: &str) -> Result<()> {
    println!("{text}");
    let dir = h.dir.join("figures");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{stem}.csv")), text)?;
    Ok(())
}

/// Figure 3: sorted per-column outlier ratios of layer-0 `wo` at S = 7 —
/// "most columns contain few outliers".
pub fn figure3(h: &Harness) -> Result<()> {
    let model = h.model(ModelKey::TinyL)?;
    let w = model.matrix(MatrixId { layer: 0, kind: MatrixKind::Wo });
    let stats = OutlierStats::compute(w, 7.0);
    let mut ratios = stats.ratios.clone();
    ratios.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut out = String::from("rank,outlier_ratio\n");
    for (i, r) in ratios.iter().enumerate() {
        writeln!(out, "{i},{r:.6}").unwrap();
    }
    let top10 = stats.concentration(0.10);
    writeln!(
        out,
        "# layers.0.wo S=7: top-10% columns hold {:.1}% of outliers (paper: ~90%)",
        top10 * 100.0
    )
    .unwrap();
    write_figure(h, "figure3", &out)
}

/// Figure 4: positions of the top-10% outlier columns within the matrix —
/// "evenly distributed with no apparent pattern".
pub fn figure4(h: &Harness) -> Result<()> {
    let model = h.model(ModelKey::TinyL)?;
    let w = model.matrix(MatrixId { layer: 0, kind: MatrixKind::Wo });
    let stats = OutlierStats::compute(w, 7.0);
    let top = {
        let mut t = stats.top_columns(0.10);
        t.sort_unstable();
        t
    };
    let mut out = String::from("column_position\n");
    for c in &top {
        writeln!(out, "{c}").unwrap();
    }
    // dispersion diagnostic: mean gap vs uniform expectation
    if top.len() >= 2 {
        let gaps: Vec<f64> = top.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let uniform_gap = w.cols as f64 / top.len() as f64;
        writeln!(
            out,
            "# mean gap {:.2} vs uniform expectation {:.2} (close => spread out, as the paper observes)",
            mean_gap, uniform_gap
        )
        .unwrap();
    }
    write_figure(h, "figure4", &out)
}

/// Figure 5: overall outlier ratio per decoder layer — "initial layers
/// exhibit disproportionately high outlier incidence".
pub fn figure5(h: &Harness) -> Result<()> {
    let model = h.model(ModelKey::TinyL)?;
    let mut out = String::from("layer,overall_outlier_ratio\n");
    for layer in 0..model.config.n_layers {
        let mut total = 0.0;
        let mut n = 0usize;
        for kind in MatrixKind::ALL {
            let w = model.matrix(MatrixId { layer, kind });
            let st = OutlierStats::compute(w, 7.0);
            total += st.overall_ratio();
            n += 1;
        }
        writeln!(out, "{layer},{:.6}", total / n as f64).unwrap();
    }
    write_figure(h, "figure5", &out)
}
