//! Tables 1, 2, 8/9, 10/11 — the paper's main perplexity and zero-shot
//! results, on the tiny-L ("LLaMA-1" stand-in) and tiny-XL ("LLaMA-2/Yi"
//! stand-in) model families.

use super::runner::{emit, render_table, Harness, ModelKey, Row};
use crate::data::corpus::CorpusKind;
use crate::quant::config::Method;
use anyhow::Result;

/// The method grid of Table 1 (implemented comparators only; OmniQuant /
/// SqueezeLLM / SpQR / decoupleQ are other papers' training loops — see
/// DESIGN.md §1).
pub fn table1_methods() -> Vec<Method> {
    vec![
        Method::Fp16,
        Method::Rtn { bits: 4 },
        Method::Gptq { bits: 4 },
        Method::Awq { bits: 4 },
        Method::Claq { bits: 4 },
        Method::Rtn { bits: 3 },
        Method::Gptq { bits: 3 },
        Method::Awq { bits: 3 },
        Method::Claq { bits: 3 },
        Method::fusion_3_12(),
        Method::fusion_3_23(),
        Method::Gptq { bits: 2 },
        Method::Claq { bits: 2 },
        Method::fusion_2_12(),
        Method::fusion_2_24(),
    ]
}

/// Table 1: perplexity grid on tiny-L.
pub fn table1(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for m in table1_methods() {
        eprintln!("[table1] {}", m.name());
        rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, false, "table1")?);
    }
    emit(h, "table1", &render_table("Table 1 — perplexity (tiny-L)", &rows, false))?;
    Ok(rows)
}

/// Table 2's method subset (zero-shot is expensive).
pub fn table2_methods() -> Vec<Method> {
    vec![
        Method::Fp16,
        Method::Gptq { bits: 4 },
        Method::Claq { bits: 4 },
        Method::Gptq { bits: 2 },
        Method::fusion_2_12(),
    ]
}

/// Table 2: zero-shot accuracy on tiny-L.
pub fn table2(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for m in table2_methods() {
        eprintln!("[table2] {}", m.name());
        rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table2")?);
    }
    emit(h, "table2", &render_table("Table 2 — zero-shot accuracy % (tiny-L)", &rows, true))?;
    Ok(rows)
}

/// Tables 8+9 (Appendix E): perplexity on the second model family.
pub fn table8(h: &Harness) -> Result<Vec<Row>> {
    let methods = vec![
        Method::Fp16,
        Method::Gptq { bits: 4 },
        Method::Claq { bits: 4 },
        Method::Gptq { bits: 3 },
        Method::Claq { bits: 3 },
        Method::fusion_3_12(),
        Method::fusion_3_23(),
        Method::Gptq { bits: 2 },
        Method::Claq { bits: 2 },
        Method::fusion_2_12(),
        Method::fusion_2_24(),
    ];
    let mut rows = Vec::new();
    for m in methods {
        eprintln!("[table8] {}", m.name());
        rows.push(h.run(ModelKey::TinyXl, &m, CorpusKind::SynthC4, false, "table8")?);
    }
    emit(
        h,
        "table8",
        &render_table("Tables 8/9 (App. E) — perplexity (tiny-XL)", &rows, false),
    )?;
    Ok(rows)
}

/// Tables 10+11 (Appendix E): zero-shot on the second family.
pub fn table10(h: &Harness) -> Result<Vec<Row>> {
    let methods = vec![
        Method::Fp16,
        Method::Gptq { bits: 4 },
        Method::Claq { bits: 4 },
        Method::Gptq { bits: 3 },
        Method::fusion_3_12(),
        Method::Gptq { bits: 2 },
        Method::fusion_2_12(),
    ];
    let mut rows = Vec::new();
    for m in methods {
        eprintln!("[table10] {}", m.name());
        rows.push(h.run(ModelKey::TinyXl, &m, CorpusKind::SynthC4, true, "table10")?);
    }
    emit(
        h,
        "table10",
        &render_table("Tables 10/11 (App. E) — zero-shot accuracy % (tiny-XL)", &rows, true),
    )?;
    Ok(rows)
}
