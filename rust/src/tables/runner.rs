//! Shared experiment runner: loads the trained models + corpora from
//! `artifacts/`, quantizes with a method, evaluates perplexity and
//! zero-shot accuracy, and records rows in the run registry.

use crate::coordinator::pipeline::{quantize_model, PipelineOpts};
use crate::coordinator::registry::{artifacts_dir, Registry, RunRecord};
use crate::data::calibration::{sample_segments, CalibConfig};
use crate::data::corpus::{load_tokens, CorpusKind};
use crate::data::tasks::{generate_task, TaskItem, TASKS};
use crate::eval::perplexity::perplexity;
use crate::eval::zeroshot::accuracy;
use crate::model::io::load_model;
use crate::model::{Model, TransformerConfig};
use crate::quant::config::Method;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Which trained model family a row uses ("LLaMA-1" stand-in vs the
/// Appendix E "LLaMA-2/Yi" stand-in).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKey {
    TinyL,
    TinyXl,
}

impl ModelKey {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKey::TinyL => "tiny-L",
            ModelKey::TinyXl => "tiny-XL",
        }
    }

    pub fn weights_file(&self) -> &'static str {
        match self {
            ModelKey::TinyL => "weights_l.bin",
            ModelKey::TinyXl => "weights_xl.bin",
        }
    }
}

/// Evaluation knobs (scaled down in --fast mode).
#[derive(Clone, Copy, Debug)]
pub struct EvalBudget {
    pub ppl_windows: usize,
    pub zs_items: usize,
    pub calib_segments: usize,
}

impl EvalBudget {
    pub fn standard() -> Self {
        Self { ppl_windows: 60, zs_items: 100, calib_segments: 32 }
    }

    pub fn fast() -> Self {
        Self { ppl_windows: 16, zs_items: 32, calib_segments: 12 }
    }
}

/// One evaluated configuration.
#[derive(Clone, Debug)]
pub struct Row {
    pub model: String,
    pub method: String,
    pub nominal_bits: f64,
    pub achieved_bits: f64,
    pub container_bits: f64,
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    /// (task name, accuracy) when zero-shot was requested.
    pub zeroshot: Vec<(String, f64)>,
    pub mean_rel_err: f64,
}

impl Row {
    pub fn zs_avg(&self) -> f64 {
        if self.zeroshot.is_empty() {
            return f64::NAN;
        }
        self.zeroshot.iter().map(|(_, a)| a).sum::<f64>() / self.zeroshot.len() as f64
    }
}

/// Loaded experiment context.
pub struct Harness {
    pub dir: PathBuf,
    pub model_l: Model,
    pub model_xl: Option<Model>,
    pub held_wiki: Vec<u16>,
    pub held_c4: Vec<u16>,
    pub calib_c4: Vec<Vec<u16>>,
    pub calib_wiki: Vec<Vec<u16>>,
    pub budget: EvalBudget,
    pub registry: Registry,
}

impl Harness {
    /// Load from the artifacts directory; fails with guidance when `make
    /// artifacts` has not been run.
    pub fn load(fast: bool) -> Result<Self> {
        let dir = artifacts_dir();
        let budget = if fast { EvalBudget::fast() } else { EvalBudget::standard() };
        let wl = dir.join("weights_l.bin");
        if !wl.exists() {
            bail!(
                "missing {} — run `make artifacts` (datagen + training) first",
                wl.display()
            );
        }
        let model_l = load_model(&wl).context("load tiny-L")?;
        let model_xl = load_model(&dir.join("weights_xl.bin")).ok();
        let held_wiki = load_tokens(&dir.join("corpus_wiki_heldout.bin"))?;
        let held_c4 = load_tokens(&dir.join("corpus_c4_heldout.bin"))?;
        let train_c4 = load_tokens(&dir.join("corpus_c4_train.bin"))?;
        let train_wiki = load_tokens(&dir.join("corpus_wiki_train.bin"))?;
        let seq = model_l.config.max_seq;
        let calib_cfg = CalibConfig { n_segments: budget.calib_segments, seq_len: seq, seed: 0xCA11B };
        let calib_c4 = sample_segments(&train_c4, &calib_cfg);
        let calib_wiki = sample_segments(&train_wiki, &calib_cfg);
        let registry = Registry::new(&dir)?;
        Ok(Self {
            dir,
            model_l,
            model_xl,
            held_wiki,
            held_c4,
            calib_c4,
            calib_wiki,
            budget,
            registry,
        })
    }

    pub fn model(&self, key: ModelKey) -> Result<&Model> {
        match key {
            ModelKey::TinyL => Ok(&self.model_l),
            ModelKey::TinyXl => self
                .model_xl
                .as_ref()
                .context("weights_xl.bin missing — rerun `make artifacts`"),
        }
    }

    /// Quantize (with the given calibration corpus) and evaluate.
    pub fn run(
        &self,
        key: ModelKey,
        method: &Method,
        calib_on: CorpusKind,
        with_zeroshot: bool,
        experiment: &str,
    ) -> Result<Row> {
        let model = self.model(key)?;
        let calib = match calib_on {
            CorpusKind::SynthC4 => &self.calib_c4,
            CorpusKind::SynthWiki => &self.calib_wiki,
        };
        let (qm, _stats) = quantize_model(model, method, calib, &PipelineOpts::default());
        let dense = qm.to_dense();
        let rep = qm.size_report();
        let ppl_wiki = perplexity(&dense, &self.held_wiki, self.budget.ppl_windows).ppl;
        let ppl_c4 = perplexity(&dense, &self.held_c4, self.budget.ppl_windows).ppl;
        let mut zeroshot = Vec::new();
        if with_zeroshot {
            for spec in &TASKS {
                let items = self.task_items(spec.name)?;
                zeroshot.push((spec.name.to_string(), accuracy(&dense, &items)));
            }
        }
        let achieved = if qm.matrices.is_empty() { 16.0 } else { rep.paper_equivalent_bits };
        let container = if qm.matrices.is_empty() { 32.0 } else { rep.container_bits_per_param };
        let row = Row {
            model: key.name().to_string(),
            method: method.name(),
            nominal_bits: method.nominal_bits(),
            achieved_bits: achieved,
            container_bits: container,
            ppl_wiki,
            ppl_c4,
            zeroshot,
            mean_rel_err: qm.mean_rel_err(),
        };
        self.record(experiment, &row)?;
        Ok(row)
    }

    fn task_items(&self, name: &str) -> Result<Vec<TaskItem>> {
        let spec = TASKS
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("unknown task {name}"))?;
        Ok(generate_task(spec, CorpusKind::SynthWiki, self.budget.zs_items))
    }

    fn record(&self, experiment: &str, row: &Row) -> Result<()> {
        for (metric, value) in [("ppl_wiki", row.ppl_wiki), ("ppl_c4", row.ppl_c4)] {
            self.registry.record(&RunRecord {
                experiment: experiment.to_string(),
                model: row.model.clone(),
                method: row.method.clone(),
                bits: row.achieved_bits,
                metric_name: metric.to_string(),
                metric_value: value,
                detail: String::new(),
            })?;
        }
        for (task, acc) in &row.zeroshot {
            self.registry.record(&RunRecord {
                experiment: experiment.to_string(),
                model: row.model.clone(),
                method: row.method.clone(),
                bits: row.achieved_bits,
                metric_name: format!("acc_{}", task.trim_end_matches('*')),
                metric_value: *acc,
                detail: String::new(),
            })?;
        }
        Ok(())
    }

    /// FP16 baseline row (no quantization).
    pub fn fp16_row(&self, key: ModelKey, with_zeroshot: bool, experiment: &str) -> Result<Row> {
        self.run(key, &Method::Fp16, CorpusKind::SynthC4, with_zeroshot, experiment)
    }
}

/// Render rows as an aligned text table (and return the string).
pub fn render_table(title: &str, rows: &[Row], with_zeroshot: bool) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    if with_zeroshot {
        out.push_str(&format!(
            "{:<10} {:<22} {:>6} {:>7} {:>9} {:>9}",
            "model", "method", "bits", "eq.bits", "ppl-wiki", "ppl-c4"
        ));
        if let Some(r) = rows.first() {
            for (name, _) in &r.zeroshot {
                out.push_str(&format!(" {:>11}", name));
            }
        }
        out.push_str(&format!(" {:>7}\n", "avg"));
    } else {
        out.push_str(&format!(
            "{:<10} {:<22} {:>6} {:>7} {:>9} {:>9} {:>10}\n",
            "model", "method", "bits", "eq.bits", "ppl-wiki", "ppl-c4", "rel-err"
        ));
    }
    for r in rows {
        if with_zeroshot {
            out.push_str(&format!(
                "{:<10} {:<22} {:>6.2} {:>7.2} {:>9.2} {:>9.2}",
                r.model, r.method, r.nominal_bits, r.achieved_bits, r.ppl_wiki, r.ppl_c4
            ));
            for (_, acc) in &r.zeroshot {
                out.push_str(&format!(" {:>11.2}", acc * 100.0));
            }
            out.push_str(&format!(" {:>7.2}\n", r.zs_avg() * 100.0));
        } else {
            out.push_str(&format!(
                "{:<10} {:<22} {:>6.2} {:>7.2} {:>9.2} {:>9.2} {:>10.4}\n",
                r.model, r.method, r.nominal_bits, r.achieved_bits, r.ppl_wiki, r.ppl_c4, r.mean_rel_err
            ));
        }
    }
    out
}

/// Print a table and persist it under artifacts/tables/.
pub fn emit(harness: &Harness, file_stem: &str, text: &str) -> Result<()> {
    println!("{text}");
    let dir = harness.dir.join("tables");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{file_stem}.txt")), text)?;
    Ok(())
}

/// Shared model-size guard used by tests.
pub fn default_config_matches(model: &Model) -> bool {
    model.config == TransformerConfig::tiny_l() || model.config == TransformerConfig::tiny_xl()
}
