//! Tables 3–7 — the ablation studies: adaptive precision vs magnitude
//! mixed precision, OR vs fixed reservation, the outlier standard S sweep,
//! the OR budget-split grid, and the 2&3 vs 2&4 candidate study.

use super::runner::{emit, render_table, Harness, ModelKey, Row};
use crate::data::corpus::CorpusKind;
use crate::quant::config::{Method, DEFAULT_S};
use crate::quant::outliers::ColumnMetric;
use crate::quant::precision::BitPair;
use crate::quant::reservation::OrSetting;
use anyhow::Result;

/// Table 3: column-level AP (Outlier Order) vs MP† (SparseGPT-style
/// salience metric) at 2.5 / 2.2 / 2.1 equivalent bits.
pub fn table3(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    rows.push(h.fp16_row(ModelKey::TinyL, true, "table3")?);
    for m in [Method::Claq { bits: 3 }, Method::Claq { bits: 2 }] {
        eprintln!("[table3] {}", m.name());
        rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table3")?);
    }
    for target in [2.5, 2.2, 2.1] {
        for metric in [ColumnMetric::Salience, ColumnMetric::OutlierRatio] {
            let m = Method::ClaqAp {
                pair: BitPair::new(4, 2),
                target_bits: target,
                metric,
                s: DEFAULT_S,
            };
            eprintln!("[table3] {}", m.name());
            rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table3")?);
        }
    }
    emit(h, "table3", &render_table("Table 3 — AP vs MP† ablation (tiny-L)", &rows, true))?;
    Ok(rows)
}

/// Table 4: adaptive OR vs fixed outlier reservation at 2.28 / 2.14.
pub fn table4(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    rows.push(h.fp16_row(ModelKey::TinyL, true, "table4")?);
    rows.push(h.run(ModelKey::TinyL, &Method::Claq { bits: 2 }, CorpusKind::SynthC4, true, "table4")?);
    for budget in [0.28, 0.14] {
        for fixed in [true, false] {
            let m = if fixed {
                Method::ClaqOrFixed { bits: 2, budget_bits: budget }
            } else {
                Method::ClaqOr { bits: 2, budget_bits: budget, setting: OrSetting::SETTING2, s: DEFAULT_S }
            };
            eprintln!("[table4] {}", m.name());
            rows.push(h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table4")?);
        }
    }
    emit(h, "table4", &render_table("Table 4 — OR vs fixed reservation (tiny-L)", &rows, true))?;
    Ok(rows)
}

/// Table 5 (Appendix B): outlier standard S sweep at AP 2.2.
pub fn table5(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for s in [1.0, 3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0, 17.0] {
        let m = Method::ClaqAp {
            pair: BitPair::new(4, 2),
            target_bits: 2.2,
            metric: ColumnMetric::OutlierRatio,
            s,
        };
        eprintln!("[table5] S={s}");
        let mut row = h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, false, "table5")?;
        row.method = format!("CLAQ+AP-2.2 (S={s})");
        rows.push(row);
    }
    emit(h, "table5", &render_table("Table 5 (App. B) — outlier standard S sweep", &rows, false))?;
    Ok(rows)
}

/// Table 6 (Appendix C): OR budget-split settings 1–3 at 2.28 / 2.14.
pub fn table6(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    rows.push(h.fp16_row(ModelKey::TinyL, true, "table6")?);
    for budget in [0.28, 0.14] {
        for setting in 1..=3usize {
            let m = Method::ClaqOr {
                bits: 2,
                budget_bits: budget,
                setting: OrSetting::by_id(setting),
                s: DEFAULT_S,
            };
            eprintln!("[table6] budget={budget} setting={setting}");
            let mut row = h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, true, "table6")?;
            row.method = format!("+OR-{:.2} Setting{setting}", 2.0 + budget);
            rows.push(row);
        }
    }
    emit(h, "table6", &render_table("Table 6 (App. C) — OR budget split grid", &rows, true))?;
    Ok(rows)
}

/// Table 7 (Appendix D): 2&3 vs 2&4 bit candidates at 2.1, S ∈ {5, 9, 13}.
pub fn table7(h: &Harness) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for s in [5.0, 9.0, 13.0] {
        for hi in [3u8, 4u8] {
            let m = Method::ClaqAp {
                pair: BitPair::new(hi, 2),
                target_bits: 2.1,
                metric: ColumnMetric::OutlierRatio,
                s,
            };
            eprintln!("[table7] S={s} bits=2&{hi}");
            let mut row = h.run(ModelKey::TinyL, &m, CorpusKind::SynthC4, false, "table7")?;
            row.method = format!("AP-2.1 2&{hi} (S={s})");
            rows.push(row);
        }
    }
    emit(h, "table7", &render_table("Table 7 (App. D) — AP candidate bit-width study", &rows, false))?;
    Ok(rows)
}
