//! `claq` — CLI entrypoint of the CLAQ reproduction.
//!
//! Subcommands:
//! * `datagen`   — write the synthetic corpora to `artifacts/` (build step;
//!                 the JAX trainer consumes these files).
//! * `quantize`  — quantize a trained model with a chosen method and
//!                 report size + perplexity.
//! * `pack`      — quantize once and write the single-file CLAQMD01
//!                 checkpoint (the quantize-once / serve-many artifact).
//! * `serve`     — cold-start the continuous-batching engine from a
//!                 checkpoint, skipping quantization entirely.
//! * `tune`      — per-layer bit-budget autotuner: probe layer sensitivity
//!                 against measured perplexity + decode tok/s, emit a tuned
//!                 mixed-bit checkpoint.
//! * `table <n>` — regenerate paper table n (1–13).
//! * `figure <n>`— regenerate paper figure n (3–5).
//! * `outliers`  — print outlier-order diagnostics for a model.
//! * `bench-check` — compare fresh `BENCH_*.json` bench results against
//!                 the committed `ci/bench_baseline/` and fail on
//!                 regressions beyond tolerance (the CI perf gate).
//!
//! Run `claq help` for flags.

use anyhow::{bail, Result};
use claq::util::cli::Args;

const VALUE_FLAGS: &[&str] = &[
    "out", "model", "method", "bits", "s", "segments", "windows", "items", "tokens", "seed",
    "setting", "calib", "target", "workers", "artifacts", "checkpoint", "requests", "slots",
    "baseline", "fresh", "tol", "kv-page-tokens", "kv-quant-bits", "kv-budget-mb", "max-queue",
    "deadline-steps", "group-dim", "hi", "lo", "decode-tokens",
];

fn usage() -> &'static str {
    "claq — CLAQ: Column-Level Adaptive weight Quantization (reproduction)

USAGE:
  claq datagen  [--out artifacts] [--tokens N]
  claq quantize --model artifacts/weights_l.bin --method fusion-2.12
  claq pack     --out model.claq [--model l|xl|PATH] [--method SPEC] [--random] [--fast]
  claq serve    --checkpoint model.claq [--requests 16] [--slots 4] [--seed 17]
                [--kv-page-tokens 64] [--kv-quant-bits 0] [--kv-budget-mb 0]
                [--max-queue 0] [--deadline-steps 0]
  claq tune     [--target 2.5] [--hi 4 --lo 2] [--windows 8] [--decode-tokens 64]
                [--out tuned.claq] [--model l|xl|PATH] [--random] [--smoke]
  claq table    <1|2|3|4|5|6|7|8|10|12|13> [--fast]
  claq figure   <3|4|5>
  claq outliers [--model PATH] [--s 13]
  claq eval     --model PATH [--method SPEC]
  claq bench-check [--baseline ci/bench_baseline] [--fresh .] [--tol 0.25] [--update]
  claq help

METHOD SPECS (for --method; parse-time validated, see README methods table):
  fp16              no quantization
  rtn:B gptq:B awq:B claq:B
                    uniform B-bit baselines / CLAQ K-Means (B in 1..=8)
  claq-ap:LO+HI@T   adaptive precision, LO/HI-bit columns mixed to hit
                    T equivalent bits (e.g. claq-ap:2+4@2.05)
  claq-or:B+E       outlier reservation, B-bit + E extra budget bits
  claq-or-fixed:B+E fixed-rate reservation variant
  claq-vq:dDbB      vector-quantized groups of D adjacent columns sharing
                    one 2^B-entry codebook (B/D bits per param indices)
  fusion-2.12|2.24|3.12|3.23
                    paper Appendix F fusion presets (AP + OR); also
                    spelled claq-fusion-2.12; fusion:LO+HI@A+O is the
                    generic form (AP target A, OR budget O)
  tune emits per-layer mixed-bit BitPlans searched against measured
  perplexity and decode tok/s; --smoke is the fast CI self-check.

  Bare names (claq, claq-ap, claq-vq, ... with --bits/--hi/--lo/--group-dim
  /--s/--setting) remain as deprecated aliases for one release.
"
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv, VALUE_FLAGS).map_err(|e| anyhow::anyhow!(e))?;
    match cmd.as_str() {
        "datagen" => claq::tables::bootstrap::datagen(&args),
        "quantize" => claq::tables::cli_entry::quantize(&args),
        "pack" => claq::tables::cli_entry::pack(&args),
        "serve" => claq::tables::cli_entry::serve(&args),
        "tune" => claq::tables::cli_entry::tune(&args),
        "eval" => claq::tables::cli_entry::eval(&args),
        "table" => claq::tables::cli_entry::table(&args),
        "figure" => claq::tables::cli_entry::figure(&args),
        "outliers" => claq::tables::cli_entry::outliers(&args),
        "bench-check" => claq::tables::cli_entry::bench_check(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}
