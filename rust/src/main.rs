//! `claq` — CLI entrypoint of the CLAQ reproduction.
//!
//! Subcommands:
//! * `datagen`   — write the synthetic corpora to `artifacts/` (build step;
//!                 the JAX trainer consumes these files).
//! * `quantize`  — quantize a trained model with a chosen method and
//!                 report size + perplexity.
//! * `pack`      — quantize once and write the single-file CLAQMD01
//!                 checkpoint (the quantize-once / serve-many artifact).
//! * `serve`     — cold-start the continuous-batching engine from a
//!                 checkpoint, skipping quantization entirely.
//! * `table <n>` — regenerate paper table n (1–13).
//! * `figure <n>`— regenerate paper figure n (3–5).
//! * `outliers`  — print outlier-order diagnostics for a model.
//! * `bench-check` — compare fresh `BENCH_*.json` bench results against
//!                 the committed `ci/bench_baseline/` and fail on
//!                 regressions beyond tolerance (the CI perf gate).
//!
//! Run `claq help` for flags.

use anyhow::{bail, Result};
use claq::util::cli::Args;

const VALUE_FLAGS: &[&str] = &[
    "out", "model", "method", "bits", "s", "segments", "windows", "items", "tokens", "seed",
    "setting", "calib", "target", "workers", "artifacts", "checkpoint", "requests", "slots",
    "baseline", "fresh", "tol", "kv-page-tokens", "kv-quant-bits", "kv-budget-mb", "max-queue",
    "deadline-steps", "group-dim", "hi", "lo",
];

fn usage() -> &'static str {
    "claq — CLAQ: Column-Level Adaptive weight Quantization (reproduction)

USAGE:
  claq datagen  [--out artifacts] [--tokens N]
  claq quantize --model artifacts/weights_l.bin --method claq --bits 2.12
  claq pack     --out model.claq [--model l|xl|PATH] [--method claq --bits 2.12] [--random] [--fast]
                [--method claq-ap --bits 2.2 --hi 4 --lo 2]
                [--method claq-vq --bits 2 --group-dim 4]   (sub-2-bit: bits/group-dim b/param)
  claq serve    --checkpoint model.claq [--requests 16] [--slots 4] [--seed 17]
                [--kv-page-tokens 64] [--kv-quant-bits 0] [--kv-budget-mb 0]
                [--max-queue 0] [--deadline-steps 0]
  claq table    <1|2|3|4|5|6|7|8|10|12|13> [--fast]
  claq figure   <3|4|5>
  claq outliers [--model PATH] [--s 13]
  claq eval     --model PATH [--method METHOD --bits B]
  claq bench-check [--baseline ci/bench_baseline] [--fresh .] [--tol 0.25] [--update]
  claq help

METHODS (for --method): fp16, rtn, gptq, awq, claq, claq-ap, claq-or,
  claq-or-fixed, claq-fusion, claq-search, claq-vq

  claq-ap takes --hi/--lo (default 4/floor(bits)) for the dual-level pair.
  claq-vq quantizes groups of --group-dim adjacent columns with one 2^bits
  vector codebook per group: index cost is bits/group-dim bits per param,
  e.g. --bits 2 --group-dim 4 is 0.5-bit indices.
"
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv, VALUE_FLAGS).map_err(|e| anyhow::anyhow!(e))?;
    match cmd.as_str() {
        "datagen" => claq::tables::bootstrap::datagen(&args),
        "quantize" => claq::tables::cli_entry::quantize(&args),
        "pack" => claq::tables::cli_entry::pack(&args),
        "serve" => claq::tables::cli_entry::serve(&args),
        "eval" => claq::tables::cli_entry::eval(&args),
        "table" => claq::tables::cli_entry::table(&args),
        "figure" => claq::tables::cli_entry::figure(&args),
        "outliers" => claq::tables::cli_entry::outliers(&args),
        "bench-check" => claq::tables::cli_entry::bench_check(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}
