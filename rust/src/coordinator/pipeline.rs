//! The quantization pipeline — the paper's workflow end to end:
//!
//! 1. **Calibrate**: run the (partially quantized) model over the
//!    calibration segments, capturing the inputs of every linear layer and
//!    accumulating per-matrix Hessians H = 2·Σ x xᵀ.
//! 2. **Sensitivity**: compute Outlier Order / comparator metrics.
//! 3. **Allocate**: AP bit maps and OR reservation budgets per matrix.
//! 4. **Quantize**: the GPTQ engine with K-Means (or baseline) codebooks,
//!    matrices of one layer fanned out over the thread pool.
//! 5. Layers are processed **sequentially** so layer k's calibration
//!    activations reflect the already-quantized layers < k (GPTQ
//!    convention).

use crate::model::forward::{forward_captured, ForwardState, LayerCapture};
use crate::model::quantized::QuantizedModel;
use crate::model::{MatrixId, MatrixKind, Model};
use crate::quant::awq::{dequantize_awq, quantize_awq};
use crate::quant::config::Method;
use crate::quant::gptq::quantize_matrix;
use crate::quant::outliers::OutlierStats;
use crate::quant::precision::BitPair;
use crate::quant::search::{self, MatrixClass, SearchConfig};
use crate::util::threadpool::{host_threads, ThreadPool};
use std::collections::HashMap;
use std::time::Instant;

/// Pipeline options.
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    /// Worker threads for intra-layer matrix fan-out. Parallelism nests
    /// one level deep: with `workers > 1` this fan-out occupies the pool
    /// and the quantizer's row-sharded trailing OBS updates fall back
    /// inline (the thread pool's nested-dispatch rule); with
    /// `workers == 1` matrices quantize sequentially and each trailing
    /// update fans out across `ThreadPool::global` instead — the right
    /// mode for few huge matrices.
    pub workers: usize,
    /// Progress logging to stderr.
    pub verbose: bool,
    /// Incremental calibration: keep per-segment hidden states and advance
    /// them one layer at a time (2 layer-steps per layer) instead of
    /// re-running a full forward per layer (L layer-steps + LM head per
    /// layer). Same math, ~L/2× less calibration work — see DESIGN.md §5.
    /// The non-incremental path is kept for the ablation bench.
    pub incremental: bool,
    /// OBS lazy-update block width handed to every `MatrixPlan`
    /// (DESIGN.md §8). Purely a performance knob — any value, 0 meaning
    /// unblocked, yields bit-identical quantization.
    pub quant_block: usize,
    /// Save-after-quantize: write the single-file `CLAQMD01` checkpoint
    /// here once quantization finishes (quantize once, cold-start serve
    /// many — DESIGN.md §9). Outcome lands in
    /// `PipelineStats::checkpoint_bytes` / `checkpoint_error`.
    pub save_checkpoint: Option<std::path::PathBuf>,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        Self {
            workers: host_threads(),
            verbose: false,
            incremental: true,
            quant_block: crate::quant::gptq::DEFAULT_BLOCK,
            save_checkpoint: None,
        }
    }
}

/// Per-run statistics.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub calib_seconds: f64,
    pub quant_seconds: f64,
    pub per_matrix_err: Vec<(String, f64)>,
    /// Bytes written by the save-after-quantize option (None when not
    /// requested or failed).
    pub checkpoint_bytes: Option<u64>,
    /// Why the save-after-quantize write failed, if it did (e.g. an FP16
    /// run has nothing to checkpoint, or the path is unwritable).
    pub checkpoint_error: Option<String>,
}

/// Run the save-after-quantize option, recording the outcome in `stats`.
fn save_checkpoint_if_requested(
    qm: &QuantizedModel,
    opts: &PipelineOpts,
    stats: &mut PipelineStats,
) {
    let Some(path) = &opts.save_checkpoint else { return };
    match crate::model::checkpoint::save_checkpoint(qm, path) {
        Ok(bytes) => {
            stats.checkpoint_bytes = Some(bytes);
            if opts.verbose {
                eprintln!("[pipeline] checkpoint: {} ({bytes} bytes)", path.display());
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            eprintln!("[pipeline] checkpoint save to {} failed: {msg}", path.display());
            stats.checkpoint_error = Some(msg);
        }
    }
}

/// Accumulated Hessians for the matrices of one layer.
pub struct LayerHessians {
    /// H per matrix kind, each cols×cols (f64).
    pub h: HashMap<MatrixKind, Vec<f64>>,
    pub samples: usize,
}

/// Accumulate X → H += 2·XᵀX for a (seq × n) activation block.
fn accumulate(h: &mut [f64], x: &[f32], seq: usize, n: usize) {
    debug_assert_eq!(h.len(), n * n);
    debug_assert!(x.len() >= seq * n);
    for t in 0..seq {
        let row = &x[t * n..(t + 1) * n];
        for i in 0..n {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h[i * n..(i + 1) * n];
            let two_xi = 2.0 * xi;
            for j in 0..n {
                hrow[j] += two_xi * row[j] as f64;
            }
        }
    }
}

/// Run calibration for one layer of `model`, returning the four Hessians
/// (attention-in drives wq/wk/wv; wo, mlp-in drives w_gate/w_up; w_down).
pub fn calibrate_layer(
    model: &Model,
    segments: &[Vec<u16>],
    layer: usize,
    state: &mut ForwardState,
) -> LayerHessians {
    let d = model.config.d_model;
    let f = model.config.d_ff;
    let mut h_attn = vec![0.0f64; d * d];
    let mut h_wo = vec![0.0f64; d * d];
    let mut h_mlp = vec![0.0f64; d * d];
    let mut h_down = vec![0.0f64; f * f];
    let mut cap = LayerCapture::default();
    for seg in segments {
        let _ = forward_captured(model, seg, state, layer, &mut cap);
        let seq = cap.seq;
        accumulate(&mut h_attn, &cap.attn_in, seq, d);
        accumulate(&mut h_wo, &cap.wo_in, seq, d);
        accumulate(&mut h_mlp, &cap.mlp_in, seq, d);
        accumulate(&mut h_down, &cap.down_in, seq, f);
    }
    let mut h = HashMap::new();
    let shared = h_attn;
    h.insert(MatrixKind::Wq, shared.clone());
    h.insert(MatrixKind::Wk, shared.clone());
    h.insert(MatrixKind::Wv, shared);
    h.insert(MatrixKind::Wo, h_wo);
    let mlp_shared = h_mlp;
    h.insert(MatrixKind::WGate, mlp_shared.clone());
    h.insert(MatrixKind::WUp, mlp_shared);
    h.insert(MatrixKind::WDown, h_down);
    LayerHessians { h, samples: segments.len() }
}

fn hess_diag(h: &[f64], n: usize) -> Vec<f64> {
    (0..n).map(|i| h[i * n + i]).collect()
}

/// Incremental calibration state: per-segment hidden states advanced one
/// layer at a time (GPTQ's sequential protocol without re-forwarding).
pub struct IncrementalCalib {
    xs: Vec<Vec<f32>>,
}

impl IncrementalCalib {
    pub fn new(model: &Model, segments: &[Vec<u16>]) -> Self {
        Self { xs: segments.iter().map(|s| crate::model::forward::embed(model, s)).collect() }
    }

    /// Hessians of `layer` from the current hidden states (weights of the
    /// layer unchanged — captures run on scratch copies of the states).
    pub fn capture(
        &self,
        model: &Model,
        segments: &[Vec<u16>],
        layer: usize,
        state: &mut ForwardState,
    ) -> LayerHessians {
        let d = model.config.d_model;
        let f = model.config.d_ff;
        let mut h_attn = vec![0.0f64; d * d];
        let mut h_wo = vec![0.0f64; d * d];
        let mut h_mlp = vec![0.0f64; d * d];
        let mut h_down = vec![0.0f64; f * f];
        let mut cap = LayerCapture::default();
        let mut scratch: Vec<f32> = Vec::new();
        for (seg, x) in segments.iter().zip(&self.xs) {
            scratch.clear();
            scratch.extend_from_slice(x);
            crate::model::forward::layer_step(model, layer, &mut scratch, seg.len(), state, Some(&mut cap));
            let seq = cap.seq;
            accumulate(&mut h_attn, &cap.attn_in, seq, d);
            accumulate(&mut h_wo, &cap.wo_in, seq, d);
            accumulate(&mut h_mlp, &cap.mlp_in, seq, d);
            accumulate(&mut h_down, &cap.down_in, seq, f);
        }
        let mut h = HashMap::new();
        h.insert(MatrixKind::Wq, h_attn.clone());
        h.insert(MatrixKind::Wk, h_attn.clone());
        h.insert(MatrixKind::Wv, h_attn);
        h.insert(MatrixKind::Wo, h_wo);
        h.insert(MatrixKind::WGate, h_mlp.clone());
        h.insert(MatrixKind::WUp, h_mlp);
        h.insert(MatrixKind::WDown, h_down);
        LayerHessians { h, samples: segments.len() }
    }

    /// Advance all segment states through `layer` with the (now-quantized)
    /// weights in `model`.
    pub fn advance(
        &mut self,
        model: &Model,
        segments: &[Vec<u16>],
        layer: usize,
        state: &mut ForwardState,
    ) {
        for (seg, x) in segments.iter().zip(self.xs.iter_mut()) {
            crate::model::forward::layer_step(model, layer, x, seg.len(), state, None);
        }
    }
}

/// Quantize a whole model with `method`, sequentially by layer. The
/// returned `QuantizedModel.base` has its quantized matrices *replaced* by
/// their dequantized values, so downstream layers calibrated against it see
/// quantization error upstream (and `to_dense` is consistent).
pub fn quantize_model(
    model: &Model,
    method: &Method,
    segments: &[Vec<u16>],
    opts: &PipelineOpts,
) -> (QuantizedModel, PipelineStats) {
    let mut stats = PipelineStats::default();
    let mut work = model.clone();
    let mut matrices = HashMap::new();
    let mut awq_scales = HashMap::new();
    if matches!(method, Method::Fp16) {
        let qm = QuantizedModel {
            base: work,
            matrices,
            awq_scales,
            method_name: method.name(),
        };
        // An FP16 run has nothing to checkpoint; the attempt records a
        // clear error instead of silently skipping the requested save.
        save_checkpoint_if_requested(&qm, opts, &mut stats);
        return (qm, stats);
    }
    let pool = ThreadPool::new(opts.workers);
    let mut state = ForwardState::new(model.config);
    let mut inc = (opts.incremental && method.needs_hessian())
        .then(|| IncrementalCalib::new(&work, segments));

    for layer in 0..model.config.n_layers {
        // 1. calibration Hessians against the partially-quantized model
        let t0 = Instant::now();
        let hessians = if method.needs_hessian() {
            Some(match &inc {
                Some(ic) => ic.capture(&work, segments, layer, &mut state),
                None => calibrate_layer(&work, segments, layer, &mut state),
            })
        } else {
            None
        };
        stats.calib_seconds += t0.elapsed().as_secs_f64();

        // 2–4. quantize the 7 matrices of this layer in parallel
        let t1 = Instant::now();
        let kinds = MatrixKind::ALL;
        let results: Vec<_> = pool.run(kinds.len(), |ki| {
            let kind = kinds[ki];
            let id = MatrixId { layer, kind };
            let w = work.matrix(id);
            let h = hessians.as_ref().map(|hs| hs.h.get(&kind).unwrap().as_slice());
            match method {
                Method::Awq { bits } => {
                    let r = quantize_awq(w, h.expect("AWQ needs hessian"), *bits);
                    let deq = dequantize_awq(&r);
                    (id, Some((r.quantized, Some(r.scales))), deq)
                }
                m => {
                    let hd = h.map(|h| hess_diag(h, w.cols));
                    let mut plan = m.plan_for(w, hd.as_deref()).expect("plan");
                    plan.block_size = opts.quant_block;
                    let q = quantize_matrix(w, h, &plan);
                    let deq = q.dequantize();
                    (id, Some((q, None)), deq)
                }
            }
        });
        stats.quant_seconds += t1.elapsed().as_secs_f64();

        for (id, q, deq) in results {
            if let Some((qm, scales)) = q {
                stats
                    .per_matrix_err
                    .push((id.name(), qm.metrics.rel_frobenius_err));
                matrices.insert(id, qm);
                if let Some(s) = scales {
                    awq_scales.insert(id, s);
                }
            }
            *work.matrix_mut(id) = deq;
        }
        // advance the incremental states through the quantized layer
        if let Some(ic) = inc.as_mut() {
            let t2 = Instant::now();
            ic.advance(&work, segments, layer, &mut state);
            stats.calib_seconds += t2.elapsed().as_secs_f64();
        }
        if opts.verbose {
            eprintln!(
                "[pipeline] layer {layer}: calib {:.2}s quant {:.2}s",
                stats.calib_seconds, stats.quant_seconds
            );
        }
    }

    let qm = QuantizedModel { base: work, matrices, awq_scales, method_name: method.name() };
    save_checkpoint_if_requested(&qm, opts, &mut stats);
    (qm, stats)
}

/// Appendix G: heuristic adaptive-precision search across all matrices,
/// then per-matrix quantization with the searched assignments.
pub fn quantize_model_heuristic(
    model: &Model,
    cfg: &SearchConfig,
    s: f64,
    segments: &[Vec<u16>],
    opts: &PipelineOpts,
) -> (QuantizedModel, PipelineStats, search::SearchResult) {
    // 1. per-matrix outlier ratios (Appendix A Figure-5 statistic)
    let ids = model.matrix_ids();
    let infos: Vec<search::MatrixInfo> = ids
        .iter()
        .map(|&id| {
            let w = model.matrix(id);
            let st = OutlierStats::compute(w, s);
            search::MatrixInfo {
                name: id.name(),
                outlier_ratio: st.overall_ratio(),
                params: w.rows * w.cols,
            }
        })
        .collect();
    let result = search::search(&infos, cfg);

    // 2. express each assignment as a per-matrix Method and quantize layer
    // by layer (sequential calibration, as in quantize_model).
    let mut work = model.clone();
    let mut matrices = HashMap::new();
    let mut stats = PipelineStats::default();
    let mut state = ForwardState::new(model.config);
    let pool = ThreadPool::new(opts.workers);
    let mut inc = opts.incremental.then(|| IncrementalCalib::new(&work, segments));

    for layer in 0..model.config.n_layers {
        let t0 = Instant::now();
        let hessians = match &inc {
            Some(ic) => ic.capture(&work, segments, layer, &mut state),
            None => calibrate_layer(&work, segments, layer, &mut state),
        };
        stats.calib_seconds += t0.elapsed().as_secs_f64();
        let kinds = MatrixKind::ALL;
        let t1 = Instant::now();
        let results: Vec<_> = pool.run(kinds.len(), |ki| {
            let kind = kinds[ki];
            let id = MatrixId { layer, kind };
            let idx = ids.iter().position(|&x| x == id).unwrap();
            let assign = &result.assignments[idx];
            let w = work.matrix(id);
            let h = hessians.h.get(&kind).unwrap().as_slice();
            let target = assign.equivalent_bits(cfg.base_bits);
            let method = match assign.class {
                MatrixClass::Lo => Method::Claq { bits: cfg.base_bits },
                MatrixClass::Mix3 => Method::ClaqAp {
                    pair: BitPair::new(3, cfg.base_bits),
                    target_bits: target,
                    metric: crate::quant::outliers::ColumnMetric::OutlierRatio,
                    s,
                },
                MatrixClass::Mix4 => Method::ClaqAp {
                    pair: BitPair::new(4, cfg.base_bits),
                    target_bits: target,
                    metric: crate::quant::outliers::ColumnMetric::OutlierRatio,
                    s,
                },
            };
            let mut plan = method.plan_for(w, None).unwrap();
            plan.block_size = opts.quant_block;
            let q = quantize_matrix(w, Some(h), &plan);
            let deq = q.dequantize();
            (id, q, deq)
        });
        stats.quant_seconds += t1.elapsed().as_secs_f64();
        for (id, q, deq) in results {
            stats.per_matrix_err.push((id.name(), q.metrics.rel_frobenius_err));
            matrices.insert(id, q);
            *work.matrix_mut(id) = deq;
        }
        if let Some(ic) = inc.as_mut() {
            ic.advance(&work, segments, layer, &mut state);
        }
    }
    let qm = QuantizedModel {
        base: work,
        matrices,
        awq_scales: HashMap::new(),
        method_name: format!("CLAQ+AP(search)-{:.2}", result.achieved_bits),
    };
    save_checkpoint_if_requested(&qm, opts, &mut stats);
    (qm, stats, result)
}

/// `claq tune` driver (DESIGN.md §16): per-layer adaptive precision where
/// every matrix of layer `l` is quantized at `targets[l]` equivalent bits
/// within `pair` — plain uniform CLAQ at the interval ends, `ClaqAp`
/// mixed-bit planes in between. Same sequential-calibration discipline as
/// [`quantize_model_heuristic`]; the targets come from
/// `quant::search::allocate_layer_targets` over measured probe runs.
pub fn quantize_model_tuned(
    model: &Model,
    pair: BitPair,
    targets: &[f64],
    s: f64,
    segments: &[Vec<u16>],
    opts: &PipelineOpts,
) -> (QuantizedModel, PipelineStats) {
    assert_eq!(
        targets.len(),
        model.config.n_layers,
        "one bit target per layer ({} targets, {} layers)",
        targets.len(),
        model.config.n_layers
    );
    let (lo, hi) = (pair.lo as f64, pair.hi as f64);
    let mut work = model.clone();
    let mut matrices = HashMap::new();
    let mut stats = PipelineStats::default();
    let mut state = ForwardState::new(model.config);
    let pool = ThreadPool::new(opts.workers);
    let mut inc = opts.incremental.then(|| IncrementalCalib::new(&work, segments));

    for layer in 0..model.config.n_layers {
        let t0 = Instant::now();
        let hessians = match &inc {
            Some(ic) => ic.capture(&work, segments, layer, &mut state),
            None => calibrate_layer(&work, segments, layer, &mut state),
        };
        stats.calib_seconds += t0.elapsed().as_secs_f64();
        let target = targets[layer].clamp(lo, hi);
        let method = if (target - lo).abs() < 1e-9 {
            Method::Claq { bits: pair.lo }
        } else if (target - hi).abs() < 1e-9 {
            Method::Claq { bits: pair.hi }
        } else {
            Method::ClaqAp {
                pair,
                target_bits: target,
                metric: crate::quant::outliers::ColumnMetric::OutlierRatio,
                s,
            }
        };
        let kinds = MatrixKind::ALL;
        let t1 = Instant::now();
        let results: Vec<_> = pool.run(kinds.len(), |ki| {
            let kind = kinds[ki];
            let id = MatrixId { layer, kind };
            let w = work.matrix(id);
            let h = hessians.h.get(&kind).unwrap().as_slice();
            let mut plan = method.plan_for(w, None).unwrap();
            plan.block_size = opts.quant_block;
            let q = quantize_matrix(w, Some(h), &plan);
            let deq = q.dequantize();
            (id, q, deq)
        });
        stats.quant_seconds += t1.elapsed().as_secs_f64();
        for (id, q, deq) in results {
            stats.per_matrix_err.push((id.name(), q.metrics.rel_frobenius_err));
            matrices.insert(id, q);
            *work.matrix_mut(id) = deq;
        }
        if let Some(ic) = inc.as_mut() {
            ic.advance(&work, segments, layer, &mut state);
        }
    }
    // parameter-weighted achieved equivalent bits, for the method label
    let mut bits_params = 0.0f64;
    let mut total_params = 0.0f64;
    for (layer, &t) in targets.iter().enumerate() {
        let params: usize = MatrixKind::ALL
            .iter()
            .map(|&kind| {
                let w = model.matrix(MatrixId { layer, kind });
                w.rows * w.cols
            })
            .sum();
        bits_params += t.clamp(lo, hi) * params as f64;
        total_params += params as f64;
    }
    let qm = QuantizedModel {
        base: work,
        matrices,
        awq_scales: HashMap::new(),
        method_name: format!("CLAQ+AP(tuned)-{:.2}", bits_params / total_params),
    };
    save_checkpoint_if_requested(&qm, opts, &mut stats);
    (qm, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::calibration::{sample_segments, CalibConfig};
    use crate::data::corpus::{generate, CorpusKind, VOCAB};
    use crate::eval::perplexity::perplexity;
    use crate::model::TransformerConfig;
    use crate::util::rng::Rng;

    fn test_cfg() -> TransformerConfig {
        TransformerConfig {
            vocab: VOCAB,
            d_model: 24,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 32,
            rope_theta: 10000.0,
            eps: 1e-5,
        }
    }

    fn setup() -> (Model, Vec<Vec<u16>>, Vec<u16>) {
        let model = Model::random(test_cfg(), &mut Rng::new(11));
        let stream = generate(CorpusKind::SynthC4, 4000, 1);
        let calib = sample_segments(&stream, &CalibConfig { n_segments: 8, seq_len: 32, seed: 5 });
        let heldout = generate(CorpusKind::SynthC4, 640, 2);
        (model, calib, heldout)
    }

    #[test]
    fn fp16_passthrough() {
        let (model, calib, _) = setup();
        let (qm, _) = quantize_model(&model, &Method::Fp16, &calib, &PipelineOpts::default());
        assert!(qm.matrices.is_empty());
        let dense = qm.to_dense();
        assert_eq!(dense.layers[0].wq.data, model.layers[0].wq.data);
    }

    #[test]
    fn all_matrices_quantized() {
        let (model, calib, _) = setup();
        let (qm, stats) =
            quantize_model(&model, &Method::Claq { bits: 4 }, &calib, &PipelineOpts::default());
        assert_eq!(qm.matrices.len(), model.matrix_ids().len());
        assert_eq!(stats.per_matrix_err.len(), qm.matrices.len());
        assert!(stats.quant_seconds > 0.0);
    }

    #[test]
    fn claq4_ppl_close_to_fp16() {
        let (model, calib, heldout) = setup();
        let base_ppl = perplexity(&model, &heldout, 0).ppl;
        let (qm, _) =
            quantize_model(&model, &Method::Claq { bits: 4 }, &calib, &PipelineOpts::default());
        let q_ppl = perplexity(&qm.to_dense(), &heldout, 0).ppl;
        // 4-bit CLAQ on a random model: small relative PPL change
        assert!((q_ppl / base_ppl - 1.0).abs() < 0.15, "fp {base_ppl} vs q {q_ppl}");
    }

    #[test]
    fn awq_path_produces_scales() {
        let (model, calib, _) = setup();
        let (qm, _) =
            quantize_model(&model, &Method::Awq { bits: 4 }, &calib, &PipelineOpts::default());
        assert_eq!(qm.awq_scales.len(), qm.matrices.len());
        let dense = qm.to_dense();
        // reconstruction must be in original weight space (close to source)
        let a = &model.layers[0].wq;
        let b = &dense.layers[0].wq;
        let mut num = 0.0;
        let mut den = 0.0;
        for (x, y) in a.data.iter().zip(&b.data) {
            num += ((x - y) as f64).powi(2);
            den += (*x as f64).powi(2);
        }
        assert!((num / den).sqrt() < 0.2, "rel err {}", (num / den).sqrt());
    }

    #[test]
    fn heuristic_search_pipeline_runs() {
        let (model, calib, _) = setup();
        let cfg = SearchConfig { target_bits: 2.5, ..Default::default() };
        let (qm, _, result) =
            quantize_model_heuristic(&model, &cfg, 13.0, &calib, &PipelineOpts::default());
        assert_eq!(qm.matrices.len(), model.matrix_ids().len());
        assert!(result.achieved_bits <= 2.5 + 1e-6);
        let rep = qm.size_report();
        assert!(rep.paper_equivalent_bits <= 2.5 + 0.1);
    }

    #[test]
    fn tuned_pipeline_mixes_bits_per_layer() {
        let (model, calib, _) = setup();
        let pair = BitPair::new(4, 2);
        let targets = vec![2.0, 2.5];
        let (qm, _) =
            quantize_model_tuned(&model, pair, &targets, 13.0, &calib, &PipelineOpts::default());
        assert_eq!(qm.matrices.len(), model.matrix_ids().len());
        assert!(qm.method_name.starts_with("CLAQ+AP(tuned)-"), "{}", qm.method_name);
        for kind in MatrixKind::ALL {
            // layer 0 at the lo end is plain uniform 2-bit
            let q0 = &qm.matrices[&MatrixId { layer: 0, kind }];
            assert!(q0.columns().iter().all(|c| c.bits == 2), "{kind:?} layer 0 not uniform");
            // layer 1 at 2.5 equivalent bits is genuinely mixed 2/4
            let q1 = &qm.matrices[&MatrixId { layer: 1, kind }];
            let n_hi = q1.columns().iter().filter(|c| c.bits == 4).count();
            let n_lo = q1.columns().iter().filter(|c| c.bits == 2).count();
            assert_eq!(n_hi + n_lo, q1.cols, "{kind:?} layer 1 has off-pair widths");
            assert!(n_hi > 0 && n_lo > 0, "{kind:?} layer 1 should mix bits");
        }
    }

    #[test]
    fn sequential_calibration_differs_from_static() {
        // The Hessian of layer 1 must be computed against the quantized
        // layer 0 — check the pipeline actually mutates `work`.
        let (model, calib, _) = setup();
        let (qm, _) =
            quantize_model(&model, &Method::Claq { bits: 2 }, &calib, &PipelineOpts::default());
        // base weights must equal dequantized matrices (mutated in place)
        let id = MatrixId { layer: 0, kind: MatrixKind::Wq };
        let deq = qm.matrices[&id].dequantize();
        assert_eq!(qm.base.matrix(id).data, deq.data);
        assert_ne!(model.matrix(id).data, deq.data);
    }

    #[test]
    fn save_after_quantize_writes_checkpoint() {
        use crate::model::exec::{prefill, ExecModel, ExecState, KvCache};
        let (model, calib, _) = setup();
        let path = crate::util::tmp::unique_path("pipeline_ckpt").with_extension("claq");
        let _ = std::fs::remove_file(&path);
        let opts = PipelineOpts { save_checkpoint: Some(path.clone()), ..Default::default() };
        let (qm, stats) = quantize_model(&model, &Method::Claq { bits: 3 }, &calib, &opts);
        assert!(stats.checkpoint_error.is_none(), "{:?}", stats.checkpoint_error);
        assert_eq!(stats.checkpoint_bytes, Some(std::fs::metadata(&path).unwrap().len()));
        assert_eq!(stats.checkpoint_bytes, Some(qm.size_report().checkpoint_bytes as u64));

        // the written artifact cold-starts into a working packed model
        let ckpt = crate::model::checkpoint::Checkpoint::load(&path).unwrap();
        let exec = ExecModel::from_checkpoint(ckpt).unwrap();
        let mut st = ExecState::new(model.config);
        let mut cache = KvCache::new(&model.config);
        let logits = prefill(&exec, &mut cache, &[1, 2, 3], &mut st);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        let _ = std::fs::remove_file(&path);

        // FP16 has nothing to checkpoint: the option fails loudly in stats
        let (_, stats) = quantize_model(&model, &Method::Fp16, &calib, &opts);
        assert!(stats.checkpoint_bytes.is_none());
        assert!(stats.checkpoint_error.is_some());
    }

    #[test]
    fn quant_block_size_is_invisible() {
        // The blocked quantizer is pinned bit-identical to the unblocked
        // path at the matrix level (tests/property_quant.rs); this checks
        // the same discipline survives the whole sequential pipeline,
        // where layer k's calibration depends on layers < k bit for bit.
        let (model, calib, _) = setup();
        let tiny = PipelineOpts { quant_block: 3, ..PipelineOpts::default() };
        let unblocked = PipelineOpts { quant_block: 0, ..PipelineOpts::default() };
        let (a, _) = quantize_model(&model, &Method::Claq { bits: 2 }, &calib, &tiny);
        let (b, _) = quantize_model(&model, &Method::Claq { bits: 2 }, &calib, &unblocked);
        for id in model.matrix_ids() {
            let (da, db) = (a.matrices[&id].dequantize(), b.matrices[&id].dequantize());
            let bits = |m: &crate::tensor::Matrix| {
                m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(bits(&da), bits(&db), "{} differs across block sizes", id.name());
        }
    }

    #[test]
    fn incremental_equals_full_recompute() {
        // The incremental calibration path must produce bit-identical
        // quantized models to the re-forward path (same math, less work).
        let (model, calib, _) = setup();
        let mut fast = PipelineOpts::default();
        fast.incremental = true;
        let mut slow = PipelineOpts::default();
        slow.incremental = false;
        for method in [Method::Claq { bits: 2 }, Method::Gptq { bits: 3 }] {
            let (a, _) = quantize_model(&model, &method, &calib, &fast);
            let (b, _) = quantize_model(&model, &method, &calib, &slow);
            for id in model.matrix_ids() {
                let da = a.matrices[&id].dequantize();
                let db = b.matrices[&id].dequantize();
                for (x, y) in da.data.iter().zip(&db.data) {
                    assert!(
                        (x - y).abs() < 1e-4,
                        "{}: incremental {x} vs full {y}",
                        id.name()
                    );
                }
            }
        }
    }

    #[test]
    fn layer_step_composes_to_forward() {
        use crate::model::forward::{embed, forward, layer_step};
        let (model, _, _) = setup();
        let toks: Vec<u16> = (0..24u16).map(|i| i * 7 % 256).collect();
        let mut state = ForwardState::new(model.config);
        let full = forward(&model, &toks, &mut state);

        // compose: embed -> layer_step* -> final norm -> head
        let mut x = embed(&model, &toks);
        for l in 0..model.config.n_layers {
            layer_step(&model, l, &mut x, toks.len(), &mut state, None);
        }
        let d = model.config.d_model;
        let seq = toks.len();
        // final rmsnorm + lm head, scalar reference
        for t in 0..seq {
            let row = &x[t * d..(t + 1) * d];
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + model.config.eps).sqrt();
            for v in 0..model.config.vocab {
                let wrow = model.lm_head.row(v);
                let mut acc = 0.0f32;
                for i in 0..d {
                    acc += row[i] * inv * model.final_norm[i] * wrow[i];
                }
                assert!(
                    (acc - full.at(t, v)).abs() < 1e-3,
                    "logit mismatch at ({t},{v}): {acc} vs {}",
                    full.at(t, v)
                );
            }
        }
    }

    #[test]
    fn calibrate_layer_hessian_is_spd_ish() {
        let (model, calib, _) = setup();
        let mut state = ForwardState::new(model.config);
        let h = calibrate_layer(&model, &calib, 0, &mut state);
        let d = model.config.d_model;
        let hq = h.h.get(&MatrixKind::Wq).unwrap();
        // symmetric
        for i in 0..d {
            for j in 0..d {
                let a = hq[i * d + j];
                let b = hq[j * d + i];
                assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()));
            }
        }
        // positive diagonal
        for i in 0..d {
            assert!(hq[i * d + i] > 0.0);
        }
    }
}
