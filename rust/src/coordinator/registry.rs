//! Run registry: every experiment the harness executes is appended as a
//! CSV row to `artifacts/runs.csv` with its configuration and metrics, so
//! every reported number is traceable to a recorded run (DESIGN.md §5).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// One recorded run.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub experiment: String,
    pub model: String,
    pub method: String,
    pub bits: f64,
    pub metric_name: String,
    pub metric_value: f64,
    pub detail: String,
}

/// Appends run records to a CSV file.
pub struct Registry {
    path: PathBuf,
}

impl Registry {
    pub fn new(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("runs.csv");
        if !path.exists() {
            std::fs::write(&path, "experiment,model,method,bits,metric,value,detail\n")
                .with_context(|| format!("init {}", path.display()))?;
        }
        Ok(Self { path })
    }

    pub fn record(&self, r: &RunRecord) -> Result<()> {
        let mut f = std::fs::OpenOptions::new().append(true).open(&self.path)?;
        writeln!(
            f,
            "{},{},{},{:.3},{},{:.6},{}",
            r.experiment,
            r.model,
            r.method.replace(',', ";"),
            r.bits,
            r.metric_name,
            r.metric_value,
            r.detail.replace(',', ";")
        )?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Repo-standard artifact directory (env override for tests).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CLAQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_rows() {
        let dir = std::env::temp_dir().join("claq_registry_test");
        let _ = std::fs::remove_dir_all(&dir);
        let reg = Registry::new(&dir).unwrap();
        reg.record(&RunRecord {
            experiment: "table1".into(),
            model: "tiny-l".into(),
            method: "CLAQ*-2.12".into(),
            bits: 2.12,
            metric_name: "ppl_wiki".into(),
            metric_value: 7.57,
            detail: "calib=synth-c4, with,comma".into(),
        })
        .unwrap();
        let text = std::fs::read_to_string(reg.path()).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("CLAQ*-2.12"));
        assert!(text.contains("with;comma"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
