//! L3 coordination: the end-to-end quantization pipeline (calibrate →
//! sensitivity → allocate → quantize → pack), the run registry, and the
//! artifact/data bootstrap used by the CLI and the table harness.

pub mod pipeline;
pub mod registry;
