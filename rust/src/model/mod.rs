//! The LLaMA-architecture transformer the experiments quantize: config,
//! weight container with binary IO (shared format with the JAX trainer),
//! a pure-Rust forward pass, the [`linear`] operator abstraction with its
//! packed CLAQ execution backend, the KV-cached [`exec`] serving path, and
//! the quantized-model wrapper.

pub mod checkpoint;
pub mod exec;
pub mod forward;
pub mod io;
pub mod linear;
pub mod quantized;

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Model hyper-parameters. Two presets stand in for the paper's model
/// families (see DESIGN.md §1): `tiny_l` ("LLaMA-1 7B" column) and
/// `tiny_xl` ("LLaMA-2 / Yi" appendix tables).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub eps: f32,
}

impl TransformerConfig {
    /// ~0.9M parameter model (the main experiments).
    pub fn tiny_l() -> Self {
        Self {
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 352,
            max_seq: 128,
            rope_theta: 10000.0,
            eps: 1e-5,
        }
    }

    /// ~2.8M parameter model (the Appendix E tables).
    pub fn tiny_xl() -> Self {
        Self {
            vocab: 256,
            d_model: 192,
            n_layers: 6,
            n_heads: 6,
            d_ff: 512,
            max_seq: 128,
            rope_theta: 10000.0,
            eps: 1e-5,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 2 * d // norms
            + 4 * d * d // attention
            + 2 * self.d_ff * d + d * self.d_ff; // mlp
        self.vocab * d // embedding
            + self.n_layers * per_layer
            + d // final norm
            + self.vocab * d // lm head
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.d_model % self.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(self.head_dim() % 2 == 0, "head_dim must be even for RoPE");
        anyhow::ensure!(self.vocab > 1 && self.n_layers > 0, "degenerate config");
        Ok(())
    }
}

/// One decoder layer's weights. Linear weights are stored (out × in), so a
/// projection computes `y = x · Wᵀ`; the quantization "columns" (GPTQ
/// groups) are input features, matching the paper.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Matrix,
    pub w_up: Matrix,
    pub w_down: Matrix,
}

/// The full model.
#[derive(Clone, Debug)]
pub struct Model {
    pub config: TransformerConfig,
    /// (vocab × d_model)
    pub tok_embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    /// (vocab × d_model)
    pub lm_head: Matrix,
}

/// Identifier of one quantizable matrix inside the model. The embedding,
/// norms, and LM head stay FP (the paper quantizes self-attention and MLP
/// parameter matrices only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixId {
    pub layer: usize,
    pub kind: MatrixKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatrixKind {
    Wq,
    Wk,
    Wv,
    Wo,
    WGate,
    WUp,
    WDown,
}

impl MatrixKind {
    pub const ALL: [MatrixKind; 7] = [
        MatrixKind::Wq,
        MatrixKind::Wk,
        MatrixKind::Wv,
        MatrixKind::Wo,
        MatrixKind::WGate,
        MatrixKind::WUp,
        MatrixKind::WDown,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MatrixKind::Wq => "wq",
            MatrixKind::Wk => "wk",
            MatrixKind::Wv => "wv",
            MatrixKind::Wo => "wo",
            MatrixKind::WGate => "w_gate",
            MatrixKind::WUp => "w_up",
            MatrixKind::WDown => "w_down",
        }
    }

    /// Stable wire tag (the index in [`MatrixKind::ALL`]) — the checkpoint
    /// codec (`model/checkpoint.rs`) serializes kinds by this byte.
    pub fn to_u8(self) -> u8 {
        MatrixKind::ALL.iter().position(|&k| k == self).unwrap() as u8
    }

    /// Inverse of [`MatrixKind::to_u8`]; `None` for out-of-range tags.
    pub fn from_u8(tag: u8) -> Option<MatrixKind> {
        MatrixKind::ALL.get(tag as usize).copied()
    }

    /// (rows, cols) of this projection under `cfg` — the shape a serialized
    /// container must decode to.
    pub fn shape(&self, cfg: &TransformerConfig) -> (usize, usize) {
        let (d, f) = (cfg.d_model, cfg.d_ff);
        match self {
            MatrixKind::Wq | MatrixKind::Wk | MatrixKind::Wv | MatrixKind::Wo => (d, d),
            MatrixKind::WGate | MatrixKind::WUp => (f, d),
            MatrixKind::WDown => (d, f),
        }
    }
}

impl MatrixId {
    pub fn name(&self) -> String {
        format!("layers.{}.{}", self.layer, self.kind.name())
    }
}

impl Model {
    /// Random-initialized model (tests and quantization micro-benches; the
    /// experiments use trained weights from `artifacts/`).
    pub fn random(config: TransformerConfig, rng: &mut Rng) -> Self {
        config.validate().expect("valid config");
        let d = config.d_model;
        let dff = config.d_ff;
        let scale = |fan_in: usize| (1.0 / (fan_in as f32)).sqrt();
        let mut mat = |rows: usize, cols: usize| {
            let mut m = Matrix::zeros(rows, cols);
            rng.fill_normal(&mut m.data, scale(cols));
            m
        };
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; d],
                wq: mat(d, d),
                wk: mat(d, d),
                wv: mat(d, d),
                wo: mat(d, d),
                mlp_norm: vec![1.0; d],
                w_gate: mat(dff, d),
                w_up: mat(dff, d),
                w_down: mat(d, dff),
            })
            .collect();
        let tok_embed = mat(config.vocab, d);
        let lm_head = mat(config.vocab, d);
        Self { config, tok_embed, layers, final_norm: vec![1.0; d], lm_head }
    }

    /// All quantizable matrices in pipeline (forward) order.
    pub fn matrix_ids(&self) -> Vec<MatrixId> {
        let mut out = Vec::new();
        for layer in 0..self.config.n_layers {
            for kind in MatrixKind::ALL {
                out.push(MatrixId { layer, kind });
            }
        }
        out
    }

    pub fn matrix(&self, id: MatrixId) -> &Matrix {
        let l = &self.layers[id.layer];
        match id.kind {
            MatrixKind::Wq => &l.wq,
            MatrixKind::Wk => &l.wk,
            MatrixKind::Wv => &l.wv,
            MatrixKind::Wo => &l.wo,
            MatrixKind::WGate => &l.w_gate,
            MatrixKind::WUp => &l.w_up,
            MatrixKind::WDown => &l.w_down,
        }
    }

    pub fn matrix_mut(&mut self, id: MatrixId) -> &mut Matrix {
        let l = &mut self.layers[id.layer];
        match id.kind {
            MatrixKind::Wq => &mut l.wq,
            MatrixKind::Wk => &mut l.wk,
            MatrixKind::Wv => &mut l.wv,
            MatrixKind::Wo => &mut l.wo,
            MatrixKind::WGate => &mut l.w_gate,
            MatrixKind::WUp => &mut l.w_up,
            MatrixKind::WDown => &mut l.w_down,
        }
    }

    /// Number of parameters in quantizable matrices.
    pub fn quantizable_params(&self) -> usize {
        self.matrix_ids()
            .iter()
            .map(|&id| {
                let m = self.matrix(id);
                m.rows * m.cols
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_valid() {
        TransformerConfig::tiny_l().validate().unwrap();
        TransformerConfig::tiny_xl().validate().unwrap();
    }

    #[test]
    fn param_count_matches_actual() {
        let cfg = TransformerConfig::tiny_l();
        let mut rng = Rng::new(1);
        let m = Model::random(cfg, &mut rng);
        let mut actual = m.tok_embed.data.len() + m.lm_head.data.len() + m.final_norm.len();
        for l in &m.layers {
            actual += l.attn_norm.len()
                + l.mlp_norm.len()
                + l.wq.data.len()
                + l.wk.data.len()
                + l.wv.data.len()
                + l.wo.data.len()
                + l.w_gate.data.len()
                + l.w_up.data.len()
                + l.w_down.data.len();
        }
        assert_eq!(cfg.n_params(), actual);
        // sanity: the size ordering of the paper's model families holds
        assert!(cfg.n_params() > 500_000, "{}", cfg.n_params());
        assert!(TransformerConfig::tiny_xl().n_params() > 2 * cfg.n_params());
    }

    #[test]
    fn matrix_ids_cover_all_kinds() {
        let cfg = TransformerConfig::tiny_l();
        let mut rng = Rng::new(2);
        let m = Model::random(cfg, &mut rng);
        let ids = m.matrix_ids();
        assert_eq!(ids.len(), cfg.n_layers * 7);
        // access every one
        for id in ids {
            let mat = m.matrix(id);
            assert!(mat.rows > 0 && mat.cols > 0);
        }
    }

    #[test]
    fn kind_tags_round_trip_and_shapes_match() {
        let cfg = TransformerConfig::tiny_l();
        let mut rng = Rng::new(3);
        let m = Model::random(cfg, &mut rng);
        for (i, kind) in MatrixKind::ALL.into_iter().enumerate() {
            assert_eq!(kind.to_u8(), i as u8);
            assert_eq!(MatrixKind::from_u8(i as u8), Some(kind));
            let mat = m.matrix(MatrixId { layer: 0, kind });
            assert_eq!(kind.shape(&cfg), (mat.rows, mat.cols), "{}", kind.name());
        }
        assert_eq!(MatrixKind::from_u8(7), None);
        assert_eq!(MatrixKind::from_u8(255), None);
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = TransformerConfig::tiny_l();
        cfg.n_heads = 3; // 128 % 3 != 0
        assert!(cfg.validate().is_err());
    }
}
