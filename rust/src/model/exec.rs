//! Packed-execution layer: the serving counterpart of `forward.rs`.
//!
//! An [`ExecModel`] is the transformer with every attention/MLP projection
//! behind the [`LinearOp`] trait, so the same forward code runs off dense
//! f32 weights *or* straight off the packed CLAQ planes (embedding, norms,
//! and LM head stay FP, as in the paper). On top of it sits the
//! incremental decode path the scoring-only harness never needed:
//!
//! * [`KvCache`] — per-request key/value cache, held as a table of
//!   refcounted fixed-size pages ([`KvPageBuf`], default
//!   [`DEFAULT_PAGE_TOKENS`] tokens) so prefix hits share pages
//!   copy-on-write and cold pages can be k-means-quantized in place.
//! * [`KvPagePool`] — recycling page allocator, so steady-state serving
//!   does zero large allocations (the scheduler's page source).
//! * [`prefill`] — run a prompt chunk once, populating the cache and
//!   returning logits for every prompt position.
//! * [`decode_step`] — advance a *batch* of requests by one token each,
//!   each request at its own cache position (variable lengths; the
//!   continuous-batching scheduler mixes requests at arbitrary depths).
//!   Caches are passed as `&mut [&mut KvCache]` so a batch can be formed
//!   over caches owned by different scheduler slots without moving them.
//!   Batching matters for the packed backend: a weight column is decoded
//!   once per step and the rank-1 update is applied to every sequence in
//!   the batch, amortizing plane unpacking across the batch.
//!
//! Both paths reuse the RMSNorm/RoPE/SiLU kernels of `forward.rs`, so the
//! dense ExecModel agrees with [`forward`](super::forward::forward) to
//! rounding error (pinned by tests below).

use super::checkpoint::Checkpoint;
use super::forward::{rmsnorm, rope_row, rope_tables, silu};
use super::linear::{DenseLinear, LinearOp, LinearScratch, PackedLinear};
use super::{MatrixId, MatrixKind, Model, TransformerConfig};
use crate::quant::kvpage::QuantKvPage;
use crate::tensor::Matrix;
use crate::util::failpoint::{self, Failpoints};
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// One decoder layer with backend-agnostic projections.
pub struct ExecLayer {
    pub attn_norm: Vec<f32>,
    pub wq: Box<dyn LinearOp>,
    pub wk: Box<dyn LinearOp>,
    pub wv: Box<dyn LinearOp>,
    pub wo: Box<dyn LinearOp>,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Box<dyn LinearOp>,
    pub w_up: Box<dyn LinearOp>,
    pub w_down: Box<dyn LinearOp>,
}

/// The executable model: FP embedding/norms/LM-head plus `LinearOp`
/// projections (dense or packed).
pub struct ExecModel {
    pub config: TransformerConfig,
    /// (vocab × d_model), FP.
    pub tok_embed: Matrix,
    pub layers: Vec<ExecLayer>,
    pub final_norm: Vec<f32>,
    pub lm_head: Box<dyn LinearOp>,
    /// Backend label for reports ("dense" / "packed").
    pub backend: &'static str,
}

impl ExecModel {
    /// Wrap a dense model (the reference backend).
    pub fn dense(model: &Model) -> Self {
        let boxed = |w: &Matrix| -> Box<dyn LinearOp> { Box::new(DenseLinear::new(w.clone())) };
        let layers = model
            .layers
            .iter()
            .map(|l| ExecLayer {
                attn_norm: l.attn_norm.clone(),
                wq: boxed(&l.wq),
                wk: boxed(&l.wk),
                wv: boxed(&l.wv),
                wo: boxed(&l.wo),
                mlp_norm: l.mlp_norm.clone(),
                w_gate: boxed(&l.w_gate),
                w_up: boxed(&l.w_up),
                w_down: boxed(&l.w_down),
            })
            .collect();
        Self {
            config: model.config,
            tok_embed: model.tok_embed.clone(),
            layers,
            final_norm: model.final_norm.clone(),
            lm_head: Box::new(DenseLinear::new(model.lm_head.clone())),
            backend: "dense",
        }
    }

    /// Cold-start path: build the packed execution model straight from a
    /// loaded `CLAQMD01` checkpoint — every projection becomes a
    /// [`PackedLinear`] over the serialized container (f16 codebooks, AWQ
    /// scales folded in) and **no dense projection matrix is ever
    /// materialized**. Consumes the checkpoint so the FP parts (embedding,
    /// norms, LM head — the largest FP blocks) are moved in, not copied:
    /// copies would double peak FP memory and land straight in the
    /// cold-start latency `bench_decode` tracks. Bit-identical to
    /// `QuantizedModel::to_exec_deployed` on the model that saved the
    /// checkpoint (pinned by `tests/checkpoint_roundtrip.rs`).
    pub fn from_checkpoint(ckpt: Checkpoint) -> Result<Self> {
        let Checkpoint { fp, entries, .. } = ckpt;
        let cfg = fp.config;
        let by_id: std::collections::HashMap<MatrixId, &super::checkpoint::CheckpointEntry> =
            entries.iter().map(|e| (e.id, e)).collect();
        let super::io::FpParts { tok_embed, attn_norms, mlp_norms, final_norm, lm_head, .. } = fp;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (layer, (attn_norm, mlp_norm)) in
            attn_norms.into_iter().zip(mlp_norms).enumerate()
        {
            let op = |kind: MatrixKind| -> Result<Box<dyn LinearOp>> {
                let id = MatrixId { layer, kind };
                let e = by_id
                    .get(&id)
                    .with_context(|| format!("checkpoint is missing {}", id.name()))?;
                let lin = PackedLinear::from_container(&e.container, e.awq_scales.as_deref())
                    .with_context(|| format!("build packed op for {}", id.name()))?;
                let want = kind.shape(&cfg);
                ensure!(
                    (lin.out_features(), lin.in_features()) == want,
                    "{}: container is {}x{} but the config expects {}x{}",
                    id.name(),
                    lin.out_features(),
                    lin.in_features(),
                    want.0,
                    want.1
                );
                Ok(Box::new(lin))
            };
            layers.push(ExecLayer {
                attn_norm,
                wq: op(MatrixKind::Wq)?,
                wk: op(MatrixKind::Wk)?,
                wv: op(MatrixKind::Wv)?,
                wo: op(MatrixKind::Wo)?,
                mlp_norm,
                w_gate: op(MatrixKind::WGate)?,
                w_up: op(MatrixKind::WUp)?,
                w_down: op(MatrixKind::WDown)?,
            });
        }
        Ok(Self {
            config: cfg,
            tok_embed,
            layers,
            final_norm,
            lm_head: Box::new(DenseLinear::new(lm_head)),
            backend: "packed",
        })
    }

    /// Resident bytes of the quantizable projections (the part the packed
    /// backend shrinks; FP embedding/head are identical across backends).
    pub fn projection_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.weight_bytes()
                    + l.wk.weight_bytes()
                    + l.wv.weight_bytes()
                    + l.wo.weight_bytes()
                    + l.w_gate.weight_bytes()
                    + l.w_up.weight_bytes()
                    + l.w_down.weight_bytes()
            })
            .sum()
    }

    /// Packed index-plane bytes decoded by one full forward step (all
    /// layers + LM head; 0 for the dense backend) — the per-step numerator
    /// of the bench layer's `bytes_decoded_per_s` throughput extra.
    pub fn decoded_plane_bytes_per_step(&self) -> usize {
        self.lm_head.decoded_plane_bytes()
            + self
                .layers
                .iter()
                .map(|l| {
                    l.wq.decoded_plane_bytes()
                        + l.wk.decoded_plane_bytes()
                        + l.wv.decoded_plane_bytes()
                        + l.wo.decoded_plane_bytes()
                        + l.w_gate.decoded_plane_bytes()
                        + l.w_up.decoded_plane_bytes()
                        + l.w_down.decoded_plane_bytes()
                })
                .sum::<usize>()
    }
}

/// Tokens per KV page unless overridden (`SchedulerConfig::kv_page_tokens`,
/// [`KvCache::with_page_tokens`]). Clamped to `max_seq` at construction so
/// tiny test configs get exactly one page per sequence.
pub const DEFAULT_PAGE_TOKENS: usize = 64;

/// One f32 KV page: keys and values for `page_tokens` consecutive
/// positions across **all** layers (`n_layers × page_tokens × d` floats
/// each), so a single refcount covers a position range for the whole
/// model. Within a plane, `(layer * page_tokens + slot) * d` addresses the
/// row of `slot = pos % page_tokens`.
pub struct KvPageBuf {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPageBuf {
    fn zeroed(n_layers: usize, page_tokens: usize, d: usize) -> Self {
        let n = n_layers * page_tokens * d;
        Self { k: vec![0.0; n], v: vec![0.0; n] }
    }

    fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// One entry of a cache's page table. `Clone` bumps the refcount — that is
/// the whole point: a prefix-cache hit clones table entries instead of
/// copying KV bytes, and writers fork copy-on-write when the count is > 1.
#[derive(Clone)]
enum Page {
    /// Plain f32 page; shared (strong count > 1) after a prefix hit.
    F32(Arc<KvPageBuf>),
    /// Cold page re-encoded as per-page k-means codebooks
    /// (`quant/kvpage.rs`); immutable, dequantized into scratch on read.
    Quant(Arc<QuantKvPage>),
}

impl Page {
    fn bytes(&self) -> usize {
        match self {
            Page::F32(b) => b.bytes(),
            Page::Quant(q) => q.bytes(),
        }
    }

    fn ptr(&self) -> usize {
        match self {
            Page::F32(b) => Arc::as_ptr(b) as usize,
            Page::Quant(q) => Arc::as_ptr(q) as usize,
        }
    }

    fn is_shared(&self) -> bool {
        match self {
            Page::F32(b) => Arc::strong_count(b) > 1,
            Page::Quant(q) => Arc::strong_count(q) > 1,
        }
    }
}

/// Identity and size of one resident page, for the distinct-page
/// accounting walks (`SchedulerStats` counts every shared page once by
/// deduplicating on `ptr`).
pub struct PageStat {
    /// Address of the page allocation — stable for the page's lifetime.
    pub ptr: usize,
    /// Exact resident bytes of this page (f32 planes or quant codec).
    pub bytes: usize,
    /// True for k-means-encoded cold pages.
    pub quantized: bool,
    /// True when more than one page table references the page.
    pub shared: bool,
}

/// Per-request key/value cache over all layers, held as a table of
/// refcounted fixed-size pages instead of one contiguous buffer.
///
/// * Pages are allocated lazily: a fresh cache owns no memory, and
///   standalone callers ([`prefill`]/[`decode_step`] outside the
///   scheduler) grow the table automatically. The serving path reserves
///   pages from the [`KvPagePool`] instead ([`KvCache::reserve`]), so
///   steady-state serving allocates nothing.
/// * A prefix-cache hit [`share_prefix_from`](KvCache::share_prefix_from)s
///   the source's pages — O(pages) `Arc` clones, zero KV bytes copied.
///   The only page that can ever need copying is a *partial* tail page,
///   and it is forked lazily, the first time the new request appends into
///   it (copy-on-write; full shared pages are never copied).
/// * Pages that fall behind the decode head can be re-encoded as per-page
///   k-means codebooks ([`quantize_cold_pages`](KvCache::quantize_cold_pages));
///   reads dequantize into `ExecState` scratch.
///
/// Invariant: `pages.len()` is between `ceil(len / page_tokens)` and
/// `ceil(max_seq / page_tokens)`; only the slots below `len` hold defined
/// data (recycled pool pages are not zeroed — every slot is written before
/// it is read).
pub struct KvCache {
    n_layers: usize,
    d: usize,
    max_seq: usize,
    page_tokens: usize,
    len: usize,
    pages: Vec<Page>,
}

impl KvCache {
    /// Empty cache with the default page size (no memory allocated yet).
    pub fn new(cfg: &TransformerConfig) -> Self {
        Self::with_page_tokens(cfg, DEFAULT_PAGE_TOKENS)
    }

    /// Empty cache with `page_tokens`-token pages (clamped to `1..=max_seq`).
    pub fn with_page_tokens(cfg: &TransformerConfig, page_tokens: usize) -> Self {
        Self {
            n_layers: cfg.n_layers,
            d: cfg.d_model,
            max_seq: cfg.max_seq,
            page_tokens: page_tokens.max(1).min(cfg.max_seq.max(1)),
            len: 0,
            pages: Vec::new(),
        }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum cacheable positions.
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// Tokens per page of this cache's table.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Bytes of one full f32 page of this geometry.
    pub fn page_bytes(&self) -> usize {
        2 * self.n_layers * self.page_tokens * self.d * std::mem::size_of::<f32>()
    }

    /// f32 KV bytes of one cached position across all layers — the unit of
    /// the `shared_kv_bytes_saved` accounting (what the pre-paging
    /// `copy_prefix_from` memcpy moved per prefix token).
    pub fn token_bytes(&self) -> usize {
        2 * self.n_layers * self.d * std::mem::size_of::<f32>()
    }

    /// Bytes a pre-paging contiguous cache held for `cfg`: the
    /// full-context f32 allocation every request used to pin regardless of
    /// its actual length. Benches report paged residency against this.
    pub fn contiguous_bytes(cfg: &TransformerConfig) -> usize {
        2 * cfg.n_layers * cfg.max_seq * cfg.d_model * std::mem::size_of::<f32>()
    }

    /// Drop all cached positions *and* the page table (start a fresh
    /// sequence). Pages this cache exclusively owned are freed; use
    /// [`KvPagePool::put_cache`] instead to recycle them.
    pub fn reset(&mut self) {
        self.len = 0;
        self.pages.clear();
    }

    /// Roll back to the first `len` positions (e.g. re-decode from a
    /// shared prefix), dropping pages that fall wholly beyond it.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond cached length");
        self.len = len;
        self.pages.truncate(len.div_ceil(self.page_tokens));
    }

    /// [`truncate`](KvCache::truncate), releasing dropped pages into
    /// `pool` instead of freeing them (the prefix cache's insert path).
    pub fn truncate_into(&mut self, len: usize, pool: &mut KvPagePool) {
        assert!(len <= self.len, "truncate beyond cached length");
        self.len = len;
        let keep = len.div_ceil(self.page_tokens);
        for page in self.pages.drain(keep..).collect::<Vec<_>>() {
            pool.release(page);
        }
    }

    /// Become a fork of the first `len` positions of `src` by cloning its
    /// page table entries — O(pages) refcount bumps, **zero KV bytes
    /// copied**. K/V rows of a position depend only on the tokens at or
    /// before it, so reads through the shared pages are bit-identical to a
    /// cold prefill of those `len` tokens (the prefix-sharing cache's
    /// foundation, DESIGN.md §13). Any pages this cache previously held
    /// are dropped; call on pool shells or pass a pool via
    /// [`reserve`](KvCache::reserve) before appending.
    pub fn share_prefix_from(&mut self, src: &KvCache, len: usize) {
        assert!(len <= src.len, "fork beyond source length ({len} > {})", src.len);
        assert!(
            self.n_layers == src.n_layers
                && self.d == src.d
                && self.max_seq == src.max_seq
                && self.page_tokens == src.page_tokens,
            "fork between caches of different geometries"
        );
        self.pages.clear();
        self.pages.extend_from_slice(&src.pages[..len.div_ceil(self.page_tokens)]);
        self.len = len;
    }

    /// Clone-by-sharing the first `len` positions of `src` into a new
    /// cache (allocation-free aside from the table itself).
    pub fn fork_from(src: &KvCache, len: usize) -> KvCache {
        let mut cache = KvCache {
            n_layers: src.n_layers,
            d: src.d,
            max_seq: src.max_seq,
            page_tokens: src.page_tokens,
            len: 0,
            pages: Vec::new(),
        };
        cache.share_prefix_from(src, len);
        cache
    }

    /// Resident bytes of every page this cache references (shared pages
    /// count fully here; the scheduler's distinct-page walk is what
    /// deduplicates system-wide residency).
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(Page::bytes).sum()
    }

    /// Walk the page table for accounting (see [`PageStat`]).
    pub fn page_stats(&self) -> impl Iterator<Item = PageStat> + '_ {
        self.pages.iter().map(|p| PageStat {
            ptr: p.ptr(),
            bytes: p.bytes(),
            quantized: matches!(p, Page::Quant(_)),
            shared: p.is_shared(),
        })
    }

    /// Make positions `len .. len + n` writable: fork a shared or
    /// quantized partial tail page copy-on-write (only the `len %
    /// page_tokens` filled slots are copied/dequantized — the lazy-fork
    /// rule), then extend the table with fresh pages. `pool` is the page
    /// source/sink on the serving path; `None` allocates and frees
    /// directly (standalone callers).
    /// Returns `false` when a pool-backed page take failed (budget
    /// exhaustion or an injected fault) — the table is left valid: `len`
    /// is unchanged, and any pages already acquired stay in the table
    /// (harmless surplus, released with the cache). A later retry
    /// re-checks writability from scratch, so partial progress (including
    /// a completed CoW fork) is kept. Pool-less allocation cannot fail.
    fn ensure_appendable(&mut self, n: usize, mut pool: Option<&mut KvPagePool>) -> bool {
        assert!(self.len + n <= self.max_seq, "append overflows KV cache ({}+{n})", self.len);
        if n == 0 {
            return true;
        }
        let pt = self.page_tokens;
        let filled = self.len % pt;
        if filled != 0 {
            let idx = self.len / pt;
            let writable =
                matches!(&self.pages[idx], Page::F32(b) if Arc::strong_count(b) == 1);
            if !writable {
                let mut fresh = match pool.as_deref_mut() {
                    Some(p) => match p.take_page() {
                        Some(page) => page,
                        None => return false,
                    },
                    None => Arc::new(KvPageBuf::zeroed(self.n_layers, pt, self.d)),
                };
                {
                    let dst = Arc::get_mut(&mut fresh).expect("pages are handed out unique");
                    let rows = filled * self.d;
                    match &self.pages[idx] {
                        Page::F32(src) => {
                            for li in 0..self.n_layers {
                                let o = li * pt * self.d;
                                dst.k[o..o + rows].copy_from_slice(&src.k[o..o + rows]);
                                dst.v[o..o + rows].copy_from_slice(&src.v[o..o + rows]);
                            }
                        }
                        Page::Quant(q) => {
                            for li in 0..self.n_layers {
                                let o = li * pt * self.d;
                                q.dequantize_k_into(o, &mut dst.k[o..o + rows]);
                                q.dequantize_v_into(o, &mut dst.v[o..o + rows]);
                            }
                        }
                    }
                }
                let old = std::mem::replace(&mut self.pages[idx], Page::F32(fresh));
                match pool.as_deref_mut() {
                    Some(p) => p.release(old),
                    None => drop(old),
                }
            }
        }
        let needed = (self.len + n).div_ceil(pt);
        while self.pages.len() < needed {
            let page = match pool.as_deref_mut() {
                Some(p) => match p.take_page() {
                    Some(page) => page,
                    None => return false,
                },
                None => Arc::new(KvPageBuf::zeroed(self.n_layers, pt, self.d)),
            };
            self.pages.push(Page::F32(page));
        }
        true
    }

    /// Standalone grow-before-append: called internally by [`prefill`] /
    /// [`decode_step`], allocating directly. A no-op when the table is
    /// already writable for `n` more positions (the serving path reserves
    /// from the pool first, so the hot loop never lands here).
    pub fn prepare_append(&mut self, n: usize) {
        let ok = self.ensure_appendable(n, None);
        debug_assert!(ok, "pool-less allocation cannot fail");
    }

    /// Pool-backed grow-before-append: the scheduler's zero-allocation
    /// path. Forked tails and fresh pages come from (and spill back to)
    /// `pool`. Panics when the pool cannot supply the pages — callers
    /// that can degrade gracefully use [`try_reserve`](Self::try_reserve).
    pub fn reserve(&mut self, pool: &mut KvPagePool, n: usize) {
        assert!(
            self.try_reserve(pool, n),
            "KV page pool exhausted reserving {n} position(s) \
             (budget {} bytes, {} pages created)",
            pool.budget_bytes,
            pool.created
        );
    }

    /// Fallible [`reserve`](Self::reserve): `false` means the pool could
    /// not supply a page (byte budget exhausted, or an injected
    /// [`failpoint::POOL_TAKE`] fault). The cache stays valid and a retry
    /// after the caller frees pages picks up where this left off — the
    /// scheduler's degradation ladder (DESIGN.md §14) is built on that.
    pub fn try_reserve(&mut self, pool: &mut KvPagePool, n: usize) -> bool {
        assert!(
            self.n_layers == pool.cfg.n_layers
                && self.d == pool.cfg.d_model
                && self.max_seq == pool.cfg.max_seq
                && self.page_tokens == pool.page_tokens,
            "cache reserved from a pool of a different geometry"
        );
        self.ensure_appendable(n, Some(pool))
    }

    /// Re-encode cold pages as per-page k-means codebooks: every *full*,
    /// exclusively-owned f32 page lying wholly below `len - margin` is
    /// replaced by a [`QuantKvPage`] (`bits` ∈ 1..=8) and its f32 buffer
    /// released to `pool` (or freed when `None`). Shared pages are skipped
    /// — other tables still append through them, and replacing one table's
    /// entry would duplicate, not shrink, residency. Returns the number of
    /// pages quantized by this call. Lossy: downstream logits are
    /// tolerance-gated, never bit-compared (DESIGN.md §13).
    pub fn quantize_cold_pages(
        &mut self,
        bits: u8,
        margin: usize,
        mut pool: Option<&mut KvPagePool>,
    ) -> usize {
        let pt = self.page_tokens;
        let cold_end = self.len.saturating_sub(margin);
        let mut quantized = 0usize;
        for idx in 0..self.pages.len() {
            if (idx + 1) * pt > cold_end {
                break; // first page not wholly cold; later ones are hotter
            }
            let encoded = match &self.pages[idx] {
                Page::F32(buf) if Arc::strong_count(buf) == 1 => {
                    Some(QuantKvPage::encode(&buf.k, &buf.v, bits))
                }
                _ => None, // already quantized, or shared
            };
            if let Some(q) = encoded {
                let old = std::mem::replace(&mut self.pages[idx], Page::Quant(Arc::new(q)));
                match pool.as_deref_mut() {
                    Some(p) => p.release(old),
                    None => drop(old),
                }
                quantized += 1;
            }
        }
        quantized
    }

    #[inline]
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(layer < self.n_layers && pos < self.max_seq);
        let pt = self.page_tokens;
        let Page::F32(arc) = &mut self.pages[pos / pt] else {
            panic!("write into a quantized page (prepare_append not called)");
        };
        let buf = Arc::get_mut(arc).expect("write into a shared page (CoW fork missed)");
        let o = (layer * pt + pos % pt) * self.d;
        buf.k[o..o + self.d].copy_from_slice(k);
        buf.v[o..o + self.d].copy_from_slice(v);
    }
}

/// Recycling page allocator for [`KvCache`]s — the successor of the
/// whole-cache `KvCachePool`. Requests draw fixed-size pages (plus a cheap
/// table shell) instead of full-context buffers, so a request holds only
/// `ceil(len / page_tokens)` pages and the same free list serves any mix
/// of request lengths; once the pool is warm, steady-state serving
/// allocates nothing. Released pages return to the free list only when
/// their refcount proves them unique — a shared page simply drops one
/// reference, which makes double-frees structurally impossible (the free
/// list can never hold a page some table still reads). Hit/miss counters
/// are per *page take*; `pages_created` vs [`free_pages`] is the leak
/// check the refcount-hygiene property test pins.
pub struct KvPagePool {
    cfg: TransformerConfig,
    page_tokens: usize,
    free: Vec<Arc<KvPageBuf>>,
    /// Empty page tables recycled between requests (no KV memory).
    shells: Vec<KvCache>,
    /// Hard cap on bytes of pages this pool will ever create (`0` =
    /// unbounded, the pre-PR-8 behaviour). Free-list takes always
    /// succeed; only *allocation* past the budget fails.
    budget_bytes: usize,
    /// Armed failpoints ([`failpoint::POOL_TAKE`] makes a take fail as if
    /// the budget were exhausted). Wired from `CLAQ_FAILPOINTS` at
    /// construction; tests inject via [`set_failpoints`](Self::set_failpoints).
    failpoints: Option<Arc<Failpoints>>,
    hits: u64,
    misses: u64,
    created: u64,
    failed_takes: u64,
}

impl KvPagePool {
    /// Empty pool with the default page size.
    pub fn new(cfg: TransformerConfig) -> Self {
        Self::with_page_tokens(cfg, DEFAULT_PAGE_TOKENS)
    }

    /// Empty pool handing out `page_tokens`-token pages (clamped to
    /// `1..=max_seq`), unbounded.
    pub fn with_page_tokens(cfg: TransformerConfig, page_tokens: usize) -> Self {
        let page_tokens = page_tokens.max(1).min(cfg.max_seq.max(1));
        Self {
            cfg,
            page_tokens,
            free: Vec::new(),
            shells: Vec::new(),
            budget_bytes: 0,
            failpoints: failpoint::global().cloned(),
            hits: 0,
            misses: 0,
            created: 0,
            failed_takes: 0,
        }
    }

    /// Pool pre-warmed for `n` full-context requests (pages and shells;
    /// counted as neither hits nor misses), default page size.
    pub fn with_capacity(cfg: TransformerConfig, n: usize) -> Self {
        Self::with_capacity_paged(cfg, DEFAULT_PAGE_TOKENS, n)
    }

    /// [`with_capacity`](KvPagePool::with_capacity) with an explicit page
    /// size: pre-warms `n × ceil(max_seq / page_tokens)` pages.
    pub fn with_capacity_paged(cfg: TransformerConfig, page_tokens: usize, n: usize) -> Self {
        Self::with_budget_paged(cfg, page_tokens, 0, n)
    }

    /// [`with_capacity_paged`](KvPagePool::with_capacity_paged) under a
    /// hard byte budget (`0` = unbounded): the pre-warm is capped so the
    /// pool never starts life over budget, and every take past the budget
    /// fails instead of allocating.
    pub fn with_budget_paged(
        cfg: TransformerConfig,
        page_tokens: usize,
        budget_bytes: usize,
        n: usize,
    ) -> Self {
        let mut pool = Self::with_page_tokens(cfg, page_tokens);
        pool.budget_bytes = budget_bytes;
        let prewarm = (n * pool.pages_per_request()).min(pool.max_pages());
        for _ in 0..prewarm {
            let page = pool.alloc_page();
            pool.free.push(page);
        }
        for _ in 0..n {
            let shell = KvCache::with_page_tokens(&pool.cfg, pool.page_tokens);
            pool.shells.push(shell);
        }
        pool
    }

    /// Install an armed failpoint set (replacing any env-derived one) —
    /// the chaos suite's deterministic injection path.
    pub fn set_failpoints(&mut self, fp: Arc<Failpoints>) {
        self.failpoints = Some(fp);
    }

    /// Pages this pool may ever create (`usize::MAX` when unbounded).
    pub fn max_pages(&self) -> usize {
        if self.budget_bytes == 0 {
            usize::MAX
        } else {
            self.budget_bytes / self.page_bytes()
        }
    }

    /// The configured byte budget (`0` = unbounded).
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Tokens per page handed out by this pool.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Bytes of one f32 page of this pool's geometry.
    pub fn page_bytes(&self) -> usize {
        2 * self.cfg.n_layers * self.page_tokens * self.cfg.d_model * std::mem::size_of::<f32>()
    }

    /// Pages a full-context request needs.
    pub fn pages_per_request(&self) -> usize {
        self.cfg.max_seq.div_ceil(self.page_tokens)
    }

    fn alloc_page(&mut self) -> Arc<KvPageBuf> {
        self.created += 1;
        Arc::new(KvPageBuf::zeroed(self.cfg.n_layers, self.page_tokens, self.cfg.d_model))
    }

    /// Take an empty cache shell (recycled table or a fresh one — shells
    /// own no KV memory, so shell takes are not hits/misses). Pages arrive
    /// later via [`KvCache::reserve`] / [`KvCache::share_prefix_from`].
    pub fn take_cache(&mut self) -> KvCache {
        match self.shells.pop() {
            Some(mut shell) => {
                debug_assert!(shell.pages.is_empty() && shell.len == 0);
                shell.reset();
                shell
            }
            None => KvCache::with_page_tokens(&self.cfg, self.page_tokens),
        }
    }

    /// Return a retired request's cache: every page it held is released
    /// (unique f32 pages back to the free list, shared/quantized ones just
    /// drop a reference) and the empty shell is kept for reuse. Panics on
    /// geometry mismatch.
    pub fn put_cache(&mut self, mut cache: KvCache) {
        assert!(
            cache.n_layers == self.cfg.n_layers
                && cache.d == self.cfg.d_model
                && cache.max_seq == self.cfg.max_seq
                && cache.page_tokens == self.page_tokens,
            "cache returned to a pool of a different geometry"
        );
        cache.len = 0;
        while let Some(page) = cache.pages.pop() {
            self.release(page);
        }
        self.shells.push(cache);
    }

    /// Take one page: recycled from the free list (hit) or freshly
    /// allocated (miss). Recycled pages are *not* zeroed — the cache
    /// invariant is that every slot below `len` is written before read.
    ///
    /// `None` means the take **failed**: either the [`failpoint::POOL_TAKE`]
    /// failpoint fired (deterministic injected exhaustion), or the free
    /// list is empty and allocating one more page would overshoot
    /// `budget_bytes`. Failed takes are counted separately from
    /// hits/misses ([`failed_takes`](Self::failed_takes)).
    fn take_page(&mut self) -> Option<Arc<KvPageBuf>> {
        if self.failpoints.as_ref().is_some_and(|fp| fp.fire(failpoint::POOL_TAKE)) {
            self.failed_takes += 1;
            return None;
        }
        if let Some(page) = self.free.pop() {
            debug_assert_eq!(Arc::strong_count(&page), 1);
            self.hits += 1;
            return Some(page);
        }
        if self.budget_bytes > 0 && (self.created as usize + 1) * self.page_bytes() > self.budget_bytes
        {
            self.failed_takes += 1;
            return None;
        }
        self.misses += 1;
        Some(self.alloc_page())
    }

    /// Release one page table entry. Only an f32 page whose `Arc` we hold
    /// the *last* reference to re-enters the free list; shared f32 clones
    /// and quantized pages (wrong size class) just drop.
    fn release(&mut self, page: Page) {
        if let Page::F32(buf) = page {
            if Arc::strong_count(&buf) == 1 {
                self.free.push(buf);
            }
        }
    }

    /// Free (recyclable) pages currently held.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Total pool pages ever allocated (pre-warm + misses). After every
    /// request retires and the prefix cache drains, [`free_pages`] must
    /// equal this — the no-leak / no-double-free invariant.
    pub fn pages_created(&self) -> u64 {
        self.created
    }

    /// Page takes served from the free list.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Page takes that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Page takes that failed outright: budget exhaustion plus injected
    /// [`failpoint::POOL_TAKE`] faults. Each one sent the scheduler down
    /// its degradation ladder.
    pub fn failed_takes(&self) -> u64 {
        self.failed_takes
    }

    /// Fraction of page takes served without allocating (1.0 before any).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Resident bytes of the pooled (free) pages.
    pub fn resident_bytes(&self) -> usize {
        self.free.len() * self.page_bytes()
    }
}

/// Scratch buffers for the exec paths; `rows` capacity must cover both the
/// longest prefill chunk and the largest decode batch.
pub struct ExecState {
    cfg: TransformerConfig,
    cap: usize,
    x: Vec<f32>,      // (rows × d)
    normed: Vec<f32>, // (rows × d)
    q: Vec<f32>,      // (rows × d)
    k: Vec<f32>,      // (rows × d)
    v: Vec<f32>,      // (rows × d)
    attn: Vec<f32>,   // (rows × d)
    proj: Vec<f32>,   // (rows × d)
    gate: Vec<f32>,   // (rows × d_ff)
    up: Vec<f32>,     // (rows × d_ff)
    scores: Vec<f32>, // (n_heads × max_seq): all heads of one page pass
    inv_z: Vec<f32>,  // (n_heads) softmax normalizers
    /// Dequant scratch for quantized pages (lazily sized to one page's
    /// layer run; untouched — and unallocated — while serving f32-only).
    kpage: Vec<f32>,
    vpage: Vec<f32>,
    cos: Vec<f32>, // (max_seq × head_dim/2)
    sin: Vec<f32>,
    scratch: LinearScratch, // LinearOp backend workspace
}

impl ExecState {
    /// State sized for full-context prefill (rows = max_seq), which also
    /// covers any decode batch up to max_seq requests.
    pub fn new(cfg: TransformerConfig) -> Self {
        Self::with_capacity(cfg, cfg.max_seq)
    }

    /// Row capacity: the largest prefill chunk / decode batch this state
    /// can run.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// State with explicit row capacity (≥ prefill chunk length and ≥
    /// decode batch size; max_seq-position RoPE/score tables regardless).
    pub fn with_capacity(cfg: TransformerConfig, rows: usize) -> Self {
        let cap = rows.max(1);
        let (d, f, s) = (cfg.d_model, cfg.d_ff, cfg.max_seq);
        let (cos, sin) = rope_tables(&cfg, s);
        // The LinearOp workspace (column-decode scratch, shard staging, and
        // the shard descriptors of the parallel dispatch) is sized up front
        // for the widest projection at full row capacity, so nothing on the
        // decode hot path allocates at all.
        let max_out = d.max(f).max(cfg.vocab);
        Self {
            cfg,
            cap,
            x: vec![0.0; cap * d],
            normed: vec![0.0; cap * d],
            q: vec![0.0; cap * d],
            k: vec![0.0; cap * d],
            v: vec![0.0; cap * d],
            attn: vec![0.0; cap * d],
            proj: vec![0.0; cap * d],
            gate: vec![0.0; cap * f],
            up: vec![0.0; cap * f],
            scores: vec![0.0; cfg.n_heads * s],
            inv_z: vec![0.0; cfg.n_heads],
            kpage: Vec::new(),
            vpage: Vec::new(),
            cos,
            sin,
            scratch: LinearScratch::with_capacity(max_out, cap),
        }
    }
}

/// Attention of one query row (`st.q[row]` at absolute `pos`) against the
/// cached keys/values `0..=pos` of `layer`, mixed into `st.attn[row]`.
///
/// Page-wise three-pass form: (1) raw scores for *all* heads, page by
/// page, so each page's K rows are touched (or dequantized) exactly once;
/// (2) per-head softmax over the contiguous score row; (3) value mix,
/// again page by page with V rows touched once. Per head, every
/// float operation — dot-product order, max fold, exp/sum order, and the
/// ascending-position value accumulation — is identical to the historical
/// contiguous single-head loop, so paged attention over f32 pages is
/// **bit-identical** to the pre-paging path regardless of page size
/// (pinned by `page_size_is_invisible_to_decoding` and the scheduler /
/// prefix-cache property suites). Quantized pages are dequantized into
/// `st.kpage`/`st.vpage` and are tolerance-gated instead.
fn attend_cached(st: &mut ExecState, cache: &KvCache, layer: usize, row: usize, pos: usize) {
    let d = st.cfg.d_model;
    let nh = st.cfg.n_heads;
    let hd = st.cfg.head_dim();
    let stride = st.cfg.max_seq;
    let scale = 1.0 / (hd as f32).sqrt();
    let pt = cache.page_tokens;
    let ExecState { q, attn, scores, inv_z, kpage, vpage, .. } = st;
    let qrow = &q[row * d..(row + 1) * d];
    let n_pages = pos / pt + 1;

    // pass 1: raw scores, every head, page by page
    for pidx in 0..n_pages {
        let base = pidx * pt;
        let filled = (pos + 1 - base).min(pt);
        let rows = filled * d;
        let krun: &[f32] = match &cache.pages[pidx] {
            Page::F32(buf) => &buf.k[layer * pt * d..layer * pt * d + rows],
            Page::Quant(qp) => {
                if kpage.len() < pt * d {
                    kpage.resize(pt * d, 0.0);
                }
                qp.dequantize_k_into(layer * pt * d, &mut kpage[..rows]);
                &kpage[..rows]
            }
        };
        for h in 0..nh {
            let off = h * hd;
            let qh = &qrow[off..off + hd];
            for s in 0..filled {
                let krow = &krun[s * d + off..s * d + off + hd];
                let mut acc = 0.0f32;
                for i in 0..hd {
                    acc += qh[i] * krow[i];
                }
                scores[h * stride + base + s] = acc * scale;
            }
        }
    }

    // pass 2: per-head softmax (same max/exp/sum order as the contiguous
    // loop: ascending positions)
    for h in 0..nh {
        let sc = &mut scores[h * stride..h * stride + pos + 1];
        let m = sc.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for e in sc.iter_mut() {
            let x = (*e - m).exp();
            *e = x;
            z += x;
        }
        inv_z[h] = 1.0 / z;
    }

    // pass 3: value mix, ascending positions per head, page by page
    let out = &mut attn[row * d..(row + 1) * d];
    out.fill(0.0);
    for pidx in 0..n_pages {
        let base = pidx * pt;
        let filled = (pos + 1 - base).min(pt);
        let rows = filled * d;
        let vrun: &[f32] = match &cache.pages[pidx] {
            Page::F32(buf) => &buf.v[layer * pt * d..layer * pt * d + rows],
            Page::Quant(qp) => {
                if vpage.len() < pt * d {
                    vpage.resize(pt * d, 0.0);
                }
                qp.dequantize_v_into(layer * pt * d, &mut vpage[..rows]);
                &vpage[..rows]
            }
        };
        for h in 0..nh {
            let off = h * hd;
            let o = &mut out[off..off + hd];
            for s in 0..filled {
                let p = scores[h * stride + base + s] * inv_z[h];
                let vrow = &vrun[s * d + off..s * d + off + hd];
                for i in 0..hd {
                    o[i] += p * vrow[i];
                }
            }
        }
    }
}

/// Final RMSNorm + LM head over `rows` hidden-state rows → logits.
fn head_logits(model: &ExecModel, st: &mut ExecState, rows: usize) -> Matrix {
    let cfg = &model.config;
    let d = cfg.d_model;
    rmsnorm(&st.x, &model.final_norm, cfg.eps, rows, d, &mut st.normed);
    let mut logits = Matrix::zeros(rows, cfg.vocab);
    model
        .lm_head
        .forward_into(&st.normed[..rows * d], rows, &mut logits.data, &mut st.scratch);
    logits
}

/// Run `tokens` through the model starting at the cache's current length,
/// appending K/V for every position; returns logits (seq × vocab). The
/// cache advances by `tokens.len()`; call with a fresh/reset cache for a
/// full-sequence forward. The start offset is the cache's length itself:
/// positions, RoPE angles, and attention spans all begin at `cache.len()`,
/// which is what makes partial prefill over a shared prefix
/// ([`KvCache::share_prefix_from`], used by the prefix-sharing cache in
/// `runtime/prefix_cache.rs`) bit-identical to prefilling the whole
/// prompt cold. Pages are taken on demand ([`KvCache::prepare_append`]);
/// serving callers reserve from the pool first so this allocates nothing.
pub fn prefill(
    model: &ExecModel,
    cache: &mut KvCache,
    tokens: &[u16],
    st: &mut ExecState,
) -> Matrix {
    let cfg = &model.config;
    assert_eq!(*cfg, st.cfg, "state built for a different config");
    let seq = tokens.len();
    let p0 = cache.len;
    assert!(seq > 0 && seq <= st.cap, "prefill chunk {seq} exceeds state capacity {}", st.cap);
    assert!(p0 + seq <= cache.max_seq, "prompt overflows KV cache ({p0}+{seq})");
    assert_eq!(cache.n_layers, cfg.n_layers);
    assert_eq!(cache.d, cfg.d_model);
    cache.prepare_append(seq);
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();

    for (t, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        st.x[t * d..(t + 1) * d].copy_from_slice(model.tok_embed.row(tok));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // --- attention block ---
        rmsnorm(&st.x, &layer.attn_norm, cfg.eps, seq, d, &mut st.normed);
        layer.wq.forward_into(&st.normed, seq, &mut st.q, &mut st.scratch);
        layer.wk.forward_into(&st.normed, seq, &mut st.k, &mut st.scratch);
        layer.wv.forward_into(&st.normed, seq, &mut st.v, &mut st.scratch);
        for t in 0..seq {
            let pos = p0 + t;
            rope_row(&mut st.q[t * d..(t + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            rope_row(&mut st.k[t * d..(t + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            cache.write(li, pos, &st.k[t * d..(t + 1) * d], &st.v[t * d..(t + 1) * d]);
        }
        for t in 0..seq {
            attend_cached(st, cache, li, t, p0 + t);
        }
        layer.wo.forward_into(&st.attn[..seq * d], seq, &mut st.proj, &mut st.scratch);
        for i in 0..seq * d {
            st.x[i] += st.proj[i];
        }

        // --- MLP block ---
        rmsnorm(&st.x, &layer.mlp_norm, cfg.eps, seq, d, &mut st.normed);
        layer.w_gate.forward_into(&st.normed, seq, &mut st.gate, &mut st.scratch);
        layer.w_up.forward_into(&st.normed, seq, &mut st.up, &mut st.scratch);
        let f = cfg.d_ff;
        for i in 0..seq * f {
            st.gate[i] = silu(st.gate[i]) * st.up[i];
        }
        layer.w_down.forward_into(&st.gate[..seq * f], seq, &mut st.proj, &mut st.scratch);
        for i in 0..seq * d {
            st.x[i] += st.proj[i];
        }
    }
    cache.len = p0 + seq;
    head_logits(model, st, seq)
}

/// Advance a batch of requests by one token each: `tokens[b]` is appended
/// to `caches[b]`, each cache at its own position (`caches[b].len()`), so
/// requests of arbitrary, unequal depths batch together — the form the
/// continuous-batching scheduler needs. Returns next-token logits
/// (batch × vocab). All batch rows go through each projection in a single
/// `LinearOp` call, so packed weight columns are decoded once per step for
/// the whole batch; per-row results do not depend on what else is in the
/// batch (pinned by `tests/scheduler.rs`).
pub fn decode_step(
    model: &ExecModel,
    caches: &mut [&mut KvCache],
    tokens: &[u16],
    st: &mut ExecState,
) -> Matrix {
    let cfg = &model.config;
    assert_eq!(*cfg, st.cfg, "state built for a different config");
    let bn = tokens.len();
    assert!(bn > 0 && bn == caches.len(), "batch/caches mismatch");
    assert!(bn <= st.cap, "batch {bn} exceeds state capacity {}", st.cap);
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();
    for c in caches.iter_mut() {
        assert_eq!(c.n_layers, cfg.n_layers);
        assert_eq!(c.d, d);
        assert!(c.len < c.max_seq, "KV cache full");
        c.prepare_append(1); // no-op when the scheduler reserved already
    }

    for (b, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        st.x[b * d..(b + 1) * d].copy_from_slice(model.tok_embed.row(tok));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // --- attention block ---
        rmsnorm(&st.x, &layer.attn_norm, cfg.eps, bn, d, &mut st.normed);
        layer.wq.forward_into(&st.normed, bn, &mut st.q, &mut st.scratch);
        layer.wk.forward_into(&st.normed, bn, &mut st.k, &mut st.scratch);
        layer.wv.forward_into(&st.normed, bn, &mut st.v, &mut st.scratch);
        for b in 0..bn {
            let pos = caches[b].len;
            rope_row(&mut st.q[b * d..(b + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            rope_row(&mut st.k[b * d..(b + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            caches[b].write(li, pos, &st.k[b * d..(b + 1) * d], &st.v[b * d..(b + 1) * d]);
        }
        for b in 0..bn {
            let pos = caches[b].len;
            attend_cached(st, &*caches[b], li, b, pos);
        }
        layer.wo.forward_into(&st.attn[..bn * d], bn, &mut st.proj, &mut st.scratch);
        for i in 0..bn * d {
            st.x[i] += st.proj[i];
        }

        // --- MLP block ---
        rmsnorm(&st.x, &layer.mlp_norm, cfg.eps, bn, d, &mut st.normed);
        layer.w_gate.forward_into(&st.normed, bn, &mut st.gate, &mut st.scratch);
        layer.w_up.forward_into(&st.normed, bn, &mut st.up, &mut st.scratch);
        let f = cfg.d_ff;
        for i in 0..bn * f {
            st.gate[i] = silu(st.gate[i]) * st.up[i];
        }
        layer.w_down.forward_into(&st.gate[..bn * f], bn, &mut st.proj, &mut st.scratch);
        for i in 0..bn * d {
            st.x[i] += st.proj[i];
        }
    }
    for c in caches.iter_mut() {
        c.len += 1;
    }
    head_logits(model, st, bn)
}

/// Greedy next-token choice from one logits row. Ties break to the
/// *lowest* index — the strict `>` never replaces an equal best — so
/// greedy decode is reproducible across backends, batch compositions, and
/// thread counts; NaN entries never win (every comparison against NaN is
/// false). Pinned by `argmax_tie_breaks_to_lowest_index` below.
pub fn argmax(row: &[f32]) -> u16 {
    debug_assert!(!row.is_empty(), "argmax of empty logits row");
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{forward, ForwardState};
    use crate::util::rng::Rng;

    fn small_model(seed: u64) -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(seed))
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn dense_prefill_matches_forward() {
        let m = small_model(1);
        let em = ExecModel::dense(&m);
        let toks = [3u16, 7, 1, 30, 12, 9, 9, 2];
        let mut fstate = ForwardState::new(m.config);
        let want = forward(&m, &toks, &mut fstate);
        let mut st = ExecState::new(m.config);
        let mut cache = KvCache::new(&m.config);
        let got = prefill(&em, &mut cache, &toks, &mut st);
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        assert_eq!(cache.len(), toks.len());
        close(&got.data, &want.data, 1e-5);
    }

    #[test]
    fn decode_steps_match_full_prefill() {
        // prefill(prefix) + decode_step per remaining token must reproduce
        // the last-row logits of a full prefill at every position.
        let m = small_model(2);
        let em = ExecModel::dense(&m);
        let toks: Vec<u16> = vec![5, 1, 8, 30, 2, 2, 17, 9, 4, 11];
        let mut st = ExecState::new(m.config);

        let mut full_cache = KvCache::new(&m.config);
        let full = prefill(&em, &mut full_cache, &toks, &mut st);

        let split = 4;
        let mut cache = KvCache::new(&m.config);
        let pre = prefill(&em, &mut cache, &toks[..split], &mut st);
        close(pre.row(split - 1), full.row(split - 1), 1e-5);
        for (i, &tok) in toks[split..].iter().enumerate() {
            let logits = decode_step(&em, &mut [&mut cache], &[tok], &mut st);
            close(logits.row(0), full.row(split + i), 1e-5);
        }
        assert_eq!(cache.len(), toks.len());
    }

    #[test]
    fn batched_decode_matches_single() {
        let m = small_model(3);
        let em = ExecModel::dense(&m);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[9, 8, 7, 6, 5], &[31, 0]];
        let next = [4u16, 4, 4];
        let mut st = ExecState::new(m.config);

        // individually
        let mut singles = Vec::new();
        for (p, &n) in prompts.iter().zip(&next) {
            let mut cache = KvCache::new(&m.config);
            let _ = prefill(&em, &mut cache, p, &mut st);
            singles.push(decode_step(&em, &mut [&mut cache], &[n], &mut st));
        }

        // batched, each request at its own depth
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&m.config);
                let _ = prefill(&em, &mut c, p, &mut st);
                c
            })
            .collect();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let batched = decode_step(&em, &mut refs, &next, &mut st);
        for (b, single) in singles.iter().enumerate() {
            close(batched.row(b), single.row(0), 1e-6);
            assert_eq!(caches[b].len(), prompts[b].len() + 1);
        }
    }

    #[test]
    fn cache_reset_and_truncate() {
        let m = small_model(4);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut cache = KvCache::new(&m.config);
        let a = prefill(&em, &mut cache, &[1, 2, 3, 4], &mut st);
        // truncate back to the 2-token prefix and replay: same logits
        cache.truncate(2);
        let b = prefill(&em, &mut cache, &[3, 4], &mut st);
        close(b.row(1), a.row(3), 1e-6);
        cache.reset();
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0, "reset drops the page table");
        let c = prefill(&em, &mut cache, &[1, 2, 3, 4], &mut st);
        close(&c.data, &a.data, 1e-6);
    }

    /// The tentpole contract (quantization off): the page table is
    /// invisible — any page size reproduces the single-page (contiguous-
    /// equivalent) logits **bit-for-bit**, through prefill, chunked
    /// prefill, and decode.
    #[test]
    fn page_size_is_invisible_to_decoding() {
        let m = small_model(9);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let toks = [3u16, 1, 4, 1, 5, 9, 2, 6];

        let mut whole = KvCache::with_page_tokens(&m.config, m.config.max_seq);
        let want_pre = prefill(&em, &mut whole, &toks, &mut st);
        let mut want_dec = Vec::new();
        let mut tok = argmax(want_pre.row(toks.len() - 1));
        for _ in 0..4 {
            let l = decode_step(&em, &mut [&mut whole], &[tok], &mut st);
            tok = argmax(l.row(0));
            want_dec.push(l.data);
        }

        for pt in [1usize, 3, 4, 7] {
            let mut c = KvCache::with_page_tokens(&m.config, pt);
            assert_eq!(c.page_tokens(), pt);
            // chunked prefill crosses page boundaries mid-chunk
            let got_a = prefill(&em, &mut c, &toks[..5], &mut st);
            let got_b = prefill(&em, &mut c, &toks[5..], &mut st);
            assert_eq!(&got_a.data[..], &want_pre.data[..5 * m.config.vocab], "pt={pt}");
            assert_eq!(&got_b.data[..], &want_pre.data[5 * m.config.vocab..], "pt={pt}");
            let mut tok = argmax(got_b.row(toks.len() - 5 - 1));
            for want in &want_dec {
                let l = decode_step(&em, &mut [&mut c], &[tok], &mut st);
                tok = argmax(l.row(0));
                assert_eq!(&l.data, want, "pt={pt}");
            }
            assert_eq!(c.pages.len(), (toks.len() + 4).div_ceil(pt));
        }
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.0, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn argmax_tie_breaks_to_lowest_index() {
        // exact ties resolve to the lowest index, so greedy decode is
        // reproducible no matter which backend produced the logits
        assert_eq!(argmax(&[0.0, 7.5, 2.0, 7.5, 7.5]), 1);
        assert_eq!(argmax(&[3.25, 3.25]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // NaN never wins, wherever it sits
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
    }

    #[test]
    fn fork_from_matches_cold_prefix() {
        let m = small_model(7);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let toks = [2u16, 9, 4, 4, 1, 7];

        // 2-token pages so forks land mid-page (CoW) and on boundaries
        let mut full = KvCache::with_page_tokens(&m.config, 2);
        let want = prefill(&em, &mut full, &toks, &mut st);

        // share at every interior depth and prefill the tail: logits for
        // the tail positions must be bit-identical to the cold prefill
        for depth in 1..toks.len() {
            let mut fork = KvCache::fork_from(&full, depth);
            assert_eq!(fork.len(), depth);
            let got = prefill(&em, &mut fork, &toks[depth..], &mut st);
            for (r, pos) in (depth..toks.len()).enumerate() {
                assert_eq!(got.row(r), want.row(pos), "fork depth {depth}, position {pos}");
            }
            assert_eq!(fork.len(), toks.len());
        }
        // ...and the source is untouched by all that appending
        assert_eq!(full.len(), toks.len());
        let replay = prefill(&em, &mut KvCache::fork_from(&full, 0), &toks, &mut st);
        assert_eq!(replay.data, want.data, "source pages were mutated by a fork");

        // the pool-shell flavour over a recycled cache is the same
        let mut pool = KvPagePool::with_page_tokens(m.config, 2);
        let mut dst = pool.take_cache();
        dst.share_prefix_from(&full, 3);
        let got = prefill(&em, &mut dst, &toks[3..], &mut st);
        assert_eq!(got.row(toks.len() - 3 - 1), want.row(toks.len() - 1));
    }

    /// Copy-on-write mechanics: sharing copies nothing; the first append
    /// into a shared *partial* tail page forks exactly that page, while
    /// full shared pages stay shared (and page-aligned shares never copy).
    #[test]
    fn share_is_zero_copy_and_forks_lazily() {
        let m = small_model(11);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut src = KvCache::with_page_tokens(&m.config, 4);
        let _ = prefill(&em, &mut src, &[1, 2, 3, 4, 5, 6], &mut st);

        // mid-page share: both pages shared, zero bytes copied
        let mut fork = KvCache::fork_from(&src, 6);
        let pages: Vec<usize> = src.page_stats().map(|s| s.ptr).collect();
        let fpages: Vec<usize> = fork.page_stats().map(|s| s.ptr).collect();
        assert_eq!(pages, fpages, "sharing must reference the same pages");
        assert!(src.page_stats().all(|s| s.shared));

        // appending forks ONLY the partial tail page (index 1)
        let _ = decode_step(&em, &mut [&mut fork], &[7], &mut st);
        let fpages: Vec<usize> = fork.page_stats().map(|s| s.ptr).collect();
        assert_eq!(fpages[0], pages[0], "full page stays shared");
        assert_ne!(fpages[1], pages[1], "partial tail page must fork on append");
        let src_stats: Vec<PageStat> = src.page_stats().collect();
        assert!(src_stats[0].shared && !src_stats[1].shared);

        // page-aligned share + append: no fork, the new write opens page 2
        let mut fork2 = KvCache::fork_from(&src, 4);
        let _ = decode_step(&em, &mut [&mut fork2], &[7], &mut st);
        assert_eq!(fork2.page_stats().next().unwrap().ptr, pages[0]);
        assert_eq!(fork2.pages.len(), 2);
    }

    /// Page-pool accounting stays exact while the prefix cache pins and
    /// evicts pages: pins hold pages outside the pool, sharing takes
    /// nothing, CoW forks take exactly one page, and everything drains
    /// back (free == created).
    #[test]
    fn pool_accounting_under_share_and_pin() {
        use crate::runtime::prefix_cache::PrefixCache;
        let m = small_model(8);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut pool = KvPagePool::with_capacity_paged(m.config, 4, 2);
        let page = pool.page_bytes();
        assert_eq!(pool.pages_per_request(), 4);
        assert_eq!((pool.free_pages(), pool.pages_created()), (8, 8));
        assert_eq!(pool.resident_bytes(), 8 * page);
        let mut pc = PrefixCache::new(page); // room to pin exactly one 1-page prefix

        let mut a = pool.take_cache();
        let mut b = pool.take_cache();
        a.reserve(&mut pool, 3);
        b.reserve(&mut pool, 3);
        let _ = prefill(&em, &mut a, &[1, 2, 3], &mut st);
        let _ = prefill(&em, &mut b, &[1, 2, 4], &mut st);
        assert_eq!((pool.hits(), pool.misses()), (2, 0));
        assert_eq!(pool.free_pages(), 6);

        pc.insert(&[1, 2, 3], a, &mut pool);
        assert_eq!(pc.resident_bytes(), page);
        assert_eq!(pool.free_pages(), 6, "pinned pages live outside the pool");

        // a second pin evicts the first back into the pool
        pc.insert(&[1, 2, 4], b, &mut pool);
        assert_eq!(pc.evictions(), 1);
        assert_eq!(pc.resident_bytes(), page);
        assert_eq!(pool.free_pages(), 7);

        // sharing into a pooled shell takes zero pages
        let mut dst = pool.take_cache();
        let depth = pc.share_into(&[1, 2, 4], &mut dst);
        assert_eq!(depth, 2);
        assert_eq!(pool.free_pages(), 7, "a prefix hit copies no pages");
        assert_eq!(dst.bytes(), page);

        // the first append CoW-forks the shared tail from the pool
        dst.reserve(&mut pool, 1);
        assert_eq!(pool.free_pages(), 6);
        pool.put_cache(dst); // fork comes home; the pinned page stays put
        assert_eq!(pool.free_pages(), 7);

        // hygiene: drain the trie and every page is back
        pc.drain(&mut pool);
        assert_eq!(pool.free_pages() as u64, pool.pages_created());
        assert_eq!((pool.hits(), pool.misses()), (4, 0), "prewarmed pool never allocated");
    }

    #[test]
    fn pool_recycles_and_resets() {
        let m = small_model(6);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut pool = KvPagePool::new(m.config); // max_seq 16 → 1 page/request

        let mut a = pool.take_cache();
        a.reserve(&mut pool, 3); // cold: allocates a page
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        assert_eq!(pool.pages_created(), 1);
        let logits1 = prefill(&em, &mut a, &[1, 2, 3], &mut st);
        assert_eq!(a.len(), 3);
        pool.put_cache(a);
        assert_eq!(pool.free_pages(), 1);
        assert!(pool.resident_bytes() > 0);

        let mut b = pool.take_cache();
        assert!(b.is_empty(), "recycled shell must start a fresh sequence");
        b.reserve(&mut pool, 3); // warm: recycled page (dirty, fully overwritten)
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
        assert_eq!(pool.free_pages(), 0);
        let logits2 = prefill(&em, &mut b, &[1, 2, 3], &mut st);
        close(&logits2.data, &logits1.data, 0.0);
        assert!((pool.hit_rate() - 0.5).abs() < 1e-9);
        pool.put_cache(b);
        assert_eq!(pool.free_pages() as u64, pool.pages_created());
    }

    /// Cold-page quantization: exact byte accounting, idempotence, shared
    /// pages skipped, and tolerance-gated (not bit-gated) logits.
    #[test]
    fn quantize_cold_pages_accounting_and_tolerance() {
        let m = small_model(10);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let toks: Vec<u16> = (0..12).map(|i| (i * 5 % 31) as u16).collect();

        let mut c = KvCache::with_page_tokens(&m.config, 4);
        let mut c_ref = KvCache::with_page_tokens(&m.config, 4);
        let _ = prefill(&em, &mut c, &toks, &mut st);
        let _ = prefill(&em, &mut c_ref, &toks, &mut st);
        let f32_bytes = c.bytes();

        // margin 4 → cold_end 8 → exactly pages 0 and 1 (tokens 0..8)
        assert_eq!(c.quantize_cold_pages(8, 4, None), 2);
        assert_eq!(c.quantize_cold_pages(8, 4, None), 0, "idempotent until len grows");
        let stats: Vec<PageStat> = c.page_stats().collect();
        assert_eq!(stats.iter().filter(|s| s.quantized).count(), 2);
        let want: usize = stats.iter().map(|s| s.bytes).sum();
        assert_eq!(c.bytes(), want, "bytes() must track the quant codec exactly");
        assert!(c.bytes() < f32_bytes, "quantized pages must shrink residency");

        // reads through quantized pages: tolerance, not bit-identity
        let next = 3u16;
        let a = decode_step(&em, &mut [&mut c], &[next], &mut st);
        let b = decode_step(&em, &mut [&mut c_ref], &[next], &mut st);
        close(&a.data, &b.data, 0.05);

        // shared pages are never quantized out from under a reader
        let mut src = KvCache::with_page_tokens(&m.config, 4);
        let _ = prefill(&em, &mut src, &toks, &mut st);
        let _pin = KvCache::fork_from(&src, 8);
        assert_eq!(src.quantize_cold_pages(8, 4, None), 0, "shared pages skipped");
    }
}
