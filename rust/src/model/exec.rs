//! Packed-execution layer: the serving counterpart of `forward.rs`.
//!
//! An [`ExecModel`] is the transformer with every attention/MLP projection
//! behind the [`LinearOp`] trait, so the same forward code runs off dense
//! f32 weights *or* straight off the packed CLAQ planes (embedding, norms,
//! and LM head stay FP, as in the paper). On top of it sits the
//! incremental decode path the scoring-only harness never needed:
//!
//! * [`KvCache`] — per-request key/value cache (n_layers × max_seq × d).
//! * [`KvCachePool`] — recycling allocator for caches, so steady-state
//!   serving does zero large allocations (the scheduler's cache source).
//! * [`prefill`] — run a prompt chunk once, populating the cache and
//!   returning logits for every prompt position.
//! * [`decode_step`] — advance a *batch* of requests by one token each,
//!   each request at its own cache position (variable lengths; the
//!   continuous-batching scheduler mixes requests at arbitrary depths).
//!   Caches are passed as `&mut [&mut KvCache]` so a batch can be formed
//!   over caches owned by different scheduler slots without moving them.
//!   Batching matters for the packed backend: a weight column is decoded
//!   once per step and the rank-1 update is applied to every sequence in
//!   the batch, amortizing plane unpacking across the batch.
//!
//! Both paths reuse the RMSNorm/RoPE/SiLU kernels of `forward.rs`, so the
//! dense ExecModel agrees with [`forward`](super::forward::forward) to
//! rounding error (pinned by tests below).

use super::checkpoint::Checkpoint;
use super::forward::{rmsnorm, rope_row, rope_tables, silu};
use super::linear::{DenseLinear, LinearOp, LinearScratch, PackedLinear};
use super::{MatrixId, MatrixKind, Model, TransformerConfig};
use crate::tensor::Matrix;
use anyhow::{ensure, Context, Result};

/// One decoder layer with backend-agnostic projections.
pub struct ExecLayer {
    pub attn_norm: Vec<f32>,
    pub wq: Box<dyn LinearOp>,
    pub wk: Box<dyn LinearOp>,
    pub wv: Box<dyn LinearOp>,
    pub wo: Box<dyn LinearOp>,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Box<dyn LinearOp>,
    pub w_up: Box<dyn LinearOp>,
    pub w_down: Box<dyn LinearOp>,
}

/// The executable model: FP embedding/norms/LM-head plus `LinearOp`
/// projections (dense or packed).
pub struct ExecModel {
    pub config: TransformerConfig,
    /// (vocab × d_model), FP.
    pub tok_embed: Matrix,
    pub layers: Vec<ExecLayer>,
    pub final_norm: Vec<f32>,
    pub lm_head: Box<dyn LinearOp>,
    /// Backend label for reports ("dense" / "packed").
    pub backend: &'static str,
}

impl ExecModel {
    /// Wrap a dense model (the reference backend).
    pub fn dense(model: &Model) -> Self {
        let boxed = |w: &Matrix| -> Box<dyn LinearOp> { Box::new(DenseLinear::new(w.clone())) };
        let layers = model
            .layers
            .iter()
            .map(|l| ExecLayer {
                attn_norm: l.attn_norm.clone(),
                wq: boxed(&l.wq),
                wk: boxed(&l.wk),
                wv: boxed(&l.wv),
                wo: boxed(&l.wo),
                mlp_norm: l.mlp_norm.clone(),
                w_gate: boxed(&l.w_gate),
                w_up: boxed(&l.w_up),
                w_down: boxed(&l.w_down),
            })
            .collect();
        Self {
            config: model.config,
            tok_embed: model.tok_embed.clone(),
            layers,
            final_norm: model.final_norm.clone(),
            lm_head: Box::new(DenseLinear::new(model.lm_head.clone())),
            backend: "dense",
        }
    }

    /// Cold-start path: build the packed execution model straight from a
    /// loaded `CLAQMD01` checkpoint — every projection becomes a
    /// [`PackedLinear`] over the serialized container (f16 codebooks, AWQ
    /// scales folded in) and **no dense projection matrix is ever
    /// materialized**. Consumes the checkpoint so the FP parts (embedding,
    /// norms, LM head — the largest FP blocks) are moved in, not copied:
    /// copies would double peak FP memory and land straight in the
    /// cold-start latency `bench_decode` tracks. Bit-identical to
    /// `QuantizedModel::to_exec_deployed` on the model that saved the
    /// checkpoint (pinned by `tests/checkpoint_roundtrip.rs`).
    pub fn from_checkpoint(ckpt: Checkpoint) -> Result<Self> {
        let Checkpoint { fp, entries, .. } = ckpt;
        let cfg = fp.config;
        let by_id: std::collections::HashMap<MatrixId, &super::checkpoint::CheckpointEntry> =
            entries.iter().map(|e| (e.id, e)).collect();
        let super::io::FpParts { tok_embed, attn_norms, mlp_norms, final_norm, lm_head, .. } = fp;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (layer, (attn_norm, mlp_norm)) in
            attn_norms.into_iter().zip(mlp_norms).enumerate()
        {
            let op = |kind: MatrixKind| -> Result<Box<dyn LinearOp>> {
                let id = MatrixId { layer, kind };
                let e = by_id
                    .get(&id)
                    .with_context(|| format!("checkpoint is missing {}", id.name()))?;
                let lin = PackedLinear::from_container(&e.container, e.awq_scales.as_deref())
                    .with_context(|| format!("build packed op for {}", id.name()))?;
                let want = kind.shape(&cfg);
                ensure!(
                    (lin.out_features(), lin.in_features()) == want,
                    "{}: container is {}x{} but the config expects {}x{}",
                    id.name(),
                    lin.out_features(),
                    lin.in_features(),
                    want.0,
                    want.1
                );
                Ok(Box::new(lin))
            };
            layers.push(ExecLayer {
                attn_norm,
                wq: op(MatrixKind::Wq)?,
                wk: op(MatrixKind::Wk)?,
                wv: op(MatrixKind::Wv)?,
                wo: op(MatrixKind::Wo)?,
                mlp_norm,
                w_gate: op(MatrixKind::WGate)?,
                w_up: op(MatrixKind::WUp)?,
                w_down: op(MatrixKind::WDown)?,
            });
        }
        Ok(Self {
            config: cfg,
            tok_embed,
            layers,
            final_norm,
            lm_head: Box::new(DenseLinear::new(lm_head)),
            backend: "packed",
        })
    }

    /// Resident bytes of the quantizable projections (the part the packed
    /// backend shrinks; FP embedding/head are identical across backends).
    pub fn projection_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.weight_bytes()
                    + l.wk.weight_bytes()
                    + l.wv.weight_bytes()
                    + l.wo.weight_bytes()
                    + l.w_gate.weight_bytes()
                    + l.w_up.weight_bytes()
                    + l.w_down.weight_bytes()
            })
            .sum()
    }

    /// Packed index-plane bytes decoded by one full forward step (all
    /// layers + LM head; 0 for the dense backend) — the per-step numerator
    /// of the bench layer's `bytes_decoded_per_s` throughput extra.
    pub fn decoded_plane_bytes_per_step(&self) -> usize {
        self.lm_head.decoded_plane_bytes()
            + self
                .layers
                .iter()
                .map(|l| {
                    l.wq.decoded_plane_bytes()
                        + l.wk.decoded_plane_bytes()
                        + l.wv.decoded_plane_bytes()
                        + l.wo.decoded_plane_bytes()
                        + l.w_gate.decoded_plane_bytes()
                        + l.w_up.decoded_plane_bytes()
                        + l.w_down.decoded_plane_bytes()
                })
                .sum::<usize>()
    }
}

/// Per-request key/value cache over all layers.
pub struct KvCache {
    n_layers: usize,
    d: usize,
    max_seq: usize,
    len: usize,
    /// (n_layers × max_seq × d) each.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(cfg: &TransformerConfig) -> Self {
        let n = cfg.n_layers * cfg.max_seq * cfg.d_model;
        Self {
            n_layers: cfg.n_layers,
            d: cfg.d_model,
            max_seq: cfg.max_seq,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum cacheable positions.
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// Drop all cached positions (start a fresh sequence).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll back to the first `len` positions (e.g. re-decode from a
    /// shared prefix).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond cached length");
        self.len = len;
    }

    /// Clone the first `len` cached positions of `src` into a new cache.
    /// K/V rows of a position depend only on the tokens at or before it,
    /// so a fork at `len` is bit-identical to a cold prefill of those
    /// `len` tokens — the property the prefix-sharing cache
    /// (`runtime/prefix_cache.rs`) is built on. Serving paths should
    /// prefer [`copy_prefix_from`](KvCache::copy_prefix_from) onto a
    /// pooled cache to avoid the allocation.
    pub fn fork_from(src: &KvCache, len: usize) -> KvCache {
        let mut cache = KvCache {
            n_layers: src.n_layers,
            d: src.d,
            max_seq: src.max_seq,
            len: 0,
            k: vec![0.0; src.k.len()],
            v: vec![0.0; src.v.len()],
        };
        cache.copy_prefix_from(src, len);
        cache
    }

    /// Overwrite this cache with the first `len` positions of `src` and
    /// set the length to `len` — the allocation-free fork used by the
    /// prefix cache on pool-recycled destinations. A partial `prefill`
    /// afterwards appends at position `len`, exactly as if the prefix had
    /// just been prefilled here.
    pub fn copy_prefix_from(&mut self, src: &KvCache, len: usize) {
        assert!(len <= src.len, "fork beyond source length ({len} > {})", src.len);
        assert!(
            self.n_layers == src.n_layers && self.d == src.d && self.max_seq == src.max_seq,
            "fork between caches of different configs"
        );
        for layer in 0..self.n_layers {
            let base = layer * self.max_seq * self.d;
            let n = len * self.d;
            self.k[base..base + n].copy_from_slice(&src.k[base..base + n]);
            self.v[base..base + n].copy_from_slice(&src.v[base..base + n]);
        }
        self.len = len;
    }

    /// Resident bytes of the cache buffers.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    #[inline]
    fn at(&self, layer: usize, pos: usize) -> usize {
        debug_assert!(layer < self.n_layers && pos < self.max_seq);
        (layer * self.max_seq + pos) * self.d
    }

    #[inline]
    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let i = self.at(layer, pos);
        &self.k[i..i + self.d]
    }

    #[inline]
    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let i = self.at(layer, pos);
        &self.v[i..i + self.d]
    }

    #[inline]
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let i = self.at(layer, pos);
        self.k[i..i + self.d].copy_from_slice(k);
        self.v[i..i + self.d].copy_from_slice(v);
    }
}

/// Recycling allocator for [`KvCache`]s. A cache is ~n_layers × max_seq ×
/// d × 8 bytes — the single biggest per-request allocation on the serving
/// path — so the scheduler takes caches from a pool and returns them on
/// retirement; once the pool is warm (≥ peak live batch), steady-state
/// serving allocates nothing. Hit/miss counters and resident bytes feed
/// the scheduler's stats report.
pub struct KvCachePool {
    cfg: TransformerConfig,
    free: Vec<KvCache>,
    hits: u64,
    misses: u64,
}

impl KvCachePool {
    pub fn new(cfg: TransformerConfig) -> Self {
        Self { cfg, free: Vec::new(), hits: 0, misses: 0 }
    }

    /// Pool pre-warmed with `n` caches (counted as neither hits nor
    /// misses), so even the first requests allocate nothing.
    pub fn with_capacity(cfg: TransformerConfig, n: usize) -> Self {
        let free = (0..n).map(|_| KvCache::new(&cfg)).collect();
        Self { cfg, free, hits: 0, misses: 0 }
    }

    /// Take a cache, recycled (reset to length 0) when one is free,
    /// freshly allocated otherwise.
    pub fn take(&mut self) -> KvCache {
        match self.free.pop() {
            Some(mut cache) => {
                cache.reset();
                self.hits += 1;
                cache
            }
            None => {
                self.misses += 1;
                KvCache::new(&self.cfg)
            }
        }
    }

    /// Return a retired request's cache for reuse. The cache is reset
    /// immediately; panics if it was built for a different config.
    pub fn put(&mut self, mut cache: KvCache) {
        assert!(
            cache.n_layers == self.cfg.n_layers
                && cache.d == self.cfg.d_model
                && cache.max_seq == self.cfg.max_seq,
            "cache returned to a pool of a different config"
        );
        cache.reset();
        self.free.push(cache);
    }

    /// Free (recyclable) caches currently held.
    pub fn free_caches(&self) -> usize {
        self.free.len()
    }

    /// Takes served from the free list.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Takes that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Fraction of takes served without allocating (1.0 before any take).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Resident bytes of the pooled (free) cache buffers.
    pub fn resident_bytes(&self) -> usize {
        self.free.iter().map(KvCache::bytes).sum()
    }
}

/// Scratch buffers for the exec paths; `rows` capacity must cover both the
/// longest prefill chunk and the largest decode batch.
pub struct ExecState {
    cfg: TransformerConfig,
    cap: usize,
    x: Vec<f32>,      // (rows × d)
    normed: Vec<f32>, // (rows × d)
    q: Vec<f32>,      // (rows × d)
    k: Vec<f32>,      // (rows × d)
    v: Vec<f32>,      // (rows × d)
    attn: Vec<f32>,   // (rows × d)
    proj: Vec<f32>,   // (rows × d)
    gate: Vec<f32>,   // (rows × d_ff)
    up: Vec<f32>,     // (rows × d_ff)
    scores: Vec<f32>, // (max_seq)
    cos: Vec<f32>,    // (max_seq × head_dim/2)
    sin: Vec<f32>,
    scratch: LinearScratch, // LinearOp backend workspace
}

impl ExecState {
    /// State sized for full-context prefill (rows = max_seq), which also
    /// covers any decode batch up to max_seq requests.
    pub fn new(cfg: TransformerConfig) -> Self {
        Self::with_capacity(cfg, cfg.max_seq)
    }

    /// Row capacity: the largest prefill chunk / decode batch this state
    /// can run.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// State with explicit row capacity (≥ prefill chunk length and ≥
    /// decode batch size; max_seq-position RoPE/score tables regardless).
    pub fn with_capacity(cfg: TransformerConfig, rows: usize) -> Self {
        let cap = rows.max(1);
        let (d, f, s) = (cfg.d_model, cfg.d_ff, cfg.max_seq);
        let (cos, sin) = rope_tables(&cfg, s);
        // The LinearOp workspace (column-decode scratch, shard staging, and
        // the shard descriptors of the parallel dispatch) is sized up front
        // for the widest projection at full row capacity, so nothing on the
        // decode hot path allocates at all.
        let max_out = d.max(f).max(cfg.vocab);
        Self {
            cfg,
            cap,
            x: vec![0.0; cap * d],
            normed: vec![0.0; cap * d],
            q: vec![0.0; cap * d],
            k: vec![0.0; cap * d],
            v: vec![0.0; cap * d],
            attn: vec![0.0; cap * d],
            proj: vec![0.0; cap * d],
            gate: vec![0.0; cap * f],
            up: vec![0.0; cap * f],
            scores: vec![0.0; s],
            cos,
            sin,
            scratch: LinearScratch::with_capacity(max_out, cap),
        }
    }
}

/// Attention of one query row (`st.q[row]` at absolute `pos`) against the
/// cached keys/values `0..=pos` of `layer`, mixed into `st.attn[row]`.
fn attend_cached(st: &mut ExecState, cache: &KvCache, layer: usize, row: usize, pos: usize) {
    let d = st.cfg.d_model;
    let nh = st.cfg.n_heads;
    let hd = st.cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..nh {
        let off = h * hd;
        for u in 0..=pos {
            let krow = cache.k_row(layer, u);
            let qrow = &st.q[row * d + off..row * d + off + hd];
            let mut s = 0.0f32;
            for i in 0..hd {
                s += qrow[i] * krow[off + i];
            }
            st.scores[u] = s * scale;
        }
        let m = st.scores[..=pos].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for u in 0..=pos {
            let e = (st.scores[u] - m).exp();
            st.scores[u] = e;
            z += e;
        }
        let inv_z = 1.0 / z;
        let out = &mut st.attn[row * d + off..row * d + off + hd];
        out.fill(0.0);
        for u in 0..=pos {
            let p = st.scores[u] * inv_z;
            let vrow = cache.v_row(layer, u);
            for i in 0..hd {
                out[i] += p * vrow[off + i];
            }
        }
    }
}

/// Final RMSNorm + LM head over `rows` hidden-state rows → logits.
fn head_logits(model: &ExecModel, st: &mut ExecState, rows: usize) -> Matrix {
    let cfg = &model.config;
    let d = cfg.d_model;
    rmsnorm(&st.x, &model.final_norm, cfg.eps, rows, d, &mut st.normed);
    let mut logits = Matrix::zeros(rows, cfg.vocab);
    model
        .lm_head
        .forward_into(&st.normed[..rows * d], rows, &mut logits.data, &mut st.scratch);
    logits
}

/// Run `tokens` through the model starting at the cache's current length,
/// appending K/V for every position; returns logits (seq × vocab). The
/// cache advances by `tokens.len()`; call with a fresh/reset cache for a
/// full-sequence forward. The start offset is the cache's length itself:
/// positions, RoPE angles, and attention spans all begin at `cache.len()`,
/// which is what makes partial prefill over a forked prefix
/// ([`KvCache::copy_prefix_from`], used by the prefix-sharing cache in
/// `runtime/prefix_cache.rs`) bit-identical to prefilling the whole
/// prompt cold.
pub fn prefill(
    model: &ExecModel,
    cache: &mut KvCache,
    tokens: &[u16],
    st: &mut ExecState,
) -> Matrix {
    let cfg = &model.config;
    assert_eq!(*cfg, st.cfg, "state built for a different config");
    let seq = tokens.len();
    let p0 = cache.len;
    assert!(seq > 0 && seq <= st.cap, "prefill chunk {seq} exceeds state capacity {}", st.cap);
    assert!(p0 + seq <= cache.max_seq, "prompt overflows KV cache ({p0}+{seq})");
    assert_eq!(cache.n_layers, cfg.n_layers);
    assert_eq!(cache.d, cfg.d_model);
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();

    for (t, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        st.x[t * d..(t + 1) * d].copy_from_slice(model.tok_embed.row(tok));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // --- attention block ---
        rmsnorm(&st.x, &layer.attn_norm, cfg.eps, seq, d, &mut st.normed);
        layer.wq.forward_into(&st.normed, seq, &mut st.q, &mut st.scratch);
        layer.wk.forward_into(&st.normed, seq, &mut st.k, &mut st.scratch);
        layer.wv.forward_into(&st.normed, seq, &mut st.v, &mut st.scratch);
        for t in 0..seq {
            let pos = p0 + t;
            rope_row(&mut st.q[t * d..(t + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            rope_row(&mut st.k[t * d..(t + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            cache.write(li, pos, &st.k[t * d..(t + 1) * d], &st.v[t * d..(t + 1) * d]);
        }
        for t in 0..seq {
            attend_cached(st, cache, li, t, p0 + t);
        }
        layer.wo.forward_into(&st.attn[..seq * d], seq, &mut st.proj, &mut st.scratch);
        for i in 0..seq * d {
            st.x[i] += st.proj[i];
        }

        // --- MLP block ---
        rmsnorm(&st.x, &layer.mlp_norm, cfg.eps, seq, d, &mut st.normed);
        layer.w_gate.forward_into(&st.normed, seq, &mut st.gate, &mut st.scratch);
        layer.w_up.forward_into(&st.normed, seq, &mut st.up, &mut st.scratch);
        let f = cfg.d_ff;
        for i in 0..seq * f {
            st.gate[i] = silu(st.gate[i]) * st.up[i];
        }
        layer.w_down.forward_into(&st.gate[..seq * f], seq, &mut st.proj, &mut st.scratch);
        for i in 0..seq * d {
            st.x[i] += st.proj[i];
        }
    }
    cache.len = p0 + seq;
    head_logits(model, st, seq)
}

/// Advance a batch of requests by one token each: `tokens[b]` is appended
/// to `caches[b]`, each cache at its own position (`caches[b].len()`), so
/// requests of arbitrary, unequal depths batch together — the form the
/// continuous-batching scheduler needs. Returns next-token logits
/// (batch × vocab). All batch rows go through each projection in a single
/// `LinearOp` call, so packed weight columns are decoded once per step for
/// the whole batch; per-row results do not depend on what else is in the
/// batch (pinned by `tests/scheduler.rs`).
pub fn decode_step(
    model: &ExecModel,
    caches: &mut [&mut KvCache],
    tokens: &[u16],
    st: &mut ExecState,
) -> Matrix {
    let cfg = &model.config;
    assert_eq!(*cfg, st.cfg, "state built for a different config");
    let bn = tokens.len();
    assert!(bn > 0 && bn == caches.len(), "batch/caches mismatch");
    assert!(bn <= st.cap, "batch {bn} exceeds state capacity {}", st.cap);
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();
    for c in caches.iter() {
        assert_eq!(c.n_layers, cfg.n_layers);
        assert_eq!(c.d, d);
        assert!(c.len < c.max_seq, "KV cache full");
    }

    for (b, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        st.x[b * d..(b + 1) * d].copy_from_slice(model.tok_embed.row(tok));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // --- attention block ---
        rmsnorm(&st.x, &layer.attn_norm, cfg.eps, bn, d, &mut st.normed);
        layer.wq.forward_into(&st.normed, bn, &mut st.q, &mut st.scratch);
        layer.wk.forward_into(&st.normed, bn, &mut st.k, &mut st.scratch);
        layer.wv.forward_into(&st.normed, bn, &mut st.v, &mut st.scratch);
        for b in 0..bn {
            let pos = caches[b].len;
            rope_row(&mut st.q[b * d..(b + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            rope_row(&mut st.k[b * d..(b + 1) * d], pos, &st.cos, &st.sin, nh, hd);
            caches[b].write(li, pos, &st.k[b * d..(b + 1) * d], &st.v[b * d..(b + 1) * d]);
        }
        for b in 0..bn {
            let pos = caches[b].len;
            attend_cached(st, &*caches[b], li, b, pos);
        }
        layer.wo.forward_into(&st.attn[..bn * d], bn, &mut st.proj, &mut st.scratch);
        for i in 0..bn * d {
            st.x[i] += st.proj[i];
        }

        // --- MLP block ---
        rmsnorm(&st.x, &layer.mlp_norm, cfg.eps, bn, d, &mut st.normed);
        layer.w_gate.forward_into(&st.normed, bn, &mut st.gate, &mut st.scratch);
        layer.w_up.forward_into(&st.normed, bn, &mut st.up, &mut st.scratch);
        let f = cfg.d_ff;
        for i in 0..bn * f {
            st.gate[i] = silu(st.gate[i]) * st.up[i];
        }
        layer.w_down.forward_into(&st.gate[..bn * f], bn, &mut st.proj, &mut st.scratch);
        for i in 0..bn * d {
            st.x[i] += st.proj[i];
        }
    }
    for c in caches.iter_mut() {
        c.len += 1;
    }
    head_logits(model, st, bn)
}

/// Greedy next-token choice from one logits row. Ties break to the
/// *lowest* index — the strict `>` never replaces an equal best — so
/// greedy decode is reproducible across backends, batch compositions, and
/// thread counts; NaN entries never win (every comparison against NaN is
/// false). Pinned by `argmax_tie_breaks_to_lowest_index` below.
pub fn argmax(row: &[f32]) -> u16 {
    debug_assert!(!row.is_empty(), "argmax of empty logits row");
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{forward, ForwardState};
    use crate::util::rng::Rng;

    fn small_model(seed: u64) -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(seed))
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn dense_prefill_matches_forward() {
        let m = small_model(1);
        let em = ExecModel::dense(&m);
        let toks = [3u16, 7, 1, 30, 12, 9, 9, 2];
        let mut fstate = ForwardState::new(m.config);
        let want = forward(&m, &toks, &mut fstate);
        let mut st = ExecState::new(m.config);
        let mut cache = KvCache::new(&m.config);
        let got = prefill(&em, &mut cache, &toks, &mut st);
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        assert_eq!(cache.len(), toks.len());
        close(&got.data, &want.data, 1e-5);
    }

    #[test]
    fn decode_steps_match_full_prefill() {
        // prefill(prefix) + decode_step per remaining token must reproduce
        // the last-row logits of a full prefill at every position.
        let m = small_model(2);
        let em = ExecModel::dense(&m);
        let toks: Vec<u16> = vec![5, 1, 8, 30, 2, 2, 17, 9, 4, 11];
        let mut st = ExecState::new(m.config);

        let mut full_cache = KvCache::new(&m.config);
        let full = prefill(&em, &mut full_cache, &toks, &mut st);

        let split = 4;
        let mut cache = KvCache::new(&m.config);
        let pre = prefill(&em, &mut cache, &toks[..split], &mut st);
        close(pre.row(split - 1), full.row(split - 1), 1e-5);
        for (i, &tok) in toks[split..].iter().enumerate() {
            let logits = decode_step(&em, &mut [&mut cache], &[tok], &mut st);
            close(logits.row(0), full.row(split + i), 1e-5);
        }
        assert_eq!(cache.len(), toks.len());
    }

    #[test]
    fn batched_decode_matches_single() {
        let m = small_model(3);
        let em = ExecModel::dense(&m);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[9, 8, 7, 6, 5], &[31, 0]];
        let next = [4u16, 4, 4];
        let mut st = ExecState::new(m.config);

        // individually
        let mut singles = Vec::new();
        for (p, &n) in prompts.iter().zip(&next) {
            let mut cache = KvCache::new(&m.config);
            let _ = prefill(&em, &mut cache, p, &mut st);
            singles.push(decode_step(&em, &mut [&mut cache], &[n], &mut st));
        }

        // batched, each request at its own depth
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&m.config);
                let _ = prefill(&em, &mut c, p, &mut st);
                c
            })
            .collect();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let batched = decode_step(&em, &mut refs, &next, &mut st);
        for (b, single) in singles.iter().enumerate() {
            close(batched.row(b), single.row(0), 1e-6);
            assert_eq!(caches[b].len(), prompts[b].len() + 1);
        }
    }

    #[test]
    fn cache_reset_and_truncate() {
        let m = small_model(4);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut cache = KvCache::new(&m.config);
        let a = prefill(&em, &mut cache, &[1, 2, 3, 4], &mut st);
        // truncate back to the 2-token prefix and replay: same logits
        cache.truncate(2);
        let b = prefill(&em, &mut cache, &[3, 4], &mut st);
        close(b.row(1), a.row(3), 1e-6);
        cache.reset();
        assert!(cache.is_empty());
        let c = prefill(&em, &mut cache, &[1, 2, 3, 4], &mut st);
        close(&c.data, &a.data, 1e-6);
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.0, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn argmax_tie_breaks_to_lowest_index() {
        // exact ties resolve to the lowest index, so greedy decode is
        // reproducible no matter which backend produced the logits
        assert_eq!(argmax(&[0.0, 7.5, 2.0, 7.5, 7.5]), 1);
        assert_eq!(argmax(&[3.25, 3.25]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // NaN never wins, wherever it sits
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
    }

    #[test]
    fn fork_from_matches_cold_prefix() {
        let m = small_model(7);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let toks = [2u16, 9, 4, 4, 1, 7];

        let mut full = KvCache::new(&m.config);
        let want = prefill(&em, &mut full, &toks, &mut st);

        // fork at every interior depth and prefill the tail: logits for
        // the tail positions must be bit-identical to the cold prefill
        for depth in 1..toks.len() {
            let mut fork = KvCache::fork_from(&full, depth);
            assert_eq!(fork.len(), depth);
            let got = prefill(&em, &mut fork, &toks[depth..], &mut st);
            for (r, pos) in (depth..toks.len()).enumerate() {
                assert_eq!(got.row(r), want.row(pos), "fork depth {depth}, position {pos}");
            }
            assert_eq!(fork.len(), toks.len());
        }

        // the allocation-free flavour over a recycled cache is the same
        let mut dst = KvCache::new(&m.config);
        let _ = prefill(&em, &mut dst, &[5, 5, 5, 5, 5, 5, 5], &mut st); // dirty it
        dst.reset();
        dst.copy_prefix_from(&full, 3);
        let got = prefill(&em, &mut dst, &toks[3..], &mut st);
        assert_eq!(got.row(toks.len() - 3 - 1), want.row(toks.len() - 1));
    }

    /// Pool accounting stays exact while the prefix cache pins and evicts
    /// caches: pins take buffers out of circulation (visible as misses
    /// once the free list drains), evictions hand them back.
    #[test]
    fn pool_accounting_under_fork_and_pin() {
        use crate::runtime::prefix_cache::PrefixCache;
        let m = small_model(8);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut pool = KvCachePool::with_capacity(m.config, 2);
        let cache_bytes = KvCache::new(&m.config).bytes();
        assert_eq!(pool.resident_bytes(), 2 * cache_bytes);
        let mut pc = PrefixCache::new(cache_bytes); // room for exactly one pin

        // take both pre-warmed caches (hits), pin one under its prompt
        let mut a = pool.take();
        let mut b = pool.take();
        assert_eq!((pool.hits(), pool.misses()), (2, 0));
        assert_eq!(pool.resident_bytes(), 0);
        let _ = prefill(&em, &mut a, &[1, 2, 3], &mut st);
        let _ = prefill(&em, &mut b, &[1, 2, 4], &mut st);
        pc.insert(&[1, 2, 3], a, &mut pool);
        assert_eq!(pc.resident_bytes(), cache_bytes);
        assert_eq!(pool.free_caches(), 0, "pinned caches live outside the pool");

        // a third take must allocate: one buffer is pinned, one is out
        let c = pool.take();
        assert_eq!((pool.hits(), pool.misses()), (2, 1));

        // pinning a second prompt evicts the first back into the pool
        pc.insert(&[1, 2, 4], b, &mut pool);
        assert_eq!(pc.evictions(), 1);
        assert_eq!(pc.resident_bytes(), cache_bytes);
        assert_eq!(pool.free_caches(), 1);
        assert_eq!(pool.resident_bytes(), cache_bytes);

        // forking copies: the pinned entry stays resident, the fork is a
        // pool cache, and the books balance
        let mut dst = pool.take();
        assert_eq!((pool.hits(), pool.misses()), (3, 1));
        let depth = pc.fork_into(&[1, 2, 4], &mut dst);
        assert_eq!(depth, 2);
        assert_eq!(pc.resident_bytes(), cache_bytes);
        pool.put(dst);
        pool.put(c);
        assert_eq!(pool.free_caches(), 2);
        assert_eq!(pool.resident_bytes(), 2 * cache_bytes);
    }

    #[test]
    fn pool_recycles_and_resets() {
        let m = small_model(6);
        let em = ExecModel::dense(&m);
        let mut st = ExecState::new(m.config);
        let mut pool = KvCachePool::new(m.config);

        let mut a = pool.take(); // cold: allocates
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        let logits1 = prefill(&em, &mut a, &[1, 2, 3], &mut st);
        assert_eq!(a.len(), 3);
        pool.put(a);
        assert_eq!(pool.free_caches(), 1);
        assert!(pool.resident_bytes() > 0);

        let mut b = pool.take(); // warm: recycled, reset to empty
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
        assert_eq!(pool.free_caches(), 0);
        assert!(b.is_empty(), "recycled cache must start a fresh sequence");
        let logits2 = prefill(&em, &mut b, &[1, 2, 3], &mut st);
        close(&logits2.data, &logits1.data, 0.0);
        assert!((pool.hit_rate() - 0.5).abs() < 1e-9);
        pool.put(b);
    }
}
