//! Binary weight container shared with the JAX trainer (`python/compile/
//! train.py` writes it, this module reads and also writes it for tests).
//!
//! Layout (little-endian):
//! ```text
//! magic "CLAQWT01"
//! vocab u32 | d_model u32 | n_layers u32 | n_heads u32 | d_ff u32 |
//! max_seq u32 | rope_theta f32 | eps f32
//! tok_embed (vocab×d f32)
//! per layer: attn_norm d | wq d×d | wk d×d | wv d×d | wo d×d |
//!            mlp_norm d | w_gate dff×d | w_up dff×d | w_down d×dff
//! final_norm d
//! lm_head (vocab×d)
//! ```
//!
//! The module also defines [`FpParts`] — the **FP-only** subset of a model
//! (config, token embedding, norms, LM head; no attention/MLP projection
//! weights). It is the FP block of the single-file CLAQMD01 checkpoint
//! (`model/checkpoint.rs`). Serializing a quantized model's FP side
//! through `FpParts` instead of `save_model` is what keeps checkpoints
//! smaller than the FP artifact: the dense projections (stale copies for a
//! quantized model) are never written.
//!
//! ```text
//! FP block (no magic — the checkpoint owns framing):
//! vocab u32 | d_model u32 | n_layers u32 | n_heads u32 | d_ff u32 |
//! max_seq u32 | rope_theta f32 | eps f32
//! tok_embed (vocab×d f32)
//! per layer: attn_norm d | mlp_norm d
//! final_norm d
//! lm_head (vocab×d)
//! ```

use super::{LayerWeights, Model, TransformerConfig};
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CLAQWT01";

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    // bulk conversion: f32 slice -> LE bytes
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf).context("short read")?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Write the 32-byte config block (shared by CLAQWT01 and the checkpoint
/// codec).
fn write_config(w: &mut impl Write, c: &TransformerConfig) -> Result<()> {
    for v in [c.vocab, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.max_seq] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    w.write_all(&c.rope_theta.to_le_bytes())?;
    w.write_all(&c.eps.to_le_bytes())?;
    Ok(())
}

/// Read + validate the 32-byte config block.
fn read_config(r: &mut impl Read) -> Result<TransformerConfig> {
    let vocab = read_u32(r)? as usize;
    let d_model = read_u32(r)? as usize;
    let n_layers = read_u32(r)? as usize;
    let n_heads = read_u32(r)? as usize;
    let d_ff = read_u32(r)? as usize;
    let max_seq = read_u32(r)? as usize;
    let rope_theta = read_f32(r)?;
    let eps = read_f32(r)?;
    let config = TransformerConfig { vocab, d_model, n_layers, n_heads, d_ff, max_seq, rope_theta, eps };
    config.validate()?;
    Ok(config)
}

/// Serialize a model.
pub fn save_model(model: &Model, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_config(&mut w, &model.config)?;
    write_f32s(&mut w, &model.tok_embed.data)?;
    for l in &model.layers {
        write_f32s(&mut w, &l.attn_norm)?;
        write_f32s(&mut w, &l.wq.data)?;
        write_f32s(&mut w, &l.wk.data)?;
        write_f32s(&mut w, &l.wv.data)?;
        write_f32s(&mut w, &l.wo.data)?;
        write_f32s(&mut w, &l.mlp_norm)?;
        write_f32s(&mut w, &l.w_gate.data)?;
        write_f32s(&mut w, &l.w_up.data)?;
        write_f32s(&mut w, &l.w_down.data)?;
    }
    write_f32s(&mut w, &model.final_norm)?;
    write_f32s(&mut w, &model.lm_head.data)?;
    w.flush()?;
    Ok(())
}

/// Load a model.
pub fn load_model(path: &Path) -> Result<Model> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let config = read_config(&mut r)?;
    let TransformerConfig { vocab, d_model, n_layers, d_ff, .. } = config;

    let d = d_model;
    let tok_embed = Matrix::from_vec(vocab, d, read_f32s(&mut r, vocab * d)?);
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        layers.push(LayerWeights {
            attn_norm: read_f32s(&mut r, d)?,
            wq: Matrix::from_vec(d, d, read_f32s(&mut r, d * d)?),
            wk: Matrix::from_vec(d, d, read_f32s(&mut r, d * d)?),
            wv: Matrix::from_vec(d, d, read_f32s(&mut r, d * d)?),
            wo: Matrix::from_vec(d, d, read_f32s(&mut r, d * d)?),
            mlp_norm: read_f32s(&mut r, d)?,
            w_gate: Matrix::from_vec(d_ff, d, read_f32s(&mut r, d_ff * d)?),
            w_up: Matrix::from_vec(d_ff, d, read_f32s(&mut r, d_ff * d)?),
            w_down: Matrix::from_vec(d, d_ff, read_f32s(&mut r, d * d_ff)?),
        });
    }
    let final_norm = read_f32s(&mut r, d)?;
    let lm_head = Matrix::from_vec(vocab, d, read_f32s(&mut r, vocab * d)?);
    // ensure EOF
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        bail!("trailing bytes in {}", path.display());
    }
    Ok(Model { config, tok_embed, layers, final_norm, lm_head })
}

// ------------------------------------------------------------ FP parts ----

/// The FP-only subset of a model: config, token embedding, per-layer RMSNorm
/// gains, final norm, and LM head. This is everything a packed execution
/// model needs besides the CLAQ planes — the dense projection weights are
/// deliberately absent (for a quantized model they are stale copies, and
/// writing them would make the checkpoint larger than the FP artifact it
/// replaces).
#[derive(Clone, Debug)]
pub struct FpParts {
    pub config: TransformerConfig,
    /// (vocab × d_model)
    pub tok_embed: Matrix,
    /// Per-layer attention-block RMSNorm gains (each `d_model` long).
    pub attn_norms: Vec<Vec<f32>>,
    /// Per-layer MLP-block RMSNorm gains (each `d_model` long).
    pub mlp_norms: Vec<Vec<f32>>,
    pub final_norm: Vec<f32>,
    /// (vocab × d_model)
    pub lm_head: Matrix,
}

/// Exact serialized size of an [`FpParts`] block (config block + tensors,
/// excluding any magic): the checkpoint size accounting depends on this
/// being byte-accurate, which `model/checkpoint.rs` tests pin.
pub fn fp_parts_byte_len(cfg: &TransformerConfig) -> usize {
    let floats = 2 * cfg.vocab * cfg.d_model // tok_embed + lm_head
        + (2 * cfg.n_layers + 1) * cfg.d_model; // per-layer norms + final
    32 + 4 * floats
}

/// Exact serialized size of a full `CLAQWT01` model file ([`save_model`]):
/// magic + config block + every parameter as f32. The single source of
/// truth for "how big is the FP artifact" comparisons (pinned equal to the
/// real file size by the round-trip test below).
pub fn model_file_byte_len(cfg: &TransformerConfig) -> usize {
    8 + 32 + 4 * cfg.n_params()
}

impl FpParts {
    /// Extract (clone) the FP parts of a model.
    pub fn from_model(model: &Model) -> Self {
        Self {
            config: model.config,
            tok_embed: model.tok_embed.clone(),
            attn_norms: model.layers.iter().map(|l| l.attn_norm.clone()).collect(),
            mlp_norms: model.layers.iter().map(|l| l.mlp_norm.clone()).collect(),
            final_norm: model.final_norm.clone(),
            lm_head: model.lm_head.clone(),
        }
    }

    /// Write the config block + tensors (no magic — the enclosing format
    /// owns framing).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        write_config(w, &self.config)?;
        write_f32s(w, &self.tok_embed.data)?;
        for (a, m) in self.attn_norms.iter().zip(&self.mlp_norms) {
            write_f32s(w, a)?;
            write_f32s(w, m)?;
        }
        write_f32s(w, &self.final_norm)?;
        write_f32s(w, &self.lm_head.data)?;
        Ok(())
    }

    /// Read the block written by [`FpParts::write_to`].
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let config = read_config(r)?;
        let (v, d) = (config.vocab, config.d_model);
        let tok_embed = Matrix::from_vec(v, d, read_f32s(r, v * d)?);
        let mut attn_norms = Vec::with_capacity(config.n_layers);
        let mut mlp_norms = Vec::with_capacity(config.n_layers);
        for _ in 0..config.n_layers {
            attn_norms.push(read_f32s(r, d)?);
            mlp_norms.push(read_f32s(r, d)?);
        }
        let final_norm = read_f32s(r, d)?;
        let lm_head = Matrix::from_vec(v, d, read_f32s(r, v * d)?);
        Ok(Self { config, tok_embed, attn_norms, mlp_norms, final_norm, lm_head })
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip() {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let mut rng = Rng::new(1);
        let m = Model::random(cfg, &mut rng);
        let dir = std::env::temp_dir().join("claq_model_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bin");
        save_model(&m, &path).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len() as usize, model_file_byte_len(&cfg));
        let back = load_model(&path).unwrap();
        assert_eq!(back.config, m.config);
        assert_eq!(back.tok_embed.data, m.tok_embed.data);
        assert_eq!(back.layers[1].w_down.data, m.layers[1].w_down.data);
        assert_eq!(back.final_norm, m.final_norm);
        assert_eq!(back.lm_head.data, m.lm_head.data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fp_parts_round_trip_and_byte_len_exact() {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let mut rng = Rng::new(7);
        let m = Model::random(cfg, &mut rng);
        let parts = FpParts::from_model(&m);

        // in-memory block length matches the analytic accounting exactly
        let mut buf = Vec::new();
        parts.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), fp_parts_byte_len(&cfg));
        let back = FpParts::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.config, cfg);
        assert_eq!(back.tok_embed.data, m.tok_embed.data);
        assert_eq!(back.attn_norms[1], m.layers[1].attn_norm);
        assert_eq!(back.mlp_norms[0], m.layers[0].mlp_norm);
        assert_eq!(back.final_norm, m.final_norm);
        assert_eq!(back.lm_head.data, m.lm_head.data);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("claq_model_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTAMODELFILE").unwrap();
        assert!(load_model(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
