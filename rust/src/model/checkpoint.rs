//! Single-file CLAQ model checkpoint (`CLAQMD01`) — the quantize-once /
//! serve-many deployment artifact.
//!
//! The pre-checkpoint `save_dir` had two defects this module fixes:
//! it silently **dropped the AWQ activation scales** (an AWQ model saved to
//! disk could never dequantize correctly again), and it serialized the FP
//! side through `save_model`, which writes the full dense model *including
//! the stale quantized projection weights* — making the "deployment
//! artifact" larger than the FP checkpoint it replaces. `CLAQMD01` stores
//! only what cold-start serving needs: the FP parts (token embedding,
//! norms, LM head), one packed container per projection — scalar
//! `CLAQPK01` or vector-quantized `CLAQVQ01`, dispatched per matrix on
//! the container magic, so one file can mix plane kinds — the AWQ
//! scales, and the method name. `ExecModel::from_checkpoint`
//! (`model/exec.rs`) builds `PackedLinear` ops straight from the loaded
//! containers without ever materializing a dense projection matrix.
//!
//! Layout (little-endian; see DESIGN.md §9 for the byte table):
//! ```text
//! magic "CLAQMD01"
//! method_len u32 | method UTF-8
//! FP block (framing-less, model/io.rs): config | tok_embed |
//!   per layer: attn_norm, mlp_norm | final_norm | lm_head
//! n_entries u32
//! per entry (write order: layer-major, MatrixKind::ALL order):
//!   layer u32 | kind u8
//!   awq_len u32 | awq scales f32 × awq_len      (0 = no AWQ)
//!   container_len u32 | container bytes (CLAQPK01 or CLAQVQ01)
//! ```
//! Strict reads: unknown magic, bad kind tags, shape mismatches against the
//! config, duplicate or missing matrices, and trailing bytes are all
//! rejected (`bail!`), mirroring the container-level
//! `corrupt_containers_rejected` discipline.
//!
//! The deprecated `save_dir`/`load_dir` directory layout (per-matrix
//! `.claq` files + `fp_parts.bin` + `method.txt` + `awq_scales.bin`) is
//! gone: `CLAQMD01` is the only checkpoint format.

use super::io::{fp_parts_byte_len, FpParts};
use super::quantized::QuantizedModel;
use super::{MatrixId, MatrixKind};
use crate::quant::packed::{self, PackedMatrix};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashSet;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"CLAQMD01";
const CONTAINER_MAGIC: &[u8; 8] = b"CLAQPK01";
const VQ_CONTAINER_MAGIC: &[u8; 8] = b"CLAQVQ01";

/// Fixed per-entry framing bytes: layer u32 + kind u8 + awq_len u32 +
/// container_len u32.
pub const ENTRY_FRAMING_BYTES: usize = 13;

/// Fixed header framing bytes: magic + method length field + method name +
/// entry count field.
pub fn header_bytes(method_name: &str) -> usize {
    8 + 4 + method_name.len() + 4
}

/// Does this method name carry AWQ activation scales? (`Method::Awq`
/// renders as `AWQ-{bits}`.)
pub fn method_uses_awq(method_name: &str) -> bool {
    method_name.starts_with("AWQ")
}

/// One packed projection of the checkpoint.
#[derive(Clone, Debug)]
pub struct CheckpointEntry {
    pub id: MatrixId,
    /// AWQ per-input-column activation scales (None for non-AWQ methods).
    pub awq_scales: Option<Vec<f32>>,
    /// The packed matrix container (`CLAQPK01` or `CLAQVQ01`).
    pub container: PackedMatrix,
}

/// A loaded (or to-be-saved) single-file model checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub method_name: String,
    /// FP parts: config, token embedding, norms, LM head.
    pub fp: FpParts,
    /// One entry per quantizable matrix, layer-major in
    /// [`MatrixKind::ALL`] order.
    pub entries: Vec<CheckpointEntry>,
}

fn u32_len(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| anyhow!("{what} too large for the u32 length field ({n} bytes)"))
}

/// Cheap container-header validation (magic + dims) without a full unpack
/// — a mismatched plane fails at load, not at first forward. Accepts both
/// plane kinds: scalar `CLAQPK01` and vector-quantized `CLAQVQ01` share
/// the rows/cols fields at offsets 8..16, so one checkpoint can mix
/// per-matrix plane kinds and dispatch happens on the container magic.
fn validate_container_header(bytes: &[u8], id: MatrixId, want: (usize, usize)) -> Result<()> {
    ensure!(bytes.len() >= 20, "{}: container truncated ({} bytes)", id.name(), bytes.len());
    ensure!(
        &bytes[..8] == CONTAINER_MAGIC || &bytes[..8] == VQ_CONTAINER_MAGIC,
        "{}: bad container magic",
        id.name()
    );
    let rows = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    ensure!(
        (rows, cols) == want,
        "{}: container is {rows}x{cols} but the config expects {}x{}",
        id.name(),
        want.0,
        want.1
    );
    Ok(())
}

impl Checkpoint {
    pub fn config(&self) -> &super::TransformerConfig {
        &self.fp.config
    }

    /// Build a checkpoint from a quantized model: pack every matrix and
    /// carry its AWQ scales. Requires a **fully** quantized model (the
    /// checkpoint has no dense-projection fallback); an FP16/partial model
    /// is rejected, and an AWQ model missing scales for any matrix is
    /// rejected rather than silently saved lossy (the old `save_dir` bug).
    pub fn from_quantized(qm: &QuantizedModel) -> Result<Self> {
        ensure!(
            !qm.matrices.is_empty(),
            "nothing to checkpoint for method {}: CLAQMD01 stores packed planes only — \
             use model::io::save_model for FP models",
            qm.method_name
        );
        ensure!(
            !method_uses_awq(&qm.method_name) || !qm.awq_scales.is_empty(),
            "method {} is AWQ but the model carries no activation scales — refusing to \
             save a checkpoint that cannot dequantize",
            qm.method_name
        );
        let mut entries = Vec::with_capacity(qm.base.matrix_ids().len());
        for id in qm.base.matrix_ids() {
            let m = qm.matrices.get(&id).with_context(|| {
                format!(
                    "matrix {} is not quantized — checkpoints require a fully quantized model",
                    id.name()
                )
            })?;
            let (container, _) =
                packed::pack(m).with_context(|| format!("pack {}", id.name()))?;
            let awq_scales = qm.awq_scales.get(&id).cloned();
            if let Some(s) = &awq_scales {
                ensure!(s.len() == m.cols, "{}: AWQ scales/columns mismatch", id.name());
            } else {
                ensure!(
                    qm.awq_scales.is_empty(),
                    "{}: AWQ model is missing activation scales — refusing to save a \
                     checkpoint that cannot dequantize",
                    id.name()
                );
            }
            entries.push(CheckpointEntry { id, awq_scales, container });
        }
        Ok(Self {
            method_name: qm.method_name.clone(),
            fp: FpParts::from_model(&qm.base),
            entries,
        })
    }

    /// Exact serialized size in bytes. Pinned equal to `encode().len()`
    /// (and therefore to the on-disk file size) by tests.
    pub fn byte_len(&self) -> usize {
        let entry_bytes: usize = self
            .entries
            .iter()
            .map(|e| {
                ENTRY_FRAMING_BYTES
                    + 4 * e.awq_scales.as_ref().map_or(0, Vec::len)
                    + e.container.bytes.len()
            })
            .sum();
        header_bytes(&self.method_name) + fp_parts_byte_len(&self.fp.config) + entry_bytes
    }

    /// Serialize to the single-file byte layout.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.byte_len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&u32_len(self.method_name.len(), "method name")?.to_le_bytes());
        out.extend_from_slice(self.method_name.as_bytes());
        self.fp.write_to(&mut out)?;
        out.extend_from_slice(&u32_len(self.entries.len(), "entry count")?.to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&(e.id.layer as u32).to_le_bytes());
            out.push(e.id.kind.to_u8());
            let scales = e.awq_scales.as_deref().unwrap_or(&[]);
            out.extend_from_slice(&u32_len(scales.len(), "awq scales")?.to_le_bytes());
            for &s in scales {
                out.extend_from_slice(&s.to_le_bytes());
            }
            out.extend_from_slice(&u32_len(e.container.bytes.len(), "container")?.to_le_bytes());
            out.extend_from_slice(&e.container.bytes);
        }
        debug_assert_eq!(out.len(), self.byte_len(), "byte_len accounting out of sync");
        Ok(out)
    }

    /// Strict inverse of [`Checkpoint::encode`].
    pub fn decode(b: &[u8]) -> Result<Self> {
        // Fault-injection hook: lets the chaos suite exercise the cold-start
        // error path (a checkpoint that fails to parse) without crafting
        // corrupt bytes. Zero-cost when `CLAQ_FAILPOINTS` is unset.
        ensure!(
            !crate::util::failpoint::fire(crate::util::failpoint::CKPT_DECODE),
            "injected fault: failpoint {} fired in Checkpoint::decode",
            crate::util::failpoint::CKPT_DECODE
        );
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > b.len() {
                bail!("truncated checkpoint at offset {pos}");
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let read_u32 =
            |pos: &mut usize| -> Result<u32> { Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap())) };

        if take(&mut pos, 8)? != MAGIC {
            bail!("bad magic (not a CLAQMD01 checkpoint)");
        }
        let mlen = read_u32(&mut pos)? as usize;
        ensure!(mlen <= 4096, "implausible method-name length {mlen}");
        let method_name = std::str::from_utf8(take(&mut pos, mlen)?)
            .context("method name is not UTF-8")?
            .to_string();

        let mut rdr = &b[pos..];
        let fp = FpParts::read_from(&mut rdr).context("FP parts block")?;
        pos = b.len() - rdr.len();
        let cfg = fp.config;

        let n_entries = read_u32(&mut pos)? as usize;
        let expected = cfg.n_layers * MatrixKind::ALL.len();
        ensure!(
            n_entries == expected,
            "checkpoint has {n_entries} matrices but the config requires {expected} — \
             partial checkpoints are not valid"
        );
        let mut seen: HashSet<MatrixId> = HashSet::with_capacity(n_entries);
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let layer = read_u32(&mut pos)? as usize;
            ensure!(layer < cfg.n_layers, "entry layer {layer} out of range");
            let tag = take(&mut pos, 1)?[0];
            let kind =
                MatrixKind::from_u8(tag).ok_or_else(|| anyhow!("invalid matrix kind tag {tag}"))?;
            let id = MatrixId { layer, kind };
            ensure!(seen.insert(id), "duplicate checkpoint entry for {}", id.name());
            let shape = kind.shape(&cfg);
            let awq_len = read_u32(&mut pos)? as usize;
            ensure!(
                awq_len == 0 || awq_len == shape.1,
                "{}: {awq_len} AWQ scales for {} columns",
                id.name(),
                shape.1
            );
            let mut awq_scales = None;
            if awq_len > 0 {
                let raw = take(&mut pos, 4 * awq_len)?;
                awq_scales = Some(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                );
            }
            let clen = read_u32(&mut pos)? as usize;
            let cbytes = take(&mut pos, clen)?;
            validate_container_header(cbytes, id, shape)?;
            entries.push(CheckpointEntry {
                id,
                awq_scales,
                container: PackedMatrix { bytes: cbytes.to_vec() },
            });
        }
        if pos != b.len() {
            bail!("trailing bytes ({} unread)", b.len() - pos);
        }
        // An AWQ-method checkpoint without scales would cold-start into an
        // engine that serves scaled weights it never unscales.
        if method_uses_awq(&method_name) {
            for e in &entries {
                ensure!(
                    e.awq_scales.is_some(),
                    "{}: AWQ-method checkpoint carries no activation scales for this \
                     matrix — refusing to serve mis-dequantized weights",
                    e.id.name()
                );
            }
        }
        Ok(Self { method_name, fp, entries })
    }

    /// Write the single-file checkpoint; returns the bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let bytes = self.encode()?;
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&bytes)?;
        Ok(bytes.len() as u64)
    }

    /// Read + decode a single-file checkpoint.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decode {}", path.display()))
    }
}

/// Convenience: pack + save `qm` as a single-file checkpoint; returns the
/// bytes written (what the pipeline's save-after-quantize option records).
pub fn save_checkpoint(qm: &QuantizedModel, path: &Path) -> Result<u64> {
    Checkpoint::from_quantized(qm)?.save(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, TransformerConfig};
    use crate::quant::config::Method;
    use crate::util::rng::Rng;

    fn small() -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(9))
    }

    fn quantized(method: &Method) -> QuantizedModel {
        QuantizedModel::quantize_uncalibrated(&small(), method)
    }

    /// Attach synthetic AWQ scales to every matrix (the codec does not care
    /// how scales were computed, only that they round-trip).
    fn with_awq_scales(mut qm: QuantizedModel) -> QuantizedModel {
        let mut rng = Rng::new(11);
        for id in qm.base.matrix_ids() {
            let cols = qm.base.matrix(id).cols;
            let scales: Vec<f32> = (0..cols).map(|_| 0.5 + rng.next_f32()).collect();
            qm.awq_scales.insert(id, scales);
        }
        qm.method_name = "AWQ-4".into();
        qm
    }

    fn uniq_path(tag: &str) -> std::path::PathBuf {
        crate::util::tmp::unique_path(&format!("ckpt_{tag}"))
    }

    #[test]
    fn encode_decode_round_trip_exact() {
        for qm in [
            quantized(&Method::Claq { bits: 3 }),
            with_awq_scales(quantized(&Method::Claq { bits: 4 })),
        ] {
            let ckpt = Checkpoint::from_quantized(&qm).unwrap();
            let bytes = ckpt.encode().unwrap();
            assert_eq!(bytes.len(), ckpt.byte_len(), "byte accounting must be exact");
            let back = Checkpoint::decode(&bytes).unwrap();
            assert_eq!(back.method_name, ckpt.method_name);
            assert_eq!(back.fp.config, ckpt.fp.config);
            assert_eq!(back.fp.lm_head.data, ckpt.fp.lm_head.data);
            assert_eq!(back.entries.len(), ckpt.entries.len());
            for (a, b) in back.entries.iter().zip(&ckpt.entries) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.awq_scales, b.awq_scales);
                assert_eq!(a.container.bytes, b.container.bytes);
            }
            // re-encode is byte-identical (deterministic codec)
            assert_eq!(back.encode().unwrap(), bytes);
        }
    }

    #[test]
    fn corrupt_checkpoints_rejected() {
        let ckpt = Checkpoint::from_quantized(&quantized(&Method::Claq { bits: 2 })).unwrap();
        let bytes = ckpt.encode().unwrap();
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Checkpoint::decode(&bad).is_err());
        // truncated
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 5]).is_err());
        // trailing bytes
        let mut long = bytes.clone();
        long.push(0);
        assert!(Checkpoint::decode(&long).is_err());
        // partial checkpoint (one entry dropped) is invalid
        let mut partial = ckpt.clone();
        partial.entries.pop();
        assert!(Checkpoint::decode(&partial.encode().unwrap()).is_err());
        // duplicate entry is invalid
        let mut dup = ckpt.clone();
        let e = dup.entries[0].clone();
        *dup.entries.last_mut().unwrap() = e;
        assert!(Checkpoint::decode(&dup.encode().unwrap()).is_err());
    }

    #[test]
    fn fp16_and_partial_models_refused() {
        let m = small();
        let fp = QuantizedModel {
            base: m.clone(),
            matrices: std::collections::HashMap::new(),
            awq_scales: std::collections::HashMap::new(),
            method_name: "FP16".into(),
        };
        assert!(Checkpoint::from_quantized(&fp).is_err());
        let mut partial = quantized(&Method::Claq { bits: 2 });
        let id = partial.base.matrix_ids()[0];
        partial.matrices.remove(&id);
        let err = Checkpoint::from_quantized(&partial).unwrap_err();
        assert!(format!("{err:#}").contains(&id.name()), "{err:#}");
    }

    #[test]
    fn awq_scales_survive_the_file_and_missing_scales_fail_loudly() {
        let qm = with_awq_scales(quantized(&Method::Claq { bits: 4 }));
        let path = uniq_path("awq");
        let written = save_checkpoint(&qm, &path).unwrap();
        assert_eq!(written, std::fs::metadata(&path).unwrap().len());
        let back = Checkpoint::load(&path).unwrap();
        for e in &back.entries {
            assert_eq!(
                e.awq_scales.as_ref(),
                qm.awq_scales.get(&e.id),
                "{} scales must survive the round trip",
                e.id.name()
            );
        }
        let _ = std::fs::remove_file(&path);

        // an AWQ model with a scale map missing one matrix must not save
        let mut lossy = with_awq_scales(quantized(&Method::Claq { bits: 4 }));
        let id = lossy.base.matrix_ids()[3];
        lossy.awq_scales.remove(&id);
        assert!(Checkpoint::from_quantized(&lossy).is_err());

        // an AWQ-named model with NO scales at all must not save either
        let mut no_scales = quantized(&Method::Claq { bits: 4 });
        no_scales.method_name = "AWQ-4".into();
        assert!(Checkpoint::from_quantized(&no_scales).is_err());

        // and a foreign AWQ-method *file* with its scales stripped must
        // not decode — same contract as the dir shim's missing-scales bail
        let mut stripped = Checkpoint::from_quantized(&with_awq_scales(quantized(
            &Method::Claq { bits: 4 },
        )))
        .unwrap();
        for e in &mut stripped.entries {
            e.awq_scales = None;
        }
        let err = Checkpoint::decode(&stripped.encode().unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("scales"), "{err:#}");
    }

}
