//! The quantized model: every attention/MLP matrix replaced by its packed
//! CLAQ representation (embedding, norms, and LM head stay FP, as in the
//! paper). Two consumers:
//!
//! * [`QuantizedModel::to_dense`] materializes a dense [`Model`] — the
//!   reference evaluation path.
//! * [`QuantizedModel::to_exec`] builds a packed [`ExecModel`] whose
//!   forward pass runs straight off the bit-packed planes via
//!   [`PackedLinear`] — the serving path; no dense weight matrix is ever
//!   materialized.

use super::exec::{ExecLayer, ExecModel};
use super::linear::{DenseLinear, LinearOp, PackedLinear};
use super::{MatrixId, MatrixKind, Model};
use crate::quant::gptq::QuantizedMatrix;
use crate::quant::packed::pack;
use anyhow::Result;
use std::collections::HashMap;

/// A fully quantized model plus bookkeeping.
pub struct QuantizedModel {
    /// The source model with FP parts intact (weights of quantized matrices
    /// inside are *stale*; use `to_dense` for an evaluable model).
    pub base: Model,
    pub matrices: HashMap<MatrixId, QuantizedMatrix>,
    /// AWQ per-column activation scales (quantized weights live in the
    /// scaled space; `to_dense` divides them back out). Empty for non-AWQ.
    pub awq_scales: HashMap<MatrixId, Vec<f32>>,
    pub method_name: String,
}

/// Aggregated size accounting over all quantized matrices.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelSizeReport {
    pub quantized_params: usize,
    pub container_bytes: usize,
    pub paper_equivalent_bits: f64,
    pub container_bits_per_param: f64,
    pub total_outliers: usize,
}

impl QuantizedModel {
    /// Materialize a dense model with quantized weights dequantized.
    pub fn to_dense(&self) -> Model {
        let mut m = self.base.clone();
        for (&id, qm) in &self.matrices {
            let mut deq = qm.dequantize();
            if let Some(scales) = self.awq_scales.get(&id) {
                for r in 0..deq.rows {
                    let row = deq.row_mut(r);
                    for (v, &s) in row.iter_mut().zip(scales) {
                        *v /= s;
                    }
                }
            }
            *m.matrix_mut(id) = deq;
        }
        m
    }

    /// Quantize every projection with `method`, calibration-free (identity
    /// Hessian, no AWQ) — representative planes/codebooks without the
    /// pipeline's calibration cost. This is what benches and tests use to
    /// get a packed model fast; real runs go through
    /// `coordinator::pipeline::quantize_model`.
    pub fn quantize_uncalibrated(model: &Model, method: &crate::quant::config::Method) -> Self {
        let mut matrices = HashMap::new();
        for id in model.matrix_ids() {
            let w = model.matrix(id);
            let plan = method.plan_for(w, None).expect("method yields a plan for every matrix");
            matrices.insert(id, crate::quant::gptq::quantize_matrix(w, None, &plan));
        }
        Self {
            base: model.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: method.name(),
        }
    }

    /// Build the packed execution model: every quantized matrix becomes a
    /// [`PackedLinear`] operating on its bit-packed index planes (AWQ
    /// scales folded in); anything left unquantized (and the LM head)
    /// stays dense. This is the serving path — `to_dense` never runs.
    pub fn to_exec(&self) -> ExecModel {
        let m = &self.base;
        let op = |id: MatrixId| -> Box<dyn LinearOp> {
            match self.matrices.get(&id) {
                Some(qm) => Box::new(PackedLinear::from_quantized(
                    qm,
                    self.awq_scales.get(&id).map(Vec::as_slice),
                )),
                None => Box::new(DenseLinear::new(m.matrix(id).clone())),
            }
        };
        let layers = (0..m.config.n_layers)
            .map(|layer| ExecLayer {
                attn_norm: m.layers[layer].attn_norm.clone(),
                wq: op(MatrixId { layer, kind: MatrixKind::Wq }),
                wk: op(MatrixId { layer, kind: MatrixKind::Wk }),
                wv: op(MatrixId { layer, kind: MatrixKind::Wv }),
                wo: op(MatrixId { layer, kind: MatrixKind::Wo }),
                mlp_norm: m.layers[layer].mlp_norm.clone(),
                w_gate: op(MatrixId { layer, kind: MatrixKind::WGate }),
                w_up: op(MatrixId { layer, kind: MatrixKind::WUp }),
                w_down: op(MatrixId { layer, kind: MatrixKind::WDown }),
            })
            .collect();
        ExecModel {
            config: m.config,
            tok_embed: m.tok_embed.clone(),
            layers,
            final_norm: m.final_norm.clone(),
            lm_head: Box::new(DenseLinear::new(m.lm_head.clone())),
            backend: "packed",
        }
    }

    /// Pack every matrix and aggregate size accounting.
    pub fn size_report(&self) -> ModelSizeReport {
        let mut rep = ModelSizeReport::default();
        let mut weighted_bits = 0.0f64;
        for qm in self.matrices.values() {
            let (_, r) = pack(qm);
            rep.quantized_params += r.params;
            rep.container_bytes += r.container_bytes();
            weighted_bits += r.paper_equivalent_bits * r.params as f64;
            rep.total_outliers += qm.outliers.len();
        }
        if rep.quantized_params > 0 {
            rep.paper_equivalent_bits = weighted_bits / rep.quantized_params as f64;
            rep.container_bits_per_param =
                rep.container_bytes as f64 * 8.0 / rep.quantized_params as f64;
        }
        rep
    }

    /// Serialize all packed matrices into one directory (one file per
    /// matrix), plus the FP parts as a weights file.
    pub fn save_dir(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (&id, qm) in &self.matrices {
            let (pm, _) = pack(qm);
            crate::quant::packed::save(&pm, &dir.join(format!("{}.claq", id.name())))?;
        }
        super::io::save_model(&self.base, &dir.join("fp_parts.bin"))?;
        Ok(())
    }

    /// Mean relative Frobenius error across quantized matrices (diagnostic).
    pub fn mean_rel_err(&self) -> f64 {
        if self.matrices.is_empty() {
            return 0.0;
        }
        self.matrices.values().map(|q| q.metrics.rel_frobenius_err).sum::<f64>()
            / self.matrices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::util::rng::Rng;

    fn quantize_all(model: &Model, bits: u8) -> QuantizedModel {
        let mut matrices = HashMap::new();
        for id in model.matrix_ids() {
            let w = model.matrix(id);
            let plan = MatrixPlan::uniform(w.cols, bits, CentroidRule::KMeans, false);
            matrices.insert(id, quantize_matrix(w, None, &plan));
        }
        QuantizedModel {
            base: model.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: format!("test-{bits}b"),
        }
    }

    fn small() -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(3))
    }

    #[test]
    fn dense_reconstruction_close_at_8bit() {
        let m = small();
        let qm = quantize_all(&m, 8);
        let dense = qm.to_dense();
        for id in m.matrix_ids() {
            let a = m.matrix(id);
            let b = dense.matrix(id);
            let mut num = 0.0;
            let mut den = 0.0;
            for (x, y) in a.data.iter().zip(&b.data) {
                num += ((x - y) as f64).powi(2);
                den += (*x as f64).powi(2);
            }
            assert!((num / den).sqrt() < 0.01, "{}", id.name());
        }
    }

    #[test]
    fn size_report_scales_with_bits() {
        let m = small();
        let r2 = quantize_all(&m, 2).size_report();
        let r4 = quantize_all(&m, 4).size_report();
        assert_eq!(r2.quantized_params, m.quantizable_params());
        assert!((r2.paper_equivalent_bits - 2.0).abs() < 1e-9);
        assert!((r4.paper_equivalent_bits - 4.0).abs() < 1e-9);
        assert!(r4.container_bytes > r2.container_bytes);
    }

    #[test]
    fn save_dir_writes_files() {
        let m = small();
        let qm = quantize_all(&m, 3);
        // Unique per-run directory: parallel `cargo test` processes (and
        // threads) must not collide on a shared temp path.
        static UNIQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "claq_qmodel_test_{}_{}",
            std::process::id(),
            UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        qm.save_dir(&dir).unwrap();
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, m.matrix_ids().len() + 1); // matrices + fp_parts.bin
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn packed_exec_matches_dense_forward() {
        // Acceptance gate: the PackedLinear forward agrees with the
        // dense-dequantized forward on a quantized tiny model.
        use crate::model::exec::{prefill, ExecModel, ExecState, KvCache};
        let m = small();
        let qm = quantize_all(&m, 3);
        let dense = ExecModel::dense(&qm.to_dense());
        let packed = qm.to_exec();
        assert_eq!(packed.backend, "packed");
        // (tiny 16-row matrices amortize codebooks poorly; real shapes are
        // checked in model/linear.rs — here just require a strict shrink)
        assert!(packed.projection_bytes() < dense.projection_bytes());

        let toks: Vec<u16> = (0..16).map(|i| (i * 5 % 32) as u16).collect();
        let mut st = ExecState::new(m.config);
        let mut c1 = KvCache::new(&m.config);
        let mut c2 = KvCache::new(&m.config);
        let a = prefill(&dense, &mut c1, &toks, &mut st);
        let b = prefill(&packed, &mut c2, &toks, &mut st);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "packed vs dense logits: {x} vs {y}"
            );
        }
    }
}
