//! The quantized model: every attention/MLP matrix replaced by its packed
//! CLAQ representation (embedding, norms, and LM head stay FP, as in the
//! paper). Two consumers:
//!
//! * [`QuantizedModel::to_dense`] materializes a dense [`Model`] — the
//!   reference evaluation path.
//! * [`QuantizedModel::to_exec`] builds a packed [`ExecModel`] whose
//!   forward pass runs straight off the bit-packed planes via
//!   [`PackedLinear`] — the serving path; no dense weight matrix is ever
//!   materialized.

use super::checkpoint::{self, Checkpoint};
use super::exec::{ExecLayer, ExecModel};
use super::linear::{DenseLinear, KernelKind, LinearOp, PackedLinear};
use super::{LayerWeights, MatrixId, MatrixKind, Model};
use crate::quant::gptq::QuantizedMatrix;
use crate::quant::packed::{pack, unpack};
use anyhow::{Context, Result};
use std::collections::HashMap;

/// A fully quantized model plus bookkeeping.
pub struct QuantizedModel {
    /// The source model with FP parts intact (weights of quantized matrices
    /// inside are *stale*; use `to_dense` for an evaluable model).
    pub base: Model,
    pub matrices: HashMap<MatrixId, QuantizedMatrix>,
    /// AWQ per-column activation scales (quantized weights live in the
    /// scaled space; `to_dense` divides them back out). Empty for non-AWQ.
    pub awq_scales: HashMap<MatrixId, Vec<f32>>,
    pub method_name: String,
}

/// Aggregated size accounting over all quantized matrices, plus the exact
/// byte budget of the single-file `CLAQMD01` checkpoint this model would
/// save (`model/checkpoint.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelSizeReport {
    pub quantized_params: usize,
    pub container_bytes: usize,
    pub paper_equivalent_bits: f64,
    pub container_bits_per_param: f64,
    pub total_outliers: usize,
    /// Matrices packed as scalar per-column planes (`CLAQPK01`).
    pub scalar_matrices: usize,
    /// Matrices packed as vector-quantized column groups (`CLAQVQ01`).
    pub vq_matrices: usize,
    /// Container bytes attributable to scalar planes.
    pub scalar_container_bytes: usize,
    /// Container bytes attributable to VQ planes. With
    /// `scalar_container_bytes` this partitions `container_bytes`, so
    /// mixed-kind models report where the budget actually goes.
    pub vq_container_bytes: usize,
    /// Bytes of the FP block (config + tok_embed + norms + LM head) —
    /// identical for every method on a given config.
    pub fp_bytes: usize,
    /// Bytes of serialized AWQ activation scales (0 for non-AWQ methods).
    pub awq_scale_bytes: usize,
    /// Exact size of the single-file checkpoint (`QuantizedModel::save`):
    /// header + method name + FP block + per-matrix framing + containers +
    /// AWQ scales. Pinned equal to the on-disk file size by tests.
    pub checkpoint_bytes: usize,
}

impl QuantizedModel {
    /// Materialize a dense model with quantized weights dequantized.
    pub fn to_dense(&self) -> Model {
        let mut m = self.base.clone();
        for (&id, qm) in &self.matrices {
            let mut deq = qm.dequantize();
            if let Some(scales) = self.awq_scales.get(&id) {
                for r in 0..deq.rows {
                    let row = deq.row_mut(r);
                    for (v, &s) in row.iter_mut().zip(scales) {
                        *v /= s;
                    }
                }
            }
            *m.matrix_mut(id) = deq;
        }
        m
    }

    /// Quantize every projection with `method`, calibration-free (identity
    /// Hessian, no AWQ) — representative planes/codebooks without the
    /// pipeline's calibration cost. This is what benches and tests use to
    /// get a packed model fast; real runs go through
    /// `coordinator::pipeline::quantize_model`.
    pub fn quantize_uncalibrated(model: &Model, method: &crate::quant::config::Method) -> Self {
        let mut matrices = HashMap::new();
        for id in model.matrix_ids() {
            let w = model.matrix(id);
            let plan = method.plan_for(w, None).expect("method yields a plan for every matrix");
            matrices.insert(id, crate::quant::gptq::quantize_matrix(w, None, &plan));
        }
        Self {
            base: model.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: method.name(),
        }
    }

    /// Build the packed execution model: every quantized matrix becomes a
    /// [`PackedLinear`] operating on its bit-packed index planes (AWQ
    /// scales folded in); anything left unquantized (and the LM head)
    /// stays dense. This is the serving path — `to_dense` never runs.
    /// Kernel selection follows the process-wide `CLAQ_KERNEL` default;
    /// see [`QuantizedModel::to_exec_kernel`] for an explicit choice.
    pub fn to_exec(&self) -> ExecModel {
        self.to_exec_kernel(KernelKind::from_env())
    }

    /// [`QuantizedModel::to_exec`] with an explicit packed-decode kernel —
    /// what side-by-side benches and kernel property tests use to compare
    /// the tiled and scalar kernels within one process.
    pub fn to_exec_kernel(&self, kernel: KernelKind) -> ExecModel {
        let m = &self.base;
        let op = |id: MatrixId| -> Box<dyn LinearOp> {
            match self.matrices.get(&id) {
                Some(qm) => Box::new(
                    PackedLinear::from_quantized(
                        qm,
                        self.awq_scales.get(&id).map(Vec::as_slice),
                    )
                    .with_kernel(kernel),
                ),
                None => Box::new(DenseLinear::new(m.matrix(id).clone())),
            }
        };
        let layers = (0..m.config.n_layers)
            .map(|layer| ExecLayer {
                attn_norm: m.layers[layer].attn_norm.clone(),
                wq: op(MatrixId { layer, kind: MatrixKind::Wq }),
                wk: op(MatrixId { layer, kind: MatrixKind::Wk }),
                wv: op(MatrixId { layer, kind: MatrixKind::Wv }),
                wo: op(MatrixId { layer, kind: MatrixKind::Wo }),
                mlp_norm: m.layers[layer].mlp_norm.clone(),
                w_gate: op(MatrixId { layer, kind: MatrixKind::WGate }),
                w_up: op(MatrixId { layer, kind: MatrixKind::WUp }),
                w_down: op(MatrixId { layer, kind: MatrixKind::WDown }),
            })
            .collect();
        ExecModel {
            config: m.config,
            tok_embed: m.tok_embed.clone(),
            layers,
            final_norm: m.final_norm.clone(),
            lm_head: Box::new(DenseLinear::new(m.lm_head.clone())),
            backend: "packed",
        }
    }

    /// Pack every matrix and aggregate size accounting, including the
    /// exact byte budget of the single-file checkpoint.
    pub fn size_report(&self) -> ModelSizeReport {
        let mut rep = ModelSizeReport::default();
        let mut weighted_bits = 0.0f64;
        let mut entry_bytes = 0usize;
        for (id, qm) in &self.matrices {
            let (_, r) = pack(qm).expect("size_report: un-packable quantized matrix");
            rep.quantized_params += r.params;
            rep.container_bytes += r.container_bytes();
            match r.kind {
                crate::quant::vq::PlaneKind::Scalar => {
                    rep.scalar_matrices += 1;
                    rep.scalar_container_bytes += r.container_bytes();
                }
                crate::quant::vq::PlaneKind::VectorGroup { .. } => {
                    rep.vq_matrices += 1;
                    rep.vq_container_bytes += r.container_bytes();
                }
            }
            weighted_bits += r.paper_equivalent_bits * r.params as f64;
            rep.total_outliers += qm.outliers.len();
            let awq_len = self.awq_scales.get(id).map_or(0, Vec::len);
            rep.awq_scale_bytes += 4 * awq_len;
            entry_bytes += checkpoint::ENTRY_FRAMING_BYTES + 4 * awq_len + r.container_bytes();
        }
        if rep.quantized_params > 0 {
            rep.paper_equivalent_bits = weighted_bits / rep.quantized_params as f64;
            rep.container_bits_per_param =
                rep.container_bytes as f64 * 8.0 / rep.quantized_params as f64;
        }
        rep.fp_bytes = super::io::fp_parts_byte_len(&self.base.config);
        rep.checkpoint_bytes =
            checkpoint::header_bytes(&self.method_name) + rep.fp_bytes + entry_bytes;
        rep
    }

    /// Save the single-file `CLAQMD01` checkpoint (FP parts + packed
    /// planes + AWQ scales + method metadata); returns the bytes written.
    /// See `model/checkpoint.rs` for the format and [`QuantizedModel::load`]
    /// for the inverse.
    pub fn save(&self, path: &std::path::Path) -> Result<u64> {
        checkpoint::save_checkpoint(self, path)
    }

    /// Inverse of [`QuantizedModel::save`]: rebuild a `QuantizedModel` from
    /// a checkpoint. The dense projections of `base` are rebuilt by
    /// dequantizing the loaded planes (f16-rounded codebooks, AWQ scales
    /// divided back out) — the same values the sequential pipeline leaves
    /// in `base` — so `to_dense`, evaluation, and re-quantization flows
    /// work. **Serving should not pay for this densification**: cold-start
    /// straight into the packed backend with
    /// [`ExecModel::from_checkpoint`] instead.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let Checkpoint { method_name, fp, entries } = Checkpoint::load(path)?;
        let cfg = fp.config;
        let mut matrices = HashMap::new();
        let mut awq_scales = HashMap::new();
        for e in entries {
            let qm = unpack(&e.container)
                .with_context(|| format!("unpack {}", e.id.name()))?;
            matrices.insert(e.id, qm);
            if let Some(s) = e.awq_scales {
                awq_scales.insert(e.id, s);
            }
        }
        // Rebuild base with dequantized (original-space) projections; the
        // FP tensors are moved out of the checkpoint, not copied — the
        // token embedding and LM head are the largest FP blocks.
        let super::io::FpParts { tok_embed, attn_norms, mlp_norms, final_norm, lm_head, .. } = fp;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (layer, (attn_norm, mlp_norm)) in
            attn_norms.into_iter().zip(mlp_norms).enumerate()
        {
            let deq = |kind: MatrixKind| {
                let id = MatrixId { layer, kind };
                let mut m = matrices[&id].dequantize();
                if let Some(scales) = awq_scales.get(&id) {
                    for r in 0..m.rows {
                        let row = m.row_mut(r);
                        for (v, &s) in row.iter_mut().zip(scales) {
                            *v /= s;
                        }
                    }
                }
                m
            };
            layers.push(LayerWeights {
                attn_norm,
                wq: deq(MatrixKind::Wq),
                wk: deq(MatrixKind::Wk),
                wv: deq(MatrixKind::Wv),
                wo: deq(MatrixKind::Wo),
                mlp_norm,
                w_gate: deq(MatrixKind::WGate),
                w_up: deq(MatrixKind::WUp),
                w_down: deq(MatrixKind::WDown),
            });
        }
        let base = Model { config: cfg, tok_embed, layers, final_norm, lm_head };
        Ok(Self { base, matrices, awq_scales, method_name })
    }

    /// The packed execution model exactly as a deployment sees it: every
    /// projection goes through the `CLAQPK01` codec, so codebooks are
    /// f16-rounded. Bit-identical to an [`ExecModel`] cold-started from a
    /// checkpoint of this model ([`ExecModel::from_checkpoint`]) — the
    /// property `tests/checkpoint_roundtrip.rs` pins. `to_exec` keeps the
    /// in-memory f32 codebooks (exact parity with `dequantize`).
    pub fn to_exec_deployed(&self) -> Result<ExecModel> {
        ExecModel::from_checkpoint(Checkpoint::from_quantized(self)?)
    }

    /// Mean relative Frobenius error across quantized matrices (diagnostic).
    pub fn mean_rel_err(&self) -> f64 {
        if self.matrices.is_empty() {
            return 0.0;
        }
        self.matrices.values().map(|q| q.metrics.rel_frobenius_err).sum::<f64>()
            / self.matrices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::util::rng::Rng;

    fn quantize_all(model: &Model, bits: u8) -> QuantizedModel {
        let mut matrices = HashMap::new();
        for id in model.matrix_ids() {
            let w = model.matrix(id);
            let plan = MatrixPlan::uniform(w.cols, bits, CentroidRule::KMeans, false);
            matrices.insert(id, quantize_matrix(w, None, &plan));
        }
        QuantizedModel {
            base: model.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: format!("test-{bits}b"),
        }
    }

    fn small() -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(3))
    }

    #[test]
    fn dense_reconstruction_close_at_8bit() {
        let m = small();
        let qm = quantize_all(&m, 8);
        let dense = qm.to_dense();
        for id in m.matrix_ids() {
            let a = m.matrix(id);
            let b = dense.matrix(id);
            let mut num = 0.0;
            let mut den = 0.0;
            for (x, y) in a.data.iter().zip(&b.data) {
                num += ((x - y) as f64).powi(2);
                den += (*x as f64).powi(2);
            }
            assert!((num / den).sqrt() < 0.01, "{}", id.name());
        }
    }

    #[test]
    fn size_report_scales_with_bits() {
        let m = small();
        let r2 = quantize_all(&m, 2).size_report();
        let r4 = quantize_all(&m, 4).size_report();
        assert_eq!(r2.quantized_params, m.quantizable_params());
        assert!((r2.paper_equivalent_bits - 2.0).abs() < 1e-9);
        assert!((r4.paper_equivalent_bits - 4.0).abs() < 1e-9);
        assert!(r4.container_bytes > r2.container_bytes);
    }

    fn uniq_path(tag: &str) -> std::path::PathBuf {
        crate::util::tmp::unique_path(&format!("qmodel_test_{tag}"))
    }

    /// The size report partitions containers by plane kind, and a pure-VQ
    /// model reports the sub-scalar paper bit budget (d=4 at 2 index bits
    /// is 0.5 paper-equivalent bits/param with no reserve).
    #[test]
    fn size_report_splits_plane_kinds() {
        let m = small();
        let rep = quantize_all(&m, 2).size_report();
        assert_eq!(rep.scalar_matrices, m.matrix_ids().len());
        assert_eq!(rep.vq_matrices, 0);
        assert_eq!(rep.scalar_container_bytes, rep.container_bytes);
        assert_eq!(rep.vq_container_bytes, 0);

        let vq = QuantizedModel::quantize_uncalibrated(
            &m,
            &crate::quant::config::Method::ClaqVq { d: 4, bits: 2 },
        );
        let rep = vq.size_report();
        assert_eq!(rep.vq_matrices, m.matrix_ids().len());
        assert_eq!(rep.scalar_matrices, 0);
        assert_eq!(rep.vq_container_bytes, rep.container_bytes);
        assert!((rep.paper_equivalent_bits - 0.5).abs() < 1e-9, "{}", rep.paper_equivalent_bits);

        let mut mixed = quantize_all(&m, 2);
        let id = m.matrix_ids()[0];
        let w = m.matrix(id);
        mixed
            .matrices
            .insert(id, quantize_matrix(w, None, &MatrixPlan::vector_group(w.cols, 4, 2, true)));
        let rep = mixed.size_report();
        assert_eq!(rep.vq_matrices, 1);
        assert_eq!(rep.scalar_matrices, m.matrix_ids().len() - 1);
        assert_eq!(rep.scalar_container_bytes + rep.vq_container_bytes, rep.container_bytes);
    }

    /// The old save_dir serialized the *full dense model* (stale quantized
    /// projections included) as its FP file, making the artifact larger
    /// than the FP checkpoint. The single-file checkpoint must be strictly
    /// smaller than `save_model` of the FP model for every low-bit plan,
    /// and the size report's accounting must match the file exactly.
    #[test]
    fn checkpoint_smaller_than_fp_model_and_accounting_exact() {
        let m = small();
        let fp_path = uniq_path("fp");
        super::super::io::save_model(&m, &fp_path).unwrap();
        let fp_len = std::fs::metadata(&fp_path).unwrap().len();
        for bits in [2u8, 3, 4] {
            let qm = quantize_all(&m, bits);
            let ckpt_path = uniq_path("ckpt");
            let written = qm.save(&ckpt_path).unwrap();
            let file_len = std::fs::metadata(&ckpt_path).unwrap().len();
            assert_eq!(written, file_len);
            let rep = qm.size_report();
            assert_eq!(rep.checkpoint_bytes as u64, file_len, "{bits}-bit accounting");
            assert!(
                file_len < fp_len,
                "{bits}-bit checkpoint ({file_len} B) must be smaller than the FP model ({fp_len} B)"
            );
            assert!(rep.fp_bytes > 0 && rep.fp_bytes < rep.checkpoint_bytes);
            assert_eq!(rep.awq_scale_bytes, 0);
            let _ = std::fs::remove_file(&ckpt_path);
        }
        let _ = std::fs::remove_file(&fp_path);
    }

    /// save -> load round trip: quantized planes, scales, and method name
    /// survive; the loaded model's packed exec path is bit-identical to
    /// the deployed in-memory path (f16 codebooks both sides).
    #[test]
    fn checkpoint_load_inverse_path() {
        let m = small();
        let qm = quantize_all(&m, 3);
        let path = uniq_path("inv");
        qm.save(&path).unwrap();
        let back = QuantizedModel::load(&path).unwrap();
        assert_eq!(back.method_name, qm.method_name);
        assert_eq!(back.matrices.len(), qm.matrices.len());
        for (id, orig) in &qm.matrices {
            let loaded = &back.matrices[id];
            assert_eq!(loaded.outliers, orig.outliers);
            for (a, b) in loaded.columns().iter().zip(orig.columns()) {
                assert_eq!(a.bits, b.bits);
                assert_eq!(a.indices, b.indices);
            }
        }
        // base projections are dequantized values — close to the source
        // weights at 3 bits, not the FP originals
        let id = MatrixId { layer: 0, kind: MatrixKind::Wq };
        assert_ne!(back.base.matrix(id).data, m.matrix(id).data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn packed_exec_matches_dense_forward() {
        // Acceptance gate: the PackedLinear forward agrees with the
        // dense-dequantized forward on a quantized tiny model.
        use crate::model::exec::{prefill, ExecModel, ExecState, KvCache};
        let m = small();
        let qm = quantize_all(&m, 3);
        let dense = ExecModel::dense(&qm.to_dense());
        let packed = qm.to_exec();
        assert_eq!(packed.backend, "packed");
        // (tiny 16-row matrices amortize codebooks poorly; real shapes are
        // checked in model/linear.rs — here just require a strict shrink)
        assert!(packed.projection_bytes() < dense.projection_bytes());

        let toks: Vec<u16> = (0..16).map(|i| (i * 5 % 32) as u16).collect();
        let mut st = ExecState::new(m.config);
        let mut c1 = KvCache::new(&m.config);
        let mut c2 = KvCache::new(&m.config);
        let a = prefill(&dense, &mut c1, &toks, &mut st);
        let b = prefill(&packed, &mut c2, &toks, &mut st);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "packed vs dense logits: {x} vs {y}"
            );
        }
    }
}
