//! The quantized model: every attention/MLP matrix replaced by its packed
//! CLAQ representation (embedding, norms, and LM head stay FP, as in the
//! paper). Evaluation dequantizes once into a dense [`Model`] — the CPU
//! analog of loading a quantized checkpoint onto the accelerator — while
//! the packed planes drive the size accounting and the fused
//! dequant-matmul benches.

use super::{MatrixId, Model};
use crate::quant::gptq::QuantizedMatrix;
use crate::quant::packed::pack;
use anyhow::Result;
use std::collections::HashMap;

/// A fully quantized model plus bookkeeping.
pub struct QuantizedModel {
    /// The source model with FP parts intact (weights of quantized matrices
    /// inside are *stale*; use `to_dense` for an evaluable model).
    pub base: Model,
    pub matrices: HashMap<MatrixId, QuantizedMatrix>,
    /// AWQ per-column activation scales (quantized weights live in the
    /// scaled space; `to_dense` divides them back out). Empty for non-AWQ.
    pub awq_scales: HashMap<MatrixId, Vec<f32>>,
    pub method_name: String,
}

/// Aggregated size accounting over all quantized matrices.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelSizeReport {
    pub quantized_params: usize,
    pub container_bytes: usize,
    pub paper_equivalent_bits: f64,
    pub container_bits_per_param: f64,
    pub total_outliers: usize,
}

impl QuantizedModel {
    /// Materialize a dense model with quantized weights dequantized.
    pub fn to_dense(&self) -> Model {
        let mut m = self.base.clone();
        for (&id, qm) in &self.matrices {
            let mut deq = qm.dequantize();
            if let Some(scales) = self.awq_scales.get(&id) {
                for r in 0..deq.rows {
                    let row = deq.row_mut(r);
                    for (v, &s) in row.iter_mut().zip(scales) {
                        *v /= s;
                    }
                }
            }
            *m.matrix_mut(id) = deq;
        }
        m
    }

    /// Pack every matrix and aggregate size accounting.
    pub fn size_report(&self) -> ModelSizeReport {
        let mut rep = ModelSizeReport::default();
        let mut weighted_bits = 0.0f64;
        for qm in self.matrices.values() {
            let (_, r) = pack(qm);
            rep.quantized_params += r.params;
            rep.container_bytes += r.container_bytes();
            weighted_bits += r.paper_equivalent_bits * r.params as f64;
            rep.total_outliers += qm.outliers.len();
        }
        if rep.quantized_params > 0 {
            rep.paper_equivalent_bits = weighted_bits / rep.quantized_params as f64;
            rep.container_bits_per_param =
                rep.container_bytes as f64 * 8.0 / rep.quantized_params as f64;
        }
        rep
    }

    /// Serialize all packed matrices into one directory (one file per
    /// matrix), plus the FP parts as a weights file.
    pub fn save_dir(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (&id, qm) in &self.matrices {
            let (pm, _) = pack(qm);
            crate::quant::packed::save(&pm, &dir.join(format!("{}.claq", id.name())))?;
        }
        super::io::save_model(&self.base, &dir.join("fp_parts.bin"))?;
        Ok(())
    }

    /// Mean relative Frobenius error across quantized matrices (diagnostic).
    pub fn mean_rel_err(&self) -> f64 {
        if self.matrices.is_empty() {
            return 0.0;
        }
        self.matrices.values().map(|q| q.metrics.rel_frobenius_err).sum::<f64>()
            / self.matrices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::util::rng::Rng;

    fn quantize_all(model: &Model, bits: u8) -> QuantizedModel {
        let mut matrices = HashMap::new();
        for id in model.matrix_ids() {
            let w = model.matrix(id);
            let plan = MatrixPlan::uniform(w.cols, bits, CentroidRule::KMeans, false);
            matrices.insert(id, quantize_matrix(w, None, &plan));
        }
        QuantizedModel {
            base: model.clone(),
            matrices,
            awq_scales: HashMap::new(),
            method_name: format!("test-{bits}b"),
        }
    }

    fn small() -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        Model::random(cfg, &mut Rng::new(3))
    }

    #[test]
    fn dense_reconstruction_close_at_8bit() {
        let m = small();
        let qm = quantize_all(&m, 8);
        let dense = qm.to_dense();
        for id in m.matrix_ids() {
            let a = m.matrix(id);
            let b = dense.matrix(id);
            let mut num = 0.0;
            let mut den = 0.0;
            for (x, y) in a.data.iter().zip(&b.data) {
                num += ((x - y) as f64).powi(2);
                den += (*x as f64).powi(2);
            }
            assert!((num / den).sqrt() < 0.01, "{}", id.name());
        }
    }

    #[test]
    fn size_report_scales_with_bits() {
        let m = small();
        let r2 = quantize_all(&m, 2).size_report();
        let r4 = quantize_all(&m, 4).size_report();
        assert_eq!(r2.quantized_params, m.quantizable_params());
        assert!((r2.paper_equivalent_bits - 2.0).abs() < 1e-9);
        assert!((r4.paper_equivalent_bits - 4.0).abs() < 1e-9);
        assert!(r4.container_bytes > r2.container_bytes);
    }

    #[test]
    fn save_dir_writes_files() {
        let m = small();
        let qm = quantize_all(&m, 3);
        let dir = std::env::temp_dir().join("claq_qmodel_test");
        let _ = std::fs::remove_dir_all(&dir);
        qm.save_dir(&dir).unwrap();
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, m.matrix_ids().len() + 1); // matrices + fp_parts.bin
        let _ = std::fs::remove_dir_all(&dir);
    }
}
