//! The `LinearOp` abstraction: the forward pass no longer assumes dense
//! f32 weights. A linear operator computes `out(seq × O) = x(seq × I) · Wᵀ`
//! for a weight matrix W stored (O × I); how W is represented is the
//! implementation's business:
//!
//! * [`DenseLinear`] / [`Matrix`] — the dense f32 reference path.
//! * [`PackedLinear`] — the deployable CLAQ representation: per-column
//!   bit-packed index planes + codebooks (`quant/packed.rs` layout), with
//!   reserved outliers applied as a sparse per-column override and AWQ
//!   activation scales folded in. No dense weight matrix is ever
//!   materialized; the kernel decodes one column (input feature) at a time
//!   into a reusable scratch buffer and accumulates a rank-1 update.
//!
//! Column-major traversal keeps the floating-point accumulation order
//! identical to the dense row dot products, so the packed and dense paths
//! agree to rounding error — the property `tests/packed_exec.rs` pins down.
//!
//! Both backends shard their output rows across the process-wide
//! [`ThreadPool`] (see [`run_row_sharded`]): every shard computes a
//! disjoint block of output features for the whole batch, decoding only
//! its own row range of each packed column. Because each output element is
//! still accumulated in ascending-column order, results are bit-identical
//! to the serial kernel for any thread count, shard partition, or batch
//! composition — the invariant the scheduler's batch-invariance property
//! (`tests/scheduler.rs`) relies on.

use crate::quant::gptq::QuantizedMatrix;
use crate::quant::packed::{decode_plane_range_into, pack_indices, PackedMatrix};
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Mutex;

/// A linear operator `y = x · Wᵀ` over a (rows=out × cols=in) weight.
pub trait LinearOp: Send + Sync {
    /// Output features (rows of W).
    fn out_features(&self) -> usize;
    /// Input features (cols of W).
    fn in_features(&self) -> usize;
    /// `out(seq × out_features) = x(seq × in_features) · Wᵀ`. `scratch` is a
    /// caller-owned reusable buffer for per-call workspace (column-decode
    /// and shard staging; resized on first use, e.g. pre-sized by
    /// `ExecState`) so the hot loop never reallocates its large buffers
    /// (parallel dispatch still makes O(shards) small bookkeeping
    /// allocations per call).
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>);

    /// Approximate resident bytes of the weight representation (for the
    /// serving memory report).
    fn weight_bytes(&self) -> usize;
}

/// Below this many multiply-accumulates (`seq × rows × cols`) a forward
/// runs serially: pool dispatch costs more than it buys.
const PAR_MIN_MACS: usize = 32 * 1024;
/// Minimum output rows per shard; smaller blocks don't amortize dispatch.
const PAR_MIN_ROWS: usize = 16;

/// Shard an output-rows kernel across [`ThreadPool::global`].
///
/// `kernel(r0, r1, decode, stage)` must compute output features
/// `[r0, r1)` for all `seq` batch rows into `stage`, laid out block-local
/// row-major (`seq × (r1-r0)`), using `decode` (`r1-r0` floats) as
/// column-decode scratch. Shards get disjoint sub-slices of `scratch`, so
/// the float buffers are never reallocated once `scratch` is warm (the
/// dispatch itself costs O(shards) small allocations); the staged
/// blocks are scattered into `out` afterwards. The serial path points
/// `stage` directly at `out` (block-local layout == output layout when the
/// block is all rows), so nothing is copied.
///
/// Every output element is produced by exactly one shard with the same
/// instruction stream as the serial kernel, so parallel and serial results
/// are bit-identical.
fn run_row_sharded<K>(
    rows: usize,
    cols: usize,
    seq: usize,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
    kernel: K,
) where
    K: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), seq * rows);
    let pool = ThreadPool::global();
    let shards = pool.workers().min(rows / PAR_MIN_ROWS).max(1);
    if shards <= 1 || seq * rows * cols < PAR_MIN_MACS {
        if scratch.len() < rows {
            scratch.resize(rows, 0.0);
        }
        kernel(0, rows, &mut scratch[..rows], out);
        return;
    }

    // Scratch layout: [decode: rows] ++ [stage: seq × rows], carved into
    // one disjoint (decode, stage) pair per shard.
    let need = rows + seq * rows;
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    let (decode_all, stage_all) = scratch[..need].split_at_mut(rows);
    let per_shard = rows.div_ceil(shards);
    let mut decode_rest = decode_all;
    let mut stage_rest = stage_all;
    let mut parts: Vec<Mutex<(usize, usize, &mut [f32], &mut [f32])>> = Vec::new();
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + per_shard).min(rows);
        let bl = r1 - r0;
        let (decode, rest) = std::mem::take(&mut decode_rest).split_at_mut(bl);
        decode_rest = rest;
        let (stage, rest) = std::mem::take(&mut stage_rest).split_at_mut(seq * bl);
        stage_rest = rest;
        parts.push(Mutex::new((r0, r1, decode, stage)));
        r0 = r1;
    }

    pool.run(parts.len(), |i| {
        // Uncontended: each job locks only its own part.
        let mut part = parts[i].lock().unwrap();
        let (r0, r1, ref mut decode, ref mut stage) = *part;
        kernel(r0, r1, &mut **decode, &mut **stage);
    });

    for part in parts {
        let (r0, r1, _, stage) = part.into_inner().unwrap();
        let bl = r1 - r0;
        for t in 0..seq {
            out[t * rows + r0..t * rows + r1].copy_from_slice(&stage[t * bl..(t + 1) * bl]);
        }
    }
}

/// Dense row-major f32 weights — the reference backend.
impl LinearOp for Matrix {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        run_row_sharded(rows, cols, seq, &mut out[..seq * rows], scratch, |r0, r1, _, stage| {
            let bl = r1 - r0;
            for t in 0..seq {
                let xi = &x[t * cols..(t + 1) * cols];
                let o = &mut stage[t * bl..(t + 1) * bl];
                for (j, ov) in o.iter_mut().enumerate() {
                    let wrow = self.row(r0 + j);
                    let mut acc = 0.0f32;
                    for (a, b) in xi.iter().zip(wrow) {
                        acc += a * b;
                    }
                    *ov = acc;
                }
            }
        });
    }

    fn weight_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Owning dense backend (a [`Matrix`] behind the trait, for `Box<dyn
/// LinearOp>` layers).
pub struct DenseLinear {
    pub w: Matrix,
}

impl DenseLinear {
    pub fn new(w: Matrix) -> Self {
        Self { w }
    }
}

impl LinearOp for DenseLinear {
    fn out_features(&self) -> usize {
        self.w.rows
    }

    fn in_features(&self) -> usize {
        self.w.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        self.w.forward_into(x, seq, out, scratch)
    }

    fn weight_bytes(&self) -> usize {
        self.w.weight_bytes()
    }
}

/// One quantized input feature: bit-packed row indices + decoded codebook.
struct PackedColumn {
    bits: u8,
    /// Codebook centroids decoded to f32 (2^bits entries, ≤ 256).
    centroids: Vec<f32>,
    /// `rows` indices, `bits` wide, LSB-first (the container plane layout).
    plane: Vec<u8>,
}

/// The packed CLAQ execution backend: computes `y = x · dequant(W)ᵀ`
/// straight from the index planes, applying reserved outliers as a sparse
/// override and folding AWQ per-column activation scales back out
/// (quantized weights live in the scaled space; see `model/quantized.rs`).
pub struct PackedLinear {
    rows: usize,
    cols: usize,
    columns: Vec<PackedColumn>,
    /// Reserved outliers in CSR-by-column form: for column c the entries
    /// `out_start[c]..out_start[c+1]` of (out_rows, out_vals).
    out_start: Vec<usize>,
    out_rows: Vec<u32>,
    out_vals: Vec<f32>,
    /// AWQ per-column scales to divide back out (None for non-AWQ).
    awq_scales: Option<Vec<f32>>,
}

impl PackedLinear {
    /// Build from an in-memory quantized matrix (f32 codebooks — exact
    /// parity with `QuantizedMatrix::dequantize`). `awq_scales` are the
    /// per-input-column activation scales of the AWQ path, if any.
    pub fn from_quantized(qm: &QuantizedMatrix, awq_scales: Option<&[f32]>) -> Self {
        let (rows, cols) = (qm.rows, qm.cols);
        assert_eq!(qm.columns.len(), cols);
        if let Some(s) = awq_scales {
            assert_eq!(s.len(), cols, "AWQ scales/columns mismatch");
        }
        let columns = qm
            .columns
            .iter()
            .map(|qc| {
                assert_eq!(qc.indices.len(), rows);
                PackedColumn {
                    bits: qc.bits,
                    centroids: qc.codebook.centroids.clone(),
                    plane: pack_indices(&qc.indices, qc.bits),
                }
            })
            .collect();

        // Outliers arrive sorted by (col, row); bucket them per column.
        let mut out_start = vec![0usize; cols + 1];
        for o in &qm.outliers {
            out_start[o.col as usize + 1] += 1;
        }
        for c in 0..cols {
            out_start[c + 1] += out_start[c];
        }
        let mut out_rows = Vec::with_capacity(qm.outliers.len());
        let mut out_vals = Vec::with_capacity(qm.outliers.len());
        let mut sorted: Vec<_> = qm.outliers.iter().collect();
        sorted.sort_by_key(|o| (o.col, o.row));
        for o in sorted {
            out_rows.push(o.row);
            out_vals.push(o.value);
        }

        Self {
            rows,
            cols,
            columns,
            out_start,
            out_rows,
            out_vals,
            awq_scales: awq_scales.map(<[f32]>::to_vec),
        }
    }

    /// Build from a serialized CLAQ container (codebooks come back through
    /// f16, exactly as a deployment would see them).
    pub fn from_container(pm: &PackedMatrix, awq_scales: Option<&[f32]>) -> Result<Self> {
        let qm = crate::quant::packed::unpack(pm)?;
        Ok(Self::from_quantized(&qm, awq_scales))
    }

    pub fn n_outliers(&self) -> usize {
        self.out_rows.len()
    }

    /// Decode rows `[r0, r1)` of column `c` (dequant + outlier override +
    /// AWQ un-scaling) into `out[..r1-r0]` — the per-column gather at the
    /// heart of the kernel, in the row-block form the sharded forward
    /// needs. Outliers of one column are sorted by row, so the block's
    /// overrides are found by binary search.
    fn decode_column_range_into(&self, c: usize, r0: usize, r1: usize, out: &mut [f32]) {
        let pc = &self.columns[c];
        let bl = r1 - r0;
        decode_plane_range_into(&pc.plane, pc.bits, &pc.centroids, r0, &mut out[..bl]);
        let (start, end) = (self.out_start[c], self.out_start[c + 1]);
        let lo = start + self.out_rows[start..end].partition_point(|&r| (r as usize) < r0);
        let hi = start + self.out_rows[start..end].partition_point(|&r| (r as usize) < r1);
        for i in lo..hi {
            out[self.out_rows[i] as usize - r0] = self.out_vals[i];
        }
        if let Some(scales) = &self.awq_scales {
            let scale = scales[c];
            if scale != 1.0 {
                for v in out[..bl].iter_mut() {
                    *v /= scale;
                }
            }
        }
    }
}

impl LinearOp for PackedLinear {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    /// Fused codebook-gather matmul, sharded over output rows. For each
    /// input feature c, a shard decodes its row block of the weight column
    /// once into scratch and accumulates `y[t, r0..r1] += x[t,c] · w_c`
    /// for every row of the batch, so plane unpacking is amortized across
    /// the batch and split (not duplicated) across threads. Accumulation
    /// runs in ascending-c order — the same order as the dense dot
    /// product, keeping the two paths bit-compatible.
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        run_row_sharded(rows, cols, seq, &mut out[..seq * rows], scratch, |r0, r1, decode, stage| {
            let bl = r1 - r0;
            stage[..seq * bl].fill(0.0);
            for c in 0..cols {
                self.decode_column_range_into(c, r0, r1, decode);
                let col = &decode[..bl];
                for t in 0..seq {
                    let xv = x[t * cols + c];
                    if xv == 0.0 {
                        continue;
                    }
                    let o = &mut stage[t * bl..(t + 1) * bl];
                    for (ov, &wv) in o.iter_mut().zip(col) {
                        *ov += xv * wv;
                    }
                }
            }
        });
    }

    fn weight_bytes(&self) -> usize {
        let planes: usize = self
            .columns
            .iter()
            .map(|c| c.plane.len() + c.centroids.len() * std::mem::size_of::<f32>() + 1)
            .sum();
        planes
            + self.out_rows.len() * (std::mem::size_of::<u32>() + std::mem::size_of::<f32>())
            + self.awq_scales.as_ref().map_or(0, |s| s.len() * std::mem::size_of::<f32>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::util::rng::Rng;

    fn sample(seed: u64, rows: usize, cols: usize, bits: u8, reserve: usize) -> (Matrix, QuantizedMatrix) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(cols, bits, CentroidRule::KMeans, false);
        plan.reserve = vec![reserve; cols];
        let qm = quantize_matrix(&w, None, &plan);
        (w, qm)
    }

    fn dense_ref(deq: &Matrix, x: &[f32], seq: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; seq * deq.rows];
        let mut scratch = Vec::new();
        deq.forward_into(x, seq, &mut out, &mut scratch);
        out
    }

    #[test]
    fn packed_matches_dense_dequant() {
        let (_, qm) = sample(1, 33, 12, 3, 2);
        let deq = qm.dequantize();
        let packed = PackedLinear::from_quantized(&qm, None);
        assert_eq!(packed.out_features(), 33);
        assert_eq!(packed.in_features(), 12);
        assert_eq!(packed.n_outliers(), 2 * 12);

        let mut rng = Rng::new(2);
        let seq = 5;
        let mut x = vec![0.0f32; seq * 12];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, seq);
        let mut got = vec![0.0f32; seq * 33];
        let mut scratch = Vec::new();
        packed.forward_into(&x, seq, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn awq_scales_divided_out() {
        let (_, qm) = sample(3, 20, 8, 4, 0);
        let scales: Vec<f32> = (0..8).map(|i| 0.5 + 0.25 * i as f32).collect();
        let mut deq = qm.dequantize();
        for r in 0..deq.rows {
            let row = deq.row_mut(r);
            for (v, &s) in row.iter_mut().zip(&scales) {
                *v /= s;
            }
        }
        let packed = PackedLinear::from_quantized(&qm, Some(&scales));
        let mut rng = Rng::new(4);
        let mut x = vec![0.0f32; 8];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 1);
        let mut got = vec![0.0f32; 20];
        let mut scratch = Vec::new();
        packed.forward_into(&x, 1, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn container_round_trip_backend() {
        let (_, qm) = sample(5, 40, 10, 2, 2);
        let (pm, _) = crate::quant::packed::pack(&qm).unwrap();
        let packed = PackedLinear::from_container(&pm, None).unwrap();
        // container codebooks are f16: compare against the f16-rounded deq
        let deq = crate::quant::packed::unpack(&pm).unwrap().dequantize();
        let mut rng = Rng::new(6);
        let mut x = vec![0.0f32; 3 * 10];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 3);
        let mut got = vec![0.0f32; 3 * 40];
        let mut scratch = Vec::new();
        packed.forward_into(&x, 3, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn packed_is_smaller_than_dense() {
        let (w, qm) = sample(7, 128, 64, 2, 2);
        let packed = PackedLinear::from_quantized(&qm, None);
        assert!(packed.weight_bytes() < w.weight_bytes() / 4);
    }

    /// Shapes large enough to cross the parallel threshold must produce
    /// bit-identical output to the serial kernel: each output element is
    /// accumulated in the same ascending-column order by exactly one
    /// shard. (Batch invariance of the scheduler rests on this.)
    #[test]
    fn sharded_forward_is_bit_identical_to_serial() {
        let (_, qm) = sample(9, 160, 96, 3, 2);
        let packed = PackedLinear::from_quantized(&qm, None);
        let mut rng = Rng::new(10);
        let seq = 8; // 8 × 160 × 96 MACs — well over PAR_MIN_MACS
        let mut x = vec![0.0f32; seq * 96];
        rng.fill_normal(&mut x, 1.0);

        // serial reference: run each batch row alone (below the MAC
        // threshold, so run_row_sharded takes the serial path)
        let mut want = vec![0.0f32; seq * 160];
        let mut scratch = Vec::new();
        for t in 0..seq {
            let row = &x[t * 96..(t + 1) * 96];
            packed.forward_into(row, 1, &mut want[t * 160..(t + 1) * 160], &mut scratch);
        }

        let mut got = vec![0.0f32; seq * 160];
        packed.forward_into(&x, seq, &mut got, &mut scratch);
        assert_eq!(got, want, "sharded kernel diverged from serial");

        // dense backend: same invariant
        let deq = qm.dequantize();
        let mut want_d = vec![0.0f32; seq * 160];
        for t in 0..seq {
            let row = &x[t * 96..(t + 1) * 96];
            deq.forward_into(row, 1, &mut want_d[t * 160..(t + 1) * 160], &mut scratch);
        }
        let mut got_d = vec![0.0f32; seq * 160];
        deq.forward_into(&x, seq, &mut got_d, &mut scratch);
        assert_eq!(got_d, want_d);
    }
}
