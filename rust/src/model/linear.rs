//! The `LinearOp` abstraction: the forward pass no longer assumes dense
//! f32 weights. A linear operator computes `out(seq × O) = x(seq × I) · Wᵀ`
//! for a weight matrix W stored (O × I); how W is represented is the
//! implementation's business:
//!
//! * [`DenseLinear`] / [`Matrix`] — the dense f32 reference path.
//! * [`PackedLinear`] — the deployable CLAQ representation: per-column
//!   bit-packed index planes + codebooks (`quant/packed.rs` layout), with
//!   reserved outliers applied as a sparse per-column override and AWQ
//!   activation scales folded in. No dense weight matrix is ever
//!   materialized; the kernel decodes one column (input feature) at a time
//!   into a reusable scratch buffer and accumulates a rank-1 update.
//!
//! Column-major traversal keeps the floating-point accumulation order
//! identical to the dense row dot products, so the packed and dense paths
//! agree to rounding error — the property `tests/packed_exec.rs` pins down.

use crate::quant::gptq::QuantizedMatrix;
use crate::quant::packed::{decode_plane_into, pack_indices, PackedMatrix};
use crate::tensor::Matrix;
use anyhow::Result;

/// A linear operator `y = x · Wᵀ` over a (rows=out × cols=in) weight.
pub trait LinearOp: Send + Sync {
    /// Output features (rows of W).
    fn out_features(&self) -> usize;
    /// Input features (cols of W).
    fn in_features(&self) -> usize;
    /// `out(seq × out_features) = x(seq × in_features) · Wᵀ`. `scratch` is a
    /// caller-owned reusable buffer (backends that need per-call workspace
    /// resize it; the dense path ignores it) so the hot loop allocates
    /// nothing per token.
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>);

    /// Approximate resident bytes of the weight representation (for the
    /// serving memory report).
    fn weight_bytes(&self) -> usize;
}

/// Dense row-major f32 weights — the reference backend.
impl LinearOp for Matrix {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], _scratch: &mut Vec<f32>) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        for t in 0..seq {
            let xi = &x[t * cols..(t + 1) * cols];
            let o = &mut out[t * rows..(t + 1) * rows];
            for (r, ov) in o.iter_mut().enumerate() {
                let wrow = self.row(r);
                let mut acc = 0.0f32;
                for (a, b) in xi.iter().zip(wrow) {
                    acc += a * b;
                }
                *ov = acc;
            }
        }
    }

    fn weight_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Owning dense backend (a [`Matrix`] behind the trait, for `Box<dyn
/// LinearOp>` layers).
pub struct DenseLinear {
    pub w: Matrix,
}

impl DenseLinear {
    pub fn new(w: Matrix) -> Self {
        Self { w }
    }
}

impl LinearOp for DenseLinear {
    fn out_features(&self) -> usize {
        self.w.rows
    }

    fn in_features(&self) -> usize {
        self.w.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        self.w.forward_into(x, seq, out, scratch)
    }

    fn weight_bytes(&self) -> usize {
        self.w.weight_bytes()
    }
}

/// One quantized input feature: bit-packed row indices + decoded codebook.
struct PackedColumn {
    bits: u8,
    /// Codebook centroids decoded to f32 (2^bits entries, ≤ 256).
    centroids: Vec<f32>,
    /// `rows` indices, `bits` wide, LSB-first (the container plane layout).
    plane: Vec<u8>,
}

/// The packed CLAQ execution backend: computes `y = x · dequant(W)ᵀ`
/// straight from the index planes, applying reserved outliers as a sparse
/// override and folding AWQ per-column activation scales back out
/// (quantized weights live in the scaled space; see `model/quantized.rs`).
pub struct PackedLinear {
    rows: usize,
    cols: usize,
    columns: Vec<PackedColumn>,
    /// Reserved outliers in CSR-by-column form: for column c the entries
    /// `out_start[c]..out_start[c+1]` of (out_rows, out_vals).
    out_start: Vec<usize>,
    out_rows: Vec<u32>,
    out_vals: Vec<f32>,
    /// AWQ per-column scales to divide back out (None for non-AWQ).
    awq_scales: Option<Vec<f32>>,
}

impl PackedLinear {
    /// Build from an in-memory quantized matrix (f32 codebooks — exact
    /// parity with `QuantizedMatrix::dequantize`). `awq_scales` are the
    /// per-input-column activation scales of the AWQ path, if any.
    pub fn from_quantized(qm: &QuantizedMatrix, awq_scales: Option<&[f32]>) -> Self {
        let (rows, cols) = (qm.rows, qm.cols);
        assert_eq!(qm.columns.len(), cols);
        if let Some(s) = awq_scales {
            assert_eq!(s.len(), cols, "AWQ scales/columns mismatch");
        }
        let columns = qm
            .columns
            .iter()
            .map(|qc| {
                assert_eq!(qc.indices.len(), rows);
                PackedColumn {
                    bits: qc.bits,
                    centroids: qc.codebook.centroids.clone(),
                    plane: pack_indices(&qc.indices, qc.bits),
                }
            })
            .collect();

        // Outliers arrive sorted by (col, row); bucket them per column.
        let mut out_start = vec![0usize; cols + 1];
        for o in &qm.outliers {
            out_start[o.col as usize + 1] += 1;
        }
        for c in 0..cols {
            out_start[c + 1] += out_start[c];
        }
        let mut out_rows = Vec::with_capacity(qm.outliers.len());
        let mut out_vals = Vec::with_capacity(qm.outliers.len());
        let mut sorted: Vec<_> = qm.outliers.iter().collect();
        sorted.sort_by_key(|o| (o.col, o.row));
        for o in sorted {
            out_rows.push(o.row);
            out_vals.push(o.value);
        }

        Self {
            rows,
            cols,
            columns,
            out_start,
            out_rows,
            out_vals,
            awq_scales: awq_scales.map(<[f32]>::to_vec),
        }
    }

    /// Build from a serialized CLAQ container (codebooks come back through
    /// f16, exactly as a deployment would see them).
    pub fn from_container(pm: &PackedMatrix, awq_scales: Option<&[f32]>) -> Result<Self> {
        let qm = crate::quant::packed::unpack(pm)?;
        Ok(Self::from_quantized(&qm, awq_scales))
    }

    pub fn n_outliers(&self) -> usize {
        self.out_rows.len()
    }

    /// Decode column `c` (dequant + outlier override + AWQ un-scaling) into
    /// `out[..rows]` — the per-column gather at the heart of the kernel.
    fn decode_column_into(&self, c: usize, out: &mut [f32]) {
        let pc = &self.columns[c];
        decode_plane_into(&pc.plane, pc.bits, &pc.centroids, &mut out[..self.rows]);
        for i in self.out_start[c]..self.out_start[c + 1] {
            out[self.out_rows[i] as usize] = self.out_vals[i];
        }
        if let Some(scales) = &self.awq_scales {
            let s = scales[c];
            if s != 1.0 {
                for v in out[..self.rows].iter_mut() {
                    *v /= s;
                }
            }
        }
    }
}

impl LinearOp for PackedLinear {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    /// Fused codebook-gather matmul. For each input feature c, decode the
    /// weight column once into scratch and accumulate `y[t,·] += x[t,c] ·
    /// w_c` for every row of the batch, so plane unpacking is amortized
    /// across the batch. Accumulation runs in ascending-c order — the same
    /// order as the dense dot product, keeping the two paths bit-compatible.
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        out[..seq * rows].fill(0.0);
        if scratch.len() < rows {
            scratch.resize(rows, 0.0);
        }
        for c in 0..cols {
            self.decode_column_into(c, scratch);
            let col = &scratch[..rows];
            for t in 0..seq {
                let xv = x[t * cols + c];
                if xv == 0.0 {
                    continue;
                }
                let o = &mut out[t * rows..(t + 1) * rows];
                for (ov, &wv) in o.iter_mut().zip(col) {
                    *ov += xv * wv;
                }
            }
        }
    }

    fn weight_bytes(&self) -> usize {
        let planes: usize = self
            .columns
            .iter()
            .map(|c| c.plane.len() + c.centroids.len() * std::mem::size_of::<f32>() + 1)
            .sum();
        planes
            + self.out_rows.len() * (std::mem::size_of::<u32>() + std::mem::size_of::<f32>())
            + self.awq_scales.as_ref().map_or(0, |s| s.len() * std::mem::size_of::<f32>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::util::rng::Rng;

    fn sample(seed: u64, rows: usize, cols: usize, bits: u8, reserve: usize) -> (Matrix, QuantizedMatrix) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(cols, bits, CentroidRule::KMeans, false);
        plan.reserve = vec![reserve; cols];
        let qm = quantize_matrix(&w, None, &plan);
        (w, qm)
    }

    fn dense_ref(deq: &Matrix, x: &[f32], seq: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; seq * deq.rows];
        let mut scratch = Vec::new();
        deq.forward_into(x, seq, &mut out, &mut scratch);
        out
    }

    #[test]
    fn packed_matches_dense_dequant() {
        let (_, qm) = sample(1, 33, 12, 3, 2);
        let deq = qm.dequantize();
        let packed = PackedLinear::from_quantized(&qm, None);
        assert_eq!(packed.out_features(), 33);
        assert_eq!(packed.in_features(), 12);
        assert_eq!(packed.n_outliers(), 2 * 12);

        let mut rng = Rng::new(2);
        let seq = 5;
        let mut x = vec![0.0f32; seq * 12];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, seq);
        let mut got = vec![0.0f32; seq * 33];
        let mut scratch = Vec::new();
        packed.forward_into(&x, seq, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn awq_scales_divided_out() {
        let (_, qm) = sample(3, 20, 8, 4, 0);
        let scales: Vec<f32> = (0..8).map(|i| 0.5 + 0.25 * i as f32).collect();
        let mut deq = qm.dequantize();
        for r in 0..deq.rows {
            let row = deq.row_mut(r);
            for (v, &s) in row.iter_mut().zip(&scales) {
                *v /= s;
            }
        }
        let packed = PackedLinear::from_quantized(&qm, Some(&scales));
        let mut rng = Rng::new(4);
        let mut x = vec![0.0f32; 8];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 1);
        let mut got = vec![0.0f32; 20];
        let mut scratch = Vec::new();
        packed.forward_into(&x, 1, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn container_round_trip_backend() {
        let (_, qm) = sample(5, 40, 10, 2, 2);
        let (pm, _) = crate::quant::packed::pack(&qm);
        let packed = PackedLinear::from_container(&pm, None).unwrap();
        // container codebooks are f16: compare against the f16-rounded deq
        let deq = crate::quant::packed::unpack(&pm).unwrap().dequantize();
        let mut rng = Rng::new(6);
        let mut x = vec![0.0f32; 3 * 10];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 3);
        let mut got = vec![0.0f32; 3 * 40];
        let mut scratch = Vec::new();
        packed.forward_into(&x, 3, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn packed_is_smaller_than_dense() {
        let (w, qm) = sample(7, 128, 64, 2, 2);
        let packed = PackedLinear::from_quantized(&qm, None);
        assert!(packed.weight_bytes() < w.weight_bytes() / 4);
    }
}
