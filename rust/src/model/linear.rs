//! The `LinearOp` abstraction: the forward pass no longer assumes dense
//! f32 weights. A linear operator computes `out(seq × O) = x(seq × I) · Wᵀ`
//! for a weight matrix W stored (O × I); how W is represented is the
//! implementation's business:
//!
//! * [`DenseLinear`] / [`Matrix`] — the dense f32 reference path.
//! * [`PackedLinear`] — the deployable CLAQ representation, in either
//!   plane kind (`quant/vq.rs::PlaneKind`): per-column bit-packed index
//!   planes + scalar codebooks (`CLAQPK01`), or vector-quantized
//!   column-group planes (`CLAQVQ01`) where one index plane selects an
//!   R^d centroid shared by `d` adjacent columns. Reserved outliers are
//!   applied as a sparse per-column override and AWQ activation scales
//!   folded in either way. No dense weight matrix is ever materialized;
//!   the kernel decodes columns into a reusable scratch buffer and
//!   accumulates rank-1 (scalar kernel) or rank-4 (tiled kernel) updates.
//!   The VQ path uses a *fused grouped gather*: one bulk index unpack per
//!   group scatters all `d` column lanes at once, so a group's plane is
//!   read once per row block regardless of `d`, and the decoded lanes are
//!   reused across every row of the batch exactly like scalar columns.
//!
//! `PackedLinear` ships two kernels (DESIGN.md §12):
//!
//! * [`KernelKind::Scalar`] — the pinned reference: one column decoded
//!   bit-by-bit per pass, per-element accumulation in ascending-column
//!   order, i.e. the exact order of the dense row dot product, so packed
//!   and dense agree to rounding error.
//! * [`KernelKind::Tiled`] — the default serving kernel: bulk index
//!   unpack ([`crate::quant::packed::decode_plane_tile_into`]), `COL_TILE`
//!   columns decoded per pass, and unrolled f32 lanes (`std::simd` behind
//!   the `simd` cargo feature, with a bit-identical unrolled-scalar
//!   fallback). Its accumulation order is a *fixed per-tile combine tree*
//!   over ascending column tiles — a function of `cols` alone, never of
//!   thread count, shard partition, or batch composition — so it is just
//!   as deterministic as the scalar kernel, merely a *different* fixed
//!   order. Dense-vs-packed agreement is therefore tolerance-gated, while
//!   every serial/parallel/batched bit-identity property still holds
//!   exactly under either kernel.
//!
//! Per-column bit widths may differ (CLAQ adaptive precision assigns each
//! column its own width), so scalar planes are stored as maximal
//! *equal-bit runs* ([`equal_bit_runs`]): lane-concatenated planes and
//! codebooks with uniform strides. A column tile that falls inside one run
//! decodes with a single bit-width dispatch ([`decode_run_tile_into`]);
//! a tile straddling a run boundary decodes lane by lane. Which path a
//! tile takes changes decode cost only — decoded floats are bit-identical,
//! and tile boundaries sit at fixed multiples of `COL_TILE` regardless of
//! the run structure, so the accumulation order stays a function of `cols`
//! alone and every bit-identity contract holds for mixed-bit matrices too.
//!
//! Both backends shard their output rows across the process-wide
//! [`ThreadPool`] (see [`run_row_sharded`]): every shard computes a
//! disjoint block of output features for the whole batch, decoding only
//! its own row range of each packed column. Because each output element is
//! accumulated by exactly one shard in a schedule fixed by `cols`, results
//! are bit-identical to the serial kernel for any thread count, shard
//! partition, or batch composition — the invariant the scheduler's
//! batch-invariance property (`tests/scheduler.rs`) relies on. Shard
//! bookkeeping lives in the caller's [`LinearScratch`], so steady-state
//! decode performs zero heap allocations.

use crate::quant::gptq::{QuantPlanes, QuantizedMatrix};
use crate::quant::packed::{
    decode_plane_range_into, decode_plane_tile_into, decode_run_tile_into, equal_bit_runs,
    pack_indices, unpack_indices_range_into, PackedMatrix,
};
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::OnceLock;

/// Columns decoded (and accumulated) per pass of the tiled kernel. Four
/// ≤16-entry codebooks plus four decoded row blocks stay cache-resident,
/// and the rank-4 update gives the f32 lanes four independent products per
/// output element.
const COL_TILE: usize = 4;

/// Which packed-decode kernel [`PackedLinear`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// The original column-at-a-time kernel: bit-by-bit plane walk, one
    /// rank-1 update per column, per-element accumulation in ascending
    /// column order (the dense dot-product order, so dense agreement is
    /// bit-tight). Selectable via `CLAQ_KERNEL=scalar`; kept as the pinned
    /// reference the tiled kernel is tested against.
    Scalar,
    /// The LUT-blocked tiled kernel: bulk index unpack, [`COL_TILE`]
    /// columns per pass, unrolled f32 lanes (`std::simd` behind the `simd`
    /// feature). Deterministic fixed-tile accumulation order; dense
    /// agreement is tolerance-gated. The default.
    Tiled,
}

impl KernelKind {
    /// Parse a `CLAQ_KERNEL` value. `None` for unrecognized strings.
    fn parse(s: &str) -> Option<KernelKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "tiled" => Some(KernelKind::Tiled),
            _ => None,
        }
    }

    /// The process-wide default kernel, from `CLAQ_KERNEL` (`tiled` unless
    /// `CLAQ_KERNEL=scalar`; unknown values warn and fall back to tiled).
    /// Read once, like `CLAQ_THREADS` — the choice is process-global so
    /// every layer of a model runs the same kernel.
    pub fn from_env() -> KernelKind {
        static KIND: OnceLock<KernelKind> = OnceLock::new();
        *KIND.get_or_init(|| match std::env::var("CLAQ_KERNEL") {
            Err(_) => KernelKind::Tiled,
            Ok(s) => KernelKind::parse(&s).unwrap_or_else(|| {
                eprintln!("warning: unknown CLAQ_KERNEL={s:?}; using the tiled kernel");
                KernelKind::Tiled
            }),
        })
    }

    /// Stable lowercase label (reports, bench cell names).
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Tiled => "tiled",
        }
    }
}

/// Per-shard work descriptor: plain offsets into [`LinearScratch::buf`].
/// No borrows, so the descriptor vector is reusable across calls.
#[derive(Clone, Copy)]
struct ShardDesc {
    r0: usize,
    r1: usize,
    decode_off: usize,
    decode_len: usize,
    stage_off: usize,
}

/// Caller-owned workspace for [`LinearOp::forward_into`]: the float buffer
/// for column-decode and shard staging, plus the shard-descriptor vector
/// the parallel dispatch used to allocate per call. Own one per execution
/// state (`ExecState` / `ForwardState`) and steady-state decode makes zero
/// heap allocations.
#[derive(Default)]
pub struct LinearScratch {
    buf: Vec<f32>,
    shards: Vec<ShardDesc>,
}

impl LinearScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for a backend with up to `max_out` output features and
    /// batches of up to `cap` rows, so the serving hot path never grows
    /// the buffer: the largest request is `COL_TILE·max_out` decode floats
    /// (tiled kernel) plus `cap·max_out` staging floats.
    pub fn with_capacity(max_out: usize, cap: usize) -> Self {
        Self {
            buf: vec![0.0; max_out * (cap + COL_TILE)],
            shards: Vec::with_capacity(ThreadPool::global().workers()),
        }
    }
}

/// A linear operator `y = x · Wᵀ` over a (rows=out × cols=in) weight.
pub trait LinearOp: Send + Sync {
    /// Output features (rows of W).
    fn out_features(&self) -> usize;
    /// Input features (cols of W).
    fn in_features(&self) -> usize;
    /// `out(seq × out_features) = x(seq × in_features) · Wᵀ`. `scratch` is
    /// a caller-owned reusable workspace (column-decode floats, shard
    /// staging, and the shard descriptors of the parallel dispatch; grown
    /// on first use, e.g. pre-sized by `ExecState`), so a warm hot loop
    /// performs no heap allocation at all.
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch);

    /// Approximate resident bytes of the weight representation (for the
    /// serving memory report).
    fn weight_bytes(&self) -> usize;

    /// Packed index-plane bytes decoded by one forward step (0 for dense
    /// backends) — the numerator of the bench layer's
    /// `bytes_decoded_per_s` throughput extra.
    fn decoded_plane_bytes(&self) -> usize {
        0
    }
}

/// Below this many multiply-accumulates (`seq × rows × cols`) a forward
/// runs serially: pool dispatch costs more than it buys.
const PAR_MIN_MACS: usize = 32 * 1024;
/// Minimum output rows per shard; smaller blocks don't amortize dispatch.
const PAR_MIN_ROWS: usize = 16;

/// A raw f32 base pointer that may cross the pool dispatch. Soundness
/// rests on shard geometry, not on this type: see the SAFETY comment in
/// [`run_row_sharded`].
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Shard an output-rows kernel across [`ThreadPool::global`].
///
/// `kernel(r0, r1, decode, stage)` must compute output features
/// `[r0, r1)` for all `seq` batch rows into `stage`, laid out block-local
/// row-major (`seq × (r1-r0)`), using `decode` (`decode_cols · (r1-r0)`
/// floats) as column-decode scratch. Shards get disjoint sub-ranges of
/// `scratch.buf`, described by plain offsets in the reusable
/// `scratch.shards` vector, so a warm call allocates nothing; the staged
/// blocks are scattered into `out` afterwards. The serial path points
/// `stage` directly at `out` (block-local layout == output layout when the
/// block is all rows), so nothing is copied.
///
/// Every output element is produced by exactly one shard with the same
/// instruction stream as the serial kernel, so parallel and serial results
/// are bit-identical.
fn run_row_sharded<K>(
    rows: usize,
    cols: usize,
    seq: usize,
    decode_cols: usize,
    out: &mut [f32],
    scratch: &mut LinearScratch,
    kernel: K,
) where
    K: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), seq * rows);
    let pool = ThreadPool::global();
    let shards = pool.workers().min(rows / PAR_MIN_ROWS).max(1);
    let decode_need = decode_cols * rows;
    if shards <= 1 || seq * rows * cols < PAR_MIN_MACS {
        if scratch.buf.len() < decode_need {
            scratch.buf.resize(decode_need, 0.0);
        }
        let (decode, _) = scratch.buf.split_at_mut(decode_need);
        kernel(0, rows, decode, out);
        return;
    }

    // Scratch layout: [decode: decode_cols × rows] ++ [stage: seq × rows],
    // carved into one disjoint (decode, stage) range pair per shard.
    let need = decode_need + seq * rows;
    if scratch.buf.len() < need {
        scratch.buf.resize(need, 0.0);
    }
    let per_shard = rows.div_ceil(shards);
    scratch.shards.clear();
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + per_shard).min(rows);
        scratch.shards.push(ShardDesc {
            r0,
            r1,
            decode_off: decode_cols * r0,
            decode_len: decode_cols * (r1 - r0),
            stage_off: decode_need + seq * r0,
        });
        r0 = r1;
    }

    let base = SendPtr(scratch.buf.as_mut_ptr());
    let descs = &scratch.shards;
    pool.run_units(descs.len(), |i| {
        let d = descs[i];
        // SAFETY: the descriptors carve pairwise-disjoint ranges of
        // `scratch.buf` — decode ranges [decode_cols·r0, decode_cols·r1)
        // and stage ranges [decode_need + seq·r0, decode_need + seq·r1)
        // for ascending, non-overlapping [r0, r1) blocks — and every range
        // is in-bounds (`buf.len() >= need`). `run_units` does not return
        // until every job retires, so `base` outlives all uses, and no
        // other reference into `buf` is live while the jobs run. Two
        // `&mut` slices therefore never alias.
        let decode =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(d.decode_off), d.decode_len) };
        let stage =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(d.stage_off), seq * (d.r1 - d.r0)) };
        kernel(d.r0, d.r1, decode, stage);
    });

    for d in &scratch.shards {
        let bl = d.r1 - d.r0;
        let stage = &scratch.buf[d.stage_off..d.stage_off + seq * bl];
        for t in 0..seq {
            out[t * rows + d.r0..t * rows + d.r1].copy_from_slice(&stage[t * bl..(t + 1) * bl]);
        }
    }
}

// ------------------------------------------------------------ f32 lanes ----

/// `o[j] += (x0·w0[j] + x1·w1[j]) + (x2·w2[j] + x3·w3[j])` for every j —
/// the tiled kernel's rank-4 update with its fixed per-element combine
/// tree. The SIMD and scalar bodies evaluate this exact expression
/// (`std::simd` has strict IEEE semantics — no FMA contraction, no
/// reassociation), so enabling the `simd` feature is bit-invisible.
#[inline]
#[allow(clippy::too_many_arguments)]
fn axpy4(
    o: &mut [f32],
    x0: f32,
    x1: f32,
    x2: f32,
    x3: f32,
    w0: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
) {
    let n = o.len();
    debug_assert!(w0.len() >= n && w1.len() >= n && w2.len() >= n && w3.len() >= n);
    let mut j = 0usize;
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        let (vx0, vx1) = (f32x8::splat(x0), f32x8::splat(x1));
        let (vx2, vx3) = (f32x8::splat(x2), f32x8::splat(x3));
        while j + 8 <= n {
            let a = vx0 * f32x8::from_slice(&w0[j..]) + vx1 * f32x8::from_slice(&w1[j..]);
            let b = vx2 * f32x8::from_slice(&w2[j..]) + vx3 * f32x8::from_slice(&w3[j..]);
            let acc = f32x8::from_slice(&o[j..]) + (a + b);
            acc.copy_to_slice(&mut o[j..j + 8]);
            j += 8;
        }
    }
    #[cfg(not(feature = "simd"))]
    {
        // Hand-unrolled 4-wide trips: four independent output elements per
        // iteration keep the FP ports busy; each element still evaluates
        // the identical combine tree.
        while j + 4 <= n {
            o[j] += (x0 * w0[j] + x1 * w1[j]) + (x2 * w2[j] + x3 * w3[j]);
            o[j + 1] += (x0 * w0[j + 1] + x1 * w1[j + 1]) + (x2 * w2[j + 1] + x3 * w3[j + 1]);
            o[j + 2] += (x0 * w0[j + 2] + x1 * w1[j + 2]) + (x2 * w2[j + 2] + x3 * w3[j + 2]);
            o[j + 3] += (x0 * w0[j + 3] + x1 * w1[j + 3]) + (x2 * w2[j + 3] + x3 * w3[j + 3]);
            j += 4;
        }
    }
    while j < n {
        o[j] += (x0 * w0[j] + x1 * w1[j]) + (x2 * w2[j] + x3 * w3[j]);
        j += 1;
    }
}

/// `o[j] += x·w[j]` — the rank-1 update for the ragged column tail
/// (`cols % COL_TILE`), with the same SIMD/scalar bit-identity as
/// [`axpy4`].
#[inline]
fn axpy1(o: &mut [f32], x: f32, w: &[f32]) {
    let n = o.len();
    debug_assert!(w.len() >= n);
    let mut j = 0usize;
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        let vx = f32x8::splat(x);
        while j + 8 <= n {
            let acc = f32x8::from_slice(&o[j..]) + vx * f32x8::from_slice(&w[j..]);
            acc.copy_to_slice(&mut o[j..j + 8]);
            j += 8;
        }
    }
    #[cfg(not(feature = "simd"))]
    {
        while j + 4 <= n {
            o[j] += x * w[j];
            o[j + 1] += x * w[j + 1];
            o[j + 2] += x * w[j + 2];
            o[j + 3] += x * w[j + 3];
            j += 4;
        }
    }
    while j < n {
        o[j] += x * w[j];
        j += 1;
    }
}

// -------------------------------------------------------------- backends ----

/// Dense row-major f32 weights — the reference backend.
impl LinearOp for Matrix {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        run_row_sharded(rows, cols, seq, 0, &mut out[..seq * rows], scratch, |r0, r1, _, stage| {
            let bl = r1 - r0;
            for t in 0..seq {
                let xi = &x[t * cols..(t + 1) * cols];
                let o = &mut stage[t * bl..(t + 1) * bl];
                for (j, ov) in o.iter_mut().enumerate() {
                    let wrow = self.row(r0 + j);
                    let mut acc = 0.0f32;
                    for (a, b) in xi.iter().zip(wrow) {
                        acc += a * b;
                    }
                    *ov = acc;
                }
            }
        });
    }

    fn weight_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Owning dense backend (a [`Matrix`] behind the trait, for `Box<dyn
/// LinearOp>` layers).
pub struct DenseLinear {
    pub w: Matrix,
}

impl DenseLinear {
    pub fn new(w: Matrix) -> Self {
        Self { w }
    }
}

impl LinearOp for DenseLinear {
    fn out_features(&self) -> usize {
        self.w.rows
    }

    fn in_features(&self) -> usize {
        self.w.cols
    }

    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
        self.w.forward_into(x, seq, out, scratch)
    }

    fn weight_bytes(&self) -> usize {
        self.w.weight_bytes()
    }
}

/// A maximal run of adjacent equal-bit columns, stored lane-concatenated:
/// lane `l` (column `c0 + l`) owns plane bytes
/// `planes[l·plane_stride..][..plane_stride]` and codebook floats
/// `centroids[l·cent_stride..][..cent_stride]`. Mixed-precision matrices
/// (CLAQ adaptive precision gives every column its own bit width) decompose
/// into these runs via [`equal_bit_runs`]; the uniform strides within a run
/// are what let the tiled kernel decode a whole column tile with a single
/// bit-width dispatch ([`decode_run_tile_into`]).
struct PackedRun {
    /// First column of the run.
    c0: usize,
    /// Columns in the run.
    len: usize,
    /// Index width shared by every column of the run (1..=8).
    bits: u8,
    /// Packed plane bytes per column: ceil(rows·bits / 8).
    plane_stride: usize,
    /// `len · plane_stride` bytes, lane-major (the container plane layout
    /// per lane, LSB-first).
    planes: Vec<u8>,
    /// Codebook floats per column: `1 << bits` (short quantizer codebooks
    /// are zero-padded; indices never reach the padding).
    cent_stride: usize,
    /// `len · cent_stride` f32 centroids, lane-major.
    centroids: Vec<f32>,
}

impl PackedRun {
    fn lane_plane(&self, l: usize) -> &[u8] {
        &self.planes[l * self.plane_stride..(l + 1) * self.plane_stride]
    }

    fn lane_centroids(&self, l: usize) -> &[f32] {
        &self.centroids[l * self.cent_stride..(l + 1) * self.cent_stride]
    }

    /// One past the last column of the run.
    fn end(&self) -> usize {
        self.c0 + self.len
    }
}

/// One vector-quantized column group: a single bit-packed index plane
/// whose entries select R^`width` centroids covering `width` adjacent
/// input features (`width == group_dim` except for the ragged tail).
struct PackedVqGroup {
    /// Columns this group covers (`min(group_dim, cols - g·group_dim)`).
    width: usize,
    /// `2^bits · width` f32 coordinates, centroid-major.
    centroids: Vec<f32>,
    /// `rows` indices, `bits` wide, LSB-first — one plane for all lanes.
    plane: Vec<u8>,
}

/// The execution-side mirror of [`QuantPlanes`]: which plane kind this
/// backend decodes. Both variants share the outlier CSR, AWQ scales, and
/// row-sharded dispatch; only the gather differs.
enum PackedPlanes {
    /// Per-column scalar planes, grouped into maximal equal-bit runs.
    /// `col_run[c]` is the index of the run owning column `c` — the O(1)
    /// lookup behind the tiled kernel's whole-tile-in-one-run test.
    Columns { runs: Vec<PackedRun>, col_run: Vec<u32> },
    Vq { group_dim: usize, bits: u8, groups: Vec<PackedVqGroup> },
}

/// The packed CLAQ execution backend: computes `y = x · dequant(W)ᵀ`
/// straight from the index planes, applying reserved outliers as a sparse
/// override and folding AWQ per-column activation scales back out
/// (quantized weights live in the scaled space; see `model/quantized.rs`).
pub struct PackedLinear {
    rows: usize,
    cols: usize,
    planes: PackedPlanes,
    /// Reserved outliers in CSR-by-column form: for column c the entries
    /// `out_start[c]..out_start[c+1]` of (out_rows, out_vals).
    out_start: Vec<usize>,
    out_rows: Vec<u32>,
    out_vals: Vec<f32>,
    /// AWQ per-column scales to divide back out (None for non-AWQ).
    awq_scales: Option<Vec<f32>>,
    kernel: KernelKind,
}

impl PackedLinear {
    /// Build from an in-memory quantized matrix (f32 codebooks — exact
    /// parity with `QuantizedMatrix::dequantize`). `awq_scales` are the
    /// per-input-column activation scales of the AWQ path, if any. Runs
    /// the process-default kernel ([`KernelKind::from_env`]); see
    /// [`Self::with_kernel`].
    pub fn from_quantized(qm: &QuantizedMatrix, awq_scales: Option<&[f32]>) -> Self {
        let (rows, cols) = (qm.rows, qm.cols);
        if let Some(s) = awq_scales {
            assert_eq!(s.len(), cols, "AWQ scales/columns mismatch");
        }
        let planes = match &qm.planes {
            QuantPlanes::Columns(qcols) => {
                assert_eq!(qcols.len(), cols);
                let bit_map: Vec<u8> = qcols.iter().map(|qc| qc.bits).collect();
                let mut runs: Vec<PackedRun> = Vec::new();
                let mut col_run = vec![0u32; cols];
                for br in equal_bit_runs(&bit_map) {
                    let plane_stride = (rows * br.bits as usize).div_ceil(8);
                    let cent_stride = 1usize << br.bits;
                    let mut planes = Vec::with_capacity(br.len * plane_stride);
                    let mut centroids = Vec::with_capacity(br.len * cent_stride);
                    for l in 0..br.len {
                        let qc = &qcols[br.c0 + l];
                        assert_eq!(qc.indices.len(), rows);
                        planes.extend_from_slice(&pack_indices(&qc.indices, qc.bits));
                        let cb = &qc.codebook.centroids;
                        assert!(cb.len() <= cent_stride, "codebook larger than 2^bits");
                        centroids.extend_from_slice(cb);
                        centroids.resize((l + 1) * cent_stride, 0.0);
                        col_run[br.c0 + l] = runs.len() as u32;
                    }
                    debug_assert_eq!(planes.len(), br.len * plane_stride);
                    runs.push(PackedRun {
                        c0: br.c0,
                        len: br.len,
                        bits: br.bits,
                        plane_stride,
                        planes,
                        cent_stride,
                        centroids,
                    });
                }
                PackedPlanes::Columns { runs, col_run }
            }
            QuantPlanes::Groups(vp) => {
                let d = vp.group_dim;
                assert!(d >= 1, "VQ group dim must be >= 1");
                assert_eq!(vp.groups.len(), cols.div_ceil(d));
                let bits = vp.groups.first().map_or(1, |g| g.bits);
                let groups = vp
                    .groups
                    .iter()
                    .enumerate()
                    .map(|(g, vg)| {
                        let width = (cols - g * d).min(d);
                        assert_eq!(vg.bits, bits, "VQ groups must share one bit width");
                        assert_eq!(vg.indices.len(), rows);
                        assert_eq!(vg.codebook.dim, width, "group codebook dim/width mismatch");
                        PackedVqGroup {
                            width,
                            centroids: vg.codebook.centroids.clone(),
                            plane: pack_indices(&vg.indices, vg.bits),
                        }
                    })
                    .collect();
                PackedPlanes::Vq { group_dim: d, bits, groups }
            }
        };

        // Outliers arrive sorted by (col, row); bucket them per column.
        let mut out_start = vec![0usize; cols + 1];
        for o in &qm.outliers {
            out_start[o.col as usize + 1] += 1;
        }
        for c in 0..cols {
            out_start[c + 1] += out_start[c];
        }
        let mut out_rows = Vec::with_capacity(qm.outliers.len());
        let mut out_vals = Vec::with_capacity(qm.outliers.len());
        let mut sorted: Vec<_> = qm.outliers.iter().collect();
        sorted.sort_by_key(|o| (o.col, o.row));
        for o in sorted {
            out_rows.push(o.row);
            out_vals.push(o.value);
        }

        Self {
            rows,
            cols,
            planes,
            out_start,
            out_rows,
            out_vals,
            awq_scales: awq_scales.map(<[f32]>::to_vec),
            kernel: KernelKind::from_env(),
        }
    }

    /// Build from a serialized CLAQ container (codebooks come back through
    /// f16, exactly as a deployment would see them).
    pub fn from_container(pm: &PackedMatrix, awq_scales: Option<&[f32]>) -> Result<Self> {
        let qm = crate::quant::packed::unpack(pm)?;
        Ok(Self::from_quantized(&qm, awq_scales))
    }

    /// Override the decode kernel (tests, side-by-side benches; serving
    /// uses the process-wide `CLAQ_KERNEL` default).
    pub fn with_kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Which plane kind this backend decodes (scalar per-column planes or
    /// vector-quantized column groups).
    pub fn plane_kind(&self) -> crate::quant::vq::PlaneKind {
        match &self.planes {
            PackedPlanes::Columns { .. } => crate::quant::vq::PlaneKind::Scalar,
            PackedPlanes::Vq { group_dim, .. } => {
                crate::quant::vq::PlaneKind::VectorGroup { d: *group_dim }
            }
        }
    }

    pub fn n_outliers(&self) -> usize {
        self.out_rows.len()
    }

    /// Sparse outlier override + AWQ un-scaling for rows `[r0, r1)` of
    /// column `c`, applied to an already-decoded row block. Outliers of
    /// one column are sorted by row, so the block's overrides are found by
    /// binary search.
    fn apply_column_overrides(&self, c: usize, r0: usize, r1: usize, out: &mut [f32]) {
        let bl = r1 - r0;
        let (start, end) = (self.out_start[c], self.out_start[c + 1]);
        let lo = start + self.out_rows[start..end].partition_point(|&r| (r as usize) < r0);
        let hi = start + self.out_rows[start..end].partition_point(|&r| (r as usize) < r1);
        for i in lo..hi {
            out[self.out_rows[i] as usize - r0] = self.out_vals[i];
        }
        if let Some(scales) = &self.awq_scales {
            let scale = scales[c];
            if scale != 1.0 {
                for v in out[..bl].iter_mut() {
                    *v /= scale;
                }
            }
        }
    }

    /// Decode rows `[r0, r1)` of column `c` — lane `l` of `run` — (dequant
    /// + outlier override + AWQ un-scaling) into `out[..r1-r0]`: the
    /// per-column gather of the scalar kernel, bit-by-bit plane walk.
    fn decode_column_range_into(
        &self,
        run: &PackedRun,
        l: usize,
        c: usize,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(run.c0 + l, c);
        decode_plane_range_into(
            run.lane_plane(l),
            run.bits,
            run.lane_centroids(l),
            r0,
            &mut out[..r1 - r0],
        );
        self.apply_column_overrides(c, r0, r1, out);
    }

    /// Same decode through the bulk index unpack — the tiled kernel's
    /// per-column gather, used for tiles that straddle a run boundary and
    /// for the ragged column tail. Indices are exact integers either way,
    /// so the decoded values are bit-identical to
    /// [`Self::decode_column_range_into`]; only the decode cost differs.
    fn decode_column_tile_into(
        &self,
        run: &PackedRun,
        l: usize,
        c: usize,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(run.c0 + l, c);
        decode_plane_tile_into(
            run.lane_plane(l),
            run.bits,
            run.lane_centroids(l),
            r0,
            &mut out[..r1 - r0],
        );
        self.apply_column_overrides(c, r0, r1, out);
    }

    /// The fused grouped gather: decode rows `[r0, r1)` of *every* lane of
    /// VQ group `g` from its single index plane into `lanes` (lane `jj`
    /// occupies `lanes[jj·bl..(jj+1)·bl]`, `bl = r1-r0`), then apply the
    /// per-column outlier/AWQ overrides lane by lane. Indices are bulk
    /// unpacked in 64-row chunks ([`unpack_indices_range_into`], the tiled
    /// kernel's machinery) and each index scatters one full centroid row,
    /// so the plane is read once per row block no matter how many lanes
    /// the group has. Both kernels share this gather — decoded lanes are
    /// identical; only the accumulation order downstream differs.
    fn decode_vq_group_into(
        &self,
        grp: &PackedVqGroup,
        group_dim: usize,
        bits: u8,
        g: usize,
        r0: usize,
        r1: usize,
        lanes: &mut [f32],
    ) {
        let bl = r1 - r0;
        let width = grp.width;
        debug_assert!(lanes.len() >= width * bl);
        let mut idx = [0u8; 64];
        let mut done = 0usize;
        while done < bl {
            let n = (bl - done).min(64);
            unpack_indices_range_into(&grp.plane, bits, r0 + done, &mut idx[..n]);
            for (i, &ix) in idx[..n].iter().enumerate() {
                let cent = &grp.centroids[ix as usize * width..ix as usize * width + width];
                for (jj, &cv) in cent.iter().enumerate() {
                    lanes[jj * bl + done + i] = cv;
                }
            }
            done += n;
        }
        for jj in 0..width {
            self.apply_column_overrides(g * group_dim + jj, r0, r1, &mut lanes[jj * bl..][..bl]);
        }
    }

    /// The scalar (pinned reference) kernel body: ascending-column rank-1
    /// updates, per-element accumulation in dense dot-product order.
    fn forward_scalar(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
        let (rows, cols) = (self.rows, self.cols);
        let runs = match &self.planes {
            PackedPlanes::Columns { runs, .. } => runs,
            PackedPlanes::Vq { .. } => unreachable!("VQ planes take forward_vq"),
        };
        run_row_sharded(rows, cols, seq, 1, out, scratch, |r0, r1, decode, stage| {
            let bl = r1 - r0;
            stage[..seq * bl].fill(0.0);
            // Runs tile [0, cols) in order, so iterating run-by-run visits
            // columns in the same ascending order as before.
            for run in runs {
                for l in 0..run.len {
                    let c = run.c0 + l;
                    self.decode_column_range_into(run, l, c, r0, r1, decode);
                    let col = &decode[..bl];
                    for t in 0..seq {
                        let xv = x[t * cols + c];
                        if xv == 0.0 {
                            continue;
                        }
                        let o = &mut stage[t * bl..(t + 1) * bl];
                        for (ov, &wv) in o.iter_mut().zip(col) {
                            *ov += xv * wv;
                        }
                    }
                }
            }
        });
    }

    /// The tiled kernel body: [`COL_TILE`] columns decoded in bulk per
    /// pass, then one rank-4 [`axpy4`] update per batch row, so every
    /// decoded tile is reused across all tokens of the step. A tile that
    /// falls entirely inside one equal-bit run takes the fused path — one
    /// bit-width dispatch decodes all four lanes
    /// ([`decode_run_tile_into`]); a tile straddling a run boundary (only
    /// possible for mixed-bit matrices) decodes lane by lane. Both paths
    /// produce bit-identical floats, and tile boundaries sit at fixed
    /// multiples of `COL_TILE` regardless of the run structure, so the
    /// per-element accumulation order stays a function of `cols` alone.
    /// The ragged column tail falls back to rank-1 [`axpy1`] updates.
    fn forward_tiled(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
        let (rows, cols) = (self.rows, self.cols);
        let (runs, col_run) = match &self.planes {
            PackedPlanes::Columns { runs, col_run } => (runs.as_slice(), col_run.as_slice()),
            PackedPlanes::Vq { .. } => unreachable!("VQ planes take forward_vq"),
        };
        run_row_sharded(rows, cols, seq, COL_TILE, out, scratch, |r0, r1, decode, stage| {
            let bl = r1 - r0;
            stage[..seq * bl].fill(0.0);
            let mut c = 0usize;
            while c + COL_TILE <= cols {
                let run = &runs[col_run[c] as usize];
                if c + COL_TILE <= run.end() {
                    let l0 = c - run.c0;
                    decode_run_tile_into(
                        &run.planes[l0 * run.plane_stride..(l0 + COL_TILE) * run.plane_stride],
                        run.plane_stride,
                        run.bits,
                        &run.centroids[l0 * run.cent_stride..(l0 + COL_TILE) * run.cent_stride],
                        run.cent_stride,
                        COL_TILE,
                        r0,
                        &mut decode[..COL_TILE * bl],
                    );
                    for k in 0..COL_TILE {
                        self.apply_column_overrides(c + k, r0, r1, &mut decode[k * bl..][..bl]);
                    }
                } else {
                    for k in 0..COL_TILE {
                        let rn = &runs[col_run[c + k] as usize];
                        let l = c + k - rn.c0;
                        let dst = &mut decode[k * bl..][..bl];
                        self.decode_column_tile_into(rn, l, c + k, r0, r1, dst);
                    }
                }
                let w0 = &decode[..bl];
                let w1 = &decode[bl..2 * bl];
                let w2 = &decode[2 * bl..3 * bl];
                let w3 = &decode[3 * bl..4 * bl];
                for t in 0..seq {
                    let xi = &x[t * cols + c..t * cols + c + COL_TILE];
                    let o = &mut stage[t * bl..(t + 1) * bl];
                    axpy4(o, xi[0], xi[1], xi[2], xi[3], w0, w1, w2, w3);
                }
                c += COL_TILE;
            }
            while c < cols {
                let rn = &runs[col_run[c] as usize];
                self.decode_column_tile_into(rn, c - rn.c0, c, r0, r1, &mut decode[..bl]);
                let col = &decode[..bl];
                for t in 0..seq {
                    axpy1(&mut stage[t * bl..(t + 1) * bl], x[t * cols + c], col);
                }
                c += 1;
            }
        });
    }

    /// The VQ kernel body, both flavors. Each pass gathers one whole
    /// column group through [`Self::decode_vq_group_into`], then
    /// accumulates its lanes:
    ///
    /// * scalar kernel — rank-1 per-element updates, lanes in ascending
    ///   column order: the exact dense dot-product order, same as the
    ///   scalar-plane scalar kernel.
    /// * tiled kernel — lanes in chunks of 4 via [`axpy4`] with an
    ///   [`axpy1`] ragged tail. Tiles never straddle a group boundary, so
    ///   the per-element combine tree is a function of `(cols, group_dim)`
    ///   alone — fixed across thread count, shard partition, and batch
    ///   composition, preserving the serial == sharded == batched
    ///   bit-identity contract (DESIGN.md §12) for VQ planes too.
    fn forward_vq(
        &self,
        x: &[f32],
        seq: usize,
        out: &mut [f32],
        scratch: &mut LinearScratch,
        tiled: bool,
    ) {
        let (rows, cols) = (self.rows, self.cols);
        let (group_dim, bits, groups) = match &self.planes {
            PackedPlanes::Vq { group_dim, bits, groups } => (*group_dim, *bits, groups),
            PackedPlanes::Columns { .. } => unreachable!("scalar planes take forward_scalar/tiled"),
        };
        run_row_sharded(rows, cols, seq, group_dim, out, scratch, |r0, r1, decode, stage| {
            let bl = r1 - r0;
            stage[..seq * bl].fill(0.0);
            for (g, grp) in groups.iter().enumerate() {
                let width = grp.width;
                let c0 = g * group_dim;
                self.decode_vq_group_into(grp, group_dim, bits, g, r0, r1, decode);
                if tiled {
                    let mut jj = 0usize;
                    while jj + COL_TILE <= width {
                        let w0 = &decode[jj * bl..][..bl];
                        let w1 = &decode[(jj + 1) * bl..][..bl];
                        let w2 = &decode[(jj + 2) * bl..][..bl];
                        let w3 = &decode[(jj + 3) * bl..][..bl];
                        for t in 0..seq {
                            let xi = &x[t * cols + c0 + jj..t * cols + c0 + jj + COL_TILE];
                            let o = &mut stage[t * bl..(t + 1) * bl];
                            axpy4(o, xi[0], xi[1], xi[2], xi[3], w0, w1, w2, w3);
                        }
                        jj += COL_TILE;
                    }
                    while jj < width {
                        let col = &decode[jj * bl..][..bl];
                        for t in 0..seq {
                            axpy1(&mut stage[t * bl..(t + 1) * bl], x[t * cols + c0 + jj], col);
                        }
                        jj += 1;
                    }
                } else {
                    for jj in 0..width {
                        let col = &decode[jj * bl..][..bl];
                        for t in 0..seq {
                            let xv = x[t * cols + c0 + jj];
                            if xv == 0.0 {
                                continue;
                            }
                            let o = &mut stage[t * bl..(t + 1) * bl];
                            for (ov, &wv) in o.iter_mut().zip(col) {
                                *ov += xv * wv;
                            }
                        }
                    }
                }
            }
        });
    }
}

impl LinearOp for PackedLinear {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    /// Fused codebook-gather matmul, sharded over output rows. Each shard
    /// decodes its row block of the weight columns once into scratch and
    /// accumulates `y[t, r0..r1] += x[t, c..] · W_c` for every row of the
    /// batch, so plane unpacking is amortized across the batch and split
    /// (not duplicated) across threads. The accumulation schedule is fixed
    /// by `cols` under both kernels (see the module docs), keeping the
    /// forward batch- and thread-invariant bit-for-bit.
    fn forward_into(&self, x: &[f32], seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
        let (rows, cols) = (self.rows, self.cols);
        assert!(x.len() >= seq * cols, "x too short for seq={seq}");
        assert!(out.len() >= seq * rows, "out too short for seq={seq}");
        let out = &mut out[..seq * rows];
        match (&self.planes, self.kernel) {
            (PackedPlanes::Columns { .. }, KernelKind::Scalar) => {
                self.forward_scalar(x, seq, out, scratch)
            }
            (PackedPlanes::Columns { .. }, KernelKind::Tiled) => {
                self.forward_tiled(x, seq, out, scratch)
            }
            (PackedPlanes::Vq { .. }, kernel) => {
                self.forward_vq(x, seq, out, scratch, kernel == KernelKind::Tiled)
            }
        }
    }

    fn weight_bytes(&self) -> usize {
        let planes: usize = match &self.planes {
            // Per run: packed planes + f32 codebooks + one bits byte per
            // column — the same accounting as the old per-column storage.
            PackedPlanes::Columns { runs, .. } => runs
                .iter()
                .map(|r| r.planes.len() + r.centroids.len() * std::mem::size_of::<f32>() + r.len)
                .sum(),
            PackedPlanes::Vq { groups, .. } => groups
                .iter()
                .map(|g| g.plane.len() + g.centroids.len() * std::mem::size_of::<f32>() + 1)
                .sum(),
        };
        planes
            + self.out_rows.len() * (std::mem::size_of::<u32>() + std::mem::size_of::<f32>())
            + self.awq_scales.as_ref().map_or(0, |s| s.len() * std::mem::size_of::<f32>())
    }

    fn decoded_plane_bytes(&self) -> usize {
        match &self.planes {
            PackedPlanes::Columns { runs, .. } => runs.iter().map(|r| r.planes.len()).sum(),
            PackedPlanes::Vq { groups, .. } => groups.iter().map(|g| g.plane.len()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
    use crate::quant::vq::PlaneKind;
    use crate::util::rng::Rng;

    fn sample(
        seed: u64,
        rows: usize,
        cols: usize,
        bits: u8,
        reserve: usize,
    ) -> (Matrix, QuantizedMatrix) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(cols, bits, CentroidRule::KMeans, false);
        plan.reserve = vec![reserve; cols];
        let qm = quantize_matrix(&w, None, &plan);
        (w, qm)
    }

    /// Mixed per-column bit widths: `bit_of(c)` picks column `c`'s width,
    /// so tests can place run boundaries mid-tile.
    fn sample_mixed(
        seed: u64,
        rows: usize,
        cols: usize,
        reserve: usize,
        bit_of: impl Fn(usize) -> u8,
    ) -> (Matrix, QuantizedMatrix) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
        for (c, b) in plan.bits.iter_mut().enumerate() {
            *b = bit_of(c);
        }
        plan.reserve = vec![reserve; cols];
        let qm = quantize_matrix(&w, None, &plan);
        (w, qm)
    }

    fn sample_vq(
        seed: u64,
        rows: usize,
        cols: usize,
        d: usize,
        bits: u8,
        reserve: usize,
    ) -> (Matrix, QuantizedMatrix) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::vector_group(cols, d, bits, true);
        plan.reserve = vec![reserve; cols];
        let qm = quantize_matrix(&w, None, &plan);
        (w, qm)
    }

    fn dense_ref(deq: &Matrix, x: &[f32], seq: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; seq * deq.rows];
        let mut scratch = LinearScratch::new();
        deq.forward_into(x, seq, &mut out, &mut scratch);
        out
    }

    #[test]
    fn kernel_env_values_parse() {
        assert_eq!(KernelKind::parse("scalar"), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse(" Tiled "), Some(KernelKind::Tiled));
        assert_eq!(KernelKind::parse("avx512"), None);
        assert_eq!(KernelKind::parse(""), None);
        assert_eq!(KernelKind::Scalar.name(), "scalar");
        assert_eq!(KernelKind::Tiled.name(), "tiled");
    }

    #[test]
    fn packed_matches_dense_dequant() {
        let (_, qm) = sample(1, 33, 12, 3, 2);
        let deq = qm.dequantize();
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            assert_eq!(packed.out_features(), 33);
            assert_eq!(packed.in_features(), 12);
            assert_eq!(packed.n_outliers(), 2 * 12);

            let mut rng = Rng::new(2);
            let seq = 5;
            let mut x = vec![0.0f32; seq * 12];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_ref(&deq, &x, seq);
            let mut got = vec![0.0f32; seq * 33];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    /// The two packed kernels accumulate in different (both fixed) orders,
    /// so they agree to rounding error, not bit-for-bit — shapes chosen to
    /// exercise the ragged column tail (`cols % COL_TILE != 0`).
    #[test]
    fn tiled_agrees_with_scalar_reference() {
        let (_, qm) = sample(11, 37, 14, 3, 2);
        let scalar = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Scalar);
        let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);
        let mut rng = Rng::new(12);
        let seq = 3;
        let mut x = vec![0.0f32; seq * 14];
        rng.fill_normal(&mut x, 1.0);
        let mut a = vec![0.0f32; seq * 37];
        let mut b = vec![0.0f32; seq * 37];
        let mut scratch = LinearScratch::new();
        scalar.forward_into(&x, seq, &mut a, &mut scratch);
        tiled.forward_into(&x, seq, &mut b, &mut scratch);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() <= 1e-5 * (1.0 + q.abs()), "{p} vs {q}");
        }
    }

    /// Mixed-bit planes against the dense dequant, under both kernels.
    /// The bit pattern places run boundaries so the tiled kernel exercises
    /// every path: tile [0,4) inside the 2-bit run (fused run decode),
    /// tiles [4,8) and [8,12) straddling run boundaries (per-lane
    /// fallback), and a ragged 2-column tail — with reserved outliers on
    /// every column.
    #[test]
    fn mixed_bit_packed_matches_dense_dequant() {
        let bits: [u8; 14] = [2, 2, 2, 2, 2, 2, 4, 4, 4, 3, 3, 3, 3, 8];
        let (_, qm) = sample_mixed(41, 33, bits.len(), 2, |c| bits[c]);
        let deq = qm.dequantize();
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            let mut rng = Rng::new(42);
            let seq = 5;
            let mut x = vec![0.0f32; seq * bits.len()];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_ref(&deq, &x, seq);
            let mut got = vec![0.0f32; seq * 33];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    /// The DESIGN.md §12 bit-identity contract holds for mixed-bit
    /// matrices: shapes over the parallel threshold agree bit-for-bit with
    /// row-at-a-time serial runs under both kernels, because which decode
    /// path a tile takes (fused run vs per-lane fallback) never changes
    /// the decoded floats or the accumulation schedule.
    #[test]
    fn mixed_bit_sharded_forward_is_bit_identical_to_serial() {
        // runs of 5, 4, and 1 columns repeating — boundaries at
        // non-multiples of COL_TILE, so both tile paths run
        let (_, qm) = sample_mixed(43, 160, 96, 1, |c| match c % 10 {
            0..=4 => 2,
            5..=8 => 4,
            _ => 3,
        });
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            let mut rng = Rng::new(44);
            let seq = 8; // 8 × 160 × 96 MACs — well over PAR_MIN_MACS
            let mut x = vec![0.0f32; seq * 96];
            rng.fill_normal(&mut x, 1.0);

            let mut want = vec![0.0f32; seq * 160];
            let mut scratch = LinearScratch::new();
            for t in 0..seq {
                let row = &x[t * 96..(t + 1) * 96];
                packed.forward_into(row, 1, &mut want[t * 160..(t + 1) * 160], &mut scratch);
            }

            let mut got = vec![0.0f32; seq * 160];
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            assert_eq!(got, want, "{kernel:?} mixed-bit sharded kernel diverged from serial");
        }
    }

    /// Mixed-bit byte accounting: each column's plane is ceil(rows·bits/8)
    /// bytes regardless of how columns group into runs.
    #[test]
    fn mixed_bit_decoded_plane_bytes_exact() {
        let (_, qm) = sample_mixed(45, 128, 64, 0, |c| if c < 48 { 2 } else { 4 });
        let packed = PackedLinear::from_quantized(&qm, None);
        // 48 columns of ceil(128·2/8) = 32 bytes + 16 of ceil(128·4/8) = 64
        assert_eq!(packed.decoded_plane_bytes(), 48 * 32 + 16 * 64);
    }

    #[test]
    fn awq_scales_divided_out() {
        let (_, qm) = sample(3, 20, 8, 4, 0);
        let scales: Vec<f32> = (0..8).map(|i| 0.5 + 0.25 * i as f32).collect();
        let mut deq = qm.dequantize();
        for r in 0..deq.rows {
            let row = deq.row_mut(r);
            for (v, &s) in row.iter_mut().zip(&scales) {
                *v /= s;
            }
        }
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, Some(&scales)).with_kernel(kernel);
            let mut rng = Rng::new(4);
            let mut x = vec![0.0f32; 8];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_ref(&deq, &x, 1);
            let mut got = vec![0.0f32; 20];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, 1, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn container_round_trip_backend() {
        let (_, qm) = sample(5, 40, 10, 2, 2);
        let (pm, _) = crate::quant::packed::pack(&qm).unwrap();
        let packed = PackedLinear::from_container(&pm, None).unwrap();
        // container codebooks are f16: compare against the f16-rounded deq
        let deq = crate::quant::packed::unpack(&pm).unwrap().dequantize();
        let mut rng = Rng::new(6);
        let mut x = vec![0.0f32; 3 * 10];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 3);
        let mut got = vec![0.0f32; 3 * 40];
        let mut scratch = LinearScratch::new();
        packed.forward_into(&x, 3, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn packed_is_smaller_than_dense() {
        let (w, qm) = sample(7, 128, 64, 2, 2);
        let packed = PackedLinear::from_quantized(&qm, None);
        assert!(packed.weight_bytes() < w.weight_bytes() / 4);
        // decoded_plane_bytes counts exactly the index planes: 64 columns
        // of ceil(128·2/8) = 32 bytes each
        assert_eq!(packed.decoded_plane_bytes(), 64 * 32);
    }

    /// Shapes large enough to cross the parallel threshold must produce
    /// bit-identical output to the serial kernel, under *both* kernels:
    /// each output element is accumulated by exactly one shard in a
    /// schedule fixed by `cols`. (Batch invariance of the scheduler rests
    /// on this.)
    #[test]
    fn sharded_forward_is_bit_identical_to_serial() {
        let (_, qm) = sample(9, 160, 96, 3, 2);
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            let mut rng = Rng::new(10);
            let seq = 8; // 8 × 160 × 96 MACs — well over PAR_MIN_MACS
            let mut x = vec![0.0f32; seq * 96];
            rng.fill_normal(&mut x, 1.0);

            // serial reference: run each batch row alone (below the MAC
            // threshold, so run_row_sharded takes the serial path)
            let mut want = vec![0.0f32; seq * 160];
            let mut scratch = LinearScratch::new();
            for t in 0..seq {
                let row = &x[t * 96..(t + 1) * 96];
                packed.forward_into(row, 1, &mut want[t * 160..(t + 1) * 160], &mut scratch);
            }

            let mut got = vec![0.0f32; seq * 160];
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            assert_eq!(got, want, "{kernel:?} sharded kernel diverged from serial");
        }

        // dense backend: same invariant
        let deq = qm.dequantize();
        let mut rng = Rng::new(10);
        let seq = 8;
        let mut x = vec![0.0f32; seq * 96];
        rng.fill_normal(&mut x, 1.0);
        let mut scratch = LinearScratch::new();
        let mut want_d = vec![0.0f32; seq * 160];
        for t in 0..seq {
            let row = &x[t * 96..(t + 1) * 96];
            deq.forward_into(row, 1, &mut want_d[t * 160..(t + 1) * 160], &mut scratch);
        }
        let mut got_d = vec![0.0f32; seq * 160];
        deq.forward_into(&x, seq, &mut got_d, &mut scratch);
        assert_eq!(got_d, want_d);
    }

    /// The fused grouped gather must reproduce `dequantize()` exactly:
    /// forward through VQ planes agrees with the dense reference on the
    /// dequantized matrix, under both kernels, with a ragged tail group
    /// (cols % d != 0) and reserved outliers in play.
    #[test]
    fn vq_packed_matches_dense_dequant() {
        let (_, qm) = sample_vq(21, 33, 14, 4, 3, 2);
        assert_eq!(qm.plane_kind(), PlaneKind::VectorGroup { d: 4 });
        let deq = qm.dequantize();
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            assert_eq!(packed.plane_kind(), PlaneKind::VectorGroup { d: 4 });
            assert_eq!(packed.out_features(), 33);
            assert_eq!(packed.in_features(), 14);
            assert_eq!(packed.n_outliers(), 2 * 14);

            let mut rng = Rng::new(22);
            let seq = 5;
            let mut x = vec![0.0f32; seq * 14];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_ref(&deq, &x, seq);
            let mut got = vec![0.0f32; seq * 33];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    /// Group width above `COL_TILE` exercises the in-group axpy4 chunks
    /// plus the axpy1 lane tail (d=6 → 4+2 per group); the two kernels
    /// agree to rounding error as for scalar planes.
    #[test]
    fn vq_tiled_agrees_with_scalar_reference() {
        let (_, qm) = sample_vq(23, 37, 18, 6, 3, 1);
        let scalar = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Scalar);
        let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);
        let mut rng = Rng::new(24);
        let seq = 3;
        let mut x = vec![0.0f32; seq * 18];
        rng.fill_normal(&mut x, 1.0);
        let mut a = vec![0.0f32; seq * 37];
        let mut b = vec![0.0f32; seq * 37];
        let mut scratch = LinearScratch::new();
        scalar.forward_into(&x, seq, &mut a, &mut scratch);
        tiled.forward_into(&x, seq, &mut b, &mut scratch);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() <= 1e-5 * (1.0 + q.abs()), "{p} vs {q}");
        }
    }

    /// The DESIGN.md §12 contract extends to VQ planes: shapes over the
    /// parallel threshold are bit-identical to row-at-a-time serial runs
    /// under both kernels, because the accumulation schedule is fixed by
    /// `(cols, group_dim)` and every output element has exactly one
    /// producing shard.
    #[test]
    fn vq_sharded_forward_is_bit_identical_to_serial() {
        let (_, qm) = sample_vq(25, 160, 96, 4, 2, 1);
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
            let mut rng = Rng::new(26);
            let seq = 8; // 8 × 160 × 96 MACs — well over PAR_MIN_MACS
            let mut x = vec![0.0f32; seq * 96];
            rng.fill_normal(&mut x, 1.0);

            let mut want = vec![0.0f32; seq * 160];
            let mut scratch = LinearScratch::new();
            for t in 0..seq {
                let row = &x[t * 96..(t + 1) * 96];
                packed.forward_into(row, 1, &mut want[t * 160..(t + 1) * 160], &mut scratch);
            }

            let mut got = vec![0.0f32; seq * 160];
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            assert_eq!(got, want, "{kernel:?} VQ sharded kernel diverged from serial");
        }
    }

    /// Cold-load parity: pack → CLAQVQ01 bytes → from_container forwards
    /// identically to the dense reference on the f16-rounded dequant.
    #[test]
    fn vq_container_round_trip_backend() {
        let (_, qm) = sample_vq(27, 40, 10, 4, 2, 1);
        let (pm, rep) = crate::quant::packed::pack(&qm).unwrap();
        assert_eq!(rep.kind, PlaneKind::VectorGroup { d: 4 });
        let packed = PackedLinear::from_container(&pm, None).unwrap();
        let deq = crate::quant::packed::unpack(&pm).unwrap().dequantize();
        let mut rng = Rng::new(28);
        let mut x = vec![0.0f32; 3 * 10];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_ref(&deq, &x, 3);
        let mut got = vec![0.0f32; 3 * 40];
        let mut scratch = LinearScratch::new();
        packed.forward_into(&x, 3, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    /// AWQ per-column un-scaling composes with the grouped gather: scales
    /// are applied per lane after the centroid scatter, exactly like the
    /// scalar-plane path.
    #[test]
    fn vq_awq_scales_divided_out() {
        let (_, qm) = sample_vq(29, 20, 8, 2, 4, 0);
        let scales: Vec<f32> = (0..8).map(|i| 0.5 + 0.25 * i as f32).collect();
        let mut deq = qm.dequantize();
        for r in 0..deq.rows {
            let row = deq.row_mut(r);
            for (v, &s) in row.iter_mut().zip(&scales) {
                *v /= s;
            }
        }
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            let packed = PackedLinear::from_quantized(&qm, Some(&scales)).with_kernel(kernel);
            let mut rng = Rng::new(30);
            let mut x = vec![0.0f32; 8];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_ref(&deq, &x, 1);
            let mut got = vec![0.0f32; 20];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, 1, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    /// VQ byte accounting: one index plane per *group*, not per column —
    /// `decoded_plane_bytes` shrinks by the group dim relative to scalar
    /// planes at the same bit width.
    #[test]
    fn vq_decoded_plane_bytes_counts_group_planes() {
        let (_, qm) = sample_vq(31, 128, 64, 4, 2, 0);
        let packed = PackedLinear::from_quantized(&qm, None);
        // 16 groups of ceil(128·2/8) = 32 bytes each
        assert_eq!(packed.decoded_plane_bytes(), 16 * 32);
        let (_, sqm) = sample(31, 128, 64, 2, 0);
        let spacked = PackedLinear::from_quantized(&sqm, None);
        assert_eq!(spacked.decoded_plane_bytes(), 4 * packed.decoded_plane_bytes());
        assert!(packed.weight_bytes() < spacked.weight_bytes());
    }
}
