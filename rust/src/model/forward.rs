//! Pure-Rust forward pass: RMSNorm → RoPE causal multi-head attention →
//! SiLU-gated MLP, pre-norm residual wiring (LLaMA architecture). This is
//! the evaluation reference path; the PJRT runtime executes the identical
//! computation lowered from JAX, and integration tests check the two agree.

use super::linear::{LinearOp, LinearScratch};
use super::{Model, TransformerConfig};
use crate::tensor::{matmul_into, Matrix};
use crate::util::stats::log_sum_exp;

/// Scratch buffers reused across forward calls (the CPU hot path allocates
/// nothing per token after warm-up).
pub struct ForwardState {
    cfg: TransformerConfig,
    x: Vec<f32>,       // (seq × d)
    normed: Vec<f32>,  // (seq × d)
    q: Vec<f32>,       // (seq × d)
    k: Vec<f32>,       // (seq × d)
    v: Vec<f32>,       // (seq × d)
    attn: Vec<f32>,    // (seq × d) attention mixed values
    proj: Vec<f32>,    // (seq × d)
    gate: Vec<f32>,    // (seq × d_ff)
    up: Vec<f32>,      // (seq × d_ff)
    scores: Vec<f32>,  // (seq) one query row at a time
    cos: Vec<f32>,     // (seq × head_dim/2) RoPE table
    sin: Vec<f32>,
    scratch: LinearScratch, // LinearOp backend workspace
}

/// Precompute the RoPE rotation table for positions `0..max_pos`:
/// (cos, sin), each (max_pos × head_dim/2).
pub(crate) fn rope_tables(cfg: &TransformerConfig, max_pos: usize) -> (Vec<f32>, Vec<f32>) {
    let hd2 = cfg.head_dim() / 2;
    let mut cos = vec![0.0f32; max_pos * hd2];
    let mut sin = vec![0.0f32; max_pos * hd2];
    for pos in 0..max_pos {
        for i in 0..hd2 {
            let freq = 1.0 / cfg.rope_theta.powf(2.0 * i as f32 / cfg.head_dim() as f32);
            let angle = pos as f32 * freq;
            cos[pos * hd2 + i] = angle.cos();
            sin[pos * hd2 + i] = angle.sin();
        }
    }
    (cos, sin)
}

impl ForwardState {
    pub fn new(cfg: TransformerConfig) -> Self {
        let (s, d, f) = (cfg.max_seq, cfg.d_model, cfg.d_ff);
        let (cos, sin) = rope_tables(&cfg, s);
        Self {
            cfg,
            x: vec![0.0; s * d],
            normed: vec![0.0; s * d],
            q: vec![0.0; s * d],
            k: vec![0.0; s * d],
            v: vec![0.0; s * d],
            attn: vec![0.0; s * d],
            proj: vec![0.0; s * d],
            gate: vec![0.0; s * f],
            up: vec![0.0; s * f],
            scores: vec![0.0; s],
            cos,
            sin,
            scratch: LinearScratch::new(),
        }
    }
}

/// y = rmsnorm(x) ⊙ w, row-wise over (seq × d).
pub(crate) fn rmsnorm(x: &[f32], w: &[f32], eps: f32, seq: usize, d: usize, out: &mut [f32]) {
    for t in 0..seq {
        let row = &x[t * d..(t + 1) * d];
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let o = &mut out[t * d..(t + 1) * d];
        for i in 0..d {
            o[i] = row[i] * inv * w[i];
        }
    }
}

/// Apply RoPE in place to (seq × d) laid out as heads × pairs. Pairs are
/// (2i, 2i+1) within each head — the interleaved convention; the JAX model
/// uses the same one.
fn rope(x: &mut [f32], cos: &[f32], sin: &[f32], seq: usize, n_heads: usize, head_dim: usize) {
    let d = n_heads * head_dim;
    for t in 0..seq {
        rope_row(&mut x[t * d..(t + 1) * d], t, cos, sin, n_heads, head_dim);
    }
}

/// Apply RoPE in place to a single (d)-row at absolute position `pos`.
pub(crate) fn rope_row(
    x: &mut [f32],
    pos: usize,
    cos: &[f32],
    sin: &[f32],
    n_heads: usize,
    head_dim: usize,
) {
    let hd2 = head_dim / 2;
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..hd2 {
            let (c, s) = (cos[pos * hd2 + i], sin[pos * hd2 + i]);
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * c - b * s;
            x[base + 2 * i + 1] = a * s + b * c;
        }
    }
}

/// Linear: out(seq × rows) = x(seq × cols) · Wᵀ(cols × rows), dispatched
/// through the [`LinearOp`] backend (dense or packed).
fn linear(x: &[f32], w: &dyn LinearOp, seq: usize, out: &mut [f32], scratch: &mut LinearScratch) {
    w.forward_into(x, seq, out, scratch)
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Captured inputs of one decoder layer's linear projections, used by the
/// calibration pass to accumulate per-matrix Hessians (GPTQ convention:
/// H = 2·Σ x xᵀ over calibration activations).
#[derive(Clone, Debug, Default)]
pub struct LayerCapture {
    /// Input rows to wq/wk/wv (post attn-norm), (seq × d).
    pub attn_in: Vec<f32>,
    /// Input rows to wo (attention-mixed values), (seq × d).
    pub wo_in: Vec<f32>,
    /// Input rows to w_gate/w_up (post mlp-norm), (seq × d).
    pub mlp_in: Vec<f32>,
    /// Input rows to w_down (gated activation), (seq × d_ff).
    pub down_in: Vec<f32>,
    pub seq: usize,
}

/// Run the model over `tokens` (len ≤ max_seq) and return logits
/// (seq × vocab). `state` supplies scratch memory.
pub fn forward(model: &Model, tokens: &[u16], state: &mut ForwardState) -> Matrix {
    forward_impl(model, tokens, state, None)
}

/// Forward pass that additionally captures the linear-layer inputs of
/// layer `capture.0` into `capture.1`.
pub fn forward_captured(
    model: &Model,
    tokens: &[u16],
    state: &mut ForwardState,
    layer: usize,
    cap: &mut LayerCapture,
) -> Matrix {
    forward_impl(model, tokens, state, Some((layer, cap)))
}

fn forward_impl(
    model: &Model,
    tokens: &[u16],
    state: &mut ForwardState,
    mut capture: Option<(usize, &mut LayerCapture)>,
) -> Matrix {
    let cfg = &model.config;
    assert_eq!(*cfg, state.cfg, "state built for a different config");
    let seq = tokens.len();
    assert!(seq > 0 && seq <= cfg.max_seq, "seq len {seq}");
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();

    // Embedding lookup.
    for (t, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        state.x[t * d..(t + 1) * d].copy_from_slice(model.tok_embed.row(tok));
    }

    for (li, layer) in model.layers.iter().enumerate() {
        let capturing = matches!(&capture, Some((l, _)) if *l == li);
        // --- attention block ---
        rmsnorm(&state.x, &layer.attn_norm, cfg.eps, seq, d, &mut state.normed);
        if capturing {
            if let Some((_, cap)) = capture.as_mut() {
                cap.attn_in = state.normed[..seq * d].to_vec();
                cap.seq = seq;
            }
        }
        linear(&state.normed, &layer.wq, seq, &mut state.q, &mut state.scratch);
        linear(&state.normed, &layer.wk, seq, &mut state.k, &mut state.scratch);
        linear(&state.normed, &layer.wv, seq, &mut state.v, &mut state.scratch);
        rope(&mut state.q, &state.cos, &state.sin, seq, nh, hd);
        rope(&mut state.k, &state.cos, &state.sin, seq, nh, hd);

        // causal attention, head by head
        for h in 0..nh {
            let off = h * hd;
            for t in 0..seq {
                let qrow = &state.q[t * d + off..t * d + off + hd];
                // scores over keys 0..=t
                for u in 0..=t {
                    let krow = &state.k[u * d + off..u * d + off + hd];
                    let mut s = 0.0f32;
                    for i in 0..hd {
                        s += qrow[i] * krow[i];
                    }
                    state.scores[u] = s * scale;
                }
                // softmax over 0..=t
                let m = state.scores[..=t].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for u in 0..=t {
                    let e = (state.scores[u] - m).exp();
                    state.scores[u] = e;
                    z += e;
                }
                let inv_z = 1.0 / z;
                // weighted value sum
                let out = &mut state.attn[t * d + off..t * d + off + hd];
                out.fill(0.0);
                for u in 0..=t {
                    let p = state.scores[u] * inv_z;
                    let vrow = &state.v[u * d + off..u * d + off + hd];
                    for i in 0..hd {
                        out[i] += p * vrow[i];
                    }
                }
            }
        }
        if capturing {
            if let Some((_, cap)) = capture.as_mut() {
                cap.wo_in = state.attn[..seq * d].to_vec();
            }
        }
        linear(&state.attn[..seq * d], &layer.wo, seq, &mut state.proj, &mut state.scratch);
        for i in 0..seq * d {
            state.x[i] += state.proj[i];
        }

        // --- MLP block ---
        rmsnorm(&state.x, &layer.mlp_norm, cfg.eps, seq, d, &mut state.normed);
        if capturing {
            if let Some((_, cap)) = capture.as_mut() {
                cap.mlp_in = state.normed[..seq * d].to_vec();
            }
        }
        linear(&state.normed, &layer.w_gate, seq, &mut state.gate, &mut state.scratch);
        linear(&state.normed, &layer.w_up, seq, &mut state.up, &mut state.scratch);
        let f = cfg.d_ff;
        for i in 0..seq * f {
            state.gate[i] = silu(state.gate[i]) * state.up[i];
        }
        if capturing {
            if let Some((_, cap)) = capture.as_mut() {
                cap.down_in = state.gate[..seq * f].to_vec();
            }
        }
        linear(&state.gate[..seq * f], &layer.w_down, seq, &mut state.proj, &mut state.scratch);
        for i in 0..seq * d {
            state.x[i] += state.proj[i];
        }
    }

    // Final norm + LM head.
    rmsnorm(&state.x, &model.final_norm, cfg.eps, seq, d, &mut state.normed);
    let mut logits = Matrix::zeros(seq, cfg.vocab);
    linear(&state.normed[..seq * d], &model.lm_head, seq, &mut logits.data, &mut state.scratch);
    logits
}

/// Embed tokens into a hidden-state buffer (seq × d) — the entry point of
/// the incremental layer-by-layer calibration path.
pub fn embed(model: &Model, tokens: &[u16]) -> Vec<f32> {
    let d = model.config.d_model;
    let mut x = vec![0.0f32; tokens.len() * d];
    for (t, &tok) in tokens.iter().enumerate() {
        x[t * d..(t + 1) * d].copy_from_slice(model.tok_embed.row(tok as usize));
    }
    x
}

/// Run ONE decoder layer over hidden states `x` (seq × d) in place,
/// optionally capturing the linear-layer inputs. This is the incremental
/// calibration hot path: the GPTQ protocol captures a layer's Hessian
/// inputs, quantizes the layer, then advances the states with the *new*
/// weights — one layer at a time, never re-running earlier layers.
pub fn layer_step(
    model: &Model,
    layer_idx: usize,
    x: &mut [f32],
    seq: usize,
    state: &mut ForwardState,
    mut cap: Option<&mut LayerCapture>,
) {
    let cfg = &model.config;
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    assert!(x.len() >= seq * d && seq <= cfg.max_seq);
    let layer = &model.layers[layer_idx];

    rmsnorm(x, &layer.attn_norm, cfg.eps, seq, d, &mut state.normed);
    if let Some(c) = cap.as_deref_mut() {
        c.attn_in = state.normed[..seq * d].to_vec();
        c.seq = seq;
    }
    linear(&state.normed, &layer.wq, seq, &mut state.q, &mut state.scratch);
    linear(&state.normed, &layer.wk, seq, &mut state.k, &mut state.scratch);
    linear(&state.normed, &layer.wv, seq, &mut state.v, &mut state.scratch);
    rope(&mut state.q, &state.cos, &state.sin, seq, nh, hd);
    rope(&mut state.k, &state.cos, &state.sin, seq, nh, hd);
    for h in 0..nh {
        let off = h * hd;
        for t in 0..seq {
            let qrow = &state.q[t * d + off..t * d + off + hd];
            for u in 0..=t {
                let krow = &state.k[u * d + off..u * d + off + hd];
                let mut s = 0.0f32;
                for i in 0..hd {
                    s += qrow[i] * krow[i];
                }
                state.scores[u] = s * scale;
            }
            let m = state.scores[..=t].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for u in 0..=t {
                let e = (state.scores[u] - m).exp();
                state.scores[u] = e;
                z += e;
            }
            let inv_z = 1.0 / z;
            let out = &mut state.attn[t * d + off..t * d + off + hd];
            out.fill(0.0);
            for u in 0..=t {
                let p = state.scores[u] * inv_z;
                let vrow = &state.v[u * d + off..u * d + off + hd];
                for i in 0..hd {
                    out[i] += p * vrow[i];
                }
            }
        }
    }
    if let Some(c) = cap.as_deref_mut() {
        c.wo_in = state.attn[..seq * d].to_vec();
    }
    linear(&state.attn[..seq * d], &layer.wo, seq, &mut state.proj, &mut state.scratch);
    for i in 0..seq * d {
        x[i] += state.proj[i];
    }

    rmsnorm(x, &layer.mlp_norm, cfg.eps, seq, d, &mut state.normed);
    if let Some(c) = cap.as_deref_mut() {
        c.mlp_in = state.normed[..seq * d].to_vec();
    }
    linear(&state.normed, &layer.w_gate, seq, &mut state.gate, &mut state.scratch);
    linear(&state.normed, &layer.w_up, seq, &mut state.up, &mut state.scratch);
    let f = cfg.d_ff;
    for i in 0..seq * f {
        state.gate[i] = silu(state.gate[i]) * state.up[i];
    }
    if let Some(c) = cap.as_deref_mut() {
        c.down_in = state.gate[..seq * f].to_vec();
    }
    linear(&state.gate[..seq * f], &layer.w_down, seq, &mut state.proj, &mut state.scratch);
    for i in 0..seq * d {
        x[i] += state.proj[i];
    }
}

/// Total negative log-likelihood (nats) and token count of predicting
/// `tokens[1..]` from `tokens[..-1]` — the perplexity building block.
pub fn sequence_nll(model: &Model, tokens: &[u16], state: &mut ForwardState) -> (f64, usize) {
    assert!(tokens.len() >= 2);
    let logits = forward(model, tokens, state);
    let mut nll = 0.0f64;
    for t in 0..tokens.len() - 1 {
        let row = logits.row(t);
        let lse = log_sum_exp(row);
        nll += lse - row[tokens[t + 1] as usize] as f64;
    }
    (nll, tokens.len() - 1)
}

/// Log-probability of the continuation `cont` given `prefix` (sum over
/// continuation tokens) — the zero-shot scoring primitive.
pub fn continuation_logprob(
    model: &Model,
    prefix: &[u16],
    cont: &[u16],
    state: &mut ForwardState,
) -> f64 {
    assert!(!prefix.is_empty() && !cont.is_empty());
    let mut seqtok: Vec<u16> = Vec::with_capacity(prefix.len() + cont.len());
    seqtok.extend_from_slice(prefix);
    seqtok.extend_from_slice(cont);
    let max = model.config.max_seq;
    // Truncate from the left if too long (keep the continuation intact).
    let start = seqtok.len().saturating_sub(max);
    let seqtok = &seqtok[start..];
    let cont_start = prefix.len() - start.min(prefix.len());
    let logits = forward(model, seqtok, state);
    let mut lp = 0.0f64;
    for t in cont_start.max(1)..seqtok.len() {
        if t < cont_start {
            continue;
        }
        let row = logits.row(t - 1);
        let lse = log_sum_exp(row);
        lp += row[seqtok[t] as usize] as f64 - lse;
    }
    lp
}

/// Naive reference matmul-based forward used only by tests to validate the
/// optimized loops above (builds full attention matrices; O(seq²·d) memory).
pub fn forward_reference(model: &Model, tokens: &[u16]) -> Matrix {
    let cfg = &model.config;
    let seq = tokens.len();
    let d = cfg.d_model;
    let mut x = Matrix::zeros(seq, d);
    for (t, &tok) in tokens.iter().enumerate() {
        x.row_mut(t).copy_from_slice(model.tok_embed.row(tok as usize));
    }
    let mut state = ForwardState::new(*cfg);
    let nh = cfg.n_heads;
    let hd = cfg.head_dim();

    for layer in &model.layers {
        let mut normed = vec![0.0; seq * d];
        rmsnorm(&x.data, &layer.attn_norm, cfg.eps, seq, d, &mut normed);
        let nm = Matrix::from_vec(seq, d, normed.clone());
        let mut q = nm.matmul(&layer.wq.transpose());
        let mut k = nm.matmul(&layer.wk.transpose());
        let v = nm.matmul(&layer.wv.transpose());
        rope(&mut q.data, &state.cos, &state.sin, seq, nh, hd);
        rope(&mut k.data, &state.cos, &state.sin, seq, nh, hd);
        let mut attn = Matrix::zeros(seq, d);
        for h in 0..nh {
            for t in 0..seq {
                let mut probs = vec![f32::NEG_INFINITY; seq];
                for u in 0..=t {
                    let mut s = 0.0;
                    for i in 0..hd {
                        s += q.at(t, h * hd + i) * k.at(u, h * hd + i);
                    }
                    probs[u] = s / (hd as f32).sqrt();
                }
                let mut p = vec![0.0f32; seq];
                crate::util::stats::softmax_into(&probs, &mut p);
                for u in 0..=t {
                    for i in 0..hd {
                        *attn.at_mut(t, h * hd + i) += p[u] * v.at(u, h * hd + i);
                    }
                }
            }
        }
        let proj = attn.matmul(&layer.wo.transpose());
        x.axpy(1.0, &proj);

        let mut normed2 = vec![0.0; seq * d];
        rmsnorm(&x.data, &layer.mlp_norm, cfg.eps, seq, d, &mut normed2);
        let nm2 = Matrix::from_vec(seq, d, normed2);
        let g = nm2.matmul(&layer.w_gate.transpose());
        let u = nm2.matmul(&layer.w_up.transpose());
        let mut act = Matrix::zeros(seq, cfg.d_ff);
        for i in 0..seq * cfg.d_ff {
            act.data[i] = silu(g.data[i]) * u.data[i];
        }
        let down = act.matmul(&layer.w_down.transpose());
        x.axpy(1.0, &down);
    }
    let mut normed = vec![0.0; seq * d];
    rmsnorm(&x.data, &model.final_norm, cfg.eps, seq, d, &mut normed);
    let _ = &mut state;
    let mut logits = Matrix::zeros(seq, cfg.vocab);
    matmul_into(
        &normed,
        &model.lm_head.transpose().data,
        &mut logits.data,
        seq,
        d,
        cfg.vocab,
    );
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn small_model(seed: u64) -> Model {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            eps: 1e-5,
        };
        let mut rng = Rng::new(seed);
        Model::random(cfg, &mut rng)
    }

    #[test]
    fn forward_shapes() {
        let m = small_model(1);
        let mut st = ForwardState::new(m.config);
        let logits = forward(&m, &[1, 2, 3, 4, 5], &mut st);
        assert_eq!((logits.rows, logits.cols), (5, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn optimized_matches_reference() {
        let m = small_model(2);
        let mut st = ForwardState::new(m.config);
        let toks = [3u16, 7, 1, 30, 12, 9, 9, 2];
        let a = forward(&m, &toks, &mut st);
        let b = forward_reference(&m, &toks);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let m = small_model(3);
        let mut st = ForwardState::new(m.config);
        let a = forward(&m, &[1, 2, 3, 4], &mut st);
        let b = forward(&m, &[1, 2, 3, 31], &mut st);
        for t in 0..3 {
            for v in 0..m.config.vocab {
                assert!((a.at(t, v) - b.at(t, v)).abs() < 1e-6);
            }
        }
        // ... but it must affect its own position's output row (next-token
        // distribution at t=3 differs since input embedding differs)
        let mut differs = false;
        for v in 0..m.config.vocab {
            if (a.at(3, v) - b.at(3, v)).abs() > 1e-6 {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn rope_preserves_norm() {
        let cfg = small_model(4).config;
        let mut st = ForwardState::new(cfg);
        let seq = 8;
        let d = cfg.d_model;
        let mut rng = Rng::new(5);
        let mut x = vec![0.0f32; seq * d];
        rng.fill_normal(&mut x, 1.0);
        let before: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        rope(&mut x, &st.cos, &st.sin, seq, cfg.n_heads, cfg.head_dim());
        let after: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((before - after).abs() / before < 1e-5);
        let _ = &mut st;
    }

    #[test]
    fn rope_position_zero_identity() {
        let cfg = small_model(6).config;
        let st = ForwardState::new(cfg);
        let d = cfg.d_model;
        let mut rng = Rng::new(7);
        let mut x = vec![0.0f32; d]; // seq = 1 → position 0 only
        rng.fill_normal(&mut x, 1.0);
        let orig = x.clone();
        rope(&mut x, &st.cos, &st.sin, 1, cfg.n_heads, cfg.head_dim());
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn nll_reasonable_for_random_model() {
        // A random model should be near uniform: NLL/token ≈ ln(vocab).
        let m = small_model(8);
        let mut st = ForwardState::new(m.config);
        let toks: Vec<u16> = (0..16).map(|i| (i * 7 % 32) as u16).collect();
        let (nll, n) = sequence_nll(&m, &toks, &mut st);
        let per_tok = nll / n as f64;
        let uniform = (m.config.vocab as f64).ln();
        assert!((per_tok - uniform).abs() < 1.0, "per-token nll {per_tok} vs uniform {uniform}");
    }

    #[test]
    fn continuation_logprob_negative_and_finite() {
        let m = small_model(9);
        let mut st = ForwardState::new(m.config);
        let lp = continuation_logprob(&m, &[1, 2, 3], &[4, 5], &mut st);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn rmsnorm_unit_variance() {
        let d = 8;
        let x: Vec<f32> = (0..d).map(|i| (i as f32) - 3.0).collect();
        let w = vec![1.0f32; d];
        let mut out = vec![0.0f32; d];
        rmsnorm(&x, &w, 1e-6, 1, d, &mut out);
        let ms: f32 = out.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        assert!((ms - 1.0).abs() < 1e-3);
    }
}
