//! Synthetic zero-shot multiple-choice tasks — stand-ins for the paper's
//! PiQA / ARC-e / ARC-c / BoolQ / HellaSwag / Winogrande suite.
//!
//! Each item gives a prefix drawn from the synthetic language, the true
//! corpus continuation, and distractor continuations produced by
//! corrupting the true one with language-inconsistent token swaps. Scoring
//! follows lm-eval-harness `acc_norm`: length-normalized continuation
//! log-likelihood, argmax over choices. Task families differ in choice
//! count, continuation length, and corruption strength, which controls
//! their difficulty spread (ARC-c is hardest: minimal corruption).

use crate::data::corpus::{CorpusKind, Language, VOCAB};
use crate::util::rng::Rng;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prefix: Vec<u16>,
    pub choices: Vec<Vec<u16>>,
    pub answer: usize,
}

/// A task family definition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_choices: usize,
    pub prefix_len: usize,
    pub cont_len: usize,
    /// Fraction of continuation tokens corrupted in distractors.
    pub corruption: f64,
    pub seed: u64,
}

/// The six analog tasks (difficulty ordered roughly like the paper's
/// accuracy spread: heavy corruption = easy to reject distractors).
pub const TASKS: [TaskSpec; 6] = [
    TaskSpec { name: "PIQA*", n_choices: 2, prefix_len: 24, cont_len: 12, corruption: 0.45, seed: 0xA1 },
    TaskSpec { name: "Arc-e*", n_choices: 4, prefix_len: 20, cont_len: 8, corruption: 0.6, seed: 0xA2 },
    TaskSpec { name: "Arc-c*", n_choices: 4, prefix_len: 20, cont_len: 8, corruption: 0.2, seed: 0xA3 },
    TaskSpec { name: "BoolQ*", n_choices: 2, prefix_len: 28, cont_len: 6, corruption: 0.4, seed: 0xA4 },
    TaskSpec { name: "HellaSwag*", n_choices: 4, prefix_len: 32, cont_len: 16, corruption: 0.3, seed: 0xA5 },
    TaskSpec { name: "Winogrande*", n_choices: 2, prefix_len: 24, cont_len: 10, corruption: 0.15, seed: 0xA6 },
];

/// Generate `n` items for a task family over the given language.
pub fn generate_task(spec: &TaskSpec, kind: CorpusKind, n: usize) -> Vec<TaskItem> {
    let lang = Language::new(kind);
    let mut rng = Rng::with_stream(spec.seed, kind as u64 + 1);
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        // Roll out a fresh prefix + true continuation from the language.
        let table = rng.below_usize(lang.n_tables());
        let total = spec.prefix_len + spec.cont_len;
        let mut seq: Vec<u16> = Vec::with_capacity(total);
        let (mut a, mut b) = (
            rng.below(VOCAB as u64) as u16,
            rng.below(VOCAB as u64) as u16,
        );
        seq.push(a);
        seq.push(b);
        while seq.len() < total {
            let next = lang.sample_next(a, b, table, &mut rng);
            seq.push(next);
            a = b;
            b = next;
        }
        let prefix = seq[..spec.prefix_len].to_vec();
        let true_cont = seq[spec.prefix_len..].to_vec();

        // Distractors are *language-consistent but systematically less
        // likely* rollouts: every transition stays a valid candidate (so a
        // model cannot reject them on validity alone — the discrimination
        // the real benchmarks demand), but with probability `corruption`
        // each step samples the LEAST likely candidate instead of the
        // language distribution. The likelihood margin, and hence task
        // difficulty, scales with `corruption` × `cont_len`.
        let mut choices: Vec<Vec<u16>> = Vec::with_capacity(spec.n_choices);
        let answer = rng.below_usize(spec.n_choices);
        for c in 0..spec.n_choices {
            if c == choices.len() && c == answer {
                choices.push(true_cont.clone());
                continue;
            }
            let mut d: Vec<u16> = Vec::with_capacity(spec.cont_len);
            let (mut ca, mut cb) = (prefix[prefix.len() - 2], prefix[prefix.len() - 1]);
            let mut last_ctx = (ca, cb);
            for _ in 0..spec.cont_len {
                last_ctx = (ca, cb);
                let next = if rng.next_f64() < spec.corruption {
                    // adversarial step: the rarest candidate continuation
                    let cands = lang.candidates(ca, cb, table);
                    *cands.last().unwrap()
                } else {
                    lang.sample_next(ca, cb, table, &mut rng)
                };
                d.push(next);
                ca = cb;
                cb = next;
            }
            if d == true_cont {
                // astronomically unlikely; force the final step rare
                let cands = lang.candidates(last_ctx.0, last_ctx.1, table);
                let tail = d.last_mut().unwrap();
                *tail = *cands.last().unwrap();
                if d == true_cont {
                    // true continuation already ends on the rarest
                    // candidate; use the second rarest instead
                    *d.last_mut().unwrap() = cands[cands.len().saturating_sub(2)];
                }
            }
            choices.push(d);
        }
        items.push(TaskItem { prefix, choices, answer });
    }
    items
}

fn context_at(prefix: &[u16], cont: &[u16], p: usize) -> (u16, u16) {
    let get = |i: isize| -> u16 {
        if i < 0 {
            let idx = prefix.len() as isize + i;
            prefix[idx.max(0) as usize]
        } else {
            cont[i as usize]
        }
    };
    (get(p as isize - 2), get(p as isize - 1))
}

/// Oracle accuracy check: score items with the true language probabilities
/// (the best any model could do); used by tests to verify that the answer
/// is recoverable in principle.
pub fn oracle_accuracy(items: &[TaskItem], kind: CorpusKind) -> f64 {
    let lang = Language::new(kind);
    let mut correct = 0usize;
    for item in items {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, cont) in item.choices.iter().enumerate() {
            let mut lp = 0.0f64;
            for p in 0..cont.len() {
                let (a, b) = context_at(&item.prefix, cont, p);
                // max over mixture tables (generator table is hidden)
                let prob = (0..lang.n_tables())
                    .map(|t| lang.next_prob(a, b, t, cont[p]))
                    .fold(0.0f64, f64::max);
                lp += (prob.max(1e-12)).ln();
            }
            lp /= cont.len() as f64;
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        if best.1 == item.answer {
            correct += 1;
        }
    }
    correct as f64 / items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_answers() {
        for spec in &TASKS {
            let items = generate_task(spec, CorpusKind::SynthWiki, 20);
            assert_eq!(items.len(), 20);
            for item in &items {
                assert_eq!(item.prefix.len(), spec.prefix_len);
                assert_eq!(item.choices.len(), spec.n_choices);
                assert!(item.answer < spec.n_choices);
                for ch in &item.choices {
                    assert_eq!(ch.len(), spec.cont_len);
                }
                // distractors differ from the true continuation
                for (ci, ch) in item.choices.iter().enumerate() {
                    if ci != item.answer {
                        assert_ne!(ch, &item.choices[item.answer]);
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = generate_task(&TASKS[0], CorpusKind::SynthWiki, 5);
        let b = generate_task(&TASKS[0], CorpusKind::SynthWiki, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prefix, y.prefix);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn oracle_solves_tasks_above_chance() {
        // An oracle with the true language must beat chance by a wide
        // margin (not 100%: distractors are language-consistent rollouts,
        // so occasional items are genuinely ambiguous — like the noise
        // floor of real benchmarks).
        for spec in &TASKS {
            let items = generate_task(spec, CorpusKind::SynthWiki, 60);
            let acc = oracle_accuracy(&items, CorpusKind::SynthWiki);
            let chance = 1.0 / spec.n_choices as f64;
            // Arc-c* is deliberately near the discrimination floor
            // ("challenge"); everything must still clear chance + 15pts.
            assert!(
                acc > chance + 0.15,
                "{}: oracle acc {acc} vs chance {chance}",
                spec.name
            );
        }
    }

    #[test]
    fn distractors_are_language_consistent() {
        // Every distractor transition must have nonzero probability — the
        // model can never reject on validity alone.
        let lang = crate::data::corpus::Language::new(CorpusKind::SynthWiki);
        let items = generate_task(&TASKS[2], CorpusKind::SynthWiki, 20);
        for item in &items {
            for cont in &item.choices {
                for p in 0..cont.len() {
                    let (a, b) = super::context_at(&item.prefix, cont, p);
                    assert!(
                        lang.next_prob(a, b, 0, cont[p]) > 0.0,
                        "invalid transition planted in distractor"
                    );
                }
            }
        }
    }

    #[test]
    fn answers_balanced() {
        let items = generate_task(&TASKS[1], CorpusKind::SynthC4, 200);
        let mut counts = vec![0usize; 4];
        for i in &items {
            counts[i.answer] += 1;
        }
        for &c in &counts {
            assert!(c > 20, "answer distribution skewed: {counts:?}");
        }
    }
}
