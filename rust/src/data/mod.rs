//! Synthetic data substrate standing in for the paper's gated datasets
//! (C4, WikiText2, and the lm-eval zero-shot suites) — see DESIGN.md §1
//! for the substitution rationale.

pub mod calibration;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;
