//! Minimal vocabulary / detokenizer. The synthetic language is defined
//! directly over token ids; this module gives ids stable human-readable
//! surface forms for demos and debugging output (examples print generated
//! "text"), plus a round-trip encode for tests.

use crate::data::corpus::VOCAB;
use std::collections::HashMap;

const ONSETS: [&str; 16] = [
    "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "ch", "sh",
];
const NUCLEI: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ou", "ea"];
const CODAS: [&str; 2] = ["", "n"];

/// Deterministic id ↔ pseudo-word vocabulary.
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, u16>,
}

impl Default for Vocab {
    fn default() -> Self {
        Self::new()
    }
}

impl Vocab {
    pub fn new() -> Self {
        let mut words = Vec::with_capacity(VOCAB);
        for id in 0..VOCAB {
            let o = ONSETS[id % 16];
            let n = NUCLEI[(id / 16) % 8];
            let c = CODAS[(id / 128) % 2];
            words.push(format!("{o}{n}{c}"));
        }
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u16))
            .collect();
        Self { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn word(&self, id: u16) -> &str {
        &self.words[id as usize]
    }

    pub fn id(&self, word: &str) -> Option<u16> {
        self.index.get(word).copied()
    }

    /// Render a token sequence as space-separated pseudo-words.
    pub fn decode(&self, tokens: &[u16]) -> String {
        tokens
            .iter()
            .map(|&t| self.word(t))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Parse space-separated pseudo-words back to ids.
    pub fn encode(&self, text: &str) -> Option<Vec<u16>> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_vocab_uniquely() {
        let v = Vocab::new();
        assert_eq!(v.len(), VOCAB);
        let mut set = std::collections::HashSet::new();
        for id in 0..VOCAB as u16 {
            assert!(set.insert(v.word(id).to_string()), "dup word {}", v.word(id));
        }
    }

    #[test]
    fn encode_decode_round_trip() {
        let v = Vocab::new();
        let toks = vec![0u16, 17, 255, 128, 42];
        let text = v.decode(&toks);
        assert_eq!(v.encode(&text).unwrap(), toks);
    }

    #[test]
    fn unknown_word_rejected() {
        let v = Vocab::new();
        assert!(v.encode("notaword").is_none());
    }
}
